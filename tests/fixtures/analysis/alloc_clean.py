"""Known-good / suppressed allocator corpus: zero findings expected."""


class DisciplinedBackend:
    def __init__(self, kv):
        self.kv = kv

    def grab(self, n):
        blocks = self.kv.allocator.alloc(n)    # result kept
        return blocks

    def release(self, slots):
        for s in slots:
            self.kv.release(s)                 # balanced

    def grow(self, slot, tok):
        self.kv.append_demand(slot)            # demand declared
        self.kv.append_tokens(slot, tok)

    def poke(self, slot, n):
        self.kv.lengths[slot] = n  # ra: ignore[RA204] — fixture suppression

    def admit_shared(self, shared, n):
        pinned = []
        try:
            for b in shared:
                self.kv.allocator.add_ref(b)
                pinned.append(b)
            fresh = self.kv.allocator.alloc(n)
        except MemoryError:
            self.kv.allocator.free(pinned)     # rollback: clean
            raise
        return shared + fresh


class OwnerModuleMarkerless:
    """A class with no pool contact at all — never checked."""

    def release(self):
        pass
