"""Known-bad jit-hazard corpus (RA101/RA102/RA103/RA104).

Never imported — parsed only by repro.analysis tests.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def host_sync_float(x):
    return float(x) * 2.0                      # RA101


@jax.jit
def host_sync_np(x):
    return np.asarray(x).sum()                 # RA101


@jax.jit
def host_sync_item(x):
    return x.item()                            # RA101


@jax.jit
def data_dep_branch(x):
    if x > 0:                                  # RA102
        return x
    return -x


@functools.partial(jax.jit, static_argnames=("opts",))
def bad_static_default(x, opts=[1, 2]):        # RA103
    return x * len(opts)


@jax.jit
def outer(x):
    return _helper(x)


def _helper(x):
    return int(x)                              # RA101 (jit-reachable)


def hot_account(batch):
    # registered host_hot path in the fixture registry
    total = jnp.sum(batch)                     # RA104
    return total
