"""Known-bad ref/vec parity corpus (RA401/RA402).

The test declares (go_ref, go_vec) as a module-level pair with no
allowances.
"""


def go_ref(self, cfg, batch):
    rate = cfg.ref_only_knob                   # RA401: cfg one-sided
    out = self._account(batch, rate=rate)
    return out["tokens"]


def go_vec(self, cfg, batch):
    mask = self._vec_only_mask                 # RA402: attr one-sided
    out = self._account(batch, rate=1.0, extra=mask)  # RA402: kw extra
    return out["tokens"] + out["vec_only_key"]        # RA402: key
