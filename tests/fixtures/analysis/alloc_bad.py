"""Known-bad allocator-discipline corpus (RA201..RA205).

This module does NOT define BlockAllocator/PagedKVCache, so it is
"outside the owning module" for RA204 purposes.
"""


class LeakyBackend:
    def __init__(self, kv):
        self.kv = kv

    def grab(self, n):
        self.kv.allocator.alloc(n)             # RA201: result discarded

    def release(self, slots):
        for _ in slots:                        # RA202: no release call
            pass

    def grow(self, slot, tok):
        self.kv.append_tokens(slot, tok)       # RA203: no demand decl

    def poke(self, slot, n):
        self.kv.lengths[slot] = n              # RA204: raw pool write

    def admit_shared(self, shared, n):
        for b in shared:
            self.kv.allocator.add_ref(b)
        return shared + self.kv.allocator.alloc(n)   # RA205: no cleanup
