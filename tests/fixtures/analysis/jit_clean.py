"""Known-good / suppressed jit corpus: everything here must yield zero
findings (suppressions included)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def shape_branches_ok(x, *, blk=128, interpret=False):
    n = x.shape[0]
    pad = (-n) % blk                           # shape-derived: static
    if pad:                                    # static branch — clean
        x = jnp.pad(x, ((0, pad), (0, 0)))
    if interpret:                              # static arg — clean
        x = x + 0
    return x


@jax.jit
def guards_ok(x, y=None):
    if y is None:                              # identity check — clean
        y = jnp.zeros_like(x)
    if isinstance(x, tuple):                   # isinstance — clean
        x = x[0]
    return x + y


@jax.jit
def suppressed_sync(x):
    return float(x)  # ra: ignore[RA101] — fixture: intentional sync


def plain_host_fn(x):
    # not jit-reachable: host syncs are fine here
    return float(np.asarray(x).sum())
