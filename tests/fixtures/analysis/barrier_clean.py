"""Known-good / suppressed barrier-scope corpus: zero findings."""


class Engine:
    def __init__(self):
        self.t_now = 0.0
        self.steps = 0

    def step(self):
        self.steps += 1
        self._advance(0.1)

    def _advance(self, dt):
        self.t_now += dt                       # ok: step-rooted

    def force_clock(self, t):
        self.t_now = t  # ra: ignore[RA301] — fixture: test-only override


class Fleet:
    def __init__(self, engines):
        self.engines = engines

    def _step_vec(self):
        self._dispatch()
        self._refresh(0)                       # caller refreshes: clean

    def _dispatch(self):
        for r in range(len(self.engines)):
            eng = self.engines[r]
            eng.step()

    def _refresh(self, r):
        pass
