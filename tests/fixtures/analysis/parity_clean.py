"""Known-good ref/vec parity corpus: symmetric surface plus declared
allowances (the test's pair allows ``attr:_snap_*`` on the vec side).
"""


def go_ref(self, cfg, batch):
    rate = cfg.shared_knob
    out = self._account(batch, rate=rate)
    return out["tokens"]


def go_vec(self, cfg, batch):
    rate = cfg.shared_knob
    cached = self._snap_loads                  # allowed: attr:_snap_*
    out = self._account(batch + cached, rate=rate)
    return out["tokens"]
