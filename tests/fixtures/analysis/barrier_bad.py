"""Known-bad barrier-scope corpus (RA301/RA302).

The test registers ``Engine`` as a state scope (attrs t_now/steps,
roots __init__/step) and ``Fleet`` as a vec snapshot scope
(vec_roots {_step_vec}).
"""


class Engine:
    def __init__(self):
        self.t_now = 0.0
        self.steps = 0

    def step(self):
        self.steps += 1
        self._advance(0.1)

    def _advance(self, dt):
        self.t_now += dt                       # ok: step-rooted

    def poke_clock(self, t):
        self.t_now = t                         # RA301: outside barrier


class Fleet:
    def __init__(self, engines):
        self.engines = engines

    def _step_vec(self):
        for r in range(len(self.engines)):
            eng = self.engines[r]
            eng.step()                         # RA302: no _refresh after

    def _refresh(self, r):
        pass
