"""Tier-1 gate for ``repro.analysis`` (see ISSUE 7).

Three layers:

* fixture-corpus tests — each RA code family proves at least one true
  positive and one clean/suppressed case on known snippets;
* the real-tree gate — the CLI over ``src/`` must be clean against the
  committed baseline (this is what makes new contract violations fail
  tier-1);
* mutation tests — deleting the ``kv.release`` call in
  ``serving/cache_backend.py`` or adding a vec-only stat to
  ``fleet/server.py`` must trip the gate (acceptance criteria), which
  pins the passes to the real tree, not just the fixtures.

Plus regression tests for the findings fixed in this PR (RA204/RA205)
and the satellite telemetry/CLI-parsing coverage.
"""
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import Baseline, run_analysis
from repro.analysis.findings import Finding, Suppressions, apply_baseline
from repro.analysis.registry import (RefVecPair, Registry, StateScope,
                                     VecSnapshotScope)

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
BASELINE = REPO / "tools" / "analysis_baseline.json"

FIXTURE_REGISTRY = Registry(
    state_scopes=tuple(
        StateScope(file_suffix=f, cls="Engine",
                   attrs=frozenset({"t_now", "steps"}),
                   roots=frozenset({"__init__", "step"}))
        for f in ("barrier_bad.py", "barrier_clean.py")),
    vec_scopes=tuple(
        VecSnapshotScope(file_suffix=f, cls="Fleet",
                         vec_roots=frozenset({"_step_vec"}))
        for f in ("barrier_bad.py", "barrier_clean.py")),
    pairs=(
        RefVecPair(file_suffix="parity_bad.py", cls=None,
                   ref="go_ref", vec="go_vec"),
        RefVecPair(file_suffix="parity_clean.py", cls=None,
                   ref="go_ref", vec="go_vec",
                   allow_vec=frozenset({"attr:_snap_*"})),
    ),
    host_hot=(("jit_bad.py", "hot_account"),),
)


def fixture_codes(name):
    res = run_analysis([FIXTURES / name], registry=FIXTURE_REGISTRY)
    return [f.code for f in res.findings]


def cli(args, cwd=None):
    env = dict(os.environ, PYTHONPATH=str(SRC))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *map(str, args)],
        capture_output=True, text=True, env=env, cwd=cwd or REPO)


# ---------------------------------------------------------------- fixtures

class TestJitHazardFixtures:
    def test_true_positives(self):
        codes = fixture_codes("jit_bad.py")
        assert codes.count("RA101") == 4       # float/np/item + helper
        assert "RA102" in codes
        assert "RA103" in codes
        assert "RA104" in codes

    def test_clean_and_suppressed(self):
        assert fixture_codes("jit_clean.py") == []


class TestAllocatorFixtures:
    def test_true_positives(self):
        codes = fixture_codes("alloc_bad.py")
        for code in ("RA201", "RA202", "RA203", "RA204", "RA205"):
            assert code in codes, code

    def test_clean_and_suppressed(self):
        assert fixture_codes("alloc_clean.py") == []


class TestBarrierFixtures:
    def test_true_positives(self):
        codes = fixture_codes("barrier_bad.py")
        assert "RA301" in codes
        assert "RA302" in codes

    def test_clean_and_suppressed(self):
        assert fixture_codes("barrier_clean.py") == []


class TestParityFixtures:
    def test_true_positives(self):
        codes = fixture_codes("parity_bad.py")
        assert codes.count("RA401") == 1       # cfg:ref_only_knob
        assert codes.count("RA402") >= 3       # attr + kw + key

    def test_clean_with_allowance(self):
        assert fixture_codes("parity_clean.py") == []


# ------------------------------------------------- suppressions / baseline

def test_suppression_parsing():
    sup = Suppressions([
        "x = 1",
        "y = kv.lengths[0]  # ra: ignore[RA204]",
        "z = 2  # ra: ignore",
        "w = 3  # ra: ignore[RA101, RA102]",
    ])
    assert not sup.suppressed(1, "RA204")
    assert sup.suppressed(2, "RA204")
    assert not sup.suppressed(2, "RA201")
    assert sup.suppressed(3, "RA999")          # blanket
    assert sup.suppressed(4, "RA102")


def test_baseline_roundtrip_and_budget(tmp_path):
    f1 = Finding("a.py", 10, "RA204", "C.m", "msg")
    f2 = Finding("a.py", 20, "RA204", "C.m", "msg2")
    f3 = Finding("b.py", 5, "RA101", "f", "msg3")
    base = Baseline.from_findings([f1, f2, f3])
    p = tmp_path / "base.json"
    base.save(p)
    loaded = Baseline.load(p)
    assert loaded.entries == base.entries

    # same counts -> clean, with line drift
    drifted = [Finding("a.py", 99, "RA204", "C.m", "x"),
               Finding("a.py", 1, "RA204", "C.m", "y"), f3]
    new, stale = apply_baseline(drifted, loaded)
    assert new == [] and stale == []

    # one extra finding in a baselined symbol still fails
    new, _ = apply_baseline(drifted + [
        Finding("a.py", 50, "RA204", "C.m", "z")], loaded)
    assert len(new) == 1

    # fixed finding -> stale entry reported, never failing
    new, stale = apply_baseline([f3], loaded)
    assert new == [] and stale == [("RA204", "a.py", "C.m")]


def test_baseline_rejects_unknown_version(tmp_path):
    p = tmp_path / "base.json"
    p.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError, match="version"):
        Baseline.load(p)


# ------------------------------------------------------------ CLI behavior

def test_cli_reports_findings_with_exit_1():
    r = cli([FIXTURES / "alloc_bad.py"])
    assert r.returncode == 1
    assert "RA204" in r.stdout

def test_cli_select_filters_codes():
    r = cli([FIXTURES / "alloc_bad.py", "--select", "RA201"])
    assert r.returncode == 1
    assert "RA201" in r.stdout and "RA204" not in r.stdout

def test_cli_rejects_unknown_select_code():
    r = cli([FIXTURES / "alloc_bad.py", "--select", "RA999"])
    assert r.returncode == 2
    assert "unknown code" in r.stderr

def test_cli_missing_baseline_is_usage_error(tmp_path):
    r = cli([FIXTURES / "alloc_clean.py", "--baseline",
             tmp_path / "nope.json"])
    assert r.returncode == 2


# ------------------------------------------------------- the tier-1 gate

def test_src_tree_clean_against_committed_baseline():
    r = cli([SRC, "--baseline", BASELINE])
    assert r.returncode == 0, f"\n{r.stdout}\n{r.stderr}"


def _mutated_src(tmp_path, relpath, old, new):
    dst = tmp_path / "src"
    shutil.copytree(SRC, dst)
    p = dst / relpath
    text = p.read_text()
    assert old in text, f"mutation anchor missing from {relpath}"
    p.write_text(text.replace(old, new))
    return dst


def test_deleting_kv_release_fails_gate(tmp_path):
    dst = _mutated_src(
        tmp_path, "repro/serving/cache_backend.py",
        "self.kv.release(int(s))", "pass")
    r = cli([dst, "--baseline", BASELINE])
    assert r.returncode == 1
    assert "RA202" in r.stdout
    assert "cache_backend.py" in r.stdout


def test_deleting_drain_release_fails_gate(tmp_path):
    # the drain path's one-resident eviction must pop the requeued
    # request off the wait queue; turning the pop into a peek is a
    # leaked release the RA202 pass must flag
    dst = _mutated_src(
        tmp_path, "repro/serving/engine.py",
        "return self.scheduler.wait.pop(0)",
        "return self.scheduler.wait[0]")
    r = cli([dst, "--baseline", BASELINE])
    assert r.returncode == 1
    assert "RA202" in r.stdout
    assert "engine.py" in r.stdout


def test_undeclared_obs_write_fails_gate(tmp_path):
    # the `_obs_*` family is declared step-scoped barrier state: a
    # write from a reporting method (not reachable from the declared
    # roots) must fail RA301, so observability reads can never mutate
    # the ledger they report
    dst = _mutated_src(
        tmp_path, "repro/fleet/server.py",
        "        return self._obs_ledger.report()",
        "        self._obs_ledger = StragglerLedger()\n"
        "        return self._obs_ledger.report()")
    r = cli([dst, "--baseline", BASELINE])
    assert r.returncode == 1
    assert "RA301" in r.stdout
    assert "_obs_ledger" in r.stdout


def test_vec_only_stat_fails_gate(tmp_path):
    dst = _mutated_src(
        tmp_path, "repro/fleet/server.py",
        "tokens0 = int(self._snap_tokens.sum())",
        "tokens0 = int(self._snap_tokens.sum()) + "
        "int(self._vec_only_stat)")
    r = cli([dst, "--baseline", BASELINE])
    assert r.returncode == 1
    assert "RA402" in r.stdout
    assert "_vec_only_stat" in r.stdout


# --------------------------------------- regressions for this PR's fixes

class TestFixedFindings:
    def _kv(self, n_blocks=4):
        from repro.serving.paged_cache import PagedKVCache
        return PagedKVCache.create(
            n_layers=1, n_blocks=n_blocks, block_size=4, n_kv_heads=1,
            head_dim=4, max_requests=2, max_blocks_per_req=8)

    def test_ra205_failed_admit_rolls_back_refs(self):
        # RA205: admit() pins shared blocks, then allocates the rest;
        # an alloc failure must release the pins (fixed in this PR)
        kv = self._kv(n_blocks=3)
        [b] = kv.allocator.alloc(1)            # stands in for a cached block
        before = kv.allocator.ref_count(b)
        free_before = kv.allocator.n_free
        with pytest.raises(MemoryError):
            kv.admit(1, prompt_len=4 * 4, shared=(b,))  # needs 3 fresh, 2 free
        assert kv.allocator.ref_count(b) == before
        assert kv.allocator.n_free == free_before

    def test_ra204_set_length_and_adopt_blocks(self):
        # RA204: backends rebind slots via the pool API now, not raw
        # writes to kv internals — pin the API behavior
        kv = self._kv()
        blocks = kv.allocator.alloc(2)
        kv.adopt_blocks(0, blocks, 7)
        assert kv.lengths[0] == 7
        assert list(kv.block_tables[0, :2]) == list(blocks)
        assert kv.block_tables[0, 2] == -1
        assert kv.req_blocks[0] == list(blocks)
        kv.set_length(0, 9)
        assert kv.lengths[0] == 9

    def test_ra204_prefix_note_lookup(self):
        from repro.serving.paged_cache import PrefixIndex
        idx = PrefixIndex()
        idx.note_lookup(4, 2)
        idx.note_lookup(1, 0)
        assert (idx.queries, idx.hits) == (5, 2)


# --------------------------------------------- satellite: telemetry schema

class TestTelemetrySchema:
    def _tel(self):
        from repro.fleet.telemetry import FleetTelemetry
        tel = FleetTelemetry()
        tel.record_request(rid=0, replica=0, status="done",
                           t_arrival=0.0, t_routed=0.0, ttft=0.1,
                           tpot=0.05, latency=0.5, n_prompt=4,
                           n_generated=8)
        return tel

    def test_roundtrip_carries_schema_version(self, tmp_path):
        from repro.fleet.telemetry import SCHEMA_VERSION, FleetTelemetry
        p = tmp_path / "run.jsonl"
        self._tel().write_jsonl(p)
        meta = json.loads(p.read_text().splitlines()[0])
        assert meta["schema_version"] == SCHEMA_VERSION
        tel2 = FleetTelemetry.read_jsonl(p)
        assert len(tel2.requests) == 1

    def test_reader_rejects_unknown_version(self, tmp_path):
        from repro.fleet.telemetry import FleetTelemetry
        p = tmp_path / "run.jsonl"
        self._tel().write_jsonl(p)
        lines = p.read_text().splitlines()
        meta = json.loads(lines[0])
        meta["schema_version"] = 99
        p.write_text("\n".join([json.dumps(meta)] + lines[1:]) + "\n")
        with pytest.raises(ValueError, match="schema_version"):
            FleetTelemetry.read_jsonl(p)

    def test_reader_rejects_missing_version(self, tmp_path):
        # a pre-versioning export must fail up front, not with a
        # KeyError deep in summary validation
        from repro.fleet.telemetry import FleetTelemetry
        p = tmp_path / "run.jsonl"
        self._tel().write_jsonl(p)
        lines = p.read_text().splitlines()
        meta = json.loads(lines[0])
        del meta["schema_version"]
        p.write_text("\n".join([json.dumps(meta)] + lines[1:]) + "\n")
        with pytest.raises(ValueError, match="schema_version"):
            FleetTelemetry.read_jsonl(p)


# ------------------------------------------- satellite: CLI parse coverage

class TestReplicaClassParsing:
    def _parse(self, spec):
        from repro.launch.serve import parse_replica_classes
        from repro.serving import EngineConfig
        return parse_replica_classes(spec, EngineConfig())

    def test_valid_spec(self):
        classes = self._parse("2xg1b2,1xg2b4")
        assert [(c, ec.n_workers, ec.slots_per_worker)
                for c, ec in classes] == [(2, 1, 2), (1, 2, 4)]

    @pytest.mark.parametrize("spec", [
        "2xg1b", "xg1b2", "2x1b2", "2xg1b2x", "g1b2", "2,2xg1b2", ""])
    def test_rejects_malformed(self, spec):
        with pytest.raises(ValueError,
                           match=r"bad replica class .* \(want e\.g\. "
                                 r"'2xg1b2'\)"):
            self._parse(spec)


class TestBenchSectionsValidation:
    def test_unknown_section_rejected(self):
        from benchmarks.balancer_bench import run
        with pytest.raises(ValueError, match="unknown bench sections"):
            run(smoke=True, sections={"bogus"})

    def test_unknown_section_names_known_ones(self):
        from benchmarks.balancer_bench import ALL_SECTIONS, run
        with pytest.raises(ValueError, match="solver"):
            run(smoke=True, sections={"nope"})
        assert "fleet" in ALL_SECTIONS


# --------------------------------------------------- satellite: ruff gate

def test_ruff_curated_rules_clean():
    ruff = shutil.which("ruff")
    if ruff is None:
        pytest.skip("ruff not installed in this environment")
    r = subprocess.run([ruff, "check", str(SRC), str(REPO / "benchmarks")],
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stdout
