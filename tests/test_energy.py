"""Tests of the power/energy theory (Section 5.2, Theorem 4, Corollary 1)."""
import numpy as np
import pytest

from repro.core import (
    A100_POWER,
    TPU_V5E_POWER,
    PowerModel,
    asymptotic_saving,
    energy_decomposition,
    energy_sandwich,
    saving_bound,
)


class TestPowerModel:
    def test_idle_and_peak(self):
        pm = A100_POWER
        assert pm.power(0.0) == pytest.approx(100.0)
        assert pm.power(1.0) == pytest.approx(400.0)

    def test_sublinear(self):
        """gamma<1: power at u=0.5 exceeds the linear interpolation."""
        pm = A100_POWER
        lin = 100.0 + 300.0 * 0.5
        assert pm.power(0.5) > lin

    def test_monotone(self):
        pm = A100_POWER
        u = np.linspace(0, 1, 64)
        p = pm.power(u)
        assert np.all(np.diff(p) >= -1e-12)

    def test_constants(self):
        pm = A100_POWER
        assert pm.c_gamma == pytest.approx(0.3 * 400 + 0.7 * 100)
        assert pm.d_gamma == pytest.approx(0.3 * 300)


class TestDecompositionIdentity:
    def test_exact_identity_c47(self):
        """E == kappa*(P_max W + P_idle ImbTot + (P_max-P_idle) X)."""
        rng = np.random.default_rng(0)
        pm = A100_POWER
        loads = [rng.uniform(1, 10, size=8) for _ in range(50)]
        d = energy_decomposition(loads, kappa_att=1e-7, pm=pm)
        assert d["energy"] == pytest.approx(d["identity_rhs"], rel=1e-10)

    def test_sandwich_c49(self):
        rng = np.random.default_rng(1)
        pm = A100_POWER
        for _ in range(20):
            loads = [rng.uniform(0.5, 20, size=16) for _ in range(30)]
            d = energy_decomposition(loads, kappa_att=1e-7, pm=pm)
            lo, hi = energy_sandwich(d["W"], d["ImbTot"], 1e-7, pm)
            assert lo - 1e-9 <= d["energy"] <= hi + 1e-9

    def test_x_bounds(self):
        """0 <= X <= (1-gamma) * ImbTot (concavity tangent bound)."""
        rng = np.random.default_rng(2)
        pm = A100_POWER
        loads = [rng.uniform(0.1, 5, size=12) for _ in range(40)]
        d = energy_decomposition(loads, kappa_att=1.0, pm=pm)
        assert -1e-9 <= d["X"] <= (1 - pm.gamma) * d["ImbTot"] + 1e-9

    def test_balanced_loads_zero_imbalance(self):
        pm = A100_POWER
        loads = [np.full(8, 7.0) for _ in range(10)]
        d = energy_decomposition(loads, kappa_att=1.0, pm=pm)
        assert d["ImbTot"] == pytest.approx(0.0)
        assert d["X"] == pytest.approx(0.0)
        # all-ones utilization => P_max everywhere
        assert d["energy"] == pytest.approx(1.0 * 7.0 * 8 * 400.0 * 10)


class TestSavingBounds:
    def test_corollary1_a100(self):
        """100 / (0.3*400 + 0.7*100) = 100/190 ~ 52.6 % (Remark 2)."""
        assert asymptotic_saving(A100_POWER) == pytest.approx(100.0 / 190.0)

    def test_corollary1_tpu_preset(self):
        s = asymptotic_saving(TPU_V5E_POWER)
        assert 0.0 < s < 1.0

    def test_saving_bound_monotone_alpha(self):
        pm = A100_POWER
        vals = [saving_bound(a, 0.4, pm) for a in [1.5, 3.0, 10.0, 100.0]]
        assert all(np.diff(vals) > 0)

    def test_saving_bound_alpha_one_is_zero(self):
        assert saving_bound(1.0, 0.4, A100_POWER) == 0.0

    def test_saving_bound_approaches_corollary(self):
        """alpha -> inf and eta -> inf recovers Cor 1's limit."""
        pm = A100_POWER
        s = saving_bound(1e9, 1e9, pm)
        assert s == pytest.approx(asymptotic_saving(pm), rel=1e-3)
