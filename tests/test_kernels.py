"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracles in repro.kernels.ref (assert_allclose)."""
import warnings

warnings.filterwarnings("ignore")

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.rms_norm import rms_norm_pallas
from repro.kernels.ssm_scan import ssm_chunk_scan_pallas

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,hd,L,blk", [
        (1, 2, 1, 32, 64, 32),       # MQA
        (2, 4, 2, 64, 128, 64),      # GQA 2:1
        (2, 8, 8, 64, 200, 128),     # MHA, ragged block tail
        (1, 16, 2, 128, 1024, 512),  # big GQA, qwen-like head_dim
        (3, 6, 6, 64, 96, 96),       # whisper-like
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, Hq, Hkv, hd, L, blk, dtype):
        q = jnp.asarray(RNG.normal(size=(B, Hq, hd)), dtype)
        k = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)), dtype)
        v = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)), dtype)
        lens = jnp.asarray(RNG.integers(1, L + 1, B), jnp.int32)
        out = decode_attention_pallas(q, k, v, lens, blk_l=blk,
                                      interpret=True)
        want = ref.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_length_one(self):
        """Degenerate cache: only the new token itself is attended."""
        B, Hq, hd, L = 2, 4, 32, 64
        q = jnp.asarray(RNG.normal(size=(B, Hq, hd)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, L, Hq, hd)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, L, Hq, hd)), jnp.float32)
        lens = jnp.ones((B,), jnp.int32)
        out = decode_attention_pallas(q, k, v, lens, blk_l=32)
        # softmax over a single position == that position's value
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(v[:, 0]), atol=1e-5)

    def test_matches_model_oracle(self):
        """kernels.ref == models.attention.decode_attention (two oracles)."""
        from repro.models.attention import decode_attention as model_da
        B, Hq, Hkv, hd, L = 2, 8, 4, 64, 128
        q = jnp.asarray(RNG.normal(size=(B, Hq, hd)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)), jnp.float32)
        lens = jnp.asarray([60, 128], jnp.int32)
        np.testing.assert_allclose(
            np.asarray(ref.decode_attention_ref(q, k, v, lens)),
            np.asarray(model_da(q, k, v, lens)), atol=1e-5)


class TestSSMScan:
    @pytest.mark.parametrize("B,S,H,dk,dv,chunk", [
        (1, 32, 1, 8, 8, 16),
        (2, 64, 3, 16, 8, 16),
        (2, 128, 2, 64, 64, 128),    # mamba2-like (N=64, headdim=64)
        (1, 48, 4, 32, 33, 16),      # mLSTM-like with +1 normalizer col
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, S, H, dk, dv, chunk, dtype):
        q = jnp.asarray(RNG.normal(size=(B, S, H, dk)), dtype)
        k = jnp.asarray(RNG.normal(size=(B, S, H, dk)), dtype)
        v = jnp.asarray(RNG.normal(size=(B, S, H, dv)), dtype)
        a = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
        g = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
        y, s = ssm_chunk_scan_pallas(q, k, v, a, g, chunk=chunk,
                                     interpret=True)
        y0, s0 = ref.ssm_chunk_scan_ref(q, k, v, a, g)
        tol = dict(atol=1e-1, rtol=1e-1) if dtype == jnp.bfloat16 \
            else dict(atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y0, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s0),
                                   atol=1e-3, rtol=1e-3)

    def test_ragged_pad_path_via_ops(self):
        """ops.ssm_chunk_scan pads S to the chunk size correctly."""
        B, S, H, dk, dv = 2, 37, 2, 8, 8
        q = jnp.asarray(RNG.normal(size=(B, S, H, dk)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, S, H, dk)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, S, H, dv)), jnp.float32)
        a = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
        g = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
        y, _ = ops.ssm_chunk_scan(q, k, v, a, g, use_pallas=True, chunk=16)
        y0, _ = ref.ssm_chunk_scan_ref(q, k, v, a, g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   atol=1e-3, rtol=1e-3)

    def test_matches_model_core(self):
        """Chunked jnp core used by the models == the kernel oracle."""
        from repro.models.ssm import chunked_linear_attention
        B, S, H, dk, dv = 2, 40, 2, 8, 8
        q = jnp.asarray(RNG.normal(size=(B, S, H, dk)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, S, H, dk)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, S, H, dv)), jnp.float32)
        a = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
        g = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))), jnp.float32)
        y1, s1 = chunked_linear_attention(q, k, v, a, g, chunk=8)
        y0, s0 = ref.ssm_chunk_scan_ref(q, k, v, a, g)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                                   atol=1e-4, rtol=1e-4)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 32), (2, 7, 96), (1, 128),
                                       (5, 3, 2, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, dtype):
        x = jnp.asarray(RNG.normal(size=shape), dtype)
        sc = jnp.asarray(RNG.normal(size=shape[-1:]), jnp.float32)
        out = rms_norm_pallas(x, sc, interpret=True)
        want = ref.rms_norm_ref(x, sc)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))

    def test_matches_model_layer(self):
        from repro.models.layers import rms_norm as model_rms
        x = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
        sc = jnp.asarray(RNG.normal(size=(64,)), jnp.float32)
        np.testing.assert_allclose(np.asarray(ref.rms_norm_ref(x, sc)),
                                   np.asarray(model_rms(x, sc)), atol=1e-6)
