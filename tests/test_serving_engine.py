"""End-to-end serving engine tests: continuous batching, router
integration, and the placement-invariance property (a request's greedy
decode output must not depend on which worker it lands on — this is what
makes the router a pure efficiency knob, and it catches cache-copy bugs)."""
import warnings

warnings.filterwarnings("ignore")

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import make_policy
from repro.models import init_params, split_params
from repro.serving import EngineConfig, ServeRequest, ServingEngine

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


def _requests(n=10, seed=3):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(rid=i,
                     tokens=rng.integers(1, 128, size=int(rng.integers(4, 30))),
                     max_new_tokens=int(rng.integers(3, 10)))
        for i in range(n)
    ]


def _run(params, mesh, policy_name, reqs):
    eng = ServingEngine(
        CFG, params,
        EngineConfig(n_workers=2, slots_per_worker=3, max_seq_len=64),
        make_policy(policy_name), mesh=mesh)
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_steps=500)
    return eng, stats


class TestEngine:
    def test_all_complete(self, setup):
        params, mesh = setup
        reqs = _requests()
        _, stats = _run(params, mesh, "fcfs", reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.generated) == r.max_new_tokens for r in reqs)
        assert stats["tokens"] == sum(r.max_new_tokens - 1 for r in reqs)

    def test_latency_bookkeeping(self, setup):
        params, mesh = setup
        reqs = _requests()
        _run(params, mesh, "jsq", reqs)
        for r in reqs:
            assert r.t_first_token >= r.t_submit
            assert r.t_finish >= r.t_first_token

    def test_placement_invariance(self, setup):
        """Same requests, different routers -> identical generations."""
        params, mesh = setup
        reqs_a = _requests(seed=5)
        reqs_b = _requests(seed=5)
        _run(params, mesh, "fcfs", reqs_a)
        _run(params, mesh, "bfio_h0", reqs_b)
        for ra, rb in zip(reqs_a, reqs_b):
            assert ra.generated == rb.generated, \
                f"request {ra.rid}: output depends on placement"

    def test_bfio_reduces_imbalance(self, setup):
        params, mesh = setup
        # heterogeneous prompts: long + short mix, overloaded
        rng = np.random.default_rng(9)
        def mk():
            out = []
            for i in range(24):
                n = 50 if i % 3 == 0 else 5
                out.append(ServeRequest(
                    rid=i, tokens=rng.integers(1, 128, size=n),
                    max_new_tokens=8))
            return out
        _, s_fcfs = _run(params, mesh, "fcfs", mk())
        rng = np.random.default_rng(9)
        _, s_bfio = _run(params, mesh, "bfio_h0", mk())
        assert s_bfio["avg_imbalance"] <= s_fcfs["avg_imbalance"] * 1.05

    def test_capacity_respected(self, setup):
        params, mesh = setup
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=2, slots_per_worker=2, max_seq_len=64),
            make_policy("fcfs"), mesh=mesh)
        for r in _requests(n=12, seed=1):
            eng.submit(r)
        while eng.wait or any(s is not None for s in eng.slot_req):
            eng.step()
            counts = eng._counts()
            assert counts.max() <= 2
        assert eng.steps < 300
