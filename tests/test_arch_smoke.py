"""Per-architecture smoke tests: reduced variants (<=2 layers, d_model<=256,
<=4 experts) run one forward/train step and a prefill+decode step on CPU,
asserting output shapes and absence of NaNs."""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import (
    decode_fn,
    init_params,
    loss_fn,
    prefill_fn,
    split_params,
)

ARCHS = list_archs()


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    batch["targets"] = batch["tokens"]
    batch["mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.patch_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, mesh):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    batch = _batch(cfg)

    def step(p):
        return loss_fn(cfg, p, batch, mesh=mesh)

    loss, grads = jax.value_and_grad(step)(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in gleaves), \
        f"{arch}: non-finite grads"
    # one SGD step still yields a finite loss
    p2 = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                      params, grads)
    assert np.isfinite(float(step(p2))), f"{arch}: diverged after step"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch, mesh):
    cfg = get_smoke_config(arch)
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)
    pf = {k: v for k, v in batch.items() if k not in ("targets", "mask")}
    pf["lengths"] = jnp.array([S - 4, S], jnp.int32)
    logits, cache = prefill_fn(cfg, params, pf, max_len=S + 8, mesh=mesh)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: prefill NaN"
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = decode_fn(cfg, params, cache, tok, mesh=mesh)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode NaN"
    assert int(cache["lengths"][1]) == S + 3


@pytest.mark.parametrize("arch", ["granite-8b", "qwen3-moe-30b-a3b",
                                  "xlstm-350m", "zamba2-1.2b"])
def test_decode_matches_teacher_forcing(arch, mesh):
    """Strong consistency: sequential decode equals full-sequence forward."""
    cfg = get_smoke_config(arch)
    # float32 for tight comparison; generous MoE capacity so the dropped-
    # token path (which legitimately differs between batched prefill and
    # step-wise decode) never triggers
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    B, S = 2, 12
    batch = _batch(cfg, B=B, S=S, seed=3)
    pf_part = {"tokens": batch["tokens"],
               "lengths": jnp.full((B,), 8, jnp.int32)}
    pf_full = {"tokens": batch["tokens"],
               "lengths": jnp.full((B,), S, jnp.int32)}
    for extra in ("patches", "frames"):
        if extra in batch:
            pf_part[extra] = batch[extra]
            pf_full[extra] = batch[extra]
    lg, cache = prefill_fn(cfg, params, pf_part, max_len=S + 2, mesh=mesh)
    lg_full, _ = prefill_fn(cfg, params, pf_full, max_len=S + 2, mesh=mesh)
    for t in range(8, S):
        lg, cache = decode_fn(cfg, params, cache, batch["tokens"][:, t],
                              mesh=mesh)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full),
                               atol=2e-3, rtol=1e-3)
