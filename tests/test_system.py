"""System-level behaviour: the paper's end-to-end claims at test scale.

Each test here is one of the paper's falsifiable claims, run end-to-end
through the public API (simulate / engine / theory)."""
import warnings

warnings.filterwarnings("ignore")

import numpy as np
import pytest

from repro.core import (
    A100_POWER,
    SimConfig,
    SimTrace,
    make_policy,
    saving_bound,
    simulate,
)
from repro.data import (
    LONGBENCH_LIKE,
    batched_rounds_instance,
    overload_rate,
    poisson_trace,
)


@pytest.fixture(scope="module")
def overloaded_results():
    """FCFS / JSQ / BF-IO on one Poisson-overloaded LongBench-like trace."""
    # long sustained phase: the paper's asymptotic claims are about the
    # overloaded steady state; short traces are dominated by ramp/drain
    G, B = 16, 24
    rate = overload_rate(LONGBENCH_LIKE, G, B, factor=1.5)
    inst = poisson_trace(LONGBENCH_LIKE, n_requests=G * B * 8, rate=rate,
                         seed=11)
    cfg = SimConfig(G=G, B=B, time_based_arrivals=True)
    out = {}
    for name in ["fcfs", "jsq", "bfio_h0", "bfio_h16"]:
        out[name] = simulate(inst, make_policy(name), cfg)
    return out


class TestPaperClaims:
    def test_fig1_idle_exceeds_a_third_under_fcfs(self, overloaded_results):
        """Fig. 1: barrier idle is large (>40 % in the paper's trace)."""
        assert overloaded_results["fcfs"].mean_idle_frac > 0.33

    def test_bfio_dominates_all_four_metrics(self, overloaded_results):
        f, b = overloaded_results["fcfs"], overloaded_results["bfio_h16"]
        assert b.avg_imbalance < f.avg_imbalance / 1.3
        assert b.throughput > f.throughput
        assert b.tpot < f.tpot
        assert b.energy_joules < f.energy_joules

    def test_lookahead_does_not_hurt(self, overloaded_results):
        h0 = overloaded_results["bfio_h0"]
        h16 = overloaded_results["bfio_h16"]
        assert h16.avg_imbalance <= h0.avg_imbalance * 1.10

    def test_gains_grow_with_scale(self):
        """Figs 10/11: the IIR at (G=16,B=16) < IIR at (G=32,B=32)."""
        iirs = []
        for G, B in [(8, 8), (32, 24)]:
            inst = batched_rounds_instance(LONGBENCH_LIKE, G=G, B=B,
                                           n_rounds=4, seed=5)
            cfg = SimConfig(G=G, B=B)
            f = simulate(inst, make_policy("fcfs"), cfg)
            b = simulate(inst, make_policy("bfio_h0"), cfg)
            iirs.append(f.avg_imbalance / b.avg_imbalance)
        assert iirs[1] > iirs[0]

    def test_theorem4_bound_is_sound(self, overloaded_results):
        f = overloaded_results["fcfs"]
        b = overloaded_results["bfio_h16"]
        alpha = f.avg_imbalance / b.avg_imbalance
        bound = saving_bound(alpha, f.eta_sum, A100_POWER)
        measured = 1 - b.energy_joules / f.energy_joules
        assert bound <= measured + 0.02

    def test_energy_is_time_integral_of_power(self):
        """E == sum dt * G * avg_power along the trace."""
        inst = batched_rounds_instance(LONGBENCH_LIKE, G=4, B=8,
                                       n_rounds=2, seed=3)
        tr = SimTrace()
        cfg = SimConfig(G=4, B=8)
        m = simulate(inst, make_policy("fcfs"), cfg, trace=tr)
        e = float(np.sum(np.asarray(tr.dt) * np.asarray(tr.avg_power) * 4))
        assert e == pytest.approx(m.energy_joules, rel=1e-9)
