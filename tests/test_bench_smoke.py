"""Tier-1 smoke test of the perf harness (benchmarks/check_bench.py).

Runs the balancer benchmark on tiny shapes and validates the JSON schema
and that every timing is finite — catching benchmark bit-rot in CI instead
of at the next perf investigation.
"""
import json

import pytest

from benchmarks.check_bench import check, run_smoke


def test_smoke_schema_and_finite_timings():
    doc = run_smoke()
    # the doc must round-trip through JSON (no numpy scalars etc.)
    doc2 = json.loads(json.dumps(doc))
    check(doc2)
    sections = {r["section"] for r in doc2["rows"]}
    assert sections == {"solver", "simulator", "batch", "engine",
                        "engine_paged", "engine_preempt", "fleet",
                        "fleet_scale", "fleet_async", "obs"}
    kinds = {r.get("kind") for r in doc2["rows"]
             if r["section"] == "engine_paged"}
    assert kinds == {"grid", "stall"}
    preempt_kinds = {r.get("kind") for r in doc2["rows"]
                     if r["section"] == "engine_preempt"}
    assert preempt_kinds == {"pressure", "prefix", "persist"}
    fleet_kinds = {r.get("kind") for r in doc2["rows"]
                   if r["section"] == "fleet"}
    assert fleet_kinds == {"scenario", "parity", "affinity"}
    fscale_kinds = {r.get("kind") for r in doc2["rows"]
                    if r["section"] == "fleet_scale"}
    assert fscale_kinds == {"speedup", "pod"}
    fasync_kinds = {r.get("kind") for r in doc2["rows"]
                    if r["section"] == "fleet_async"}
    assert fasync_kinds == {"compat", "diurnal"}
    obs_kinds = {r.get("kind") for r in doc2["rows"]
                 if r["section"] == "obs"}
    assert obs_kinds == {"obs"}
    obs_variants = {r.get("variant") for r in doc2["rows"]
                    if r["section"] == "obs"}
    assert obs_variants == {"barrier", "async"}


def test_sections_filter():
    """--sections runs (and the checker expects) only the named
    sections — the knob that keeps targeted perf investigations fast."""
    doc = run_smoke(sections=["batch"])
    assert {r["section"] for r in doc["rows"]} == {"batch"}
    assert doc["meta"]["sections"] == ["batch"]
    # a filtered doc must not masquerade as a full one
    doc["meta"]["sections"] = None
    with pytest.raises(AssertionError):
        check(doc)


def test_sections_filter_rejects_unknown():
    from benchmarks.balancer_bench import run

    with pytest.raises(ValueError, match="unknown bench sections"):
        run(smoke=True, sections=["no_such_section"])


def test_check_rejects_broken_docs():
    with pytest.raises(AssertionError):
        check({"meta": {"bench": "balancer"}, "rows": []})
    with pytest.raises(AssertionError):
        check({"meta": {"bench": "other"},
               "rows": [{"section": "solver"}]})
