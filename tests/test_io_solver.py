"""Unit tests for the (IO) solver: feasibility, quality vs exact, and the
s_max-balance / separation property the theory relies on."""
import numpy as np
import pytest

from repro.core import io_solver


def _rand_instance(rng, G=None, n=None, W=None):
    G = G or int(rng.integers(2, 5))
    n = n or int(rng.integers(1, 9))
    W = W or int(rng.integers(1, 4))
    base = rng.uniform(0, 10, size=(G, W))
    caps = rng.integers(0, 4, size=G)
    cands = rng.uniform(0, 5, size=(n, W))
    return base, caps, cands


def _check_feasible(base, caps, cands, assign, n_admit=None):
    G = base.shape[0]
    n = cands.shape[0]
    assert assign.shape == (n,)
    assert np.all((assign >= -1) & (assign < G))
    used = np.bincount(assign[assign >= 0], minlength=G)
    assert np.all(used <= caps), "capacity violated"
    U = min(n, int(caps.sum())) if n_admit is None else n_admit
    assert int((assign >= 0).sum()) == U, "full-utilization constraint"


class TestGreedy:
    def test_feasibility_random(self):
        rng = np.random.default_rng(0)
        for _ in range(100):
            base, caps, cands = _rand_instance(rng)
            a = io_solver.solve_greedy(base, caps, cands)
            _check_feasible(base, caps, cands, a)

    def test_zero_candidates(self):
        a = io_solver.solve_greedy(np.zeros((3, 1)), np.array([1, 1, 1]),
                                   np.zeros((0, 1)))
        assert a.shape == (0,)

    def test_zero_capacity(self):
        a = io_solver.solve_greedy(np.zeros((2, 1)), np.array([0, 0]),
                                   np.ones((4, 1)))
        assert np.all(a == -1)

    def test_single_worker_takes_all(self):
        a = io_solver.solve_greedy(np.zeros((1, 1)), np.array([3]),
                                   np.ones((3, 1)))
        assert np.all(a == 0)

    def test_balances_two_workers(self):
        # two workers, four candidates 4,3,2,1 -> greedy LPT gives 4+1 / 3+2
        base = np.zeros((2, 1))
        caps = np.array([2, 2])
        cands = np.array([[4.0], [3.0], [2.0], [1.0]])
        a = io_solver.solve_io(base, caps, cands)
        loads = np.zeros(2)
        for i, g in enumerate(a):
            loads[g] += cands[i, 0]
        assert abs(loads[0] - loads[1]) <= 1.0


class TestLocalSearchVsExact:
    def test_near_optimal_small(self):
        """Greedy + exchange is within the theory's G*W*s_max scale of the
        exact optimum (Lemma 1's exchange argument bound)."""
        rng = np.random.default_rng(1)
        for _ in range(60):
            base, caps, cands = _rand_instance(rng)
            if caps.sum() == 0:
                continue
            a = io_solver.solve_io(base, caps, cands)
            _check_feasible(base, caps, cands, a)
            a_e, v_e = io_solver.solve_exact(base, caps, cands)
            v = io_solver.objective(base, cands, a)
            G, W = base.shape
            assert v <= v_e + G * W * cands.max() + 1e-9

    def test_local_search_never_worse(self):
        rng = np.random.default_rng(2)
        for _ in range(50):
            base, caps, cands = _rand_instance(rng)
            a0 = io_solver.solve_greedy(base, caps, cands)
            a1 = io_solver.local_search(base, caps, cands, a0)
            _check_feasible(base, caps, cands, a1)
            assert (io_solver.objective(base, cands, a1)
                    <= io_solver.objective(base, cands, a0) + 1e-9)


class TestSmaxBalance:
    def test_smax_balance_fresh_round(self):
        """Lemma 1: filling G empty workers with G*B candidates, the
        max-min per-worker load gap is <= s_max (+ slack for the heuristic)."""
        rng = np.random.default_rng(3)
        for trial in range(20):
            G, B = 4, 8
            s_max = 100.0
            cands = rng.uniform(1, s_max, size=(G * B, 1))
            base = np.zeros((G, 1))
            caps = np.full(G, B)
            a = io_solver.solve_io(base, caps, cands)
            loads = np.zeros(G)
            for i, g in enumerate(a):
                assert g >= 0
                loads[g] += cands[i, 0]
            assert loads.max() - loads.min() <= 2.0 * s_max, trial

    def test_objective_matches_manual(self):
        base = np.array([[1.0], [2.0]])
        cands = np.array([[3.0], [1.0]])
        a = np.array([1, 0])
        # loads = [2, 5]; J = 2*5 - 7 = 3
        assert io_solver.objective(base, cands, a) == pytest.approx(3.0)


class TestExact:
    def test_exact_beats_or_ties_greedy(self):
        rng = np.random.default_rng(4)
        for _ in range(30):
            base, caps, cands = _rand_instance(rng, G=2, n=5, W=1)
            a_g = io_solver.solve_greedy(base, caps, cands)
            a_e, v_e = io_solver.solve_exact(base, caps, cands)
            assert v_e <= io_solver.objective(base, cands, a_g) + 1e-9

    def test_exact_rejects_big(self):
        with pytest.raises(ValueError):
            io_solver.solve_exact(np.zeros((5, 1)), np.ones(5, dtype=int),
                                  np.ones((20, 1)))
