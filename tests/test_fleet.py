"""Fleet serving layer tests (repro.fleet).

The anchors the ISSUE demands:

* ``fleet(R=1, router=*)`` is bit-identical to a bare ServingEngine on
  the same stream — every router, stats compared dict-equal;
* routing is deterministic under a fixed seed (pod2 included: the fleet
  rng is owned and seeded by the server);
* scenario generators produce schema-valid, seed-reproducible streams;
* telemetry JSONL round-trips (and tampering is detected);
* per-request failure isolation at the fleet tier.
"""
import warnings

warnings.filterwarnings("ignore")

import os

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import make_policy
from repro.fleet import (
    SCENARIOS,
    FleetServer,
    FleetTelemetry,
    RouterContext,
    SLOSpec,
    make_router,
    make_scenario,
    validate_scenario,
)
from repro.models import init_params, split_params
from repro.serving import EngineConfig, ServeRequest, ServingEngine

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")
ROUTERS = ("round_robin", "least_loaded", "pod2", "bfio")


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


def _requests(seed=7, n=16):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=i, tokens=rng.integers(1, 128, size=int(rng.integers(4, 24))),
        max_new_tokens=int(min(3 + rng.geometric(0.2), 20)))
        for i in range(n)]


def _ctx(loads, counts, wait_sizes, seed=0):
    loads = np.asarray(loads, dtype=np.float64)
    return RouterContext(
        k=0, loads=loads, counts=np.asarray(counts, dtype=np.int64),
        free_slots=np.full(len(loads), 4, dtype=np.int64),
        wait_sizes=np.asarray(wait_sizes, dtype=np.float64),
        rng=np.random.default_rng(seed))


# ----------------------------------------------------------------------
# Routers (unit level)
# ----------------------------------------------------------------------

class TestRouters:
    def test_round_robin_cycles(self):
        r = make_router("round_robin")
        a = r.route(_ctx([0, 0, 0], [0, 0, 0], [5, 5, 5, 5]))
        assert a.tolist() == [0, 1, 2, 0]
        a = r.route(_ctx([0, 0, 0], [0, 0, 0], [5]))
        assert a.tolist() == [1]          # counter persists across calls
        r.reset()
        assert r.route(_ctx([0, 0, 0], [0, 0, 0], [5])).tolist() == [0]

    def test_least_loaded_tracks_placements(self):
        r = make_router("least_loaded")
        # replica 1 starts lightest; after absorbing the 10 it is
        # heaviest, so the next two go to 0 then 2
        a = r.route(_ctx([4.0, 1.0, 5.0], [1, 1, 1], [10, 2, 3]))
        assert a.tolist() == [1, 0, 2]

    def test_pod_is_seed_deterministic(self):
        r = make_router("pod2")
        a = r.route(_ctx([0, 0, 0, 0], [3, 0, 1, 2], [1] * 6, seed=3))
        b = r.route(_ctx([0, 0, 0, 0], [3, 0, 1, 2], [1] * 6, seed=3))
        assert a.tolist() == b.tolist()

    def test_bfio_total_and_size_aware(self):
        r = make_router("bfio")
        # one huge + many small candidates onto two idle replicas: the
        # windowed-imbalance solve must not stack the huge one with the
        # small ones' sum exceeding balance — totals end up ~equal
        sizes = [40, 10, 10, 10, 10]
        a = r.route(_ctx([0.0, 0.0], [0, 0], sizes))
        assert a.shape == (5,) and ((a >= 0) & (a < 2)).all()
        per = [sum(s for s, g in zip(sizes, a) if g == rep)
               for rep in (0, 1)]
        assert abs(per[0] - per[1]) <= 10, per

    def test_make_router_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown fleet router"):
            make_router("zeta")

    def test_make_router_passthrough(self):
        r = make_router("bfio_h4")
        assert r.H == 4 and r.name == "bfio_h4"
        assert make_router(r) is r


# ----------------------------------------------------------------------
# fleet(R=1) == bare engine, per router
# ----------------------------------------------------------------------

class TestSingleReplicaParity:
    @pytest.mark.parametrize("router", ROUTERS)
    def test_stats_bit_identical(self, setup, router):
        params, mesh = setup
        ec = EngineConfig(n_workers=2, slots_per_worker=4, max_seq_len=64)
        eng = ServingEngine(CFG, params, ec, make_policy("bfio_h0"),
                            mesh=mesh)
        reqs = _requests()
        for r in reqs:
            eng.submit(r)
        bare = eng.run()
        bare_gens = [r.generated for r in reqs]

        fs = FleetServer(CFG, params, ec, n_replicas=1, router=router,
                         policy="bfio_h0", mesh=mesh)
        freqs = _requests()
        for r in freqs:
            fs.submit(r)
        stats = fs.run()
        assert stats["replicas"][0] == bare
        assert [r.generated for r in freqs] == bare_gens
        # fleet aggregates collapse to the single engine: no barrier
        # slack exists at R=1
        assert stats["idle_j"] == 0.0
        assert stats["energy_j"] == bare["energy_j"]
        assert stats["steps"] == bare["steps"]


# ----------------------------------------------------------------------
# Multi-replica semantics
# ----------------------------------------------------------------------

class TestFleetServer:
    @pytest.mark.parametrize("router", ROUTERS)
    def test_deterministic_under_fixed_seed(self, setup, router):
        params, mesh = setup
        ec = EngineConfig(n_workers=2, slots_per_worker=2, max_seq_len=64)

        def one():
            fs = FleetServer(CFG, params, ec, n_replicas=3, router=router,
                             policy="bfio_h0", mesh=mesh, seed=11)
            reqs = _requests(seed=3, n=18)
            for i, r in enumerate(reqs):
                fs.submit(r, arrival_time=0.02 * i)
            stats = fs.run()
            return dict(fs.assignments), stats, [r.generated for r in reqs]

        a1, s1, g1 = one()
        a2, s2, g2 = one()
        assert a1 == a2
        assert s1 == s2
        assert g1 == g2

    def test_generations_router_invariant(self, setup):
        """Dense greedy decode is placement-invariant: the router moves
        only efficiency, never outputs."""
        params, mesh = setup
        ec = EngineConfig(n_workers=2, slots_per_worker=2, max_seq_len=64)
        gens = {}
        for router in ROUTERS:
            fs = FleetServer(CFG, params, ec, n_replicas=2, router=router,
                             policy="bfio_h0", mesh=mesh)
            reqs = _requests(seed=5, n=12)
            for r in reqs:
                fs.submit(r)
            stats = fs.run()
            assert stats["completed"] == len(reqs)
            assert stats["failed"] == 0
            gens[router] = [r.generated for r in reqs]
        assert all(g == gens[ROUTERS[0]] for g in gens.values())

    def test_arrivals_respected_and_clock_advances(self, setup):
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64)
        tel = FleetTelemetry()
        fs = FleetServer(CFG, params, ec, n_replicas=2,
                         router="round_robin", policy="fcfs", mesh=mesh,
                         telemetry=tel)
        reqs = _requests(seed=2, n=6)
        fs.submit(reqs[0])
        for r in reqs[1:]:
            fs.submit(r, arrival_time=5.0)   # far future: forces idling
        info = fs.step()
        # only the first request is in flight; the rest are pending
        assert info["waiting"] == 5
        stats = fs.run()
        assert stats["completed"] == 6
        assert stats["time_s"] >= 5.0        # clock rode the gap
        assert stats["idle_j"] > 0.0         # idle draw was charged
        # latency is measured from each request's own arrival, not from
        # the fleet epoch: the t=5 arrivals must not inherit the gap
        late = [r for r in tel.requests if r["rid"] != reqs[0].rid]
        assert late and all(r["latency"] < 4.0 for r in late)
        assert all(r["t_arrival"] == 5.0 for r in late)

    def test_failure_isolated_at_fleet_tier(self, setup):
        """A request the pool can never serve fails alone — the fleet
        keeps serving, and the telemetry records the error."""
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=256,
                          cache_backend="paged", paged_block_size=16,
                          paged_pool_blocks=4)
        tel = FleetTelemetry()
        fs = FleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                         policy="fcfs", mesh=mesh, telemetry=tel)
        doomed = ServeRequest(rid=0, tokens=np.arange(1, 61),
                              max_new_tokens=30)
        rest = [ServeRequest(rid=1 + i, tokens=np.arange(1, 9),
                             max_new_tokens=4) for i in range(4)]
        fs.submit(doomed)
        for r in rest:
            fs.submit(r)
        stats = fs.run()
        assert doomed.status == "failed"
        assert "exceeds the entire pool" in doomed.error
        assert stats["failed"] == 1
        assert stats["completed"] == 4
        assert all(r.status == "done" for r in rest)
        failed = [r for r in tel.requests if r["status"] == "failed"]
        assert len(failed) == 1 and failed[0]["rid"] == 0
        assert "exceeds the entire pool" in failed[0]["error"]

    def test_rejects_bad_replica_count(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="n_replicas"):
            FleetServer(CFG, params, EngineConfig(), n_replicas=0,
                        router="bfio", mesh=mesh)


# ----------------------------------------------------------------------
# Scenario trace suite
# ----------------------------------------------------------------------

class TestScenarios:
    KW = dict(n_requests=20, n_replicas=2, n_workers=2,
              slots_per_worker=2, max_seq_len=64, vocab_size=128)

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_schema_valid(self, name):
        sc = make_scenario(name, seed=0, **self.KW)
        assert sc.n_requests == 20
        validate_scenario(sc, max_seq_len=64, vocab_size=128)
        assert sc.meta["seed"] == 0 and sc.meta["n_replicas"] == 2

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_seed_reproducible(self, name):
        a = make_scenario(name, seed=3, **self.KW)
        b = make_scenario(name, seed=3, **self.KW)
        c = make_scenario(name, seed=4, **self.KW)
        for ra, rb in zip(a.requests, b.requests):
            assert ra.arrival_time == rb.arrival_time
            assert (ra.tokens == rb.tokens).all()
            assert ra.max_new_tokens == rb.max_new_tokens
        assert any(
            ra.arrival_time != rc.arrival_time
            or ra.tokens.shape != rc.tokens.shape
            or (ra.tokens != rc.tokens).any()
            for ra, rc in zip(a.requests, c.requests)), \
            "different seeds produced an identical stream"

    def test_agentic_shares_a_prefix(self):
        sc = make_scenario("agentic", seed=1, **self.KW)
        pl = sc.meta["shared_prefix_len"]
        head = sc.requests[0].tokens[:pl]
        assert all((r.tokens[:pl] == head).all() for r in sc.requests)

    def test_unknown_scenario_rejected(self):
        with pytest.raises(ValueError, match="unknown scenario"):
            make_scenario("weekend", seed=0, **self.KW)

    def test_scenario_runs_end_to_end(self, setup):
        params, mesh = setup
        sc = make_scenario("steady", seed=0, **self.KW)
        ec = EngineConfig(n_workers=2, slots_per_worker=2, max_seq_len=64)
        fs = FleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                         policy="bfio_h0", mesh=mesh)
        fs.submit_scenario(sc)
        stats = fs.run()
        assert stats["completed"] == sc.n_requests
        assert stats["failed"] == 0


# ----------------------------------------------------------------------
# Telemetry
# ----------------------------------------------------------------------

class TestTelemetry:
    def _filled(self, setup):
        params, mesh = setup
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=2.0, tpot_s=0.5))
        ec = EngineConfig(n_workers=2, slots_per_worker=2, max_seq_len=64)
        fs = FleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                         policy="bfio_h0", mesh=mesh, telemetry=tel)
        reqs = _requests(seed=9, n=10)
        for i, r in enumerate(reqs):
            fs.submit(r, arrival_time=0.01 * i)
        fs.run()
        return tel

    def test_jsonl_round_trip(self, setup, tmp_path):
        tel = self._filled(setup)
        assert tel.steps and tel.requests
        path = os.path.join(tmp_path, "tel.jsonl")
        tel.write_jsonl(path)
        back = FleetTelemetry.read_jsonl(path)
        assert back.steps == tel.steps
        assert back.requests == tel.requests
        assert back.slo == tel.slo
        assert back.summary() == tel.summary()

    def test_tampered_summary_detected(self, setup, tmp_path):
        tel = self._filled(setup)
        path = os.path.join(tmp_path, "tel.jsonl")
        tel.write_jsonl(path)
        lines = open(path).read().splitlines()
        lines[1] = lines[1].replace('"tokens": ', '"tokens": 9')
        open(path, "w").write("\n".join(lines) + "\n")
        with pytest.raises(ValueError, match="does not match"):
            FleetTelemetry.read_jsonl(path)

    def test_summary_shape(self, setup):
        tel = self._filled(setup)
        s = tel.summary()
        assert s["n_requests"] == 10 and s["completed"] == 10
        assert s["failed"] == 0
        assert s["tokens"] > 0 and s["energy_j"] > 0
        assert s["energy_per_token"] > 0
        assert 0.0 <= s["slo_attainment"] <= 1.0
        for key in ("ttft", "tpot", "latency"):
            assert set(s[key]) == {"p50", "p95", "p99"}
        assert s["ttft"]["p50"] is not None
        assert s["ttft"]["p50"] <= s["ttft"]["p95"] <= s["ttft"]["p99"]

    def test_empty_percentiles_are_none(self):
        from repro.fleet import percentiles
        assert percentiles([]) == {"p50": None, "p95": None, "p99": None}
        assert percentiles([None, float("nan")])["p95"] is None

# ----------------------------------------------------------------------
# fleet_mode="ref" vs "vec" parity (the fleet_scale tentpole gate)
# ----------------------------------------------------------------------

def _run_both_modes(params, mesh, ec, sc, router, *, R=None,
                    replica_classes=None, predictor=None, seed=0):
    """Run the same scenario under both fleet modes; return
    {mode: (stats, telemetry)}."""
    out = {}
    for mode in ("ref", "vec"):
        tel = FleetTelemetry()
        fs = FleetServer(CFG, params, ec, n_replicas=R or 1,
                         router=router, policy="bfio_h0", mesh=mesh,
                         telemetry=tel, seed=seed, fleet_mode=mode,
                         replica_classes=replica_classes,
                         predictor=predictor)
        fs.submit_scenario(sc)
        out[mode] = (fs.run(), tel)
    return out


def _assert_modes_equal(out):
    s_ref, t_ref = out["ref"]
    s_vec, t_vec = out["vec"]
    assert s_ref == s_vec
    assert t_ref.steps == t_vec.steps
    assert t_ref.requests == t_vec.requests
    assert t_ref.summary() == t_vec.summary()


class TestFleetModeParity:
    PARITY_ROUTERS = ROUTERS + ("pod_bfio_p2",)

    @pytest.mark.parametrize("router", PARITY_ROUTERS)
    @pytest.mark.parametrize("R", (1, 8, 64))
    def test_ref_vec_bit_identical(self, setup, router, R):
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=48)
        sc = make_scenario("trickle", n_requests=8 if R >= 64 else 14,
                           n_replicas=R, n_workers=1, slots_per_worker=2,
                           max_seq_len=48, seed=3,
                           step_overhead=1e-3, t_token=2e-4)
        out = _run_both_modes(params, mesh, ec, sc, router, R=R)
        _assert_modes_equal(out)
        assert out["vec"][0]["failed"] == 0
        assert out["vec"][0]["completed"] == sc.n_requests

    def test_rejects_bad_mode(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="fleet_mode"):
            FleetServer(CFG, params, EngineConfig(), n_replicas=1,
                        router="bfio", mesh=mesh, fleet_mode="fast")


# ----------------------------------------------------------------------
# Hierarchical pod routing (unit level)
# ----------------------------------------------------------------------

class TestPodRouting:
    def test_single_pod_matches_flat_bfio(self):
        from repro.fleet import BFIORouter, PodBFIORouter
        ctx = _ctx([3.0, 1.0, 4.0, 1.5], [2, 1, 3, 1],
                   [5, 9, 2, 6, 3, 7, 1])
        flat = BFIORouter().route(ctx)
        pod = PodBFIORouter(pods=1).route(ctx)
        assert np.array_equal(flat, pod)

    def test_pod_boundaries_respected(self):
        """Level 1 steers the whole batch to the lighter pod; level 2
        never places outside it."""
        from repro.fleet import PodBFIORouter
        ctx = _ctx([0.0, 0.0, 100.0, 100.0], [0, 0, 8, 8], [1, 1, 1, 1])
        out = PodBFIORouter(pods=2).route(ctx)
        assert set(out.tolist()) <= {0, 1}

    def test_uneven_pod_sizes(self):
        """R % pods != 0: contiguous pods of size ceil/floor, every
        assignment in range, both pods used under symmetric load."""
        from repro.fleet import PodBFIORouter
        r = PodBFIORouter(pods=2)
        ctx = _ctx([0.0] * 5, [0] * 5, [4.0] * 10)
        out = r.route(ctx)
        assert out.shape == (10,)
        assert ((out >= 0) & (out < 5)).all()
        assert set(out.tolist()) & {0, 1, 2}      # pod 0 = replicas 0-2
        assert set(out.tolist()) & {3, 4}         # pod 1 = replicas 3-4
        out2 = PodBFIORouter(pods=2).route(
            _ctx([0.0] * 5, [0] * 5, [4.0] * 10))
        assert np.array_equal(out, out2)          # deterministic

    def test_empty_candidates(self):
        from repro.fleet import PodBFIORouter
        out = PodBFIORouter(pods=2).route(_ctx([0.0, 0.0], [0, 0], []))
        assert out.shape == (0,)

    def test_capacity_normalized_level1(self):
        """A pod with double capacity absorbs proportionally more of a
        burst than its equal-loaded half-capacity sibling."""
        from repro.fleet import PodBFIORouter
        ctx = _ctx([0.0, 0.0], [0, 0], [1.0] * 12)
        ctx.capacity = np.array([4.0, 1.0])
        out = PodBFIORouter(pods=2).route(ctx)
        n0 = int((out == 0).sum())
        assert n0 > 12 - n0

    def test_make_router_parses_pod_bfio(self):
        from repro.fleet import PodBFIORouter, PowerOfDRouter
        r = make_router("pod_bfio_p16")
        assert isinstance(r, PodBFIORouter) and r.pods == 16
        r = make_router("pod_bfio_p8_h2")
        assert r.pods == 8 and r.H == 2
        assert r.name == "pod_bfio_p8_h2"
        assert make_router("pod_bfio").pods == 4       # default
        assert isinstance(make_router("pod2"), PowerOfDRouter)
        with pytest.raises(ValueError, match="pod_bfio suffix"):
            make_router("pod_bfio_x3")
        with pytest.raises(ValueError, match="pods"):
            make_router("pod_bfio_p0")


# ----------------------------------------------------------------------
# step() waiting count + telemetry deltas (regressions)
# ----------------------------------------------------------------------

class TestStepAccounting:
    @pytest.mark.parametrize("mode", ("ref", "vec"))
    def test_waiting_includes_replica_backlog(self, setup, mode):
        """step()['waiting'] must count the routed-but-unadmitted
        backlog queued at the replicas, not just fleet-pending arrivals
        (the old field was always 0 right after routing)."""
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=1, max_seq_len=64)
        fs = FleetServer(CFG, params, ec, n_replicas=1,
                         router="round_robin", policy="fcfs", mesh=mesh,
                         fleet_mode=mode)
        for i in range(5):
            fs.submit(ServeRequest(rid=i, tokens=np.arange(1, 9),
                                   max_new_tokens=4))
        info = fs.step()
        # 1 admitted into the single slot, 4 queued at the replica
        assert info["waiting"] == 4
        assert info["replica_waiting"] == [4]
        fs.run()

    @pytest.mark.parametrize("mode", ("ref", "vec"))
    def test_step_rows_carry_deltas_not_totals(self, setup, mode):
        """Per-step telemetry preemptions/prefix_hits are deltas: their
        sum equals the run total (feeding cumulative totals per row made
        the sum quadratically larger)."""
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          cache_backend="paged", paged_block_size=8,
                          prefix_cache=True)
        tel = FleetTelemetry()
        fs = FleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                         policy="bfio_h0", mesh=mesh, telemetry=tel,
                         seed=0, fleet_mode=mode)
        sc = make_scenario("agentic", n_requests=12, n_replicas=2,
                           n_workers=1, slots_per_worker=2,
                           max_seq_len=64, seed=1)
        fs.submit_scenario(sc)
        stats = fs.run()
        assert stats["prefix_hits"] > 0
        assert sum(s["prefix_hits"] for s in tel.steps) \
            == stats["prefix_hits"]
        assert sum(s["preemptions"] for s in tel.steps) \
            == stats["preemptions"]
        assert all(s["prefix_hits"] >= 0 and s["preemptions"] >= 0
                   for s in tel.steps)
        assert tel.summary()["prefix_hits"] == stats["prefix_hits"]


# ----------------------------------------------------------------------
# Heterogeneous replica classes + predicted-output routing
# ----------------------------------------------------------------------

class TestHeterogeneousFleet:
    def test_replica_classes_expand_in_order(self, setup):
        params, mesh = setup
        small = EngineConfig(n_workers=1, slots_per_worker=1,
                             max_seq_len=48)
        big = EngineConfig(n_workers=2, slots_per_worker=2,
                           max_seq_len=48)
        sc = make_scenario("trickle", n_requests=10, n_replicas=3,
                           n_workers=1, slots_per_worker=2,
                           max_seq_len=48, seed=2)
        out = _run_both_modes(params, mesh, small, sc, "pod_bfio_p2",
                              replica_classes=[(1, small), (2, big)])
        _assert_modes_equal(out)
        stats = out["vec"][0]
        assert stats["n_replicas"] == 3
        assert stats["completed"] == 10 and stats["failed"] == 0
        fs = FleetServer(CFG, params, small, mesh=mesh,
                         replica_classes=[(1, small), (2, big)])
        assert fs._capacity.tolist() == [1.0, 4.0, 4.0]
        assert [e.N for e in fs.engines] == [1, 4, 4]

    def test_replica_classes_validated(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="count"):
            FleetServer(CFG, params, EngineConfig(), mesh=mesh,
                        replica_classes=[(0, EngineConfig())])
        with pytest.raises(ValueError, match="empty"):
            FleetServer(CFG, params, EngineConfig(), mesh=mesh,
                        replica_classes=[])

    def test_pred_weight_augments_sizes(self):
        from repro.fleet import BFIORouter
        ctx = _ctx([0.0, 0.0], [0, 0], [10.0, 10.0, 10.0])
        ctx.pred_out = np.array([100.0, 0.0, 0.0])
        plain = BFIORouter()._sizes(ctx)
        assert plain.tolist() == [10.0, 10.0, 10.0]
        weighted = BFIORouter(pred_weight=0.5)._sizes(ctx)
        assert weighted.tolist() == [60.0, 10.0, 10.0]
        # no predictor in the context -> weight is inert
        ctx.pred_out = None
        assert BFIORouter(pred_weight=0.5)._sizes(ctx).tolist() \
            == [10.0, 10.0, 10.0]

    def test_oracle_predictor_end_to_end(self, setup):
        from repro.fleet import BFIORouter
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=48)
        sc = make_scenario("trickle", n_requests=10, n_replicas=2,
                           n_workers=1, slots_per_worker=2,
                           max_seq_len=48, seed=5)
        out = _run_both_modes(params, mesh, ec, sc,
                              BFIORouter(pred_weight=0.5), R=2,
                              predictor="oracle")
        _assert_modes_equal(out)
        assert out["vec"][0]["completed"] == 10

    def test_rejects_bad_predictor(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="predictor"):
            FleetServer(CFG, params, EngineConfig(), n_replicas=1,
                        mesh=mesh, predictor="psychic")
