"""Direct Scheduler unit tests: the chunked-prefill budget edge cases
(budget=0, budget >= prompt, mid-chunk EOS, preempted-then-resumed chunk
accounting) and the admission block gate — previously exercised only
indirectly through the engine."""
import warnings

warnings.filterwarnings("ignore")

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import make_policy
from repro.core.policies import Policy
from repro.models import init_params, split_params
from repro.serving import (
    EngineConfig,
    FIFOPreemption,
    LIFOPreemption,
    PagedKVCache,
    PreemptContext,
    Scheduler,
    ServeRequest,
    ServingEngine,
)


class _TakeAll(Policy):
    """Admit every waiting request onto worker 0 (capacities permitting;
    cap_assignment trims the excess)."""

    name = "take-all"

    def assign(self, ctx):
        return np.zeros(ctx.n_wait, dtype=np.int64)


class _Req:
    def __init__(self, rid, n):
        self.rid = rid
        self.tokens = np.arange(1, n + 1)


def _ctx(n_wait, caps=(4,)):
    from repro.core.policies import SchedulerContext
    from repro.core.workload import unit_drift

    return SchedulerContext(
        k=0, loads=np.zeros(len(caps)),
        counts=np.zeros(len(caps), dtype=np.int64),
        caps=np.asarray(caps, dtype=np.int64),
        wait_prefill=np.ones(n_wait),
        active_worker=np.zeros(0, dtype=np.int64),
        active_w=np.zeros(0), active_age=np.zeros(0, dtype=np.int64),
        active_remaining=np.zeros(0, dtype=np.int64),
        drift=unit_drift(), rng=np.random.default_rng(0))


class TestChunkPlanning:
    def test_budget_zero_means_not_chunked(self):
        """chunk=0 (the default) is the synchronous mode: no jobs, no
        plans, ``chunked`` False — the engine routes everything through
        one-shot prefill."""
        s = Scheduler(_TakeAll())
        assert not s.chunked
        assert s.budget == 0
        assert s.plan_chunks() == []

    def test_budget_defaults_to_chunk(self):
        s = Scheduler(_TakeAll(), prefill_chunk=8)
        assert s.chunked and s.budget == 8

    def test_budget_at_least_prompt_finishes_in_one_plan(self):
        """budget >= the whole prompt: one plan covers it and advance()
        retires the job immediately (the degenerate-to-sync case)."""
        s = Scheduler(_TakeAll(), prefill_chunk=64, prefill_budget=1000)
        s.register_job(3, _Req(0, 40), np.arange(40))
        plan = s.plan_chunks()
        assert plan == [(3, 0, 40)]
        assert s.advance(3, 40) is True
        assert s.job(3) is None and s.n_prefilling == 0
        assert s.plan_chunks() == []

    def test_budget_split_fcfs_across_jobs(self):
        """The step budget is consumed FCFS in admission order; a job
        never exceeds ``chunk`` tokens per plan."""
        s = Scheduler(_TakeAll(), prefill_chunk=8, prefill_budget=12)
        s.register_job(0, _Req(0, 20), np.arange(20))
        s.register_job(1, _Req(1, 20), np.arange(20))
        assert s.plan_chunks() == [(0, 0, 8), (1, 0, 4)]
        s.advance(0, 8)
        s.advance(1, 4)
        # next step resumes at the recorded offsets
        assert s.plan_chunks() == [(0, 8, 8), (1, 4, 4)]

    def test_tail_chunk_clipped_to_remaining(self):
        s = Scheduler(_TakeAll(), prefill_chunk=16)
        s.register_job(0, _Req(0, 20), np.arange(20))
        assert s.plan_chunks() == [(0, 0, 16)]
        assert s.advance(0, 16) is False
        assert s.plan_chunks() == [(0, 16, 4)]
        assert s.advance(0, 4) is True

    def test_job_dropped_mid_stream_leaves_no_plan(self):
        """A job that disappears mid-prefill (its request finished on an
        eos first token, or it was preempted) must stop consuming budget
        so the freed budget flows to the remaining jobs."""
        s = Scheduler(_TakeAll(), prefill_chunk=8, prefill_budget=8)
        s.register_job(0, _Req(0, 32), np.arange(32))
        s.register_job(1, _Req(1, 32), np.arange(32))
        assert s.plan_chunks() == [(0, 0, 8)]
        job = s.drop_job(0)
        assert job is not None and s.job(0) is None
        assert s.plan_chunks() == [(1, 0, 8)]
        assert s.drop_job(0) is None  # idempotent

    def test_preempted_then_resumed_accounting(self):
        """Preemption mid-prefill drops the job; a swap-resume
        re-registers it at the preserved offset and the remaining chunks
        pick up exactly where the victim stopped."""
        s = Scheduler(_TakeAll(), prefill_chunk=8)
        r = _Req(0, 30)
        s.register_job(5, r, np.arange(30))
        s.advance(5, 8)
        s.advance(5, 8)
        job = s.drop_job(5)           # preempted at done=16
        assert job.done == 16 and job.remaining == 14
        assert s.plan_chunks() == []
        # resumed on a different slot with the offset preserved
        s.register_job(2, r, job.tokens, done=job.done,
                       resume_token=job.resume_token)
        assert s.plan_chunks() == [(2, 16, 8)]
        assert s.advance(2, 8) is False
        assert s.plan_chunks() == [(2, 24, 6)]
        assert s.advance(2, 6) is True

    def test_resume_token_round_trips(self):
        """A recompute-on-resume job carries the pending decode token."""
        s = Scheduler(_TakeAll(), prefill_chunk=8)
        s.register_job(0, _Req(0, 10), np.arange(10), resume_token=42)
        assert s.job(0).resume_token == 42
        job = s.drop_job(0)
        assert job.resume_token == 42


class TestQueue:
    def test_requeue_goes_to_front(self):
        s = Scheduler(_TakeAll())
        a, b, c = _Req(0, 4), _Req(1, 4), _Req(2, 4)
        s.submit(a)
        s.submit(b)
        s.requeue(c)              # preempted victim outranks arrivals
        assert s.wait == [c, a, b]


class TestBlockGate:
    def test_budget_limits_admissions_in_order(self):
        s = Scheduler(_TakeAll())
        reqs = [_Req(i, 16) for i in range(4)]
        for r in reqs:
            s.submit(r)
        out = s.admit(_ctx(4), caps=np.array([4]),
                      block_budget=2, blocks_of=lambda r: 1)
        assert [r.rid for r, _ in out] == [0, 1]
        assert [r.rid for r in s.wait] == [2, 3]

    def test_gate_is_strict_fcfs(self):
        """The first request that does not fit stops admission — no
        head-of-line bypass by smaller later requests."""
        s = Scheduler(_TakeAll())
        big, small = _Req(0, 64), _Req(1, 4)
        s.submit(big)
        s.submit(small)
        out = s.admit(_ctx(2), caps=np.array([4]),
                      block_budget=2,
                      blocks_of=lambda r: len(r.tokens) // 16)
        assert out == []
        assert s.wait == [big, small]

    def test_no_gate_admits_all(self):
        s = Scheduler(_TakeAll())
        for i in range(3):
            s.submit(_Req(i, 8))
        out = s.admit(_ctx(3), caps=np.array([4]))
        assert len(out) == 3 and not s.wait


class TestChunkPastCapacity:
    """Chunked prefill growing past the block table must freeze (the
    documented append_tokens overflow semantics), not raise."""

    def _cache(self):
        return PagedKVCache.create(
            n_layers=1, n_blocks=8, block_size=16, n_kv_heads=2,
            head_dim=4, max_requests=2, max_blocks_per_req=2)

    def test_ensure_capacity_clamps_to_table_width(self):
        kv = self._cache()
        kv.admit(0, 16)
        # grow chunk by chunk to 3 blocks' worth of tokens — one past
        # the 2-wide table; pre-clamp this raised a numpy broadcast
        # ValueError on the table-row assignment
        for new_len in (32, 48):
            kv.ensure_capacity(0, new_len)
        assert len(kv.req_blocks[0]) == 2      # table full, list frozen
        assert (kv.block_tables[0] >= 0).all()
        assert int(kv.lengths[0]) == 48        # length keeps counting
        kv.ensure_capacity(0, 49)              # idempotent once frozen
        assert len(kv.req_blocks[0]) == 2

    def test_write_token_drops_overflow_on_frozen_slot(self):
        kv = self._cache()
        kv.admit(0, 16)
        kv.ensure_capacity(0, 48)              # frozen past the table
        k = jax.numpy.ones((2, 4))
        before = kv.k_pool
        kv.write_token(0, 0, k, k)             # pos 47 -> block 2: off-table
        assert kv.k_pool is before             # dropped, no pool write
        kv.set_length(0, 32)
        kv.write_token(0, 0, k, k)             # pos 31: last in-cap slot
        blk = int(kv.block_tables[0, 1])
        assert float(kv.k_pool[0, blk, 15].sum()) != 0.0


CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


class TestMidChunkEos:
    """A request whose *first* token (produced when its last prefill
    chunk completes) already meets eos or the token budget must finish at
    prefill — not burn a decode step generating a token past its
    budget."""

    def _first_token(self, params, mesh, prompt, **ec_kw):
        r = ServeRequest(rid=0, tokens=prompt, max_new_tokens=4)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         **ec_kw),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(r)
        eng.run(max_steps=200)
        return r.generated[0]

    @pytest.mark.parametrize("chunk", [0, 8])
    def test_eos_on_first_token_finishes_at_prefill(self, setup, chunk):
        params, mesh = setup
        prompt = np.arange(1, 25)
        eos = self._first_token(params, mesh, prompt, prefill_chunk=chunk)
        r = ServeRequest(rid=1, tokens=prompt, max_new_tokens=8,
                         eos_id=eos)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         prefill_chunk=chunk),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(r)
        stats = eng.run(max_steps=200)
        assert r.done and r.generated == [eos]
        assert stats["tokens"] == 0      # no decode step ran for it
        assert eng.scheduler.n_prefilling == 0

    @pytest.mark.parametrize("chunk", [0, 8])
    def test_max_new_one_stops_at_prefill(self, setup, chunk):
        params, mesh = setup
        r = ServeRequest(rid=0, tokens=np.arange(1, 20),
                         max_new_tokens=1)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         prefill_chunk=chunk),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(r)
        eng.run(max_steps=200)
        assert r.done and len(r.generated) == 1

    def test_chunked_matches_sync_on_edge_requests(self, setup):
        """The two prefill schedules agree on the edge semantics."""
        params, mesh = setup
        gens = {}
        for chunk in (0, 8):
            rs = [ServeRequest(rid=i, tokens=np.arange(1, 20 + i),
                               max_new_tokens=1 + i) for i in range(4)]
            eng = ServingEngine(
                CFG, params,
                EngineConfig(n_workers=1, slots_per_worker=4,
                             max_seq_len=64, prefill_chunk=chunk),
                make_policy("fcfs"), mesh=mesh)
            for r in rs:
                eng.submit(r)
            eng.run(max_steps=500)
            gens[chunk] = [r.generated for r in rs]
        assert gens[0] == gens[8]


class TestVictimSelection:
    def test_select_victim_empty_returns_none(self):
        s = Scheduler(_TakeAll())
        ctx = PreemptContext(
            slots=np.zeros(0, dtype=np.int64),
            admit_seq=np.zeros(0, dtype=np.int64),
            kv_tokens=np.zeros(0, dtype=np.int64),
            blocks_held=np.zeros(0, dtype=np.int64),
            prefilling=np.zeros(0, dtype=bool))
        assert s.select_victim(ctx) is None

    def test_default_policy_is_lifo(self):
        assert isinstance(Scheduler(_TakeAll()).preemption, LIFOPreemption)

    def test_pluggable_policy(self):
        s = Scheduler(_TakeAll(), preemption=FIFOPreemption())
        ctx = PreemptContext(
            slots=np.array([3, 7, 1]),
            admit_seq=np.array([5, 2, 9]),
            kv_tokens=np.array([10, 20, 30]),
            blocks_held=np.array([1, 2, 3]),
            prefilling=np.zeros(3, dtype=bool))
        assert s.select_victim(ctx) == 7      # oldest admit_seq
        s2 = Scheduler(_TakeAll())
        assert s2.select_victim(ctx) == 1     # newest admit_seq (LIFO)
