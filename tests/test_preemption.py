"""Preemption + prefix-caching parity fuzz.

Randomized admission/growth/preempt/resume sequences (hypothesis when
available, seeded ``random`` fallback otherwise) hardening the paged
backend's memory-pressure subsystem against its two oracles:

(a) slot-vs-paged stats parity whenever the pool never exhausts —
    preemption machinery armed but never firing must be a no-op;
(b) generations bit-identical under swap-preemption (host-staged blocks
    restore exactly; dense decode rows are batch-composition invariant);
(c) allocator refcounts return to zero at drain — every preempt/resume/
    COW/share path hands its blocks back;
(d) prefix-cache hits never change generations on dense models (equal
    token prefix => equal KV bits, copy-on-write isolates divergence).
"""
import warnings

warnings.filterwarnings("ignore")

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import make_policy
from repro.models import init_params, split_params
from repro.serving import (
    BlockAllocator,
    EngineConfig,
    PagedKVCache,
    PrefixIndex,
    ServeRequest,
    ServingEngine,
    make_preemption_policy,
)
from repro.serving.preemption import (
    SWAP_TILE_BLOCKS,
    swap_in_blocks,
    swap_out_blocks,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def fuzz_seeds(n_fallback: int, max_seed: int = 10_000):
    """Property-test shim: @given(seed=...) under hypothesis, else a
    seeded parametrize sweep (deterministic CI without the dependency)."""
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=n_fallback, deadline=None)(
                given(seed=st.integers(0, max_seed))(fn))
        return deco
    return pytest.mark.parametrize("seed", range(n_fallback))


CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")

STAT_KEYS = ("steps", "tokens", "energy_j", "avg_imbalance", "time_s")


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


# ----------------------------------------------------------------------
# Allocator fuzz: refcount model checked against random op sequences
# ----------------------------------------------------------------------

class TestAllocatorFuzz:
    @fuzz_seeds(8)
    def test_refcounts_match_shadow_model(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 24))
        a = BlockAllocator(n)
        shadow: dict[int, int] = {}   # block -> refcount
        for _ in range(200):
            op = rng.integers(0, 3)
            if op == 0:               # alloc
                k = int(rng.integers(1, 4))
                if k > a.n_free:
                    with pytest.raises(MemoryError):
                        a.alloc(k)
                else:
                    for b in a.alloc(k):
                        assert b not in shadow
                        shadow[b] = 1
            elif op == 1 and shadow:  # add_ref
                b = int(rng.choice(list(shadow)))
                a.add_ref(b)
                shadow[b] += 1
            elif op == 2 and shadow:  # free
                b = int(rng.choice(list(shadow)))
                a.free([b])
                shadow[b] -= 1
                if shadow[b] == 0:
                    del shadow[b]
            assert a.n_free == a.n_blocks - len(shadow)
            for b, c in shadow.items():
                assert a.ref_count(b) == c
        # drain: every surviving reference released -> pool whole again
        for b, c in list(shadow.items()):
            a.free([b] * c)
        assert a.n_free == a.n_blocks
        assert (a._refs == 0).all()

    @fuzz_seeds(8)
    def test_three_state_partition_with_prefix_retention(self, seed):
        """Random alloc/register/pin/free sequences with a prefix index
        attached, against a shadow model of the persistent-evictor
        lifecycle: every block is exactly one of {free,
        cached-and-indexed, referenced}, reclaim evicts the index entry
        before the block is handed back out, and reviving a cached
        block never aliases a concurrently reclaimed one."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 24))
        a = BlockAllocator(n)
        idx = PrefixIndex()
        a.prefix = idx
        refs: dict[int, int] = {}       # referenced shadow
        cached: set[int] = set()        # cached shadow
        registered: set[int] = set()    # indexed shadow
        key_n = 0
        for _ in range(300):
            op = rng.integers(0, 4)
            if op == 0:               # alloc (reclaims LRU when dry)
                k = int(rng.integers(1, 4))
                if k > a.n_free + a.n_cached:
                    with pytest.raises(MemoryError):
                        a.alloc(k)
                else:
                    reclaim = max(k - a.n_free, 0)
                    r0 = a.blocks_reclaimed
                    for b in a.alloc(k):
                        # a handed-out block can never be one some
                        # concurrent revive holds a reference to
                        assert b not in refs
                        if b in cached:          # reclaimed
                            cached.discard(b)
                            registered.discard(b)
                        # reclaim evicted the entry before reuse
                        assert not idx.contains_block(b)
                        refs[b] = 1
                    assert a.blocks_reclaimed - r0 == reclaim
            elif op == 1 and refs:    # index a referenced block
                b = int(rng.choice(list(refs)))
                if b not in registered:
                    span = (key_n,)   # unique content per entry
                    idx.register(idx.chain(None, span), None, span, b)
                    key_n += 1
                    registered.add(b)
            elif op == 2 and (refs or cached):
                # add_ref: pin a referenced block / revive a cached one
                b = int(rng.choice(list(refs) + sorted(cached)))
                v0 = a.blocks_revived
                a.add_ref(b)
                if b in cached:
                    cached.discard(b)
                    refs[b] = 1
                    assert a.blocks_revived == v0 + 1
                else:
                    refs[b] += 1
            elif op == 3 and refs:    # free (indexed last-ref -> cached)
                b = int(rng.choice(list(refs)))
                a.free([b])
                refs[b] -= 1
                if refs[b] == 0:
                    del refs[b]
                    if b in registered:
                        cached.add(b)
            # exact three-state partition after every op
            assert a.n_free == a.n_blocks - len(refs) - len(cached)
            assert a.n_cached == len(cached)
            assert len(idx) == len(registered)
            for b in cached:
                assert a.ref_count(b) == 0 and a.is_live(b)
                assert idx.contains_block(b)
            for b, c in refs.items():
                assert a.ref_count(b) == c
        # drain: every reference released; indexed blocks persist cached
        for b, c in list(refs.items()):
            a.free([b] * c)
        assert (a._refs == 0).all()
        assert a.n_free + a.n_cached == a.n_blocks
        assert a.n_cached == len(registered)
        assert len(idx) == a.n_cached

    def test_reclaim_is_lru_ordered_and_touch_refreshes(self):
        """Cached blocks are reclaimed oldest-first; touch() moves a
        block to the MRU end so a recent hit is reclaimed last."""
        a = BlockAllocator(3)
        idx = PrefixIndex()
        a.prefix = idx
        blocks = a.alloc(3)
        for i, b in enumerate(blocks):
            idx.register(idx.chain(None, (i,)), None, (i,), b)
        for b in blocks:
            a.free([b])               # cache order = free order
        assert a.n_cached == 3
        a.touch(blocks[0])            # hit: oldest becomes MRU
        got = a.alloc(2)              # reclaims the two LRU blocks
        assert got == [blocks[1], blocks[2]]
        assert not idx.contains_block(blocks[1])
        assert not idx.contains_block(blocks[2])
        assert idx.contains_block(blocks[0])
        assert a.is_live(blocks[0])

    @fuzz_seeds(4)
    def test_double_free_never_corrupts_free_list(self, seed):
        rng = np.random.default_rng(seed)
        a = BlockAllocator(8)
        live = a.alloc(5)
        freed = live.pop()
        a.free([freed])
        for _ in range(10):
            with pytest.raises(ValueError, match="double free"):
                a.free([freed])
        assert a.n_free == 4
        a.free(live)
        assert a.n_free == 8


# ----------------------------------------------------------------------
# Swap staging: tiled copies restore bit-for-bit
# ----------------------------------------------------------------------

class TestSwapStaging:
    @pytest.mark.parametrize("n_blocks", [1, 7, SWAP_TILE_BLOCKS + 3])
    def test_swap_roundtrip_bit_exact(self, n_blocks):
        """swap_out + swap_in over scattered (and re-scattered) block ids
        is the identity on content, including across tile boundaries."""
        import jax.numpy as jnp

        rng = np.random.default_rng(0)
        pool = jnp.asarray(rng.normal(size=(2, 64, 4, 2, 8)), jnp.float32)
        out_ids = rng.choice(64, size=n_blocks, replace=False)
        host = swap_out_blocks(pool, out_ids, tile=4)
        assert host.shape[1] == n_blocks
        np.testing.assert_array_equal(host, np.asarray(pool)[:, out_ids])
        in_ids = rng.choice(64, size=n_blocks, replace=False)
        pool2 = swap_in_blocks(pool, in_ids, host, tile=4)
        np.testing.assert_array_equal(
            np.asarray(pool2)[:, in_ids], host)

    def test_empty_swap(self):
        import jax.numpy as jnp

        pool = jnp.zeros((1, 4, 2, 1, 4))
        assert swap_out_blocks(pool, []) is None
        assert swap_in_blocks(pool, [], None) is pool

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown preemption policy"):
            make_preemption_policy("round-robin")


# ----------------------------------------------------------------------
# Copy-on-write at the cache level (no model)
# ----------------------------------------------------------------------

class TestCopyOnWrite:
    def test_shared_partial_tail_copies_on_divergent_append(self):
        import jax.numpy as jnp

        cache = PagedKVCache.create(
            n_layers=1, n_blocks=16, block_size=8, n_kv_heads=1,
            head_dim=4, max_requests=4, max_blocks_per_req=4,
            dtype=jnp.float32)
        cache.prefix = PrefixIndex()
        cache.admit(0, 5)                       # A: one partial block
        (blk,) = cache.req_blocks[0]
        ((key, parent, span),) = cache.prefix.keys_for(
            [1, 2, 3, 4, 5], block_size=8)
        cache.prefix.register(key, parent, span, blk)
        cache.admit(1, 5, shared=(blk,))        # B shares A's tail block
        assert cache.allocator.ref_count(blk) == 2
        used_before = cache.used_blocks
        cache.append_token(1)                   # B's first divergent token
        new = cache.req_blocks[1][0]
        assert new != blk, "append into a shared block must COW"
        assert cache.allocator.ref_count(blk) == 1
        assert cache.allocator.ref_count(new) == 1
        assert cache.used_blocks == used_before + 1
        # A appends next: sole holder again, writes in place (no COW)
        cache.append_token(0)
        assert cache.req_blocks[0][0] == blk
        cache.release(0)
        cache.release(1)
        # persistent evictor (default): the indexed block survives its
        # last holder on the cached list; the COW copy (never indexed)
        # goes straight back to the free list
        assert cache.allocator.n_free == 15
        assert cache.allocator.n_cached == 1
        assert cache.allocator.is_live(blk)
        assert cache.allocator.ref_count(blk) == 0
        assert len(cache.prefix) == 1
        # reviving the cached block re-pins it for a new sharer
        cache.admit(2, 5, shared=(blk,))
        assert cache.allocator.ref_count(blk) == 1
        assert cache.allocator.n_cached == 0
        assert cache.allocator.blocks_revived == 1
        cache.release(2)

    def test_admission_scoped_evicts_with_last_holder(self):
        """evict='admission' pins the legacy lifetime: entry dies with
        the last resident holder's release."""
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=16, block_size=8, n_kv_heads=1,
            head_dim=4, max_requests=4, max_blocks_per_req=4,
            prefix_evict="admission")
        cache.prefix = PrefixIndex()
        cache.admit(0, 5)
        (blk,) = cache.req_blocks[0]
        ((key, parent, span),) = cache.prefix.keys_for(
            [1, 2, 3, 4, 5], block_size=8)
        cache.prefix.register(key, parent, span, blk)
        cache.release(0)
        assert cache.allocator.n_free == 16
        assert cache.allocator.n_cached == 0
        assert len(cache.prefix) == 0           # eviction followed frees

    def test_append_demand_counts_cow_and_crossings(self):
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=16, block_size=8, n_kv_heads=1,
            head_dim=4, max_requests=4, max_blocks_per_req=4)
        cache.admit(0, 8)                       # full block: next append
        slots = np.array([0])                   # crosses a boundary
        assert cache.append_demand(slots) == 1
        cache.admit(1, 5)
        (blk,) = cache.req_blocks[1]
        cache.admit(2, 5, shared=(blk,))        # shared tail: COW pending
        assert cache.append_demand(np.array([2])) == 1
        assert cache.append_demand(np.array([1])) == 1
        assert cache.append_demand(np.array([0, 1, 2])) == 3


# ----------------------------------------------------------------------
# Engine-level fuzz against the two oracles
# ----------------------------------------------------------------------

def _fuzz_requests(rng, n, vocab=128, shared_pool=None):
    reqs = []
    for i in range(n):
        if shared_pool is not None and rng.random() < 0.6:
            head = shared_pool[int(rng.integers(len(shared_pool)))]
            tail = rng.integers(1, vocab, size=int(rng.integers(1, 10)))
            tokens = np.concatenate([head, tail])
        else:
            tokens = rng.integers(1, vocab,
                                  size=int(rng.integers(1, 40)))
        reqs.append(ServeRequest(
            rid=i, tokens=tokens,
            max_new_tokens=int(rng.integers(1, 14)),
            eos_id=int(rng.integers(1, vocab)) if rng.random() < 0.2
            else -1))
    return reqs


def _clone(reqs):
    return [ServeRequest(rid=r.rid, tokens=r.tokens.copy(),
                         max_new_tokens=r.max_new_tokens, eos_id=r.eos_id)
            for r in reqs]


def _run(params, mesh, reqs, *, G, B, policy="jsq", max_seq_len=64,
         **ec_kw):
    eng = ServingEngine(
        CFG, params,
        EngineConfig(n_workers=G, slots_per_worker=B,
                     max_seq_len=max_seq_len, **ec_kw),
        make_policy(policy), mesh=mesh)
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_steps=20_000)
    return eng, stats


def _assert_drained(eng):
    """(c) three-state partition at drain: refcounts all zero, every
    block either free or cached-and-indexed, and the prefix index holds
    exactly the cached blocks (persistence: entries survive the drain,
    pinned one-to-one to LRU-cached blocks, never to recycled ones)."""
    alloc = eng.backend.kv.allocator
    assert (alloc._refs == 0).all()
    assert alloc.n_free + alloc.n_cached == alloc.n_blocks
    prefix = eng.backend.prefix
    if prefix is not None:
        assert len(prefix) == alloc.n_cached
        for b in alloc._cached:
            assert prefix.contains_block(b)
        rate = eng.stats()["prefix_hit_rate"]
        assert 0.0 <= rate <= 1.0
    else:
        assert alloc.n_cached == 0
        assert alloc.n_free == alloc.n_blocks


def _pool_for(eng, reqs, frac):
    """A pool at ``frac`` of the unconstrained peak, floored so a single
    request's lifetime demand (prompt + decode growth) always fits."""
    bs = eng.backend.block_size
    blk_bytes = eng.backend.pool_bytes() // eng.backend.n_blocks
    peak = -(-eng.kv_peak_bytes // blk_bytes)
    single = max(-(-(min(len(r.tokens), 64) + r.max_new_tokens) // bs)
                 for r in reqs)
    return max(int(peak * frac), single + 1, 2)


class TestEngineFuzz:
    @fuzz_seeds(4)
    def test_admission_growth_preempt_resume_sequences(self, setup, seed):
        params, mesh = setup
        rng = np.random.default_rng(seed)
        G = int(rng.integers(1, 3))
        B = int(rng.integers(2, 5))
        chunk = int(rng.choice([0, 8]))
        n = int(G * B * rng.uniform(1.5, 2.5))
        proto = _fuzz_requests(rng, n)

        # oracle: the contiguous slot layout (no pool, no preemption)
        ra = _clone(proto)
        _, sa = _run(params, mesh, ra, G=G, B=B, cache_backend="slot",
                     prefill_chunk=chunk)

        # (a) pool never exhausts -> armed preemption is a no-op
        rb = _clone(proto)
        eng_b, sb = _run(params, mesh, rb, G=G, B=B,
                         cache_backend="paged", prefill_chunk=chunk)
        assert eng_b.preemptions == 0
        for k in STAT_KEYS:
            assert sa[k] == sb[k], f"{k}: slot={sa[k]} paged={sb[k]}"
        for a, b in zip(ra, rb):
            assert a.generated == b.generated
        _assert_drained(eng_b)

        # (b) swap-preemption under a pool at ~half the peak demand:
        # bit-identical generations, full completion, zero recompute
        pool = _pool_for(eng_b, proto, rng.uniform(0.4, 0.7))
        rc = _clone(proto)
        eng_c, _ = _run(params, mesh, rc, G=G, B=B, cache_backend="paged",
                        prefill_chunk=chunk, paged_pool_blocks=pool,
                        preemption_mode="swap")
        assert all(r.done for r in rc)
        for a, c in zip(ra, rc):
            assert a.generated == c.generated, \
                f"request {a.rid} diverged under swap preemption"
        assert eng_c.tokens_recomputed == 0
        _assert_drained(eng_c)

        # recompute mode: completion + drain (token parity is not
        # promised — rebuilt prefill is not bit-pinned to decode)
        rd = _clone(proto)
        eng_d, _ = _run(params, mesh, rd, G=G, B=B, cache_backend="paged",
                        prefill_chunk=chunk, paged_pool_blocks=pool,
                        preemption_mode="recompute")
        assert all(r.done for r in rd)
        assert eng_d.tokens_swapped == 0
        _assert_drained(eng_d)

    @fuzz_seeds(3)
    def test_prefix_cache_never_changes_generations(self, setup, seed):
        """(d) shared-prefix workloads: hits occur, generations match the
        uncached slot oracle bit-for-bit, refcounts drain."""
        params, mesh = setup
        rng = np.random.default_rng(seed)
        G, B = 2, 4
        shared_pool = [rng.integers(1, 128, size=int(rng.integers(8, 30)))
                       for _ in range(2)]
        proto = _fuzz_requests(rng, 14, shared_pool=shared_pool)

        ra = _clone(proto)
        _, _ = _run(params, mesh, ra, G=G, B=B, cache_backend="slot")
        rb = _clone(proto)
        eng_b, sb = _run(params, mesh, rb, G=G, B=B,
                         cache_backend="paged", prefix_cache=True)
        assert sb["prefix_hits"] > 0, "shared prefixes never hit"
        for a, b in zip(ra, rb):
            assert a.generated == b.generated, \
                f"request {a.rid}: prefix-cache hit changed its output"
        _assert_drained(eng_b)

        # chunked admissions consult the index too (full-block hits are
        # pinned and the chunk job starts past them): generations still
        # bit-identical, refcounts still drain
        rc = _clone(proto)
        eng_c, _ = _run(params, mesh, rc, G=G, B=B, cache_backend="paged",
                        prefix_cache=True, prefill_chunk=8)
        for a, c in zip(ra, rc):
            assert a.generated == c.generated, \
                f"request {a.rid}: chunked prefix hit changed its output"
        _assert_drained(eng_c)

    @fuzz_seeds(2)
    def test_prefix_cache_under_pressure(self, setup, seed):
        """Sharing + swap preemption together: still bit-exact, still
        drains — COW, swap staging, and eviction compose."""
        params, mesh = setup
        rng = np.random.default_rng(seed)
        G, B = 1, 4
        shared_pool = [rng.integers(1, 128, size=20)]
        proto = _fuzz_requests(rng, 10, shared_pool=shared_pool)
        ra = _clone(proto)
        _, _ = _run(params, mesh, ra, G=G, B=B, cache_backend="slot")
        probe = _clone(proto)
        eng_p, _ = _run(params, mesh, probe, G=G, B=B,
                        cache_backend="paged")
        pool = _pool_for(eng_p, proto, 0.5)
        rb = _clone(proto)
        eng_b, _ = _run(params, mesh, rb, G=G, B=B, cache_backend="paged",
                        prefix_cache=True, paged_pool_blocks=pool,
                        preemption_mode="swap")
        assert all(r.done for r in rb)
        for a, b in zip(ra, rb):
            assert a.generated == b.generated
        _assert_drained(eng_b)


class TestChunkedPrefix:
    """Chunked-prefill admissions consulting the PrefixIndex (ROADMAP
    open item): full-block hits share the KV copy-free AND skip
    recompute of the hit prefix — a TTFT win, gens bit-identical."""

    def _shared_reqs(self, n=6, shared_len=40, sfx=4, seed=5):
        """Shared-system-prefix stream with one long-running holder:
        the index is admission-scoped (eager eviction when the last
        holder frees), so rid 0 decodes long enough that later waves
        admit while its registered prompt blocks are still resident."""
        rng = np.random.default_rng(seed)
        system = rng.integers(1, 128, size=shared_len)
        return [ServeRequest(
            rid=i,
            tokens=np.concatenate(
                [system, rng.integers(1, 128, size=sfx)]),
            max_new_tokens=24 if i == 0 else 4) for i in range(n)]

    def _run_counting(self, params, mesh, reqs, **ec_kw):
        """Like _run but also sums per-step chunk-prefill tokens."""
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         cache_backend="paged", prefill_chunk=8, **ec_kw),
            make_policy("fcfs"), mesh=mesh)
        for r in reqs:
            eng.submit(r)
        prefill_tokens = 0
        while eng.wait or eng.table.active.any():
            prefill_tokens += eng.step()["prefill_tokens"]
        return eng, prefill_tokens

    def test_hits_skip_recompute(self, setup):
        params, mesh = setup
        proto = self._shared_reqs()
        oracle = _clone(proto)
        _run(params, mesh, oracle, G=1, B=2, cache_backend="slot")

        off = _clone(proto)
        _, toks_off = self._run_counting(params, mesh, off)
        on = _clone(proto)
        eng, toks_on = self._run_counting(params, mesh, on,
                                          prefix_cache=True)
        stats = eng.stats()
        assert stats["prefix_hits"] > 0, "chunked admissions never hit"
        # the TTFT win: hit prefixes are not re-prefilled, so the total
        # chunk-prefill volume strictly drops (2 full blocks per hit)
        assert toks_on < toks_off, (toks_on, toks_off)
        for a, b, c in zip(oracle, off, on):
            assert a.generated == b.generated == c.generated
        _assert_drained(eng)

    def test_recompute_accounting_excludes_seeded_tokens(self, setup):
        """Prefix-pinned tokens were never computed, so recompute-
        preempting a seeded mid-prefill job must not charge them to
        ``tokens_recomputed``."""
        params, mesh = setup
        reqs = self._shared_reqs(n=2)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         cache_backend="paged", prefill_chunk=8,
                         prefix_cache=True, preemption_mode="recompute"),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(reqs[0])
        eng.step()                       # admit the holder
        while eng.scheduler.n_prefilling:
            eng.step()                   # finish + register its prompt
        eng.submit(reqs[1])
        eng.step()                       # admit: seeds 2 full blocks
        slot = reqs[1].slot
        job = eng.scheduler.job(slot)
        assert job is not None and job.seeded == 32
        assert job.done > job.seeded     # one chunk already ran
        expected = job.done - job.seeded
        before = eng.tokens_recomputed
        eng._preempt_slot(slot)
        assert eng.tokens_recomputed - before == expected
        stats = eng.run()                # requeued victim still finishes
        assert all(r.done and not r.failed for r in reqs)
        assert stats["preemptions"] == 1

    def test_full_cover_hit_leaves_final_token_computed(self, setup):
        """A prompt whose *every* block is indexed (exact multiple of
        the block size, seen before) must still compute its final
        position — the shared run is capped so the finishing chunk
        produces the logits the first token is sampled from."""
        params, mesh = setup
        rng = np.random.default_rng(9)
        prompt = rng.integers(1, 128, size=32)       # exactly 2 blocks
        proto = [ServeRequest(rid=i, tokens=prompt.copy(),
                              max_new_tokens=24 if i == 0 else 4)
                 for i in range(4)]
        oracle = _clone(proto)
        _run(params, mesh, oracle, G=1, B=2, cache_backend="slot")
        on = _clone(proto)
        eng, _ = self._run_counting(params, mesh, on, prefix_cache=True)
        assert eng.stats()["prefix_hits"] > 0
        for a, c in zip(oracle, on):
            assert a.generated == c.generated
        _assert_drained(eng)

    def test_preempt_restart_counts_admission_once(self, setup):
        """A recompute-preempted chunked job re-seeds its prefix on
        re-admission; the hit-rate counters must count the admission's
        lookup exactly once, not once per restart."""
        params, mesh = setup
        reqs = self._shared_reqs(n=2)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         cache_backend="paged", prefill_chunk=8,
                         prefix_cache=True, preemption_mode="recompute"),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(reqs[0])
        eng.step()
        while eng.scheduler.n_prefilling:
            eng.step()
        eng.submit(reqs[1])
        eng.step()                       # admit: seeds + counts once
        q1, h1 = eng.backend.prefix.queries, eng.backend.prefix.hits
        assert q1 > 0 and h1 > 0
        slot = reqs[1].slot
        assert eng.scheduler.job(slot) is not None
        eng._preempt_slot(slot)          # restart -> re-seed (uncounted)
        eng.run()
        assert all(r.done and not r.failed for r in reqs)
        assert eng.backend.prefix.queries == q1
        assert eng.backend.prefix.hits == h1
        rate = eng.stats()["prefix_hit_rate"]
        assert 0.0 <= rate <= 1.0

    def test_hits_survive_last_holder(self, setup):
        """The lifetime bug: with every holder of a shared prefix
        finished, a later identical-prefix arrival must still hit
        (persistent LRU evictor) — admission-scoped measures zero."""
        params, mesh = setup
        proto = self._shared_reqs(n=4, seed=11)
        for r in proto:
            r.max_new_tokens = 4         # no long-running holder
        oracle = _clone(proto)
        _run(params, mesh, oracle, G=1, B=2, cache_backend="slot")
        stats, engines = {}, {}
        for mode in ("admission", "lru"):
            reqs = _clone(proto)
            eng = ServingEngine(
                CFG, params,
                EngineConfig(n_workers=1, slots_per_worker=2,
                             max_seq_len=64, cache_backend="paged",
                             prefill_chunk=8, prefix_cache=True,
                             prefix_evict=mode),
                make_policy("fcfs"), mesh=mesh)
            # staggered turns: each submitted after the previous drained
            for r in reqs:
                eng.submit(r)
                while eng.wait or eng.table.active.any():
                    eng.step()
            stats[mode], engines[mode] = eng.stats(), eng
            for a, b in zip(oracle, reqs):
                assert a.generated == b.generated
        assert stats["admission"]["prefix_hits"] == 0
        assert stats["lru"]["prefix_hits"] > 0
        assert stats["lru"]["prefix_revived"] > 0
        assert stats["lru"]["prefix_hit_rate"] > \
            stats["admission"]["prefix_hit_rate"]
        _assert_drained(engines["lru"])


class TestPressureDeterministic:
    """Non-fuzz regression anchors for the pressure machinery."""

    def test_pressure_actually_preempts(self, setup):
        """A long-decode workload through a half-sized pool must exercise
        the preemption path (not just admission gating)."""
        params, mesh = setup
        rng = np.random.default_rng(3)
        proto = [ServeRequest(rid=i,
                              tokens=rng.integers(1, 128, size=20),
                              max_new_tokens=30) for i in range(8)]
        probe = _clone(proto)
        eng_p, _ = _run(params, mesh, probe, G=1, B=4,
                        cache_backend="paged")
        pool = _pool_for(eng_p, proto, 0.5)
        rb = _clone(proto)
        eng, s = _run(params, mesh, rb, G=1, B=4, cache_backend="paged",
                      paged_pool_blocks=pool, preemption_mode="swap")
        assert eng.preemptions > 0
        assert s["tokens_swapped"] > 0
        assert all(len(r.generated) == 30 for r in rb)
        for a, b in zip(probe, rb):
            assert a.generated == b.generated
        _assert_drained(eng)

    @pytest.mark.parametrize("chunk", [0, 8])
    def test_recompute_resume_restores_overflow_length(self, setup, chunk):
        """A victim that decoded past max_seq_len on frozen KV keeps its
        RoPE position counter through a recompute rebuild — the
        max_seq_len-truncated token sequence must not reset lengths to
        the cap."""
        from repro.serving import PreemptedState

        params, mesh = setup
        r = ServeRequest(rid=0, tokens=np.arange(1, 30).astype(np.int64),
                         max_new_tokens=60)
        r.generated = [5] * 45
        r.preempted = PreemptedState(mode="recompute", length=70,
                                     next_token=5)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         cache_backend="paged", paged_block_size=16,
                         prefill_chunk=chunk, prefill_budget=chunk * 16),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(r)
        eng.step()
        while eng.scheduler.n_prefilling:   # chunked rebuild spans steps
            eng.step()
        # rebuilt prefill covers only 64 tokens; the preempted length
        # (70) plus the finish step's decode append must be restored
        assert int(eng.backend.kv.lengths[r.slot]) == 71

    def test_growth_past_whole_pool_fails_that_request_only(self, setup):
        """A request whose decode growth exceeds the entire pool cannot
        be saved by preemption — it fails *alone* (per-request
        status/error channel) and the rest of the stream keeps serving;
        the seed raised MemoryError here and killed the engine step."""
        params, mesh = setup
        doomed = ServeRequest(rid=0, tokens=np.arange(1, 61),  # 4 blocks: fit
                              max_new_tokens=20)               # growth: no
        ok = ServeRequest(rid=1, tokens=np.arange(1, 9),
                          max_new_tokens=4)
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=256,
                         cache_backend="paged", paged_block_size=16,
                         paged_pool_blocks=4, preemption_mode="swap"),
            make_policy("fcfs"), mesh=mesh)
        eng.submit(doomed)
        eng.submit(ok)
        stats = eng.run(max_steps=20_000)    # must NOT raise
        assert doomed.status == "failed" and doomed.failed
        assert "exceeds the entire pool" in doomed.error
        assert doomed.done                   # terminal: t_finish is set
        assert eng.preemptions <= 1          # no thrash loop before failing
        assert stats["requests_failed"] == 1
        # the doomed request's blocks were released and the small request
        # completed untouched
        assert ok.status == "done" and ok.error is None
        assert len(ok.generated) == 4
        assert eng.backend.free_blocks == eng.backend.n_blocks

    def test_oversized_prompt_rejected_at_submit(self, setup):
        """Regression: a prompt that can never fit the pool used to
        surface as MemoryError mid-prefill; now submit() rejects it."""
        params, mesh = setup
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                         cache_backend="paged", paged_block_size=16,
                         paged_pool_blocks=2),
            make_policy("fcfs"), mesh=mesh)
        with pytest.raises(ValueError, match="never be admitted"):
            eng.submit(ServeRequest(rid=0, tokens=np.arange(1, 60),
                                    max_new_tokens=2))
        # a prompt that fits is accepted
        eng.submit(ServeRequest(rid=1, tokens=np.arange(1, 20),
                                max_new_tokens=2))
        assert len(eng.wait) == 1

    def test_preemption_mode_validated(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="preemption_mode"):
            ServingEngine(CFG, params,
                          EngineConfig(preemption_mode="drop"),
                          make_policy("fcfs"), mesh=mesh)

    def test_prefix_cache_requires_paged(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="prefix_cache"):
            ServingEngine(CFG, params,
                          EngineConfig(prefix_cache=True),
                          make_policy("fcfs"), mesh=mesh)


class TestDeviceLoopPool:
    def test_pooled_loop_completes_with_preemptions(self):
        from repro.serving import init_loop_state, make_device_serving_loop

        rng = np.random.default_rng(1)
        G, B, W = 4, 4, 64
        sizes = rng.uniform(5, 50, 40)
        rem = rng.integers(2, 10, 40)
        run = make_device_serving_loop(G, B, W, kv_pool=150.0)
        end = run(init_loop_state(G, B, sizes, rem, W), 400)
        assert int(end.tot_preempts) > 0
        assert int(end.slot_active.sum()) == 0
        assert int((end.wait_prefill > 0).sum()) == 0

    def test_no_pool_traces_to_original_behavior(self):
        from repro.serving import init_loop_state, make_device_serving_loop

        rng = np.random.default_rng(2)
        G, B, W = 3, 2, 32
        run = make_device_serving_loop(G, B, W)
        end = run(init_loop_state(G, B, rng.uniform(1, 9, 30),
                                  rng.integers(1, 6, 30), W), 80)
        assert int(end.tot_preempts) == 0
        assert int(end.slot_active.sum()) == 0
