"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an optional dev dependency (not part of the runtime
environment); the whole module is skipped when it is absent so the tier-1
suite still runs to completion.
"""
import warnings

warnings.filterwarnings("ignore")

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    A100_POWER,
    ArrivalInstance,
    PowerModel,
    Request,
    SimConfig,
    energy_decomposition,
    energy_sandwich,
    io_solver,
    make_policy,
    simulate,
    step_imbalance,
)
from repro.core.workload import constant_drift, fractional_drift, unit_drift

# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

sizes = st.integers(min_value=1, max_value=60)


@st.composite
def io_instances(draw):
    G = draw(st.integers(2, 5))
    n = draw(st.integers(1, 12))
    W = draw(st.integers(1, 4))
    base = np.array(draw(st.lists(
        st.lists(st.floats(0, 100), min_size=W, max_size=W),
        min_size=G, max_size=G)))
    caps = np.array(draw(st.lists(st.integers(0, 4), min_size=G,
                                  max_size=G)))
    cands = np.array(draw(st.lists(
        st.lists(st.floats(0, 50), min_size=W, max_size=W),
        min_size=n, max_size=n)))
    return base, caps, cands


@st.composite
def arrival_instances(draw):
    n = draw(st.integers(2, 40))
    drift = draw(st.sampled_from([unit_drift(), constant_drift(),
                                  fractional_drift(0.3)]))
    reqs = [
        Request(rid=i,
                arrival_step=draw(st.integers(0, 10)),
                prefill=float(draw(st.integers(1, 100))),
                decode_len=draw(st.integers(1, 20)))
        for i in range(n)
    ]
    return ArrivalInstance(requests=reqs, drift=drift)


# ---------------------------------------------------------------------------
# IO solver invariants
# ---------------------------------------------------------------------------

class TestIOSolverProperties:
    @settings(max_examples=60, deadline=None)
    @given(io_instances())
    def test_feasibility(self, inst):
        base, caps, cands = inst
        a = io_solver.solve_io(base, caps, cands)
        G, n = base.shape[0], cands.shape[0]
        assert np.all((a >= -1) & (a < G))
        used = np.bincount(a[a >= 0], minlength=G)
        assert np.all(used <= caps)
        assert (a >= 0).sum() == min(n, caps.sum())

    @settings(max_examples=40, deadline=None)
    @given(io_instances())
    def test_local_search_monotone(self, inst):
        base, caps, cands = inst
        a0 = io_solver.solve_greedy(base, caps, cands)
        a1 = io_solver.local_search(base, caps, cands, a0)
        assert (io_solver.objective(base, cands, a1)
                <= io_solver.objective(base, cands, a0) + 1e-6)

    @settings(max_examples=40, deadline=None)
    @given(io_instances())
    def test_objective_lower_bound(self, inst):
        """J >= sum_h (G*mean - sum) = 0-centered bound: J is always >= 0
        and >= the imbalance of a perfectly balanced assignment."""
        base, caps, cands = inst
        a = io_solver.solve_io(base, caps, cands)
        assert io_solver.objective(base, cands, a) >= -1e-9


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------

class TestSimulatorProperties:
    @settings(max_examples=15, deadline=None)
    @given(arrival_instances(), st.sampled_from(["fcfs", "jsq", "bfio_h0",
                                                 "bfio_h4"]))
    def test_completion_and_stickiness(self, inst, policy):
        m = simulate(inst, make_policy(policy), SimConfig(G=3, B=4))
        assert m.completed == len(inst)
        for r in inst.requests:
            assert 0 <= r.worker < 3
            # sticky: processed for exactly decode_len consecutive steps
            assert r.finish_step - r.assign_step == r.decode_len - 1

    @settings(max_examples=10, deadline=None)
    @given(arrival_instances())
    def test_work_conservation_across_policies(self, inst):
        """Eq. (11): total processed work is policy-independent."""
        from repro.core import SimTrace
        totals = []
        for policy in ["fcfs", "bfio_h0"]:
            tr = SimTrace()
            cfg = SimConfig(G=3, B=4)
            simulate(inst, make_policy(policy), cfg, trace=tr)
            totals.append(float(np.sum(np.asarray(tr.mean_load) * cfg.G)))
        assert totals[0] == pytest.approx(totals[1], rel=1e-9)
        assert totals[0] == pytest.approx(inst.total_work(), rel=1e-9)

    @settings(max_examples=10, deadline=None)
    @given(arrival_instances())
    def test_makespan_at_least_critical_path(self, inst):
        """No policy can finish faster than the longest single request."""
        cfg = SimConfig(G=3, B=4, step_overhead=1.0, t_token=0.0)
        m = simulate(inst, make_policy("bfio_h0"), cfg)
        longest = max(r.decode_len for r in inst.requests)
        assert m.steps >= longest


# ---------------------------------------------------------------------------
# energy model invariants
# ---------------------------------------------------------------------------

class TestEnergyProperties:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.lists(st.floats(0.01, 100), min_size=4, max_size=4),
                    min_size=1, max_size=30),
           st.floats(0.1, 0.9))
    def test_identity_and_sandwich(self, loads, gamma):
        pm = PowerModel(p_idle=100, p_max=400, gamma=gamma)
        loads = [np.asarray(l) for l in loads]
        d = energy_decomposition(loads, kappa_att=1e-3, pm=pm)
        assert d["energy"] == pytest.approx(d["identity_rhs"], rel=1e-9)
        lo, hi = energy_sandwich(d["W"], d["ImbTot"], 1e-3, pm)
        assert lo - 1e-6 <= d["energy"] <= hi + 1e-6

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.floats(0.0, 1000.0), min_size=2, max_size=16))
    def test_imbalance_nonnegative_and_zero_iff_balanced(self, loads):
        loads = np.asarray(loads)
        imb = step_imbalance(loads)
        assert imb >= -1e-9
        if np.allclose(loads, loads[0]):
            assert imb == pytest.approx(0.0, abs=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(st.floats(0.0, 1.0), st.floats(0.05, 0.95))
    def test_power_between_idle_and_max(self, u, gamma):
        pm = PowerModel(gamma=gamma)
        p = float(pm.power(u))
        assert pm.p_idle - 1e-9 <= p <= pm.p_max + 1e-9


# ---------------------------------------------------------------------------
# balancer_jax consistency with the numpy solver
# ---------------------------------------------------------------------------

class TestJaxBalancer:
    @settings(max_examples=15, deadline=None)
    @given(io_instances())
    def test_jax_matches_numpy_quality(self, inst):
        import jax.numpy as jnp
        from repro.core.balancer_jax import bfio_assign
        base, caps, cands = inst
        n = cands.shape[0]
        a_np = io_solver.solve_io(base, caps, cands)
        a_jx = np.asarray(bfio_assign(
            jnp.asarray(base), jnp.asarray(caps, jnp.int32),
            jnp.asarray(cands), jnp.ones(n, bool),
            jnp.int32(min(n, int(caps.sum())))))
        # feasibility
        G = base.shape[0]
        used = np.bincount(a_jx[a_jx >= 0], minlength=G)
        assert np.all(used <= caps)
        assert (a_jx >= 0).sum() == min(n, int(caps.sum()))
        # quality within the exchange-argument scale of the numpy solver
        v_np = io_solver.objective(base, cands, a_np)
        v_jx = io_solver.objective(base, cands, a_jx)
        W = base.shape[1]
        slack = G * W * (cands.max() if cands.size else 0.0) + 1e-6
        assert v_jx <= v_np + slack
