"""Training substrate tests: optimizer math, checkpointing round-trip, and
end-to-end loss decrease on a tiny model."""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data import token_batches
from repro.models import init_params, loss_fn, split_params
from repro.training import (
    AdamWConfig,
    adamw_update,
    init_opt_state,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import cosine_schedule, global_norm
from repro.training.train_loop import make_train_step, train

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  dtype="float32")


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
        params = {"w": jnp.array([3.0, -2.0])}
        state = init_opt_state(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params)
        huge = {"w": jnp.full(4, 1e9)}
        p2, s2 = adamw_update(cfg, params, huge, state)
        # clipped grad -> m bounded by (1-b1) * clip_norm
        assert float(jnp.abs(s2.m["w"]).max()) <= 0.1 + 1e-6

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        lr0 = float(cosine_schedule(cfg, jnp.asarray(0)))
        lr_w = float(cosine_schedule(cfg, jnp.asarray(10)))
        lr_end = float(cosine_schedule(cfg, jnp.asarray(100)))
        assert lr0 == 0.0
        assert lr_w == pytest.approx(1.0)
        assert lr_end == pytest.approx(0.1, rel=1e-3)

    def test_weight_decay_pulls_to_zero(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0)
        params = {"w": jnp.array([5.0])}
        state = init_opt_state(params)
        zero = {"w": jnp.zeros(1)}
        for _ in range(50):
            params, state = adamw_update(cfg, params, zero, state)
        assert float(params["w"][0]) < 5.0

    def test_global_norm(self):
        assert float(global_norm({"a": jnp.array([3.0]),
                                  "b": jnp.array([4.0])})) == pytest.approx(5.0)


class TestGradAccum:
    def test_accum_matches_full_batch(self):
        """grad_accum=2 must give the same update as the full batch."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
        batch = next(token_batches(vocab_size=64, batch=4, seq_len=16,
                                   n_batches=1, seed=0))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        ocfg = AdamWConfig(warmup_steps=0)
        s1 = make_train_step(CFG, ocfg, mesh=mesh, grad_accum=1,
                             compute_dtype="float32")
        s2 = make_train_step(CFG, ocfg, mesh=mesh, grad_accum=2,
                             compute_dtype="float32")
        opt = init_opt_state(params)
        l1, p1, _ = s1(params, opt, batch)
        l2, p2, _ = s2(params, opt, batch)
        assert float(l1) == pytest.approx(float(l2), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.int32)}}
        save_checkpoint(str(tmp_path), 7, tree)
        like = jax.tree.map(jnp.zeros_like, tree)
        loaded, step = load_checkpoint(str(tmp_path), like)
        assert step == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_latest_of_many(self, tmp_path):
        tree = {"w": jnp.zeros(2)}
        for s in (1, 5, 3):
            save_checkpoint(str(tmp_path), s, tree)
        _, step = load_checkpoint(str(tmp_path), tree)
        assert step == 5

    def test_shape_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), {"w": jnp.zeros(3)})


class TestEndToEnd:
    def test_loss_decreases(self):
        """~60 steps on a memorizable stream: loss must drop clearly."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params, _ = split_params(init_params(CFG, jax.random.PRNGKey(1)))
        batches = list(token_batches(vocab_size=64, batch=8, seq_len=32,
                                     n_batches=8, seed=1)) * 8
        params, losses = train(
            CFG, params=params, batches=batches,
            opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=64),
            mesh=mesh, log_every=0)
        assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
