"""Observability subsystem tests (repro.obs).

The anchors the ISSUE demands:

* the straggler ledger's arithmetic is *bit-exact by construction*:
  every reconciled split left-folds to its step total, and a fleet
  run's ledger total equals ``stats["idle_j"]`` to the last bit;
* the span recorder's trace round-trips through the validating reader,
  which rejects malformed documents instead of mis-reading them;
* fleet-track request spans carry the same end-to-end latency the
  telemetry computed (same subtraction, bit-equal);
* the disabled (null) recorder buffers nothing and leaves runs
  bit-identical — observation is free when off;
* the prefix-affinity probe and the engine's admission path share one
  block-hash chain per (request, block size) — each unique prompt is
  hashed exactly once, however many routing rounds it waits through.
"""
import collections
import json
import os
import warnings

warnings.filterwarnings("ignore")

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.fleet import (
    AsyncFleetServer,
    FleetServer,
    FleetTelemetry,
    SLOSpec,
    TargetUtilizationAutoscaler,
)
from repro.models import init_params, split_params
from repro.obs import (
    IDLE_CAUSES,
    NULL_RECORDER,
    SpanRecorder,
    StragglerLedger,
    attribute_step_idle,
    fold_sum,
    read_trace,
    reconcile_split,
    to_chrome_trace,
    write_trace,
)
from repro.obs.ledger import CAUSE_INDEX, N_CAUSES
from repro.serving import EngineConfig, ServeRequest

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")
TIMING = dict(step_overhead=1e-3, t_token=2e-4)


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


def _requests(seed=7, n=12, unique_head=False):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        toks = rng.integers(1, 128, size=int(rng.integers(4, 24)))
        if unique_head:
            toks[0] = i      # distinct first token -> distinct prompts
        reqs.append(ServeRequest(
            rid=i, tokens=toks,
            max_new_tokens=int(min(3 + rng.geometric(0.2), 16))))
    return reqs


# ----------------------------------------------------------------------
# Ledger arithmetic (unit level)
# ----------------------------------------------------------------------

class TestLedgerArithmetic:
    def test_fold_sum_is_sequential_accumulation(self):
        xs = [0.1, 0.2, 0.3, 1e16, -1e16, 0.4]
        total = 0.0
        for x in xs:
            total += x
        assert fold_sum(xs) == total
        # and it genuinely differs from pairwise/compensated summation
        # on adversarial inputs (the reason the helper exists)
        assert fold_sum(xs) != 1.0

    def test_reconcile_split_exact_on_adversarial_floats(self):
        # magnitudes spanning 16 decades: per-cause sums and the
        # sequential total round differently, so the residual is real
        rng = np.random.default_rng(0)
        for _ in range(500):
            slack = rng.uniform(0.0, 1.0, size=8) * 10.0 ** \
                rng.integers(-8, 8, size=8)
            total = 0.0
            for x in slack:                # the fleet's += order
                total += float(x)
            split = np.zeros(N_CAUSES)
            for i, x in enumerate(slack):
                split[i % N_CAUSES] += float(x)
            out = reconcile_split(total, split)
            assert fold_sum(out) == total
            # the fix-up only ever moves one entry
            assert (out == split).sum() >= N_CAUSES - 1

    def test_reconcile_split_zero_and_single_entry(self):
        out = reconcile_split(0.0, np.zeros(N_CAUSES))
        assert fold_sum(out) == 0.0
        one = np.zeros(N_CAUSES)
        one[2] = 3.5
        assert fold_sum(reconcile_split(3.5, one)) == 3.5

    def test_reconcile_split_raises_when_impossible(self):
        with pytest.raises(ArithmeticError, match="failed to reconcile"):
            reconcile_split(float("nan"), np.ones(N_CAUSES))

    def test_attribute_step_idle_masked_sums_fold_to_total(self):
        rng = np.random.default_rng(3)
        slack = rng.uniform(0.0, 2.0, size=16)
        causes = rng.integers(0, N_CAUSES, size=16)
        total = 0.0
        for x in slack:
            total += float(x)
        split = attribute_step_idle(total, slack, causes)
        assert split.shape == (N_CAUSES,)
        assert fold_sum(split) == total
        # causes with no replica get exactly zero
        for c in range(N_CAUSES):
            if not (causes == c).any():
                assert split[c] == 0.0

    def test_ledger_charge_matches_sequential_total(self):
        rng = np.random.default_rng(5)
        led = StragglerLedger()
        ref = 0.0
        for k in range(40):
            slack = rng.uniform(0.0, 1.0, size=4)
            causes = rng.integers(0, N_CAUSES, size=4)
            idle = 0.0
            for x in slack:
                idle += float(x)
            ref += idle                    # FleetServer.idle_j order
            led.charge(idle, attribute_step_idle(idle, slack, causes),
                       gating=k % 3 if k % 4 else -1)
        assert led.total_idle_j == ref
        rep = led.report()
        assert rep["total_idle_j"] == ref
        assert rep["charges"] == 40
        assert rep["trough_steps"] == 10
        assert sum(rep["gating_steps"].values()) == 30
        assert set(rep["by_cause"]) == set(IDLE_CAUSES)
        # the report is JSON-native
        assert json.loads(json.dumps(rep)) == rep

    def test_charge_one_and_format(self):
        led = StragglerLedger()
        led.charge_one(2.0, CAUSE_INDEX["warmup"])
        led.charge_one(1.0, CAUSE_INDEX["decode_tail"])
        assert led.total_idle_j == 3.0
        assert led.report()["by_cause"]["warmup"] == 2.0
        txt = led.format()
        assert "warmup" in txt and "decode_tail" in txt
        assert "3.000 J" in txt


# ----------------------------------------------------------------------
# Recorder + trace export / validating reader
# ----------------------------------------------------------------------

def _record_lifecycle(rec):
    rec.point(-1, 0, "queued", 0.00, n_prompt=5)
    rec.point(-1, 0, "routed", 0.01, replica=1)
    rec.point(1, 0, "admitted", 0.01, worker=0, slot=0)
    rec.point(1, 0, "prefill-chunk", 0.02, tokens=5)
    rec.point(1, 0, "decode", 0.03)
    rec.point(1, 0, "completed", 0.10, n_generated=4)
    rec.point(-1, 0, "completed", 0.12, replica=1)
    rec.point(-1, 1, "queued", 0.05)
    rec.point(-1, 1, "failed", 0.06)


class TestTrace:
    def test_recorder_buffers_and_clears(self):
        rec = SpanRecorder()
        assert rec.enabled and rec.n_events == 0
        _record_lifecycle(rec)
        assert rec.n_events == 9
        rec.clear()
        assert rec.n_events == 0

    def test_null_recorder_is_a_noop(self):
        NULL_RECORDER.point(-1, 0, "queued", 0.0, n_prompt=3)
        NULL_RECORDER.clear()
        assert NULL_RECORDER.enabled is False
        assert NULL_RECORDER.n_events == 0
        assert NULL_RECORDER.events == ()

    def test_roundtrip_through_validating_reader(self, tmp_path):
        rec = SpanRecorder()
        _record_lifecycle(rec)
        path = os.path.join(tmp_path, "run.trace")
        doc = write_trace(rec, path)
        # every recorded point appears as an instant event, plus the
        # derived spans and process-name metadata rows
        seen = read_trace(path)
        assert seen["n_points"] == rec.n_events
        assert seen["n_events"] == len(doc["traceEvents"])
        # fleet-track request spans: rid 0 done, rid 1 failed
        assert set(seen["requests"]) == {0, 1}
        r0 = seen["requests"][0]
        assert r0["status"] == "completed"
        assert r0["e2e_s"] == 0.12 - 0.00     # exporter's subtraction
        assert seen["requests"][1]["status"] == "failed"

    def test_exporter_derives_per_track_spans(self):
        rec = SpanRecorder()
        _record_lifecycle(rec)
        doc = to_chrome_trace(rec)
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        # fleet request spans (pid 0) for rids 0+1; the replica track
        # opens with "admitted" (not "queued") so it contributes only
        # the decode span
        kinds = {(e["pid"], e["name"]) for e in spans}
        assert (0, "request") in kinds
        assert (2, "decode-span") in kinds
        assert (2, "request") not in kinds
        names = {e["args"]["name"] for e in doc["traceEvents"]
                 if e["ph"] == "M"}
        assert names == {"fleet", "replica 1"}

    def _write(self, tmp_path, events):
        path = os.path.join(tmp_path, "bad.trace")
        with open(path, "w") as f:
            json.dump({"traceEvents": events}, f)
        return path

    def test_reader_rejects_malformed_documents(self, tmp_path):
        ok = {"name": "queued", "ph": "i", "s": "t", "ts": 1.0,
              "pid": 0, "tid": 0}
        span = {"name": "request", "ph": "X", "ts": 0.0, "dur": 1e6,
                "pid": 0, "tid": 0, "args": {"e2e_s": 1.0,
                                             "status": "completed"}}
        cases = [
            ("no traceEvents", {"foo": []}),
            ("unknown span event", [dict(ok, name="frobbed")]),
            ("bad ts", [dict(ok, ts=-5.0)]),
            ("unknown phase", [dict(ok, ph="B")]),
            ("bad dur", [dict(span, dur=None)]),
            ("dur/e2e_s mismatch",
             [dict(span, args={"e2e_s": 2.0, "status": "completed"})]),
            ("request span without e2e_s", [dict(span, args={})]),
            ("duplicate request span", [span, dict(span)]),
        ]
        for match, events in cases:
            if isinstance(events, dict):
                path = os.path.join(tmp_path, "bad.trace")
                with open(path, "w") as f:
                    json.dump(events, f)
            else:
                path = self._write(tmp_path, events)
            with pytest.raises(ValueError, match=match):
                read_trace(path)


# ----------------------------------------------------------------------
# Fleet integration: the exactness gates on real runs
# ----------------------------------------------------------------------

def _run_fleet(setup, *, async_fleet, recorder, telemetry):
    params, mesh = setup
    ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                      cache_backend="paged", paged_block_size=16,
                      preemption_mode="swap", **TIMING)
    if async_fleet:
        auto = TargetUtilizationAutoscaler(r_min=1, r_max=3, target=0.7,
                                           interval_s=0.05, warmup_s=0.02)
        fs = AsyncFleetServer(CFG, params, ec, n_replicas=3,
                              router="bfio", policy="bfio_h0", mesh=mesh,
                              telemetry=telemetry, autoscaler=auto,
                              max_snapshot_age=0.05, obs=recorder)
    else:
        fs = FleetServer(CFG, params, ec, n_replicas=3, router="bfio",
                         policy="bfio_h0", mesh=mesh,
                         telemetry=telemetry, obs=recorder)
    for i, r in enumerate(_requests(seed=11)):
        fs.submit(r, arrival_time=0.01 * i)
    stats = fs.run()
    assert stats["failed"] == 0
    return fs, stats


class TestFleetIntegration:
    @pytest.mark.parametrize("async_fleet", [False, True],
                             ids=["barrier", "async"])
    def test_ledger_total_is_bit_exact(self, setup, async_fleet):
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=2.0, tpot_s=0.5))
        fs, stats = _run_fleet(setup, async_fleet=async_fleet,
                               recorder=SpanRecorder(), telemetry=tel)
        ledger = fs.straggler_ledger()
        assert stats["idle_j"] > 0
        assert ledger["total_idle_j"] == stats["idle_j"]
        # every v4 step row's split folds to its idle_j bit-exactly
        assert tel.steps
        for s in tel.steps:
            assert fold_sum(s["idle_split"]) == s["idle_j"]
        by_cause = tel.summary()["idle_by_cause"]
        assert set(by_cause) == set(IDLE_CAUSES)
        if not async_fleet:
            # barrier steps name a gating replica; its idle is zero by
            # definition, so some cause must carry the others' slack
            assert tel.summary()["gating_steps"]

    @pytest.mark.parametrize("async_fleet", [False, True],
                             ids=["barrier", "async"])
    def test_spans_match_telemetry_latency(self, setup, tmp_path,
                                           async_fleet):
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=2.0, tpot_s=0.5))
        rec = SpanRecorder()
        _run_fleet(setup, async_fleet=async_fleet, recorder=rec,
                   telemetry=tel)
        path = os.path.join(tmp_path, "fleet.trace")
        write_trace(rec, path)
        seen = read_trace(path)
        assert seen["n_points"] == rec.n_events
        lat = {q["rid"]: q["latency"] for q in tel.requests}
        assert set(seen["requests"]) == set(lat)
        for rid, span in seen["requests"].items():
            assert span["e2e_s"] == lat[rid]      # bit-equal
            assert span["status"] == "completed"

    @pytest.mark.parametrize("async_fleet", [False, True],
                             ids=["barrier", "async"])
    def test_disabled_recorder_is_free(self, setup, async_fleet):
        tel_on = FleetTelemetry(slo=SLOSpec(ttft_s=2.0, tpot_s=0.5))
        fs_on, stats_on = _run_fleet(
            setup, async_fleet=async_fleet, recorder=SpanRecorder(),
            telemetry=tel_on)
        tel_off = FleetTelemetry(slo=SLOSpec(ttft_s=2.0, tpot_s=0.5))
        fs_off, stats_off = _run_fleet(
            setup, async_fleet=async_fleet, recorder=None,
            telemetry=tel_off)
        assert fs_off._obs_rec.n_events == 0
        assert stats_on == stats_off
        assert tel_on.steps == tel_off.steps
        assert tel_on.requests == tel_off.requests
        # the ledger stays on either way (it feeds telemetry v4)
        assert fs_off.straggler_ledger() == fs_on.straggler_ledger()

    def test_v4_telemetry_roundtrips_from_a_real_run(self, setup,
                                                     tmp_path):
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=2.0, tpot_s=0.5))
        _run_fleet(setup, async_fleet=False, recorder=None,
                   telemetry=tel)
        path = os.path.join(tmp_path, "tel.jsonl")
        tel.write_jsonl(path)
        back = FleetTelemetry.read_jsonl(path)
        assert back.steps == tel.steps
        assert back.summary() == json.loads(json.dumps(tel.summary()))


# ----------------------------------------------------------------------
# Shared block-hash chains: one hash walk per (prompt, block size)
# ----------------------------------------------------------------------

class TestSharedPrefixChains:
    def test_each_prompt_hashed_once_across_probe_and_admission(
            self, setup, monkeypatch):
        """The affinity probe re-scores every still-queued candidate on
        every routing round; without the memoized chain each round
        re-hashes every waiting prompt.  With sharing, ``keys_for``
        runs exactly once per unique prompt — the probe's walk is the
        one the engine's admission path reuses."""
        from repro.serving.paged_cache import PrefixIndex

        params, mesh = setup
        calls = collections.Counter()
        orig = PrefixIndex.keys_for

        def spy(self, tokens, block_size):
            key = (tuple(int(t) for t in np.asarray(tokens)),
                   int(block_size))
            calls[key] += 1
            return orig(self, tokens, block_size)

        monkeypatch.setattr(PrefixIndex, "keys_for", spy)
        ec = EngineConfig(n_workers=1, slots_per_worker=1,
                          max_seq_len=64, cache_backend="paged",
                          paged_block_size=16, prefix_cache=True,
                          **TIMING)
        fs = FleetServer(CFG, params, ec, n_replicas=2,
                         router="bfio_affinity", policy="bfio_h0",
                         mesh=mesh)
        reqs = _requests(seed=13, n=10, unique_head=True)
        for r in reqs:                 # all at t=0: a persistent queue
            fs.submit(r, arrival_time=0.0)
        stats = fs.run()
        assert stats["failed"] == 0
        # two slots fleet-wide serving ten requests: the queue survived
        # many routing rounds, so the probe scored candidates repeatedly
        assert stats["steps"] > len(reqs)
        assert len(calls) >= len(reqs)
        repeats = {k: n for k, n in calls.items() if n > 1}
        assert not repeats, \
            f"prompts re-hashed despite the shared chain: {repeats}"
