"""Vectorized-engine regression tests: the ``engine_mode="vec"`` hot path
must be bit-identical on stats (steps, tokens, energy_j, avg_imbalance)
and generations to the seed ``engine_mode="ref"`` path across policies and
drift models; plus coverage for eos early-stop, over-subscribing policies,
over-long prompts, and the shared slot table."""
import warnings

warnings.filterwarnings("ignore")

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import make_policy
from repro.core.policies import Policy
from repro.core.workload import constant_drift, fractional_drift, unit_drift
from repro.models import init_params, split_params
from repro.serving import (
    EngineConfig,
    ServeRequest,
    ServingEngine,
    SlotTable,
    cap_assignment,
)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")

STAT_KEYS = ("steps", "tokens", "energy_j", "avg_imbalance", "time_s")


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


def _requests(n=14, seed=3, max_new=(3, 10), tok_hi=128, plen=(4, 30)):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            rid=i,
            tokens=rng.integers(1, tok_hi, size=int(rng.integers(*plen))),
            max_new_tokens=int(rng.integers(*max_new)))
        for i in range(n)
    ]


def _run(params, mesh, mode, policy, reqs, *, G=2, B=4, drift=None,
         max_seq_len=64):
    eng = ServingEngine(
        CFG, params,
        EngineConfig(n_workers=G, slots_per_worker=B,
                     max_seq_len=max_seq_len, engine_mode=mode),
        policy if isinstance(policy, Policy) else make_policy(policy),
        mesh=mesh, drift=drift)
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_steps=1000)
    return eng, stats


def _assert_parity(params, mesh, policy, *, drift_factory=None, seed=3,
                   **kw):
    drift_a = drift_factory() if drift_factory else None
    drift_b = drift_factory() if drift_factory else None
    reqs_a = _requests(seed=seed)
    reqs_b = _requests(seed=seed)
    _, sa = _run(params, mesh, "ref", policy, reqs_a, drift=drift_a, **kw)
    _, sb = _run(params, mesh, "vec", policy, reqs_b, drift=drift_b, **kw)
    for k in STAT_KEYS:
        assert sa[k] == sb[k], f"{k}: ref={sa[k]} vec={sb[k]}"
    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.generated == rb.generated, f"request {ra.rid} diverged"
        assert ra.worker == rb.worker


class TestRefVecParity:
    @pytest.mark.parametrize("policy", ["fcfs", "jsq", "pod2", "bfio_h0"])
    def test_policies(self, setup, policy):
        params, mesh = setup
        _assert_parity(params, mesh, policy)

    @pytest.mark.parametrize("drift_factory",
                             [unit_drift, constant_drift,
                              lambda: fractional_drift(6.0 / 38.0)])
    def test_drift_models(self, setup, drift_factory):
        params, mesh = setup
        _assert_parity(params, mesh, "bfio_h0",
                       drift_factory=drift_factory)

    def test_compact_decode_buckets_hit(self, setup):
        """A drain-heavy workload exercises the bucketed compact path."""
        params, mesh = setup
        reqs_a = _requests(n=20, seed=11, max_new=(2, 24))
        reqs_b = _requests(n=20, seed=11, max_new=(2, 24))
        _, sa = _run(params, mesh, "ref", "jsq", reqs_a, G=2, B=8)
        eng, sb = _run(params, mesh, "vec", "jsq", reqs_b, G=2, B=8)
        assert min(eng._buckets) < eng.N  # compact buckets exist
        for k in STAT_KEYS:
            assert sa[k] == sb[k]
        for ra, rb in zip(reqs_a, reqs_b):
            assert ra.generated == rb.generated


class TestEosEarlyStop:
    def test_eos_stops_generation(self, setup):
        params, mesh = setup
        probe = ServeRequest(rid=0, tokens=np.arange(1, 9),
                             max_new_tokens=12)
        _run(params, mesh, "vec", "fcfs", [probe], G=1, B=1)
        assert len(probe.generated) == 12
        # the engine checks eos on decoded tokens (positions >= 1)
        eos = probe.generated[len(probe.generated) // 2]
        expect = next(j for j in range(1, 12)
                      if probe.generated[j] == eos) + 1
        stats = {}
        for mode in ("ref", "vec"):
            r = ServeRequest(rid=0, tokens=np.arange(1, 9),
                             max_new_tokens=12, eos_id=eos)
            _, stats[mode] = _run(params, mesh, mode, "fcfs", [r],
                                  G=1, B=1)
            assert r.done
            assert len(r.generated) == expect < 12
            assert r.generated[-1] == eos
        for k in STAT_KEYS:
            assert stats["ref"][k] == stats["vec"][k]


class _RoguePolicy(Policy):
    """Assigns every waiting request to worker 0, ignoring capacities."""

    name = "rogue"

    def assign(self, ctx):
        return np.zeros(ctx.n_wait, dtype=np.int64)


class TestOverSubscription:
    @pytest.mark.parametrize("mode", ["ref", "vec"])
    def test_oversubscribing_policy_is_capped(self, setup, mode):
        params, mesh = setup
        reqs = _requests(n=8, seed=5)
        eng, _ = _run(params, mesh, mode, _RoguePolicy(), reqs, G=2, B=2)
        assert all(r.done for r in reqs)
        assert all(r.worker == 0 for r in reqs)  # excess waited, not crashed
        assert not eng.wait

    def test_table_allocate_overflow_raises(self):
        t = SlotTable(2, 2)
        with pytest.raises(RuntimeError, match="over-subscribed"):
            t.allocate(np.array([0, 0, 0]))


class TestPrefillOverflow:
    @pytest.mark.parametrize("mode", ["ref", "vec"])
    def test_long_prompt_truncated(self, setup, mode):
        params, mesh = setup
        rng = np.random.default_rng(2)
        r = ServeRequest(rid=0, tokens=rng.integers(1, 128, size=100),
                         max_new_tokens=4)
        eng, _ = _run(params, mesh, mode, "fcfs", [r], G=1, B=1,
                      max_seq_len=32)
        assert r.done and len(r.generated) == 4
        # the prompt was clamped to max_seq_len at prefill; lengths then
        # grew only by the decoded tokens (3 decode steps after the first)
        assert int(np.asarray(eng.cache["lengths"]).max()) <= 32 + 3


class TestSlotTable:
    def test_loads_counts_caps(self):
        t = SlotTable(2, 3)
        slots = t.allocate(np.array([1, 0, 1]))
        t.load[slots] = [5.0, 2.0, 3.0]
        assert np.array_equal(t.counts(), [1, 2])
        assert np.array_equal(t.loads(), [2.0, 8.0])
        assert np.array_equal(t.caps(), [2, 1])
        # slots fill each worker's range in order
        assert np.array_equal(slots, [3, 0, 4])
        t.release(slots[:1])
        assert np.array_equal(t.counts(), [1, 1])
        assert t.load[slots[0]] == 0.0

    def test_cap_assignment(self):
        caps = np.array([1, 2])
        a = np.array([0, 0, 1, -1, 1, 1])
        out = cap_assignment(a, caps)
        assert np.array_equal(out, [0, -1, 1, -1, 1, -1])
        # no-op when within capacity
        a2 = np.array([-1, 1, 0])
        assert np.array_equal(cap_assignment(a2, np.array([1, 1])), a2)
