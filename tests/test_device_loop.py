"""Device-side routed serving loop (balancer_jax fused under lax.scan)."""
import warnings

warnings.filterwarnings("ignore")

import jax.numpy as jnp
import numpy as np

from repro.serving.device_loop import init_loop_state, \
    make_device_serving_loop


def test_all_requests_complete():
    rng = np.random.default_rng(1)
    G, B, W = 4, 4, 64
    run = make_device_serving_loop(G, B, W)
    state = init_loop_state(G, B, rng.uniform(5, 50, 40),
                            rng.integers(2, 10, 40), W)
    state = run(state, 80)
    assert int(state.slot_active.sum()) == 0
    assert int((state.wait_prefill > 0).sum()) == 0
    assert int(state.tot_steps) == 80


def test_capacity_never_exceeded():
    rng = np.random.default_rng(2)
    G, B, W = 3, 2, 32
    run = make_device_serving_loop(G, B, W)
    state = init_loop_state(G, B, rng.uniform(1, 9, 30),
                            rng.integers(1, 6, 30), W)
    slot_worker = np.repeat(np.arange(G), B)
    for _ in range(20):
        state = run(state, 1)
        act = np.asarray(state.slot_active)
        counts = np.bincount(slot_worker[act], minlength=G)
        assert counts.max() <= B


def test_balances_better_than_unrouted():
    """BF-IO-routed device loop vs a fill-in-order baseline."""
    rng = np.random.default_rng(3)
    G, B, W = 4, 8, 128
    sizes = np.concatenate([rng.uniform(90, 100, 16),
                            rng.uniform(1, 10, 48)])
    rem = np.full(len(sizes), 6)
    run = make_device_serving_loop(G, B, W)
    st = run(init_loop_state(G, B, sizes, rem, W), 24)
    routed_imb = float(st.tot_imbalance) / 24
    # baseline: same workload, slots filled in arrival order (simulate
    # by assigning blocks of B to each worker -> heavies cluster)
    loads = np.zeros(G)
    order = np.arange(len(sizes))
    for i, idx in enumerate(order[:G * B]):
        loads[i // B] += sizes[idx]
    base_imb = G * loads.max() - loads.sum()
    assert routed_imb < base_imb


def test_chunked_prefill_budget_drains_and_completes():
    """prefill_budget > 0 models chunked prefill on device: admitted
    slots ramp their load under the per-step budget, decode only after
    their prefill drains, and the loop still completes everything."""
    rng = np.random.default_rng(4)
    G, B, W = 4, 4, 64
    # every prompt exceeds the budget, so no slot can both drain its
    # prefill AND decode (+1 load) within the first step — the budget
    # bound below is exact
    sizes = rng.uniform(20, 50, 40)
    rem = rng.integers(2, 10, 40)
    run = make_device_serving_loop(G, B, W, prefill_budget=16.0)
    state = init_loop_state(G, B, sizes, rem, W)
    # after one step the admitted slots hold at most the budget of load
    s1 = run(state, 1)
    active_load = np.asarray(s1.slot_load)[np.asarray(s1.slot_active)]
    assert active_load.sum() <= 16.0 + 1e-6
    assert float(jnp.sum(s1.slot_prefill_left)) > 0  # work still queued
    # and the whole workload eventually drains (prefill + decode steps)
    end = run(state, 400)
    assert int(end.slot_active.sum()) == 0
    assert int((end.wait_prefill > 0).sum()) == 0
    assert float(jnp.sum(end.slot_prefill_left)) == 0.0
