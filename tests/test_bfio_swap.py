"""Parity tests for the tiled BF-IO swap kernel and the batched solver.

Three layers of agreement are pinned:
  1. kernel level — Pallas / tiled-XLA / dense-oracle swap searches return
     bit-identical (best_val, best_j) vectors;
  2. solver level — ``bfio_assign`` produces the identical assignment for
     every backend, and pruned refinement never regresses below greedy;
  3. objective level — on fully-packed parity fixtures (n == sum caps,
     G <= 4, N <= 8, where pairwise exchange is the complete move set)
     the jitted solver's windowed imbalance J matches ``solve_io`` within
     1% and respects the exchange-argument slack vs ``solve_exact``.
"""
import warnings

warnings.filterwarnings("ignore")

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import io_solver
from repro.core.balancer_jax import bfio_assign, bfio_assign_batch
from repro.kernels.bfio_swap import swap_best_pallas, swap_best_xla
from repro.kernels.ref import bfio_swap_best_ref

def _instance(rng, G, N, W, packed=False):
    base = rng.uniform(0, 10, (G, W))
    if packed:
        caps = rng.integers(1, 3, G)
        N = int(caps.sum())
    else:
        caps = rng.integers(0, 4, G)
    cands = rng.uniform(0.5, 5, (N, W))
    return base, caps, cands


def _jax_args(base, caps, cands, n_admit=None):
    n = cands.shape[0]
    U = min(n, int(caps.sum())) if n_admit is None else n_admit
    return (jnp.asarray(base, jnp.float32), jnp.asarray(caps, jnp.int32),
            jnp.asarray(cands, jnp.float32), jnp.ones(n, bool),
            jnp.int32(U))


class TestSwapKernelParity:
    @pytest.mark.parametrize("G,N,W,ti,tj", [
        (2, 5, 1, 4, 4),
        (4, 33, 3, 8, 16),     # ragged tiles
        (8, 64, 9, 16, 16),
        (3, 17, 2, 32, 32),    # single-tile (N < tile)
    ])
    def test_backends_bit_identical(self, G, N, W, ti, tj):
        rng = np.random.default_rng(G * 1000 + N)
        loads = jnp.asarray(rng.uniform(0, 10, (G, W)), jnp.float32)
        cands = jnp.asarray(rng.uniform(0, 5, (N, W)), jnp.float32)
        assign = jnp.asarray(rng.integers(-1, G, N), jnp.int32)
        valid = jnp.asarray(rng.random(N) > 0.1)

        vd, ad = bfio_swap_best_ref(loads, cands, assign, valid)
        vx, ax = swap_best_xla(loads, cands, assign, valid, tile_i=ti)
        vp, ap = swap_best_pallas(loads, cands, assign, valid,
                                  tile_i=ti, tile_j=tj)
        vd, ad = np.asarray(vd), np.asarray(ad)
        np.testing.assert_array_equal(vd, np.asarray(vx))
        np.testing.assert_array_equal(vd, np.asarray(vp))
        fin = np.isfinite(vd)  # argmin of an all-inf row is unconstrained
        np.testing.assert_array_equal(ad[fin], np.asarray(ax)[fin])
        np.testing.assert_array_equal(ad[fin], np.asarray(ap)[fin])

    def test_pallas_lane_padding(self):
        """TPU lane padding (W -> 128) must not change the reduction."""
        rng = np.random.default_rng(99)
        loads = jnp.asarray(rng.uniform(0, 10, (4, 5)), jnp.float32)
        cands = jnp.asarray(rng.uniform(0, 5, (12, 5)), jnp.float32)
        assign = jnp.asarray(rng.integers(0, 4, 12), jnp.int32)
        valid = jnp.ones(12, bool)
        v0, a0 = swap_best_pallas(loads, cands, assign, valid, tile_i=4,
                                  tile_j=4, pad_lanes=False)
        v1, a1 = swap_best_pallas(loads, cands, assign, valid, tile_i=4,
                                  tile_j=4, pad_lanes=True)
        np.testing.assert_allclose(np.asarray(v0), np.asarray(v1),
                                   rtol=1e-6)
        fin = np.isfinite(np.asarray(v0))
        np.testing.assert_array_equal(np.asarray(a0)[fin],
                                      np.asarray(a1)[fin])


class TestSolverBackendsIdentical:
    @pytest.mark.parametrize("trial", range(6))
    def test_dense_xla_pallas_same_assignment(self, trial):
        rng = np.random.default_rng(500 + trial)
        G = int(rng.integers(2, 6))
        N = int(rng.integers(2, 30))
        W = int(rng.integers(1, 5))
        base, caps, cands = _instance(rng, G, N, W)
        args = _jax_args(base, caps, cands)
        a_d = np.asarray(bfio_assign(*args, method="dense"))
        a_x = np.asarray(bfio_assign(*args, method="xla", tile=8))
        a_p = np.asarray(bfio_assign(*args, method="pallas", tile=8))
        np.testing.assert_array_equal(a_d, a_x)
        np.testing.assert_array_equal(a_d, a_p)

    def test_pruned_never_worse_than_greedy(self):
        base, caps, cands = _instance(np.random.default_rng(77), 8, 64, 4)
        args = _jax_args(base, caps, cands)
        a_greedy = np.asarray(bfio_assign(*args, swap_iters=0))
        a_pruned = np.asarray(bfio_assign(*args, method="xla", prune_k=16))
        G = base.shape[0]
        used = np.bincount(a_pruned[a_pruned >= 0], minlength=G)
        assert np.all(used <= caps)
        assert (a_pruned >= 0).sum() == (a_greedy >= 0).sum()
        assert (io_solver.objective(base, cands, a_pruned)
                <= io_solver.objective(base, cands, a_greedy) + 1e-4)


class TestObjectiveParityFixtures:
    """Fully-packed small fixtures: refinement's exchange moves are the
    complete local-search move set, so the jitted solver must land within
    1% of solve_io's windowed imbalance."""

    @pytest.mark.parametrize("trial", range(12))
    def test_within_1pct_of_solve_io(self, trial):
        rng = np.random.default_rng(2000 + trial)
        G = int(rng.integers(2, 5))
        W = int(rng.integers(1, 4))
        base, caps, cands = _instance(rng, G, 0, W, packed=True)
        n = cands.shape[0]
        if n > 8:
            caps = np.minimum(caps, 2)
            cands = cands[: int(caps.sum())]
            n = cands.shape[0]
        a_j = np.asarray(bfio_assign(*_jax_args(base, caps, cands),
                                     swap_iters=16))
        a_io = io_solver.solve_io(base, caps, cands)
        J_j = io_solver.objective(base, cands, a_j)
        J_io = io_solver.objective(base, cands, a_io)
        assert J_j <= J_io * 1.01 + 1e-9

        a_ex, v_ex = io_solver.solve_exact(base, caps, cands)
        assert J_j <= v_ex + G * W * cands.max() + 1e-9

    @pytest.mark.parametrize("method", ["xla", "pallas"])
    def test_batch_matches_single_and_solve_io(self, method):
        C, G, W = 4, 3, 2
        rng = np.random.default_rng(31)
        bases, capss, candss = [], [], []
        for _ in range(C):
            base, caps, cands = _instance(rng, G, 0, W, packed=True)
            n = int(caps.sum())
            bases.append(base)
            capss.append(caps)
            candss.append(cands)
        n_max = max(c.shape[0] for c in candss)
        base_b = jnp.asarray(np.stack(bases), jnp.float32)
        caps_b = jnp.asarray(np.stack(capss), jnp.int32)
        cands_b = jnp.zeros((C, n_max, W), jnp.float32)
        valid_b = np.zeros((C, n_max), bool)
        for c, cn in enumerate(candss):
            cands_b = cands_b.at[c, : cn.shape[0]].set(
                jnp.asarray(cn, jnp.float32))
            valid_b[c, : cn.shape[0]] = True
        n_admit = jnp.asarray([c.shape[0] for c in candss], jnp.int32)

        ab = np.asarray(bfio_assign_batch(
            base_b, caps_b, cands_b, jnp.asarray(valid_b), n_admit,
            swap_iters=16, method=method))
        for c in range(C):
            a1 = np.asarray(bfio_assign(
                base_b[c], caps_b[c], cands_b[c], jnp.asarray(valid_b[c]),
                n_admit[c], swap_iters=16))
            np.testing.assert_array_equal(ab[c], a1)
            n = candss[c].shape[0]
            a_io = io_solver.solve_io(bases[c], capss[c], candss[c])
            J_b = io_solver.objective(bases[c], candss[c], ab[c, :n])
            J_io = io_solver.objective(bases[c], candss[c], a_io)
            assert J_b <= J_io * 1.01 + 1e-9
