"""Integration tests of the discrete-event serving simulator (Section 6)."""
import numpy as np
import pytest

from repro.core import (
    ArrivalInstance,
    Request,
    SimConfig,
    SimTrace,
    constant_drift,
    make_policy,
    simulate,
    unit_drift,
)
from repro.data import LONGBENCH_LIKE, batched_rounds_instance, poisson_trace


def _small_instance(n=64, seed=0, drift=None):
    rng = np.random.default_rng(seed)
    reqs = [
        Request(rid=i, arrival_step=0, prefill=float(rng.integers(1, 50)),
                decode_len=int(rng.geometric(0.2)))
        for i in range(n)
    ]
    return ArrivalInstance(requests=reqs, drift=drift or unit_drift())


class TestBasics:
    def test_all_complete(self):
        inst = _small_instance()
        m = simulate(inst, make_policy("fcfs"), SimConfig(G=4, B=4))
        assert m.completed == len(inst)

    def test_total_work_conservation(self):
        """W(I) is policy independent (Eq. 11): sum over steps of all loads
        equals the instance's total work, for every policy."""
        inst = _small_instance()
        ref = inst.total_work()
        for name in ["fcfs", "jsq", "rr", "bfio_h0"]:
            tr = SimTrace()
            cfg = SimConfig(G=4, B=4)
            simulate(inst, make_policy(name), cfg, trace=tr)
            # mean_load * G summed over steps == W(I)
            tot = float(np.sum(np.asarray(tr.mean_load) * cfg.G))
            assert tot == pytest.approx(ref, rel=1e-9), name

    def test_sticky_assignment(self):
        inst = _small_instance()
        simulate(inst, make_policy("bfio_h0"), SimConfig(G=4, B=4))
        for r in inst.requests:
            assert r.worker >= 0 and r.finish_step >= r.assign_step

    def test_capacity_never_exceeded(self):
        """The simulator raises on violation; completing = pass."""
        inst = _small_instance(n=200)
        for name in ["fcfs", "jsq", "rr", "pod2", "bfio_h0", "bfio_h8"]:
            m = simulate(inst, make_policy(name), SimConfig(G=3, B=5))
            assert m.completed == 200

    def test_single_request(self):
        inst = ArrivalInstance(
            requests=[Request(rid=0, arrival_step=0, prefill=10.0,
                              decode_len=5)])
        tr = SimTrace()
        m = simulate(inst, make_policy("fcfs"), SimConfig(G=2, B=1), trace=tr)
        assert m.steps == 5
        # loads: 10, 11, 12, 13, 14 (unit drift)
        assert tr.max_load == [10.0, 11.0, 12.0, 13.0, 14.0]

    def test_constant_drift_loads_flat(self):
        inst = ArrivalInstance(
            requests=[Request(rid=0, arrival_step=0, prefill=7.0,
                              decode_len=4)],
            drift=constant_drift())
        tr = SimTrace()
        simulate(inst, make_policy("fcfs"), SimConfig(G=1, B=1), trace=tr)
        assert tr.max_load == [7.0] * 4

    def test_step_time_model(self):
        """dt = C + t_l * max load (Eq. 19)."""
        inst = ArrivalInstance(
            requests=[Request(rid=0, arrival_step=0, prefill=100.0,
                              decode_len=1)])
        cfg = SimConfig(G=1, B=1, step_overhead=0.5, t_token=0.01)
        tr = SimTrace()
        m = simulate(inst, make_policy("fcfs"), cfg, trace=tr)
        assert tr.dt[0] == pytest.approx(0.5 + 0.01 * 100.0)
        assert m.makespan == pytest.approx(tr.dt[0])

    def test_deferred_arrivals(self):
        reqs = [Request(rid=0, arrival_step=0, prefill=5.0, decode_len=2),
                Request(rid=1, arrival_step=10, prefill=5.0, decode_len=2)]
        m = simulate(ArrivalInstance(requests=reqs), make_policy("fcfs"),
                     SimConfig(G=1, B=1))
        assert m.completed == 2

    def test_time_based_arrivals(self):
        inst = poisson_trace(LONGBENCH_LIKE, n_requests=50, rate=100.0, seed=3)
        m = simulate(inst, make_policy("jsq"),
                     SimConfig(G=2, B=8, time_based_arrivals=True))
        assert m.completed == 50


class TestInstantModeRegression:
    """The vectorized instant-mode dispatch must be step-for-step identical
    to the original per-request implementation (kept as
    ``dispatch="instant_ref"``): every SimMetrics accumulator — integrated
    over all steps — must match exactly, not approximately."""

    @staticmethod
    def _instance(n=200, seed=11):
        rng = np.random.default_rng(seed)
        reqs = [
            Request(rid=i, arrival_step=int(rng.integers(0, 40)),
                    prefill=float(rng.integers(1, 80)),
                    decode_len=int(rng.geometric(0.15)))
            for i in range(n)
        ]
        return ArrivalInstance(requests=reqs)

    @pytest.mark.parametrize("policy", ["jsq", "fcfs", "rr", "pod2",
                                        "bfio_h0"])
    def test_metrics_bit_identical_to_reference(self, policy):
        import dataclasses
        runs = {}
        for mode in ["instant", "instant_ref"]:
            m = simulate(self._instance(), make_policy(policy),
                         SimConfig(G=8, B=4, dispatch=mode, seed=3))
            runs[mode] = dataclasses.asdict(m)
        assert runs["instant"] == runs["instant_ref"]

    def test_traces_bit_identical_to_reference(self):
        traces = {}
        for mode in ["instant", "instant_ref"]:
            tr = SimTrace()
            simulate(self._instance(), make_policy("jsq"),
                     SimConfig(G=8, B=4, dispatch=mode, seed=3), trace=tr)
            traces[mode] = tr.asdict()
        for key, ref in traces["instant_ref"].items():
            got = traces["instant"][key]
            assert np.array_equal(np.asarray(got), np.asarray(ref)), key

    def test_time_based_arrivals_identical(self):
        import dataclasses
        runs = {}
        for mode in ["instant", "instant_ref"]:
            inst = poisson_trace(LONGBENCH_LIKE, n_requests=80, rate=300.0,
                                 seed=5)
            m = simulate(inst, make_policy("jsq"),
                         SimConfig(G=4, B=6, dispatch=mode,
                                   time_based_arrivals=True, seed=7))
            runs[mode] = dataclasses.asdict(m)
        assert runs["instant"] == runs["instant_ref"]

    def test_golden_metrics_fixed_seed(self):
        """Pins BOTH instant implementations to the seed repo's numbers, so
        a semantics change in either path (not just a divergence between
        them) fails loudly."""
        gold = {"steps": 62, "total_imbalance": 29202.0, "completed": 200,
                "avg_imbalance": 471.0}
        for mode in ["instant", "instant_ref"]:
            m = simulate(self._instance(), make_policy("jsq"),
                         SimConfig(G=8, B=4, dispatch=mode, seed=3))
            for key, want in gold.items():
                assert getattr(m, key) == want, (mode, key)


class TestPolicyOrdering:
    """On an overloaded heterogeneous instance, BF-IO must beat the
    size-agnostic baselines on imbalance (the paper's core claim)."""

    @pytest.fixture(scope="class")
    def results(self):
        # sustained overload: short runs are dominated by the drain-out
        # tail, where BF-IO's size-aware admission defers small requests —
        # the paper's regime is the long sustained phase.
        inst = batched_rounds_instance(LONGBENCH_LIKE, G=8, B=16,
                                       n_rounds=5, seed=7)
        cfg = SimConfig(G=8, B=16)
        out = {}
        for name in ["fcfs", "jsq", "bfio_h0", "bfio_h16"]:
            out[name] = simulate(inst, make_policy(name), cfg)
        return out

    def test_bfio_beats_fcfs_imbalance(self, results):
        assert (results["bfio_h0"].avg_imbalance
                < results["fcfs"].avg_imbalance)

    def test_bfio_beats_fcfs_throughput(self, results):
        assert results["bfio_h0"].throughput > results["fcfs"].throughput

    def test_bfio_beats_fcfs_energy(self, results):
        assert (results["bfio_h0"].energy_joules
                < results["fcfs"].energy_joules)

    def test_lookahead_helps_imbalance(self, results):
        assert (results["bfio_h16"].avg_imbalance
                <= results["bfio_h0"].avg_imbalance * 1.05)

    def test_makespan_consistency(self, results):
        for m in results.values():
            assert m.makespan > 0 and m.tpot > 0
