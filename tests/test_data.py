"""Data pipeline tests: length distributions, traces, token batches."""
import numpy as np
import pytest

from repro.core import SimConfig, make_policy, simulate
from repro.data import (
    BURSTGPT_LIKE,
    LONGBENCH_LIKE,
    batched_rounds_instance,
    bursty_trace,
    decode_sampler,
    overload_rate,
    poisson_trace,
    prefill_sampler,
    token_batches,
)


class TestSamplers:
    def test_prefill_bounds(self):
        rng = np.random.default_rng(0)
        s = prefill_sampler(LONGBENCH_LIKE)(rng, 10_000)
        assert s.min() >= LONGBENCH_LIKE.s_min
        assert s.max() <= LONGBENCH_LIKE.s_max

    def test_decode_geometric_mean(self):
        rng = np.random.default_rng(0)
        o = decode_sampler(LONGBENCH_LIKE)(rng, 50_000)
        assert o.min() >= 1
        want = 1.0 / LONGBENCH_LIKE.decode_p
        assert abs(o.mean() - want) / want < 0.1

    def test_longbench_prompts_longer_than_burstgpt(self):
        rng = np.random.default_rng(0)
        lb = prefill_sampler(LONGBENCH_LIKE)(rng, 5000).mean()
        bg = prefill_sampler(BURSTGPT_LIKE)(rng, 5000).mean()
        assert lb > 3 * bg

    def test_spec_stats(self):
        assert LONGBENCH_LIKE.sigma_s > 0
        assert LONGBENCH_LIKE.mu_s > LONGBENCH_LIKE.s_min


class TestTraces:
    def test_poisson_rate(self):
        tr = poisson_trace(LONGBENCH_LIKE, n_requests=5000, rate=100.0,
                           seed=1)
        times = np.array([r.arrival_time for r in tr.requests])
        assert np.all(np.diff(times) >= 0)
        emp_rate = len(times) / times[-1]
        assert abs(emp_rate - 100.0) / 100.0 < 0.1

    def test_bursty_has_higher_variance(self):
        # short period so the trace actually alternates burst/lull episodes
        tp = poisson_trace(BURSTGPT_LIKE, n_requests=3000, rate=50.0, seed=2)
        tb = bursty_trace(BURSTGPT_LIKE, n_requests=3000, rate=50.0, seed=4,
                          period=5.0)
        def cv2(tr):
            gaps = np.diff([r.arrival_time for r in tr.requests])
            return gaps.var() / gaps.mean() ** 2
        assert cv2(tb) > 1.5 * cv2(tp)

    def test_overload_rate_overloads(self):
        """Simulating at overload_rate keeps a growing wait queue."""
        G, B = 4, 8
        rate = overload_rate(LONGBENCH_LIKE, G, B, factor=2.0)
        tr = poisson_trace(LONGBENCH_LIKE, n_requests=400, rate=rate, seed=3)
        from repro.core import SimTrace
        trace = SimTrace()
        simulate(tr, make_policy("fcfs"),
                 SimConfig(G=G, B=B, time_based_arrivals=True), trace=trace)
        waiting = np.asarray(trace.n_waiting)
        assert waiting.max() > G * B  # pool deeper than capacity

    def test_batched_rounds_overloaded(self):
        inst = batched_rounds_instance(LONGBENCH_LIKE, G=2, B=4, n_rounds=2)
        assert len(inst) >= 2 * 2 * 4 * 2


class TestTokenBatches:
    def test_shapes_and_shift(self):
        b = next(token_batches(vocab_size=100, batch=4, seq_len=16,
                               n_batches=1, pad_frac=0.0))
        assert b["tokens"].shape == (4, 16)
        assert b["targets"].shape == (4, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])

    def test_mask_matches_padding(self):
        b = next(token_batches(vocab_size=100, batch=8, seq_len=64,
                               n_batches=1, pad_frac=0.2, seed=3))
        assert b["mask"].min() == 0.0  # some padding present
        np.testing.assert_array_equal(b["mask"] == 0, b["targets"] == 0)

    def test_deterministic(self):
        a = next(token_batches(vocab_size=50, batch=2, seq_len=8,
                               n_batches=1, seed=7))
        b = next(token_batches(vocab_size=50, batch=2, seq_len=8,
                               n_batches=1, seed=7))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
