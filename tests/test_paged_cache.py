"""Paged KV cache + paged decode-attention kernel tests."""
import warnings

warnings.filterwarnings("ignore")

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.kernels.ref import decode_attention_ref
from repro.serving.paged_cache import (
    BlockAllocator,
    PagedKVCache,
    PrefixIndex,
    paged_decode_attention_ref,
)

RNG = np.random.default_rng(3)


class TestAllocator:
    def test_alloc_free_roundtrip(self):
        a = BlockAllocator(8)
        x = a.alloc(5)
        assert len(set(x)) == 5 and a.n_free == 3
        a.free(x)
        assert a.n_free == 8

    def test_exhaustion_raises(self):
        a = BlockAllocator(2)
        a.alloc(2)
        with pytest.raises(MemoryError):
            a.alloc(1)

    def test_bad_free_raises(self):
        with pytest.raises(ValueError):
            BlockAllocator(2).free([5])

    def test_double_free_raises(self):
        """Freeing twice must not silently duplicate ids on the free list
        (the duplicate would later alias two requests' KV)."""
        a = BlockAllocator(4)
        x = a.alloc(2)
        a.free(x)
        with pytest.raises(ValueError, match="double free"):
            a.free(x[:1])
        assert a.n_free == 4  # free list not corrupted by the bad call

    def test_double_free_message_names_block_and_refcount(self):
        """The guard must identify the offending block AND its refcount —
        a bare 'double free' is useless when a preempt/COW/release path
        mis-pairs its frees."""
        a = BlockAllocator(4)
        (b,) = a.alloc(1)
        a.free([b])
        with pytest.raises(ValueError,
                           match=rf"block {b}: refcount is 0"):
            a.free([b])
        with pytest.raises(ValueError, match=r"bad block id 9 \(pool has "
                                             r"4 blocks\)"):
            a.free([9])

    def test_free_returns_released_blocks(self):
        """free() reports which blocks actually returned to the pool so
        a prefix index can evict exactly those (a still-referenced
        shared block must NOT be reported)."""
        a = BlockAllocator(4)
        x, y = a.alloc(2)
        a.add_ref(x)
        assert a.free([x, y]) == [y]     # x still referenced
        assert a.free([x]) == [x]

    def test_free_unallocated_raises(self):
        a = BlockAllocator(4)
        with pytest.raises(ValueError, match="double free"):
            a.free([0])  # never allocated

    def test_still_referenced_block_not_returned(self):
        """A shared (refcounted) block survives its first free."""
        a = BlockAllocator(2)
        (b,) = a.alloc(1)
        a.add_ref(b)
        assert a.ref_count(b) == 2
        a.free([b])
        assert a.n_free == 1          # still referenced -> not in pool
        a.free([b])
        assert a.n_free == 2          # last reference returns it
        with pytest.raises(ValueError):
            a.free([b])

    def test_add_ref_unallocated_raises(self):
        with pytest.raises(ValueError, match="unallocated"):
            BlockAllocator(2).add_ref(0)

    def test_freed_blocks_are_reusable(self):
        a = BlockAllocator(2)
        x = a.alloc(2)
        a.free(x)
        y = a.alloc(2)
        assert sorted(y) == sorted(x)


class TestPrefixIndex:
    def test_chained_keys_distinguish_position(self):
        """Equal block content under different predecessors must key
        differently — a match must imply the whole prefix matches."""
        idx = PrefixIndex()
        ka = idx.keys_for([1, 2, 3, 4, 9, 9], block_size=4)
        kb = idx.keys_for([5, 2, 3, 4, 9, 9], block_size=4)
        assert len(ka) == len(kb) == 2
        assert ka[0][0] != kb[0][0]
        assert ka[1][0] != kb[1][0]    # same tail tokens, different parent
        kc = idx.keys_for([1, 2, 3, 4, 9, 9], block_size=4)
        assert kc == ka                # deterministic within a process

    def test_register_lookup_evict(self):
        idx = PrefixIndex()
        ((key, parent, span),) = idx.keys_for([1, 2, 3], block_size=4)
        assert idx.lookup(key, parent, span) is None
        idx.register(key, parent, span, 7)
        assert idx.lookup(key, parent, span) == 7
        idx.register(key, parent, span, 8)   # first registration wins
        assert idx.lookup(key, parent, span) == 7
        idx.evict([7])
        assert idx.lookup(key, parent, span) is None
        assert len(idx) == 0
        idx.evict([7])                 # idempotent

    def test_lookup_verifies_content_not_just_hash(self):
        """A hash collision must degrade to a miss, never to serving
        another prompt's KV: lookup compares the stored (parent, span)."""
        idx = PrefixIndex()
        ((key, parent, span),) = idx.keys_for([1, 2, 3], block_size=4)
        idx.register(key, parent, span, 7)
        assert idx.lookup(key, parent, (1, 2, 9)) is None
        assert idx.lookup(key, 12345, span) is None
        assert idx.lookup(key, parent, span) == 7

    def test_partial_tail_keys_differ_from_full_block(self):
        idx = PrefixIndex()
        full = idx.keys_for([1, 2, 3, 4], block_size=4)
        part = idx.keys_for([1, 2, 3], block_size=4)
        assert full[0][0] != part[0][0]


class TestPagedKernel:
    @pytest.mark.parametrize("B,Hq,Hkv,hd,bs,mb", [
        (2, 4, 2, 32, 8, 4),
        (3, 8, 4, 64, 16, 5),
        (1, 16, 2, 128, 32, 3),
    ])
    def test_matches_ref(self, B, Hq, Hkv, hd, bs, mb):
        npool = mb * B + 4
        q = jnp.asarray(RNG.normal(size=(B, Hq, hd)), jnp.float32)
        kp = jnp.asarray(RNG.normal(size=(npool, bs, Hkv, hd)), jnp.float32)
        vp = jnp.asarray(RNG.normal(size=(npool, bs, Hkv, hd)), jnp.float32)
        perm = RNG.permutation(npool)
        bt = np.full((B, mb), -1, np.int32)
        lens = np.zeros(B, np.int32)
        ptr = 0
        for b in range(B):
            L = int(RNG.integers(1, mb * bs + 1))
            n = -(-L // bs)
            bt[b, :n] = perm[ptr:ptr + n]
            ptr += n
            lens[b] = L
        out = paged_decode_attention_pallas(
            q, kp, vp, jnp.asarray(bt), jnp.asarray(lens), block_size=bs)
        want = paged_decode_attention_ref(
            q, kp, vp, jnp.asarray(bt), jnp.asarray(lens), bs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


class TestPagedCache:
    def test_matches_contiguous_attention(self):
        """Scattered blocks must attend identically to a dense cache."""
        Hkv, Hq, hd, bs = 2, 4, 32, 8
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=32, block_size=bs, n_kv_heads=Hkv,
            head_dim=hd, max_requests=3, max_blocks_per_req=6,
            dtype=jnp.float32)
        k = jnp.asarray(RNG.normal(size=(3, 40, Hkv, hd)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(3, 40, Hkv, hd)), jnp.float32)
        lens = [13, 40, 1]
        for slot, L in enumerate(lens):
            cache.admit(slot, L)
            cache.write_prompt(0, slot, k[slot, :L], v[slot, :L])
        q = jnp.asarray(RNG.normal(size=(3, Hq, hd)), jnp.float32)
        got = paged_decode_attention_ref(
            q, cache.k_pool[0], cache.v_pool[0],
            jnp.asarray(cache.block_tables[:3]),
            jnp.asarray(cache.lengths[:3]), bs)
        want = decode_attention_ref(q, k, v, jnp.asarray(lens, jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_empty_prompt_first_append_reuses_reserved_block(self):
        """admit(slot, 0) reserves one block; the first decode token
        (position 0) must land in it, not allocate a second — the
        crossing heuristic alone would leak a block per empty prompt."""
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=4, block_size=4, n_kv_heads=1,
            head_dim=8, max_requests=1, max_blocks_per_req=4)
        cache.admit(0, 0)
        assert len(cache.req_blocks[0]) == 1
        assert cache.append_demand(np.array([0])) == 0
        cache.append_token(0)             # pos 0: reserved block covers it
        assert len(cache.req_blocks[0]) == 1
        for _ in range(3):
            cache.append_token(0)         # fill the block (4 tokens)
        assert len(cache.req_blocks[0]) == 1
        assert cache.append_demand(np.array([0])) == 1
        cache.append_token(0)             # pos 4: genuine crossing
        assert len(cache.req_blocks[0]) == 2

    def test_append_grows_blocks(self):
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=16, block_size=4, n_kv_heads=1,
            head_dim=8, max_requests=1, max_blocks_per_req=8)
        cache.admit(0, 4)                 # exactly one block
        assert len(cache.req_blocks[0]) == 1
        cache.append_token(0)             # 5 tokens -> needs 2 blocks
        assert len(cache.req_blocks[0]) == 2

    def test_release_returns_blocks(self):
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=8, block_size=4, n_kv_heads=1,
            head_dim=8, max_requests=2, max_blocks_per_req=4)
        cache.admit(0, 9)
        used = cache.allocator.n_blocks - cache.allocator.n_free
        assert used == 3
        cache.release(0)
        assert cache.allocator.n_free == 8
        assert cache.utilization() == 0.0

    def test_memory_savings_vs_dense(self):
        """The point of paging: resident KV ~ actual tokens, not max_len."""
        bs, max_len = 16, 512
        cache = PagedKVCache.create(
            n_layers=1, n_blocks=256, block_size=bs, n_kv_heads=1,
            head_dim=8, max_requests=8, max_blocks_per_req=max_len // bs)
        lens = [20, 33, 7, 100]
        for slot, L in enumerate(lens):
            cache.admit(slot, L)
        blocks_used = cache.allocator.n_blocks - cache.allocator.n_free
        dense_blocks = 4 * (max_len // bs)
        assert blocks_used * bs < 0.2 * dense_blocks * bs


class TestDispatchAndDrift:
    def test_instant_dispatch_completes_and_degrades(self):
        from repro.core import SimConfig, make_policy, simulate
        from repro.data import LONGBENCH_LIKE, batched_rounds_instance
        inst = batched_rounds_instance(LONGBENCH_LIKE, G=8, B=8,
                                       n_rounds=3, seed=5)
        out = {}
        for dispatch in ["central", "instant"]:
            cfg = SimConfig(G=8, B=8, dispatch=dispatch)
            f = simulate(inst, make_policy("fcfs"), cfg)
            b = simulate(inst, make_policy("bfio_h0"), cfg)
            assert f.completed == len(inst) and b.completed == len(inst)
            out[dispatch] = f.avg_imbalance / max(b.avg_imbalance, 1e-9)
        # paper §7.3: early binding weakens future-aware balancing
        assert out["instant"] < out["central"]

    def test_spec_decode_drift_iir(self):
        """Theorem 3 at delta=2.5 (speculative decoding)."""
        from repro.core import SimConfig, make_policy, simulate
        from repro.core.workload import scaled_drift
        from repro.data import LONGBENCH_LIKE, batched_rounds_instance
        inst = batched_rounds_instance(LONGBENCH_LIKE, G=8, B=12,
                                       n_rounds=3, seed=6,
                                       drift=scaled_drift(2.5))
        cfg = SimConfig(G=8, B=12)
        f = simulate(inst, make_policy("fcfs"), cfg)
        b = simulate(inst, make_policy("bfio_h0"), cfg)
        assert b.avg_imbalance < f.avg_imbalance
