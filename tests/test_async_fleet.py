"""Async event-driven fleet tests (repro.fleet.async_server + autoscale).

The anchors the ISSUE demands:

* ``barrier_compat=True`` reproduces :class:`FleetServer` stats (and
  telemetry, and generations) bit-for-bit — every router, R in {1,4,8};
* the staleness property: the router never dispatches to a draining or
  not-yet-warm replica, even while a scripted autoscaler churns the
  fleet (hypothesis-driven when available, seeded sweep otherwise);
* drain handoffs are bit-exact: an autoscaled run whose replicas drain
  mid-flight produces the same generations as a run that never scaled,
  with zero tokens recomputed;
* telemetry schema v2: summaries gain the replica-count series and
  per-replica utilization, while v1 files still read back.
"""
import warnings

warnings.filterwarnings("ignore")

import json

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.fleet import (
    AsyncFleetServer,
    Autoscaler,
    FleetServer,
    FleetTelemetry,
    SLOAutoscaler,
    SLOSpec,
    TargetUtilizationAutoscaler,
    make_autoscaler,
)
from repro.fleet.async_server import ACTIVE
from repro.fleet.telemetry import ACCEPTED_VERSIONS, SCHEMA_VERSION
from repro.models import init_params, split_params
from repro.serving import EngineConfig, ServeRequest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                  dtype="float32")
ROUTERS = ("round_robin", "least_loaded", "pod2", "bfio")
TIMING = dict(step_overhead=1e-3, t_token=2e-4)

_SETUP: dict = {}


def _setup():
    if not _SETUP:
        params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
        _SETUP["params"] = params
        _SETUP["mesh"] = jax.make_mesh((1, 1), ("data", "model"))
    return _SETUP["params"], _SETUP["mesh"]


def _requests(seed=7, n=12):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=i, tokens=rng.integers(1, 128, size=int(rng.integers(4, 20))),
        max_new_tokens=int(min(3 + rng.geometric(0.25), 16)))
        for i in range(n)]


def _submit(fs, reqs, gap=0.01):
    for i, r in enumerate(reqs):
        fs.submit(r, arrival_time=gap * i)


class _ScriptedAutoscaler(Autoscaler):
    """Deterministic fleet-size schedule: ``decide`` returns the target
    of the latest (t_from, target) entry whose time has passed — the
    test harness's way of forcing warm-ups and drains at known points."""

    def __init__(self, schedule, **kw):
        super().__init__(**kw)
        self.schedule = sorted(schedule)

    def decide(self, signals):
        target = self.schedule[0][1]
        for t_from, tgt in self.schedule:
            if signals["t"] >= t_from:
                target = tgt
        return target


# ----------------------------------------------------------------------
# barrier_compat == FleetServer, per router, per R
# ----------------------------------------------------------------------

class TestBarrierCompat:
    @pytest.mark.parametrize("R", [1, 4, 8])
    @pytest.mark.parametrize("router", ROUTERS)
    def test_stats_bit_identical(self, router, R):
        params, mesh = _setup()
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          **TIMING)
        runs = {}
        for kind in ("barrier", "compat"):
            tel = FleetTelemetry()
            if kind == "barrier":
                fs = FleetServer(CFG, params, ec, n_replicas=R,
                                 router=router, policy="bfio_h0",
                                 mesh=mesh, telemetry=tel)
            else:
                fs = AsyncFleetServer(CFG, params, ec, n_replicas=R,
                                      router=router, policy="bfio_h0",
                                      mesh=mesh, telemetry=tel,
                                      barrier_compat=True)
            reqs = _requests(seed=5, n=10)
            _submit(fs, reqs)
            stats = fs.run()
            runs[kind] = (stats, tel, [r.generated for r in reqs])
        assert runs["compat"][0] == runs["barrier"][0]
        assert runs["compat"][1].steps == runs["barrier"][1].steps
        assert runs["compat"][1].requests == runs["barrier"][1].requests
        assert runs["compat"][2] == runs["barrier"][2]

    def test_compat_rejects_autoscaler(self):
        params, mesh = _setup()
        with pytest.raises(ValueError, match="barrier_compat"):
            AsyncFleetServer(CFG, params, EngineConfig(), n_replicas=2,
                             router="bfio", mesh=mesh, barrier_compat=True,
                             autoscaler=TargetUtilizationAutoscaler())


# ----------------------------------------------------------------------
# async tick: correctness without an autoscaler
# ----------------------------------------------------------------------

class TestAsyncTick:
    def test_plain_async_matches_barrier_generations(self):
        params, mesh = _setup()
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          **TIMING)

        fb = FleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                         policy="bfio_h0", mesh=mesh)
        reqs_b = _requests(seed=3)
        _submit(fb, reqs_b)
        stats_b = fb.run()

        fa = AsyncFleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                              policy="bfio_h0", mesh=mesh,
                              max_snapshot_age=0.05)
        reqs_a = _requests(seed=3)
        _submit(fa, reqs_a)
        stats_a = fa.run()

        assert stats_a["fleet_kind"] == "async"
        assert stats_a["failed"] == 0
        assert stats_a["completed"] == stats_b["completed"]
        assert stats_a["tokens"] == stats_b["tokens"]
        assert [r.generated for r in reqs_a] == \
            [r.generated for r in reqs_b]

    def test_energy_accounting_is_complete(self):
        # per-tick telemetry energy must sum to the stats total exactly:
        # no serving or idle joule is dropped between ticks
        params, mesh = _setup()
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          **TIMING)
        tel = FleetTelemetry()
        fs = AsyncFleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                              policy="bfio_h0", mesh=mesh, telemetry=tel)
        _submit(fs, _requests(seed=9))
        stats = fs.run()
        total = sum(s["energy_j"] + s["idle_j"] for s in tel.steps)
        assert total == pytest.approx(stats["energy_j"], rel=1e-9)
        assert sum(s["idle_j"] for s in tel.steps) == \
            pytest.approx(stats["idle_j"], rel=1e-9)


# ----------------------------------------------------------------------
# staleness property: only ACTIVE replicas are ever routed to
# ----------------------------------------------------------------------

def _staleness_run(seed):
    params, mesh = _setup()
    ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                      cache_backend="paged", paged_block_size=16,
                      preemption_mode="swap", **TIMING)
    rng = np.random.default_rng(seed)
    # an oscillating schedule forces WARMING and DRAINING replicas to
    # coexist with routing decisions
    auto = _ScriptedAutoscaler(
        [(0.0, 3), (float(rng.uniform(0.02, 0.1)), 1),
         (float(rng.uniform(0.12, 0.2)), 3)],
        r_min=1, r_max=3, interval_s=0.01, warmup_s=0.02)
    fs = AsyncFleetServer(CFG, params, ec, n_replicas=3, router="bfio",
                          policy="bfio_h0", mesh=mesh, autoscaler=auto,
                          max_snapshot_age=0.02, record_routes=True)
    _submit(fs, _requests(seed=seed, n=10), gap=0.02)
    stats = fs.run()
    assert stats["failed"] == 0
    assert fs.route_log, "no routing decisions were recorded"
    saw_ineligible = False
    for entry in fs.route_log:
        states = entry["states"]
        eligible = set(entry["eligible"])
        # the eligibility mask is exactly the ACTIVE subset...
        assert eligible == {r for r, s in enumerate(states)
                            if s == ACTIVE}
        saw_ineligible |= len(eligible) < len(states)
        # ...every placement landed inside it...
        for g in entry["assigned"]:
            assert g in eligible, \
                f"routed to replica {g} in state {states[g]}"
        # ...and every view the router saw was within the staleness bound
        for age in entry["snapshot_age"]:
            assert 0.0 <= age <= fs.max_snapshot_age + 1e-12
    return saw_ineligible


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_staleness_property(seed):
        _staleness_run(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 7, 11, 42])
    def test_staleness_property(seed):
        _staleness_run(seed)


def test_staleness_sweep_exercises_ineligible_states():
    # at least one seed must route while some replica is warming or
    # draining, or the property above would be vacuous
    assert any(_staleness_run(seed) for seed in (0, 1, 7))


# ----------------------------------------------------------------------
# bit-exact drain handoff
# ----------------------------------------------------------------------

class TestDrainHandoff:
    def test_forced_drain_preserves_generations(self):
        params, mesh = _setup()
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          cache_backend="paged", paged_block_size=16,
                          preemption_mode="swap", **TIMING)

        fb = AsyncFleetServer(CFG, params, ec, n_replicas=3, router="bfio",
                              policy="bfio_h0", mesh=mesh)
        reqs_b = _requests(seed=4)
        _submit(fb, reqs_b, gap=0.0)
        stats_b = fb.run()

        # a t=0 burst puts residents on all three replicas; collapsing
        # to one mid-stream forces those residents to hand off
        # host-staged and finish elsewhere
        auto = _ScriptedAutoscaler([(0.0, 3), (0.05, 1)],
                                   r_min=1, r_max=3, interval_s=0.01,
                                   warmup_s=0.01)
        fa = AsyncFleetServer(CFG, params, ec, n_replicas=3, router="bfio",
                              policy="bfio_h0", mesh=mesh, autoscaler=auto)
        reqs_a = _requests(seed=4)
        _submit(fa, reqs_a, gap=0.0)
        stats_a = fa.run()

        assert stats_a["drain_handoffs"] > 0, \
            "schedule produced no drain handoffs — test is vacuous"
        assert stats_a["drain_tokens_lost"] == 0
        assert stats_a["failed"] == 0
        assert stats_a["completed"] == stats_b["completed"]
        assert [r.generated for r in reqs_a] == \
            [r.generated for r in reqs_b]
        # every finished request still carries a TTFT, including those
        # whose first token predates the drain
        for r in reqs_a:
            assert r.done

    def test_slot_backend_drains_passively(self):
        # without a host-staged swap path residents finish in place;
        # drain hands off only the waiters and loses nothing
        params, mesh = _setup()
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          **TIMING)
        auto = _ScriptedAutoscaler([(0.0, 2), (0.05, 1)],
                                   r_min=1, r_max=2, interval_s=0.01,
                                   warmup_s=0.01)
        fs = AsyncFleetServer(CFG, params, ec, n_replicas=2, router="bfio",
                              policy="bfio_h0", mesh=mesh, autoscaler=auto)
        reqs = _requests(seed=6)
        _submit(fs, reqs, gap=0.02)
        stats = fs.run()
        assert stats["failed"] == 0
        assert stats["completed"] == len(reqs)
        assert stats["drain_tokens_lost"] == 0


# ----------------------------------------------------------------------
# autoscaler policies
# ----------------------------------------------------------------------

class TestAutoscalers:
    def test_target_util_scales_with_load(self):
        a = TargetUtilizationAutoscaler(r_min=1, r_max=8, target=0.5)
        base = dict(t=1.0, n_active=4, n_on=4, queue_depth=0,
                    window_slo=None, pending=0)
        assert a.decide({**base, "utilization": 1.0}) == 8
        assert a.decide({**base, "utilization": 0.1}) == 1
        # unknown utilization holds the current size
        assert a.decide({**base, "utilization": None}) == 4

    def test_slo_autoscaler_reacts_to_misses(self):
        a = SLOAutoscaler(r_min=1, r_max=8, attain_target=0.95)
        base = dict(t=1.0, n_active=4, n_on=4, utilization=0.8,
                    queue_depth=0, pending=0)
        assert a.decide({**base, "window_slo": 0.5}) == 5
        assert a.decide({**base, "window_slo": 1.0}) == 4
        down = dict(base, utilization=0.1, window_slo=1.0)
        assert a.decide(down) == 3

    def test_make_autoscaler(self):
        assert isinstance(make_autoscaler("util", r_max=4),
                          TargetUtilizationAutoscaler)
        assert isinstance(make_autoscaler("slo"), SLOAutoscaler)
        a = SLOAutoscaler()
        assert make_autoscaler(a) is a
        with pytest.raises(ValueError, match="unknown autoscaler"):
            make_autoscaler("zeta")
        with pytest.raises(ValueError):
            TargetUtilizationAutoscaler(r_min=0)
        with pytest.raises(ValueError):
            TargetUtilizationAutoscaler(r_min=4, r_max=2)

    def test_autoscaled_run_tracks_diurnal_load(self):
        # the end-to-end autoscaling claim at test scale: fewer
        # replica-seconds on a bursty stream, nothing failed, and the
        # telemetry carries the replica-count series
        params, mesh = _setup()
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64,
                          cache_backend="paged", paged_block_size=16,
                          preemption_mode="swap", **TIMING)
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=1.0, tpot_s=0.1))
        auto = TargetUtilizationAutoscaler(r_min=1, r_max=4, target=0.7,
                                           interval_s=0.02, warmup_s=0.01)
        fs = AsyncFleetServer(CFG, params, ec, n_replicas=4, router="bfio",
                              policy="bfio_h0", mesh=mesh, telemetry=tel,
                              autoscaler=auto, max_snapshot_age=0.02)
        reqs = _requests(seed=8, n=16)
        # a long quiet tail after a burst: the fleet must shrink
        _submit(fs, reqs[:12], gap=0.005)
        for i, r in enumerate(reqs[12:]):
            fs.submit(r, arrival_time=0.5 + 0.2 * i)
        stats = fs.run()
        assert stats["failed"] == 0
        assert stats["scale_downs"] > 0
        assert stats["r_on_mean"] < 4.0
        summ = tel.summary()
        assert summ["replica_count"]["min"] < 4
        assert summ["replica_count"]["max"] <= 4
        assert len(summ["replica_utilization"]) == 4


# ----------------------------------------------------------------------
# telemetry schema v2
# ----------------------------------------------------------------------

class TestTelemetryV2:
    def _step(self, i, count=2, busy=(0.1, 0.2)):
        return dict(step=i, t=0.1 * (i + 1), dt=0.1,
                    replica_loads=[1.0, 2.0], replica_active=[1, 1],
                    replica_waiting=[0, 0], cross_imbalance=0.5,
                    energy_j=1.0, idle_j=0.25, tokens=4, preemptions=0,
                    prefix_hits=0, replica_count=count,
                    replica_busy=list(busy))

    def test_v2_summary_and_roundtrip(self, tmp_path):
        assert SCHEMA_VERSION == 4
        tel = FleetTelemetry()
        for i in range(3):
            tel.record_step(**self._step(i, count=2 - (i == 2)))
        summ = tel.summary()
        assert summ["replica_count"] == {"mean": pytest.approx(5 / 3),
                                         "min": 1, "max": 2}
        assert summ["replica_utilization"] == \
            [pytest.approx(1.0), pytest.approx(2.0)]
        # v2-shaped steps carry no v3 keys: the v3 derivations are
        # simply absent, exactly like v2's on a v1 file
        assert "prefix_revived" not in summ
        assert "prefix_cached_blocks_peak" not in summ
        path = tmp_path / "v2.jsonl"
        tel.write_jsonl(str(path))
        back = FleetTelemetry.read_jsonl(str(path))
        assert back.summary() == summ

    def test_v3_summary_and_roundtrip(self, tmp_path):
        tel = FleetTelemetry()
        for i, (rev, cached) in enumerate([(0, 2), (3, 5), (1, 4)]):
            tel.record_step(**self._step(i), prefix_revived=rev,
                            prefix_cached_blocks=cached)
        summ = tel.summary()
        # revived rows are per-step deltas (summed); the cached-block
        # count is a gauge (peak reported)
        assert summ["prefix_revived"] == 4
        assert summ["prefix_cached_blocks_peak"] == 5
        path = tmp_path / "v3.jsonl"
        tel.write_jsonl(str(path))
        back = FleetTelemetry.read_jsonl(str(path))
        assert back.summary() == summ

    def test_v1_files_still_read(self, tmp_path):
        assert 1 in ACCEPTED_VERSIONS
        v1_step = {k: v for k, v in self._step(0).items()
                   if k not in ("replica_count", "replica_busy")}
        path = tmp_path / "v1.jsonl"
        with open(path, "w") as f:
            f.write(json.dumps(
                {"kind": "meta", "schema_version": 1,
                 "slo": {"ttft_s": 1.0, "tpot_s": 0.1},
                 "record_steps": True}) + "\n")
            f.write(json.dumps({"kind": "step", **v1_step}) + "\n")
        tel = FleetTelemetry.read_jsonl(str(path))
        summ = tel.summary()
        # the v2 derivations are simply absent — not wrong, not None
        assert "replica_count" not in summ
        assert "replica_utilization" not in summ

    def test_unknown_version_rejected(self, tmp_path):
        path = tmp_path / "v5.jsonl"
        with open(path, "w") as f:
            f.write(json.dumps(
                {"kind": "meta", "schema_version": 5,
                 "slo": {"ttft_s": 1.0, "tpot_s": 0.1},
                 "record_steps": True}) + "\n")
        with pytest.raises(ValueError, match="schema_version"):
            FleetTelemetry.read_jsonl(str(path))

    def test_v4_summary_and_roundtrip(self, tmp_path):
        from repro.obs import IDLE_CAUSES, fold_sum
        tel = FleetTelemetry()
        splits = [[0.25, 0.0, 0.0, 0.0, 0.0, 0.0],
                  [0.0, 0.125, 0.125, 0.0, 0.0, 0.0],
                  [0.0, 0.0, 0.0, 0.0, 0.0, 0.25]]
        for i, (g, sp) in enumerate(zip([0, 1, -1], splits)):
            tel.record_step(**self._step(i), gating_replica=g,
                            idle_split=sp)
        summ = tel.summary()
        assert summ["idle_by_cause"] == {
            name: v for name, v in zip(
                IDLE_CAUSES, [0.25, 0.125, 0.125, 0.0, 0.0, 0.25])}
        # trough rows (gating -1) are excluded from the gating counts
        assert summ["gating_steps"] == {"0": 1, "1": 1}
        # each row's split folds back to its idle_j bit-exactly
        for s in tel.steps:
            assert fold_sum(s["idle_split"]) == s["idle_j"]
        path = tmp_path / "v4.jsonl"
        tel.write_jsonl(str(path))
        back = FleetTelemetry.read_jsonl(str(path))
        assert back.summary() == summ
        for s in back.steps:
            assert fold_sum(s["idle_split"]) == s["idle_j"]

    def test_v3_shaped_rows_skip_v4_derivations(self):
        tel = FleetTelemetry()
        tel.record_step(**self._step(0), prefix_revived=0,
                        prefix_cached_blocks=1)
        summ = tel.summary()
        assert "idle_by_cause" not in summ
        assert "gating_steps" not in summ
