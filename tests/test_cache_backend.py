"""Cache-backend + scheduler seam tests.

The contract under test (ISSUE 3 / ROADMAP open items):

* ``cache_backend="paged"`` is a pure memory-layout change: on dense
  models it must produce bit-identical engine stats AND generations to
  ``cache_backend="slot"`` (the ``"gather"`` paged attention oracle makes
  this exact — masked positions contribute exactly zero).
* Resident KV under paging tracks actual tokens, not G*B*max_seq_len.
* Chunked prefill interleaves admission waves with decode: per-step
  prompt work is bounded by the budget and active decoders advance every
  step (never starved), while a large-enough budget degenerates to the
  synchronous schedule.
* MoE models run end to end on the paged/chunked paths.  Stats parity
  holds there too (scheduling is token-value independent), but generation
  parity is NOT asserted for MoE: expert-capacity truncation couples
  batch rows, so any low-bit numeric difference between attention
  implementations can legitimately flip routing and diverge token
  streams — the documented expert-capacity divergence.
"""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import make_policy
from repro.models import (
    init_cache,
    init_params,
    prefill_fn,
    split_params,
    supports_paged_stack,
)
from repro.serving import (
    EngineConfig,
    PagedCacheBackend,
    ServeRequest,
    ServingEngine,
    SlotCacheBackend,
    make_cache_backend,
)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")
MOE_CFG = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, n_experts=4,
                      experts_per_token=2, moe_d_ff=64, vocab_size=128,
                      dtype="float32")
SSM_CFG = ModelConfig(name="tiny-ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      ssm_state=16, dtype="float32")

STAT_KEYS = ("steps", "tokens", "energy_j", "avg_imbalance", "time_s")


@pytest.fixture(scope="module")
def setup():
    params, _ = split_params(init_params(CFG, jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


@pytest.fixture(scope="module")
def moe_setup():
    params, _ = split_params(init_params(MOE_CFG, jax.random.PRNGKey(1)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return params, mesh


def _requests(n=14, seed=3, max_new=(3, 10), plen=(4, 30)):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            rid=i,
            tokens=rng.integers(1, 128, size=int(rng.integers(*plen))),
            max_new_tokens=int(rng.integers(*max_new)))
        for i in range(n)
    ]


def _run(params, mesh, policy, reqs, *, cfg=CFG, G=2, B=4, max_seq_len=64,
         max_steps=1000, **ec_kw):
    eng = ServingEngine(
        cfg, params,
        EngineConfig(n_workers=G, slots_per_worker=B,
                     max_seq_len=max_seq_len, **ec_kw),
        make_policy(policy), mesh=mesh)
    for r in reqs:
        eng.submit(r)
    stats = eng.run(max_steps=max_steps)
    return eng, stats


class TestSlotPagedParity:
    @pytest.mark.parametrize("policy", ["fcfs", "jsq", "bfio_h0"])
    def test_stats_and_generations_identical(self, setup, policy):
        params, mesh = setup
        ra, rb = _requests(), _requests()
        _, sa = _run(params, mesh, policy, ra, cache_backend="slot")
        _, sb = _run(params, mesh, policy, rb, cache_backend="paged")
        for k in STAT_KEYS:
            assert sa[k] == sb[k], f"{k}: slot={sa[k]} paged={sb[k]}"
        for a, b in zip(ra, rb):
            assert a.generated == b.generated, f"request {a.rid} diverged"
            assert a.worker == b.worker

    def test_paged_attn_ref_impl_stats_parity(self, setup):
        """The standalone jnp oracle kernel path: stats parity is exact
        (scheduling never reads token values); generations are close but
        not bit-pinned, so only stats are compared."""
        params, mesh = setup
        ra, rb = _requests(), _requests()
        _, sa = _run(params, mesh, "jsq", ra, cache_backend="slot")
        _, sb = _run(params, mesh, "jsq", rb, cache_backend="paged",
                     paged_attn_impl="ref")
        for k in STAT_KEYS:
            assert sa[k] == sb[k]

    def test_ref_engine_matches_paged_vec(self, setup):
        """Transitivity check: the seed ref engine == slot vec == paged."""
        params, mesh = setup
        ra, rb = _requests(), _requests()
        _, sa = _run(params, mesh, "fcfs", ra, engine_mode="ref")
        _, sb = _run(params, mesh, "fcfs", rb, cache_backend="paged")
        for k in STAT_KEYS:
            assert sa[k] == sb[k]
        for a, b in zip(ra, rb):
            assert a.generated == b.generated

    def test_paged_decode_logits_match_slot(self, setup):
        """Model-level oracle check: one decode step through the paged
        path reproduces the contiguous decode bit-for-bit."""
        from repro.models import decode_fn, paged_decode_fn

        params, mesh = setup
        rng = np.random.default_rng(5)
        ec = EngineConfig(n_workers=1, slots_per_worker=3, max_seq_len=64,
                          paged_block_size=16)
        slot_b = SlotCacheBackend(CFG, params, ec, mesh)
        paged_b = PagedCacheBackend(CFG, params, ec, mesh)
        lens = np.array([13, 40, 1], np.int32)
        toks = np.zeros((3, 64), np.int32)
        for i, L in enumerate(lens):
            toks[i, :L] = rng.integers(1, 128, size=L)
        batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        _, mini = prefill_fn(CFG, params, batch, max_len=64, mesh=mesh)
        src = np.arange(3)
        slot_b.write_prefill(mini, src, src)
        paged_b.write_prefill(mini, src, src)
        step_toks = np.array([7, 11, 13], np.int32)
        nxt_slot = slot_b.decode(step_toks, np.arange(3), 3)
        nxt_paged = paged_b.decode(step_toks, np.arange(3), 3)
        assert np.array_equal(nxt_slot, nxt_paged)
        # and the pallas kernel agrees with the contiguous logits closely
        logits_slot, _ = decode_fn(
            CFG, params, slot_b.cache, jnp.asarray(step_toks), mesh=mesh)
        kv = paged_b.kv
        nxt_pl, _, _ = paged_decode_fn(
            CFG, params, kv.k_pool, kv.v_pool,
            jnp.asarray(kv.block_tables[:3]), jnp.asarray(kv.lengths[:3]),
            jnp.full(3, paged_b.n_blocks, jnp.int32),
            jnp.zeros(3, jnp.int32), jnp.asarray(step_toks),
            block_size=16, attn_impl="pallas", mesh=mesh)
        del logits_slot  # greedy tokens are the comparable artifact
        assert np.array_equal(np.asarray(nxt_pl), nxt_slot)


class TestResidentKV:
    def test_resident_tracks_tokens_and_frees(self, setup):
        params, mesh = setup
        reqs = _requests(n=6, seed=7, plen=(4, 20))
        eng, _ = _run(params, mesh, "jsq", reqs, G=4, B=8,
                      cache_backend="paged", paged_block_size=16)
        dense = eng.backend.pool_bytes()       # slot layout pins this
        assert 0 < eng.kv_peak_bytes < 0.25 * dense
        # all requests completed -> every block returned to the pool
        assert eng.backend.resident_kv_bytes() == 0
        assert eng.backend.kv.allocator.n_free == eng.backend.n_blocks

    def test_unsupported_family_rejected(self):
        # params never touched: the backend rejects the family up front
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert not supports_paged_stack(SSM_CFG)
        with pytest.raises(ValueError, match="attention-family"):
            ServingEngine(SSM_CFG, None,
                          EngineConfig(cache_backend="paged"),
                          make_policy("fcfs"), mesh=mesh)

    def test_decode_past_max_seq_len_matches_slot(self, setup):
        """A request whose decode outgrows max_seq_len: the slot layout
        silently drops the overflow KV writes and keeps decoding on the
        frozen cache; the paged backend must do the same (stop growing
        the block table) instead of overflowing it."""
        params, mesh = setup
        out = {}
        for backend in ("slot", "paged"):
            r = ServeRequest(rid=0, tokens=np.arange(1, 9),
                             max_new_tokens=40)
            _, s = _run(params, mesh, "fcfs", [r], G=1, B=1,
                        max_seq_len=32, cache_backend=backend,
                        paged_block_size=16)
            assert r.done and len(r.generated) == 40
            out[backend] = (s, r.generated)
        for k in STAT_KEYS:
            assert out["slot"][0][k] == out["paged"][0][k]
        assert out["slot"][1] == out["paged"][1]

    def test_block_size_must_divide_max_seq(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="divide"):
            ServingEngine(CFG, params,
                          EngineConfig(max_seq_len=64, paged_block_size=24,
                                       cache_backend="paged"),
                          make_policy("fcfs"), mesh=mesh)


class TestChunkedPrefill:
    @pytest.mark.parametrize("backend", ["slot", "paged"])
    def test_decode_never_starved_and_budget_respected(self, setup, backend):
        """An admission wave of long prompts lands while requests are
        decoding: every step processes at most `budget` prompt tokens and
        every already-decoding request advances every step."""
        params, mesh = setup
        chunk = 16
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=6, max_seq_len=64,
                         cache_backend=backend, prefill_chunk=chunk),
            make_policy("fcfs"), mesh=mesh)
        warm = _requests(n=2, seed=1, plen=(4, 8), max_new=(30, 31))
        for r in warm:
            eng.submit(r)
        for _ in range(3):
            eng.step()
        wave = _requests(n=4, seed=2, plen=(60, 61), max_new=(2, 3))
        for r in wave:
            eng.submit(r)
        while not all(r.done for r in wave):
            gen_before = [len(r.generated) for r in warm]
            info = eng.step()
            assert info["prefill_tokens"] <= chunk
            for r, before in zip(warm, gen_before):
                assert len(r.generated) == before + 1, \
                    "active decoder starved during the admission wave"
            assert eng.steps < 200
        # wave prompts were chunked: 60 tokens / 16 per step needs >= 4
        # steps per request, FCFS -> admission never ran them in one step
        assert all(r.done for r in wave)

    def test_large_budget_degenerates_to_sync_schedule(self, setup):
        """budget >= the whole wave => chunked scheduling == synchronous
        scheduling (bit-identical stats)."""
        params, mesh = setup
        ra, rb = _requests(), _requests()
        _, sa = _run(params, mesh, "jsq", ra)
        _, sb = _run(params, mesh, "jsq", rb, prefill_chunk=64,
                     prefill_budget=64 * 64)
        for k in STAT_KEYS:
            assert sa[k] == sb[k], f"{k}: sync={sa[k]} chunked={sb[k]}"

    @pytest.mark.parametrize("backend", ["slot", "paged"])
    def test_chunked_slot_paged_parity(self, setup, backend):
        """Chunked prefill itself is backend-invariant (gather oracle)."""
        params, mesh = setup
        ra, rb = _requests(seed=9), _requests(seed=9)
        _, sa = _run(params, mesh, "jsq", ra, cache_backend="slot",
                     prefill_chunk=8)
        _, sb = _run(params, mesh, "jsq", rb, cache_backend=backend,
                     prefill_chunk=8)
        for k in STAT_KEYS:
            assert sa[k] == sb[k]
        for a, b in zip(ra, rb):
            assert a.generated == b.generated

    def test_chunk_prefill_matches_full_prefill(self, setup):
        """Numerics: two chunks reproduce one-shot prefill to fp32
        tolerance (different attention kernels, same math)."""
        params, mesh = setup
        rng = np.random.default_rng(13)
        L = 24
        prompt = rng.integers(1, 128, size=L).astype(np.int32)
        batch = {"tokens": jnp.asarray(prompt[None]),
                 "lengths": jnp.asarray(np.array([L], np.int32))}
        logits_full, cache_full = prefill_fn(CFG, params, batch,
                                             max_len=64, mesh=mesh)
        ec = EngineConfig(n_workers=1, slots_per_worker=1, max_seq_len=64)
        backend = SlotCacheBackend(CFG, params, ec, mesh)
        c = 14
        toks = np.zeros((1, c), np.int32)
        toks[0, :c] = prompt[:c]
        backend.prefill_chunk(toks, np.array([0], np.int32),
                              np.array([c], np.int32), np.array([0]))
        toks2 = np.zeros((1, c), np.int32)
        toks2[0, :L - c] = prompt[c:]
        logits = backend.prefill_chunk(toks2, np.array([c], np.int32),
                                       np.array([L - c], np.int32),
                                       np.array([0]))
        np.testing.assert_allclose(logits[0], np.asarray(logits_full)[0],
                                   atol=2e-4, rtol=2e-4)
        got_k = np.asarray(backend.cache["blocks"]["k"])[:, 0, :L]
        want_k = np.asarray(cache_full["blocks"]["k"])[:, 0, :L]
        np.testing.assert_allclose(got_k, want_k, atol=2e-5)
        assert int(np.asarray(backend.cache["lengths"])[0]) == L

    def test_policy_sees_prefill_progress(self, setup):
        """SchedulerContext.active_prefill_remaining is populated under
        chunking and zero otherwise."""
        from repro.core.policies import Policy

        params, mesh = setup
        seen = []

        class Probe(Policy):
            name = "probe"

            def assign(self, ctx):
                if ctx.active_prefill_remaining is not None \
                        and len(ctx.active_prefill_remaining):
                    seen.append(ctx.active_prefill_remaining.copy())
                out = np.full(ctx.n_wait, -1, dtype=np.int64)
                caps = ctx.caps.copy()
                for i in range(ctx.n_admit):
                    g = int(np.argmax(caps))
                    if caps[g] <= 0:
                        break
                    out[i] = g
                    caps[g] -= 1
                return out

        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=4, max_seq_len=64,
                         prefill_chunk=8),
            Probe(), mesh=mesh)
        for r in _requests(n=6, seed=4, plen=(30, 40)):
            eng.submit(r)
        eng.run(max_steps=500)
        assert any((s > 0).any() for s in seen), \
            "policy never observed in-flight chunk progress"

    def test_chunked_rejected_for_non_attn_families(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError, match="chunked prefill"):
            ServingEngine(SSM_CFG, None,
                          EngineConfig(prefill_chunk=16),
                          make_policy("fcfs"), mesh=mesh)
        # a budget-only config must hit the same gate (budget implies
        # chunking), and sliding-window configs fail at construction,
        # not mid-serving
        with pytest.raises(ValueError, match="chunked prefill"):
            ServingEngine(SSM_CFG, None,
                          EngineConfig(prefill_budget=16),
                          make_policy("fcfs"), mesh=mesh)
        swin = ModelConfig(name="tiny-swin", family="dense", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                           vocab_size=128, sliding_window=16,
                           dtype="float32")
        with pytest.raises(ValueError, match="sliding"):
            ServingEngine(swin, None, EngineConfig(prefill_chunk=8),
                          make_policy("fcfs"), mesh=mesh)

    @pytest.mark.parametrize("backend", ["slot", "paged"])
    def test_empty_prompt_under_chunking(self, setup, backend):
        """A zero-length prompt has no chunk work: it must take the
        synchronous prefill path instead of crashing the paged backend
        or leaving a phantom prefill job behind."""
        params, mesh = setup
        reqs = [ServeRequest(rid=0, tokens=np.array([], dtype=np.int64),
                             max_new_tokens=3),
                ServeRequest(rid=1, tokens=np.arange(1, 20),
                             max_new_tokens=3)]
        eng, _ = _run(params, mesh, "fcfs", reqs, G=1, B=2,
                      cache_backend=backend, prefill_chunk=8)
        assert all(r.done for r in reqs)
        assert eng.scheduler.n_prefilling == 0

    def test_budget_alone_enables_chunking(self, setup):
        """--prefill-budget without --prefill-chunk must not be inert."""
        params, mesh = setup
        eng = ServingEngine(
            CFG, params,
            EngineConfig(n_workers=1, slots_per_worker=4, max_seq_len=64,
                         prefill_budget=8),
            make_policy("fcfs"), mesh=mesh)
        assert eng.scheduler.chunked and eng.scheduler.budget == 8
        for r in _requests(n=4, seed=2, plen=(20, 30)):
            eng.submit(r)
        info = eng.step()
        assert 0 < info["prefill_tokens"] <= 8

    def test_ref_mode_rejects_new_seams(self, setup):
        params, mesh = setup
        with pytest.raises(ValueError, match="ref"):
            ServingEngine(CFG, params,
                          EngineConfig(engine_mode="ref",
                                       cache_backend="paged"),
                          make_policy("fcfs"), mesh=mesh)
        with pytest.raises(ValueError, match="ref"):
            ServingEngine(CFG, params,
                          EngineConfig(engine_mode="ref", prefill_chunk=8),
                          make_policy("fcfs"), mesh=mesh)


class TestMoEFamily:
    """MoE engine smoke runs: the paged/chunked paths execute end to end.

    No generation-parity assert: expert capacity is a *batch-coupled*
    resource, so compact-decode batch composition and low-bit attention
    differences can legitimately reroute tokens between experts and
    diverge the streams.  Stats parity still holds — admission, loads,
    and completion times never read token values (eos disabled).
    """

    def test_moe_paged_chunked_smoke(self, moe_setup):
        params, mesh = moe_setup
        ra = _requests(n=10, seed=6)
        rb = _requests(n=10, seed=6)
        _, sa = _run(params, mesh, "jsq", ra, cfg=MOE_CFG,
                     cache_backend="slot")
        _, sb = _run(params, mesh, "jsq", rb, cfg=MOE_CFG,
                     cache_backend="paged", prefill_chunk=16)
        assert all(r.done for r in rb)
        # scheduling metrics that ignore chunk timing shifts match only
        # when chunking is off; with chunking on we assert completion and
        # token counts (every request generated its full budget)
        assert sb["tokens"] == sa["tokens"]
        for a, b in zip(ra, rb):
            assert len(a.generated) == len(b.generated)

    def test_moe_stats_parity_without_chunking(self, moe_setup):
        params, mesh = moe_setup
        ra = _requests(n=10, seed=8)
        rb = _requests(n=10, seed=8)
        _, sa = _run(params, mesh, "jsq", ra, cfg=MOE_CFG,
                     cache_backend="slot")
        _, sb = _run(params, mesh, "jsq", rb, cfg=MOE_CFG,
                     cache_backend="paged")
        for k in STAT_KEYS:
            assert sa[k] == sb[k]


class TestBackendFactory:
    def test_make_cache_backend_names(self, setup):
        params, mesh = setup
        ec = EngineConfig(n_workers=1, slots_per_worker=2, max_seq_len=64)
        assert make_cache_backend("slot", CFG, params, ec, mesh).name \
            == "slot"
        assert make_cache_backend("paged", CFG, params, ec, mesh).name \
            == "paged"
        with pytest.raises(ValueError, match="unknown cache backend"):
            make_cache_backend("mmap", CFG, params, ec, mesh)

    def test_slot_cache_property_roundtrip(self, setup):
        """engine.cache keeps working (ref path + existing tests)."""
        params, mesh = setup
        eng = ServingEngine(CFG, params,
                            EngineConfig(n_workers=1, slots_per_worker=2,
                                         max_seq_len=64),
                            make_policy("fcfs"), mesh=mesh)
        assert eng.cache is eng.backend.cache
        new = init_cache(CFG, 2, 64)
        eng.cache = new
        assert eng.backend.cache is new
