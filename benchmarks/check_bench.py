"""Perf-harness smoke check: run the balancer benchmark on tiny shapes and
validate the emitted JSON schema and that every timing is finite/positive.

Wired into tier-1 (tests/test_bench_smoke.py) so bit-rot in the benchmark
harness is caught by the test suite, not at the next perf investigation.

Run standalone:  PYTHONPATH=src python -m benchmarks.check_bench
"""
from __future__ import annotations

import math
import os
import tempfile

from .balancer_bench import ALL_SECTIONS as _SECTIONS

ALL_SECTIONS = set(_SECTIONS)   # single source of truth: balancer_bench

SOLVER_KEYS = {"G", "N", "W", "swap_iters", "prune_k", "post_tiled_us",
               "J_post", "greedy_us", "pre_dense_us", "J_pre", "speedup",
               "refine_speedup", "quality_rel_diff"}
SIM_KEYS = {"G", "B", "policy", "pre_steps_per_s", "post_steps_per_s",
            "pre_wall_s", "post_wall_s", "steps", "speedup", "metrics_equal"}
BATCH_KEYS = {"C", "G", "N", "W", "prune_k", "batch_us", "sequential_us",
              "speedup"}
ENGINE_KEYS = {"G", "B", "policy", "n_requests", "pre_steps_per_s",
               "post_steps_per_s", "pre_wall_s", "post_wall_s", "steps",
               "speedup", "metrics_equal"}
PAGED_GRID_KEYS = {"G", "B", "policy", "n_requests", "slot_steps_per_s",
                   "paged_steps_per_s", "slot_wall_s", "paged_wall_s",
                   "steps", "slot_kv_bytes", "paged_kv_peak_bytes",
                   "paged_pool_bytes", "kv_bytes_ratio", "speedup",
                   "metrics_equal"}
PAGED_STALL_KEYS = {"G", "B", "prefill_chunk", "burst_prompts",
                    "prompt_len", "warm_decoders", "repeats",
                    "steady_step_ms_sync", "burst_max_step_ms_sync",
                    "stall_x_sync", "burst_steps_sync",
                    "steady_step_ms_chunked", "burst_max_step_ms_chunked",
                    "stall_x_chunked", "burst_steps_chunked"}
PREEMPT_PRESSURE_KEYS = {"G", "B", "policy", "n_requests", "mode",
                         "pool_frac", "pool_blocks",
                         "peak_blocks_unconstrained", "steps",
                         "steps_per_s", "unconstrained_steps",
                         "preemptions", "tokens_swapped",
                         "tokens_recomputed", "completed", "gens_equal"}
PREEMPT_PREFIX_KEYS = {"G", "B", "policy", "n_requests",
                       "shared_prefix_len", "steps_per_s_off",
                       "steps_per_s_on", "kv_peak_bytes_off",
                       "kv_peak_bytes_on", "prefix_hits", "prefix_queries",
                       "prefix_hit_rate", "kv_bytes_ratio", "gens_equal"}
PREEMPT_PERSIST_KEYS = {"G", "B", "policy", "n_requests",
                        "shared_prefix_len", "prefix_revived",
                        "kv_bytes_ratio", "gens_equal"} | {
    f"{k}_{m}" for m in ("off", "admission", "lru")
    for k in ("steps_per_s", "kv_peak_bytes")} | {
    f"{k}_{m}" for m in ("admission", "lru")
    for k in ("prefix_hits", "prefix_queries", "prefix_hit_rate")}
# fleet rows always carry the round_robin + bfio columns (full runs add
# least_loaded / pod2); the scenario gate below needs exactly these two
FLEET_SCENARIO_KEYS = {"scenario", "R", "G", "B", "n_requests",
                       "load_factor", "bfio_wins"} | {
    f"{r}_{m}" for r in ("round_robin", "bfio")
    for m in ("imbalance", "energy_per_token", "throughput_tok_s",
              "ttft_p95", "slo_attainment", "completed", "failed",
              "steps", "wall_s")}
FLEET_PARITY_KEYS = {"G", "B", "n_requests", "routers", "steps",
                     "stats_equal"}
FLEET_AFFINITY_KEYS = {"scenario", "R", "G", "B", "n_requests",
                       "affinity_wins"} | {
    f"{r}_{m}" for r in ("bfio", "bfio_affinity")
    for m in ("imbalance", "energy_per_token", "prefix_hits",
              "prefix_revived", "completed", "failed", "steps",
              "wall_s")}
FLEET_SCENARIOS = {"steady", "flash_crowd", "diurnal", "agentic",
                   "long_doc"}
FLEET_MIN_WINS = 3
FSCALE_SPEEDUP_KEYS = {"scenario", "R", "G", "B", "router", "n_requests",
                       "load_factor", "repeats", "steps", "ref_wall_s",
                       "vec_wall_s", "ref_steps_per_s", "vec_steps_per_s",
                       "speedup", "stats_equal", "telemetry_equal",
                       "completed", "failed"}
FSCALE_POD_KEYS = {"scenario", "R", "G", "B", "pods", "n_requests",
                   "load_factor", "pod_wins"} | {
    f"{r}_{m}" for r in ("round_robin", "pod_bfio")
    for m in ("imbalance", "energy_per_token", "completed", "failed",
              "steps", "wall_s", "steps_per_s")}
# full-grid-only thresholds (wall-clock gates are meaningless on the
# tiny smoke shapes): the vectorized hot path must pay at scale, and
# the hierarchical pod run must both finish and beat flat round_robin
FSCALE_MIN_R = 64           # the speedup grid must reach this R
FSCALE_MIN_SPEEDUP = 5.0    # best router at R >= FSCALE_MIN_R
FSCALE_MIN_EACH = 0.8       # no router may regress under vec
FSCALE_POD_MIN_R = 256      # the pod-routed run must reach this R
FASYNC_COMPAT_KEYS = {"scenario", "R", "G", "B", "router", "n_requests",
                      "load_factor", "steps", "completed", "failed",
                      "stats_equal", "telemetry_equal", "gens_equal"}
FASYNC_DIURNAL_KEYS = {"scenario", "R", "G", "B", "router", "n_requests",
                       "load_factor", "target_util", "interval_s",
                       "warmup_s", "idle_saving", "drain_handoffs",
                       "tokens_lost", "scale_ups", "scale_downs",
                       "r_on_mean", "gens_equal"} | {
    f"{side}_{m}" for side in ("barrier", "async")
    for m in ("idle_j", "energy_per_token", "slo_attainment",
              "completed", "failed", "tokens", "steps")}
OBS_KEYS = {"scenario", "variant", "R", "G", "B", "n_requests",
            "load_factor", "wall_s_enabled", "wall_s_disabled",
            "overhead_ratio", "idle_j", "ledger_total_j",
            "ledger_matches", "split_sums_match", "by_cause",
            "gating_steps", "trough_steps", "trace_events",
            "trace_spans", "trace_events_disabled", "trace_roundtrip",
            "spans_match_latency", "stats_bit_identical",
            "telemetry_bit_identical", "telemetry_roundtrip"}
OBS_VARIANTS = {"barrier", "async"}
# enabled-recorder wall-clock bound, full grid only (smoke shapes are
# dispatch-jitter-dominated); generous because the gate is "observation
# is cheap", not a perf race — the exactness gates are the hard ones
OBS_MAX_OVERHEAD = 10.0


def _finite_pos(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def check(doc: dict) -> None:
    """Raise AssertionError on any schema/sanity violation.  The
    expected section set follows ``meta["sections"]`` (the --sections
    filter); docs without it are required to carry every section."""
    assert set(doc) >= {"meta", "rows"}, "missing meta/rows"
    meta = doc["meta"]
    assert meta.get("bench") == "balancer"
    rows = doc["rows"]
    assert rows, "no benchmark rows"
    expected = set(meta.get("sections") or ALL_SECTIONS)
    assert expected <= ALL_SECTIONS, expected - ALL_SECTIONS
    sections = {r.get("section") for r in rows}
    assert sections == expected, (sections, expected)
    if "engine_paged" in expected:
        paged_kinds = {r.get("kind") for r in rows
                       if r.get("section") == "engine_paged"}
        assert paged_kinds == {"grid", "stall"}, paged_kinds
    if "engine_preempt" in expected:
        preempt_kinds = {r.get("kind") for r in rows
                         if r.get("section") == "engine_preempt"}
        assert preempt_kinds == {"pressure", "prefix", "persist"}, \
            preempt_kinds
        preempt_modes = {r.get("mode") for r in rows
                         if r.get("section") == "engine_preempt"
                         and r.get("kind") == "pressure"}
        assert preempt_modes == {"swap", "recompute"}, preempt_modes
    if "fleet" in expected:
        fleet_kinds = {r.get("kind") for r in rows
                       if r.get("section") == "fleet"}
        assert fleet_kinds == {"scenario", "parity", "affinity"}, \
            fleet_kinds
        scen = [r for r in rows if r.get("section") == "fleet"
                and r.get("kind") == "scenario"]
        assert ({r["scenario"] for r in scen} == FLEET_SCENARIOS), \
            {r["scenario"] for r in scen}
        # THE fleet gate: the paper's principle must pay at the replica
        # tier — BF-IO routing beats round-robin on both cross-replica
        # imbalance and energy-per-token on most scenario traces
        wins = sum(bool(r["bfio_wins"]) for r in scen)
        assert wins >= FLEET_MIN_WINS, \
            f"bfio beat round_robin on only {wins}/{len(scen)} scenarios"
    if "fleet_scale" in expected:
        fs_kinds = {r.get("kind") for r in rows
                    if r.get("section") == "fleet_scale"}
        assert fs_kinds == {"speedup", "pod"}, fs_kinds
        spd = [r for r in rows if r.get("section") == "fleet_scale"
               and r.get("kind") == "speedup"]
        pod = [r for r in rows if r.get("section") == "fleet_scale"
               and r.get("kind") == "pod"]
        if not meta.get("smoke"):
            # THE fleet_scale gates, full grid only
            big = [r for r in spd if r["R"] >= FSCALE_MIN_R]
            assert big, f"no speedup rows at R >= {FSCALE_MIN_R}"
            best = max(r["speedup"] for r in big)
            assert best >= FSCALE_MIN_SPEEDUP, \
                (f"vec fleet hot path only {best:.2f}x ref at "
                 f"R >= {FSCALE_MIN_R} (need {FSCALE_MIN_SPEEDUP}x)")
            assert any(r["R"] >= FSCALE_POD_MIN_R for r in pod), \
                f"no pod-routed run at R >= {FSCALE_POD_MIN_R}"
    if "fleet_async" in expected:
        fa_kinds = {r.get("kind") for r in rows
                    if r.get("section") == "fleet_async"}
        assert fa_kinds == {"compat", "diurnal"}, fa_kinds
    if "obs" in expected:
        obs_variants = {r.get("variant") for r in rows
                        if r.get("section") == "obs"}
        assert obs_variants == OBS_VARIANTS, obs_variants
    for r in rows:
        sec = r["section"]
        if sec == "solver":
            assert SOLVER_KEYS <= set(r), SOLVER_KEYS - set(r)
            assert _finite_pos(r["post_tiled_us"])
            assert math.isfinite(r["J_post"])
            if r["pre_dense_us"] is not None:
                assert _finite_pos(r["pre_dense_us"])
                assert _finite_pos(r["speedup"])
                assert math.isfinite(r["quality_rel_diff"])
        elif sec == "simulator":
            assert SIM_KEYS <= set(r), SIM_KEYS - set(r)
            assert _finite_pos(r["pre_steps_per_s"])
            assert _finite_pos(r["post_steps_per_s"])
            assert r["metrics_equal"] is True, \
                "vectorized simulator diverged from the reference"
        elif sec == "batch":
            assert BATCH_KEYS <= set(r), BATCH_KEYS - set(r)
            assert _finite_pos(r["batch_us"])
            assert _finite_pos(r["sequential_us"])
        elif sec == "engine":
            assert ENGINE_KEYS <= set(r), ENGINE_KEYS - set(r)
            assert _finite_pos(r["pre_steps_per_s"])
            assert _finite_pos(r["post_steps_per_s"])
            assert _finite_pos(r["steps"])
            assert r["metrics_equal"] is True, \
                "vectorized engine stats diverged from the ref engine"
        elif sec == "engine_paged":
            if r.get("kind") == "grid":
                assert PAGED_GRID_KEYS <= set(r), PAGED_GRID_KEYS - set(r)
                assert _finite_pos(r["slot_steps_per_s"])
                assert _finite_pos(r["paged_steps_per_s"])
                assert _finite_pos(r["slot_kv_bytes"])
                assert _finite_pos(r["paged_kv_peak_bytes"])
                # the paging win: peak resident KV never exceeds the dense
                # per-slot reservation (and in practice is well below it)
                assert r["kv_bytes_ratio"] <= 1.0 + 1e-9, r["kv_bytes_ratio"]
                assert r["metrics_equal"] is True, \
                    "paged backend stats diverged from the slot backend"
            else:
                assert r.get("kind") == "stall", r.get("kind")
                assert PAGED_STALL_KEYS <= set(r), PAGED_STALL_KEYS - set(r)
                assert _finite_pos(r["stall_x_sync"])
                assert _finite_pos(r["stall_x_chunked"])
                # wall-clock ratios are noisy on shared CI hosts, so the
                # smoke gate only requires chunking not to make the stall
                # worse; the committed full-grid run documents the real
                # >10x (sync) vs <2x (chunked) gap
                assert (r["stall_x_chunked"]
                        <= max(r["stall_x_sync"], 3.0)), \
                    (r["stall_x_chunked"], r["stall_x_sync"])
        elif sec == "engine_preempt":
            if r.get("kind") == "pressure":
                assert PREEMPT_PRESSURE_KEYS <= set(r), \
                    PREEMPT_PRESSURE_KEYS - set(r)
                assert _finite_pos(r["steps_per_s"])
                # the whole point: a pool at half the demand still serves
                # the full stream through preemption, not MemoryError
                assert r["completed"] is True
                assert r["pool_blocks"] < r["peak_blocks_unconstrained"]
                assert r["preemptions"] >= 0
                assert r["tokens_swapped"] >= 0
                assert r["tokens_recomputed"] >= 0
                if r["mode"] == "swap":
                    # host-staged blocks restore bit-for-bit, so a dense
                    # model's outputs cannot depend on the preemptions
                    assert r["gens_equal"] is True, \
                        "swap preemption changed generations"
                    assert r["tokens_recomputed"] == 0
                else:
                    assert r["tokens_swapped"] == 0
            elif r.get("kind") == "prefix":
                assert PREEMPT_PREFIX_KEYS <= set(r), \
                    PREEMPT_PREFIX_KEYS - set(r)
                assert _finite_pos(r["steps_per_s_on"])
                assert _finite_pos(r["steps_per_s_off"])
                assert 0.0 <= r["prefix_hit_rate"] <= 1.0
                assert r["prefix_hit_rate"] > 0, \
                    "shared-prefix workload produced no prefix hits"
                # dedup must shrink resident KV on a shared-prefix stream
                assert r["kv_bytes_ratio"] < 1.0, r["kv_bytes_ratio"]
                assert r["gens_equal"] is True, \
                    "prefix-cache hits changed generations"
            else:
                assert r.get("kind") == "persist", r.get("kind")
                assert PREEMPT_PERSIST_KEYS <= set(r), \
                    PREEMPT_PERSIST_KEYS - set(r)
                for m in ("off", "admission", "lru"):
                    assert _finite_pos(r[f"steps_per_s_{m}"])
                for m in ("admission", "lru"):
                    assert 0.0 <= r[f"prefix_hit_rate_{m}"] <= 1.0
                # THE lifetime gate: on a staggered stream every shared
                # block loses its last holder before the next request
                # arrives, so admission-scoped sharing never hits while
                # the persistent evictor keeps hitting across the gaps
                assert r["prefix_hit_rate_admission"] == 0.0, \
                    r["prefix_hit_rate_admission"]
                assert r["prefix_hit_rate_lru"] > 0, \
                    "persistent evictor produced no cross-request hits"
                assert r["prefix_revived"] > 0, \
                    "no cached block was ever revived by a later hit"
                # cached blocks are reclaimable, not resident: keeping
                # them indexed must not cost peak KV vs the uncached run
                assert r["kv_bytes_ratio"] <= 1.0 + 1e-9, \
                    r["kv_bytes_ratio"]
                assert r["gens_equal"] is True, \
                    "the persistent evictor changed generations"
        elif sec == "fleet":
            if r.get("kind") == "scenario":
                assert FLEET_SCENARIO_KEYS <= set(r), \
                    FLEET_SCENARIO_KEYS - set(r)
                for router in ("round_robin", "bfio"):
                    assert _finite_pos(r[f"{router}_throughput_tok_s"])
                    assert _finite_pos(r[f"{router}_energy_per_token"])
                    assert r[f"{router}_imbalance"] >= 0
                    assert 0.0 <= r[f"{router}_slo_attainment"] <= 1.0
                    # every scenario stream is servable: nothing fails,
                    # everything completes
                    assert r[f"{router}_failed"] == 0
                    assert r[f"{router}_completed"] == r["n_requests"]
            elif r.get("kind") == "parity":
                assert FLEET_PARITY_KEYS <= set(r), \
                    FLEET_PARITY_KEYS - set(r)
                assert r["stats_equal"] is True, \
                    "fleet(R=1) diverged from the bare ServingEngine"
            else:
                assert r.get("kind") == "affinity", r.get("kind")
                assert FLEET_AFFINITY_KEYS <= set(r), \
                    FLEET_AFFINITY_KEYS - set(r)
                for router in ("bfio", "bfio_affinity"):
                    assert _finite_pos(r[f"{router}_energy_per_token"])
                    assert r[f"{router}_imbalance"] >= 0
                    assert r[f"{router}_failed"] == 0
                    assert r[f"{router}_completed"] == r["n_requests"]
                # the affinity trace only discriminates if sessions
                # actually come back to still-cached context blocks
                assert r["bfio_affinity_prefix_hits"] > 0, \
                    "multi_turn trace produced no prefix hits"
                # THE affinity gate (the row is a deterministic trace,
                # so it holds at every shape, smoke included):
                # prefix-affinity routing pays in energy-per-token at
                # equal-or-better cross-replica imbalance
                assert r["affinity_wins"] is True, \
                    (f"bfio_affinity J/tok "
                     f"{r['bfio_affinity_energy_per_token']:.4f} vs "
                     f"{r['bfio_energy_per_token']:.4f}, imbalance "
                     f"{r['bfio_affinity_imbalance']:.1f} vs "
                     f"{r['bfio_imbalance']:.1f}")
        elif sec == "fleet_scale":
            if r.get("kind") == "speedup":
                assert FSCALE_SPEEDUP_KEYS <= set(r), \
                    FSCALE_SPEEDUP_KEYS - set(r)
                assert _finite_pos(r["ref_steps_per_s"])
                assert _finite_pos(r["vec_steps_per_s"])
                assert _finite_pos(r["steps"])
                # the bit-identity contract holds at every shape, smoke
                # included: same stats, same per-step telemetry
                assert r["stats_equal"] is True, \
                    "vec fleet stats diverged from the ref fleet"
                assert r["telemetry_equal"] is True, \
                    "vec fleet telemetry diverged from the ref fleet"
                assert r["failed"] == 0
                assert r["completed"] == r["n_requests"]
                if not doc["meta"].get("smoke"):
                    assert r["speedup"] >= FSCALE_MIN_EACH, \
                        (r["router"], r["speedup"])
            else:
                assert r.get("kind") == "pod", r.get("kind")
                assert FSCALE_POD_KEYS <= set(r), FSCALE_POD_KEYS - set(r)
                for router in ("round_robin", "pod_bfio"):
                    assert _finite_pos(r[f"{router}_steps_per_s"])
                    assert r[f"{router}_imbalance"] >= 0
                    # the pod-routed run completes: nothing fails
                    assert r[f"{router}_failed"] == 0
                    assert r[f"{router}_completed"] == r["n_requests"]
                if not doc["meta"].get("smoke") \
                        and r["R"] >= FSCALE_POD_MIN_R:
                    assert r["pod_wins"] is True, \
                        (f"pod_bfio imbalance {r['pod_bfio_imbalance']:.1f}"
                         f" not below flat round_robin "
                         f"{r['round_robin_imbalance']:.1f} at R={r['R']}")
        elif sec == "fleet_async":
            if r.get("kind") == "compat":
                assert FASYNC_COMPAT_KEYS <= set(r), \
                    FASYNC_COMPAT_KEYS - set(r)
                # the parity oracle holds at every shape, smoke included:
                # barrier_compat=True reproduces FleetServer bit-for-bit
                assert r["stats_equal"] is True, \
                    "async barrier_compat stats diverged from FleetServer"
                assert r["telemetry_equal"] is True, \
                    "async barrier_compat telemetry diverged"
                assert r["gens_equal"] is True, \
                    "async barrier_compat generations diverged"
                assert r["failed"] == 0
                assert r["completed"] == r["n_requests"]
            else:
                assert r.get("kind") == "diurnal", r.get("kind")
                assert FASYNC_DIURNAL_KEYS <= set(r), \
                    FASYNC_DIURNAL_KEYS - set(r)
                # correctness gates hold at every shape: nothing fails,
                # drain handoffs lose no work, and the autoscaled run's
                # generations match the fixed-R run bit-for-bit
                assert r["barrier_failed"] == 0
                assert r["async_failed"] == 0
                assert r["async_completed"] == r["n_requests"]
                assert r["tokens_lost"] == 0, \
                    f"drain handoffs recomputed {r['tokens_lost']} tokens"
                assert r["gens_equal"] is True, \
                    "autoscaling changed generations"
                assert 0.0 <= r["async_slo_attainment"] <= 1.0
                if not doc["meta"].get("smoke"):
                    # THE fleet_async gates, full grid only: the elastic
                    # fleet pays — less idle energy and a lower J/token
                    # at equal-or-better SLO attainment
                    assert r["async_idle_j"] < r["barrier_idle_j"], \
                        (r["async_idle_j"], r["barrier_idle_j"])
                    assert (r["async_energy_per_token"]
                            < r["barrier_energy_per_token"]), \
                        (r["async_energy_per_token"],
                         r["barrier_energy_per_token"])
                    assert (r["async_slo_attainment"]
                            >= r["barrier_slo_attainment"]), \
                        (r["async_slo_attainment"],
                         r["barrier_slo_attainment"])
        elif sec == "obs":
            assert OBS_KEYS <= set(r), OBS_KEYS - set(r)
            assert _finite_pos(r["wall_s_enabled"])
            assert _finite_pos(r["wall_s_disabled"])
            # the exactness contracts hold at every shape, smoke
            # included — they are bit-equality checks, not timings
            assert r["ledger_matches"] is True, \
                "straggler ledger total != fleet idle_j bit-exactly"
            assert r["split_sums_match"] is True, \
                "a step's idle_split does not left-fold to its idle_j"
            assert r["trace_roundtrip"] is True, \
                "trace reader saw a different event count than written"
            assert r["spans_match_latency"] is True, \
                "a request span's e2e_s != its telemetry latency"
            assert r["telemetry_roundtrip"] is True, \
                "v4 telemetry did not survive a JSONL round-trip"
            # observation is free when off: the null recorder buffers
            # nothing and the run's stats/telemetry are bit-identical
            assert r["trace_events"] > 0
            assert r["trace_spans"] == r["n_requests"], \
                (r["trace_spans"], r["n_requests"])
            assert r["trace_events_disabled"] == 0, \
                r["trace_events_disabled"]
            assert r["stats_bit_identical"] is True, \
                "enabling the recorder changed the run's stats"
            assert r["telemetry_bit_identical"] is True, \
                "enabling the recorder changed the run's telemetry"
            if not doc["meta"].get("smoke"):
                assert r["overhead_ratio"] < OBS_MAX_OVERHEAD, \
                    (r["variant"], r["overhead_ratio"])


def run_smoke(sections=None) -> dict:
    """Run the balancer bench on tiny shapes, validate, return the doc."""
    from .balancer_bench import run

    with tempfile.TemporaryDirectory() as d:
        doc = run(smoke=True, out_path=os.path.join(d, "BENCH_balancer.json"),
                  sections=sections)
    check(doc)
    return doc


def main():
    run_smoke()
    print("check_bench: smoke run OK (schema valid, timings finite)")


if __name__ == "__main__":
    main()
