"""Validation of the worst-case theory (Theorems 1-4, Corollary 1).

1. IIR scaling (Thm 1/2): measured FCFS/BF-IO imbalance ratios across a
   (B, G) grid must grow ~ sqrt(B log G) — we fit IIR = c * sqrt(B log G)
   and report the fit quality.
2. BF-IO upper bound (Lemma 1/4): in the homogeneous-decode warm-up, the
   post-admission max-min gap must be <= s_max (+ heuristic slack).
3. Energy theorem (Thm 4): the *guaranteed* saving from Eq. (16) with the
   measured alpha and eta_sum must not exceed the measured saving
   (soundness of the bound), and Cor 1's A100 limit is ~52.6 %.
"""
from __future__ import annotations

import argparse
import math

import numpy as np

from repro.core import (
    A100_POWER,
    SimConfig,
    make_policy,
    saving_bound,
    simulate,
)
from repro.core.theory import asymptotic_saving
from repro.data import LONGBENCH_LIKE, UNIFORM_PREFILL, \
    batched_rounds_instance

from .common import print_csv, save_rows

QUICK = dict(grid=[(8, 8), (16, 8), (16, 16), (32, 16), (64, 16),
                   (64, 32)], n_rounds=4.0)
FULL = dict(grid=[(16, 8), (32, 16), (64, 16), (64, 32), (96, 48),
                  (128, 64), (128, 128)], n_rounds=4.0)


def iir_scaling(full: bool, seed: int = 5) -> list[dict]:
    """Theorem 1's warm-up model (homogeneous decode lengths): rounds are
    i.i.d., FCFS imbalance ~ G*sigma_s*sqrt(B log G), BF-IO <= (G-1)*s_max
    — the cleanest setting to observe the sqrt(B log G) scaling."""
    p = FULL if full else QUICK
    rows = []
    for B, G in p["grid"]:
        inst = batched_rounds_instance(UNIFORM_PREFILL, G=G, B=B,
                                       n_rounds=p["n_rounds"], seed=seed,
                                       homogeneous_decode=32)
        cfg = SimConfig(G=G, B=B)
        m_f = simulate(inst, make_policy("fcfs"), cfg)
        m_b = simulate(inst, make_policy("bfio_h0"), cfg)
        iir = m_f.avg_imbalance / max(m_b.avg_imbalance, 1e-9)
        x = math.sqrt(B * math.log(G))
        rows.append({"B": B, "G": G, "sqrt_BlogG": x, "iir": iir,
                     "fcfs_imb": m_f.avg_imbalance,
                     "bfio_imb": m_b.avg_imbalance,
                     "eta_sum_fcfs": m_f.eta_sum})
        print(f"  B={B:3d} G={G:3d}: IIR={iir:6.2f}  sqrt(BlogG)={x:5.2f}",
              flush=True)
    # (a) the FCFS side is an equality in the proof (Step B):
    #     E[Imb] ~= c * G * sigma_s * sqrt(B log G) — check the constant
    #     is stable across the grid.
    sigma_s = UNIFORM_PREFILL.s_max / np.sqrt(12.0)  # uniform [1, s_max]
    consts = np.array([
        r["fcfs_imb"] / (r["G"] * sigma_s * r["sqrt_BlogG"]) for r in rows])
    cv = float(consts.std() / consts.mean())
    print(f"  FCFS ~ c*G*sigma_s*sqrt(B log G): c = {consts.mean():.3f} "
          f"+/- {consts.std():.3f} (CV {cv:.2f})")
    # (b) the IIR *lower bound* Omega(sqrt(B log G)): measured IIR must
    #     stay above a positive multiple of sqrt(B log G).  (Measured IIR
    #     grows faster — BF-IO's achieved gap is far below the s_max used
    #     by the bound, so the guarantee is conservative.)
    xs = np.array([r["sqrt_BlogG"] for r in rows])
    ys = np.array([r["iir"] for r in rows])
    c_env = float((ys / xs).min())
    order = np.argsort(xs)
    mono = bool(np.all(np.diff(ys[order]) > -0.15 * ys[order][:-1]))
    print(f"  IIR >= {c_env:.2f} * sqrt(B log G) across the grid "
          f"(monotone={mono})")
    return rows, {"fcfs_const_mean": float(consts.mean()),
                  "fcfs_const_cv": cv, "iir_envelope_c": c_env,
                  "monotone": mono}


def smax_balance(seed: int = 6) -> dict:
    """Warm-up model: homogeneous decode, fresh rounds (Theorem 1)."""
    from repro.core import SimTrace
    G, B = 8, 16
    inst = batched_rounds_instance(UNIFORM_PREFILL, G=G, B=B, n_rounds=2,
                                   homogeneous_decode=50, seed=seed)
    tr = SimTrace()
    cfg = SimConfig(G=G, B=B, record_loads_every=1)
    simulate(inst, make_policy("bfio_h0"), cfg, trace=tr)
    gaps = [float(l.max() - l.min()) for l in tr.loads if l.max() > 0]
    s_max = UNIFORM_PREFILL.s_max
    frac_ok = float(np.mean([g <= 2.0 * s_max for g in gaps]))
    print(f"  s_max-balance: max-min gap <= 2*s_max on {frac_ok:.0%} of "
          f"steps (s_max={s_max})")
    return {"frac_within_2smax": frac_ok,
            "median_gap_over_smax": float(np.median(gaps) / s_max)}


def energy_theorem(full: bool, seed: int = 7) -> dict:
    G, B = (64, 48) if full else (24, 24)
    inst = batched_rounds_instance(LONGBENCH_LIKE, G=G, B=B, n_rounds=4,
                                   seed=seed)
    cfg = SimConfig(G=G, B=B)
    m_f = simulate(inst, make_policy("fcfs"), cfg)
    m_b = simulate(inst, make_policy("bfio_h40", p_new=LONGBENCH_LIKE.decode_p),
                   cfg)
    alpha = m_f.avg_imbalance / max(m_b.avg_imbalance, 1e-9)
    eta = m_f.eta_sum
    bound = saving_bound(alpha, eta, A100_POWER)
    measured = 1 - m_b.energy_joules / m_f.energy_joules
    limit = asymptotic_saving(A100_POWER)
    sound = bound <= measured + 0.02
    print(f"  Thm4: alpha={alpha:.2f} eta={eta:.3f} -> guaranteed "
          f"saving >= {bound:.1%}; measured {measured:.1%}; "
          f"Cor1 limit {limit:.1%}  [{'SOUND' if sound else 'VIOLATED'}]")
    return {"alpha": alpha, "eta_sum": eta, "bound": bound,
            "measured_saving": measured, "cor1_limit": limit,
            "sound": bool(sound)}


def run(full: bool = False) -> dict:
    print(" IIR scaling (Thm 1/2):")
    rows, fit = iir_scaling(full)
    print(" s_max balance (Lemma 1):")
    bal = smax_balance()
    print(" energy guarantee (Thm 4 / Cor 1):")
    en = energy_theorem(full)
    out = {"iir_rows": rows, "fit": fit, "smax": bal, "energy": en}
    save_rows("theory_validation_full" if full else "theory_validation",
              rows, meta={"fit": fit, "smax": bal, "energy": en})
    return out


def main(full: bool = False):
    out = run(full)
    print_csv("theory", out["iir_rows"], ["B", "G", "iir", "sqrt_BlogG"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
