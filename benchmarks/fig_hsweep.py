"""Figures 4 & 9: effect of the lookahead horizon H.

Paper: metrics improve rapidly 0 -> 40, then plateau (and decision cost
grows); optimum around H=40."""
from __future__ import annotations

import argparse

from repro.data import LONGBENCH_LIKE

from .common import print_csv, run_policy, save_rows, sim_config, \
    standard_instance

QUICK = dict(G=32, B=24, n_rounds=4.0, hs=[0, 5, 10, 20, 40, 80])
FULL = dict(G=128, B=72, n_rounds=3.0, hs=[0, 10, 20, 40, 60, 80, 100])


def run(full: bool = False, seed: int = 1) -> list[dict]:
    p = FULL if full else QUICK
    inst = standard_instance(p["G"], p["B"], p["n_rounds"], seed=seed)
    cfg = sim_config(p["G"], p["B"])
    rows = []
    for h in p["hs"]:
        r = run_policy(inst, f"bfio_h{h}", LONGBENCH_LIKE, cfg)
        row = r.row()
        row["H"] = h
        rows.append(row)
        print(f"  H={h:3d}: imb={row['avg_imbalance']:.3e} "
              f"thr={row['throughput']:.4e} tpot={row['tpot']:.4f} "
              f"E={row['energy_mj']:.2f}MJ (router wall {row['wall_s']:.0f}s)",
              flush=True)
    save_rows("fig_hsweep_full" if full else "fig_hsweep", rows,
              meta={k: v for k, v in p.items() if k != "hs"})
    return rows


def main(full: bool = False):
    rows = run(full)
    print_csv("fig_hsweep", rows, ["H", "avg_imbalance", "throughput",
                                   "tpot", "energy_mj"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
