"""Balancer + simulator hot-path benchmark -> BENCH_balancer.json.

Tracks the two hot paths this repo's scale story rests on, with the
pre-optimization implementations measured live (they are kept in-tree
precisely for this):

* **solver** — jitted BF-IO solve time, pre = ``method="dense"`` (the
  original O(N^2 W) ``_swap_once`` formulation) vs post = the tiled
  swap kernel with top-K candidate pruning (``method="xla"``,
  ``prune_k``).  Assignment quality (windowed imbalance J) is recorded
  for both so the speed/quality trade stays visible.
* **simulator** — instant-mode steps/sec, pre = ``dispatch="instant_ref"``
  (the original per-request Python loop) vs post = the vectorized
  ``dispatch="instant"`` path, with a bit-equality check on SimMetrics.
* **batch** — ``bfio_assign_batch`` (one vmapped call over C clusters)
  vs C sequential ``bfio_assign`` calls.
* **engine** — end-to-end ``ServingEngine`` steps/sec on a tiny dense
  model, pre = ``engine_mode="ref"`` (the original per-slot Python loops
  + per-request cache writes + always-decode-all-G*B) vs post =
  ``engine_mode="vec"`` (slot-table arrays, batched cache scatter,
  bucketed compact decode), with a stats-equality check (steps, tokens,
  energy_j, avg_imbalance bit-identical).
* **engine_paged** — the pluggable serving seams.  ``kind="grid"`` rows:
  ``cache_backend="slot"`` vs ``"paged"`` steps/sec with a stats-equality
  check, plus resident-KV bytes (paged peak resident vs the dense
  G*B*max_seq_len the slot layout pins — the ratio is the paging win).
  The ``kind="stall"`` row: max step wall-time while an admission wave of
  long prompts lands, synchronous prefill vs chunked
  (``prefill_chunk``) — chunking bounds the per-step prompt work so
  decode is never stalled behind a wave.
* **fleet** — the two-tier serving layer (``repro.fleet``).
  ``kind="scenario"`` rows run each named scenario trace (steady /
  flash_crowd / diurnal / agentic / long_doc) through a FleetServer of
  R engine replicas once per router, with the step-time constants in
  the attention-dominated regime (per-step wall tracks the max resident
  load, so the barrier actually prices imbalance); metrics come from
  the telemetry subsystem (mean cross-replica imbalance,
  energy-per-token including barrier idle, TTFT p95, SLO attainment).
  The CI gate: ``router="bfio"`` beats ``"round_robin"`` on both
  imbalance and energy-per-token on >= 3 of the 5 scenarios.  The
  ``kind="parity"`` row anchors the layer: ``fleet(R=1, router=*)``
  stats are bit-identical to a bare ServingEngine on the same stream.
* **fleet_scale** — the vectorized fleet hot path.  ``kind="speedup"``
  rows time the same trickle stream (sparse arrivals over mostly-idle
  replicas — the regime where fleet bookkeeping dominates) through the
  same router under ``fleet_mode="ref"`` (the original per-step O(R)
  re-gather loops, kept in-tree) vs ``"vec"`` (incrementally-updated
  per-replica arrays), with stats and per-step telemetry checked
  bit-identical.  The CI gate on the full grid: vec >= 5x ref steps/s
  at R=64 on at least one router.  The ``kind="pod"`` row runs
  R-in-the-hundreds with two-level hierarchical ``pod_bfio`` routing
  (one batched solve over all pods) vs flat round_robin: it must
  complete with zero failures and lower mean cross-replica imbalance.
* **engine_preempt** — the memory-pressure subsystem.  ``kind=
  "pressure"`` rows: the same request stream through a pool sized at
  ``pool_frac`` (0.5) of the unconstrained peak-resident demand, once per
  ``preemption_mode`` — the engine must complete everything via
  preemption (no MemoryError), swap mode bit-identical to the
  unconstrained run (``gens_equal``), with throughput plus the
  tokens-swapped vs tokens-recomputed trade recorded.  The
  ``kind="prefix"`` row: a shared-system-prompt workload with
  ``prefix_cache`` off vs on — block hit-rate, identical generations,
  and the resident-KV reduction (``kv_bytes_ratio < 1``).  The
  ``kind="persist"`` row: the same shared-prompt workload *staggered*
  (each request drains before the next arrives), once per
  ``prefix_evict`` mode — admission-scoped sharing hits nothing
  (every shared block dies with its last holder) while the persistent
  LRU evictor keeps hitting across the gaps, with identical
  generations and no extra peak resident KV.
* **fleet** (``kind="affinity"``) — the multi_turn scenario (sessions
  return for later turns after their first turn drained) under
  ``bfio`` vs ``bfio_affinity``: prefix-affinity routing sends return
  visits to the replica still holding their context blocks, and must
  cut energy-per-token at equal-or-better cross-replica imbalance.

Run:  PYTHONPATH=src python -m benchmarks.balancer_bench [--full] [--smoke]
Writes BENCH_balancer.json at the repo root (and benchmarks/results/).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SWAP_ITERS = 8
PRUNE_K = 128
W = 9  # lookahead window H=8


def _solver_case(G: int, N: int, *, measure_dense: bool, iters: int = 10,
                 seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.core import io_solver
    from repro.core.balancer_jax import bfio_assign

    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.uniform(0, 100, (G, W)), jnp.float32)
    caps = jnp.asarray(rng.integers(4, 16, (G,)), jnp.int32)
    cands = jnp.asarray(rng.uniform(1, 50, (N, W)), jnp.float32)
    valid = jnp.ones((N,), bool)
    n_admit = jnp.int32(min(N, int(np.asarray(caps).sum())))

    def timed(swap_iters=SWAP_ITERS, **kw):
        def call():
            return bfio_assign(base, caps, cands, valid, n_admit,
                               swap_iters=swap_iters, **kw)
        a = np.asarray(call())  # warmup/compile
        t0 = time.time()
        for _ in range(iters):
            call().block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        J = io_solver.objective(np.asarray(base), np.asarray(cands), a)
        return us, J

    prune = min(PRUNE_K, N)
    post_us, J_post = timed(method="xla", prune_k=prune)
    greedy_us, _ = timed(swap_iters=0)  # construction-only floor
    row = {"section": "solver", "G": G, "N": N, "W": W,
           "swap_iters": SWAP_ITERS, "prune_k": prune,
           "post_tiled_us": post_us, "J_post": J_post,
           "greedy_us": greedy_us,
           "pre_dense_us": None, "J_pre": None, "speedup": None,
           "refine_speedup": None, "quality_rel_diff": None}
    if measure_dense:
        pre_us, J_pre = timed(method="dense")
        # refinement-only ratio: subtract the shared greedy construction,
        # which no swap backend touches
        pre_ref = max(pre_us - greedy_us, 1e-9)
        post_ref = max(post_us - greedy_us, 1e-9)
        row.update(pre_dense_us=pre_us, J_pre=J_pre,
                   speedup=pre_us / post_us,
                   refine_speedup=pre_ref / post_ref,
                   quality_rel_diff=(J_post - J_pre) / max(abs(J_pre), 1e-9))
    return row


def _sim_instance(G: int, B: int, n_rounds: float, seed: int = 1):
    from repro.core import ArrivalInstance, Request

    rng = np.random.default_rng(seed)
    n = int(G * B * n_rounds)
    reqs = [
        Request(rid=i, arrival_step=int(rng.integers(0, 50)),
                prefill=float(rng.integers(1, 80)),
                decode_len=int(rng.geometric(0.1)))
        for i in range(n)
    ]
    return ArrivalInstance(requests=reqs)


def _sim_case(G: int, B: int, *, n_rounds: float = 4.0, policy: str = "jsq",
              seed: int = 1) -> dict:
    from repro.core import SimConfig, make_policy, simulate

    out = {"section": "simulator", "G": G, "B": B, "policy": policy}
    metrics = {}
    for mode, key in [("instant_ref", "pre"), ("instant", "post")]:
        inst = _sim_instance(G, B, n_rounds, seed=seed)
        t0 = time.time()
        m = simulate(inst, make_policy(policy),
                     SimConfig(G=G, B=B, dispatch=mode, max_steps=500_000))
        wall = time.time() - t0
        metrics[key] = dataclasses.asdict(m)
        out[f"{key}_steps_per_s"] = m.steps / max(wall, 1e-9)
        out[f"{key}_wall_s"] = wall
        out["steps"] = m.steps
    out["speedup"] = out["post_steps_per_s"] / out["pre_steps_per_s"]
    out["metrics_equal"] = metrics["pre"] == metrics["post"]
    return out


def _batch_case(C: int, G: int, N: int, iters: int = 5, seed: int = 2) -> dict:
    import jax.numpy as jnp

    from repro.core.balancer_jax import bfio_assign, bfio_assign_batch

    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.uniform(0, 100, (C, G, W)), jnp.float32)
    caps = jnp.asarray(rng.integers(4, 16, (C, G)), jnp.int32)
    cands = jnp.asarray(rng.uniform(1, 50, (C, N, W)), jnp.float32)
    valid = jnp.ones((C, N), bool)
    n_admit = jnp.minimum(N, caps.sum(axis=1)).astype(jnp.int32)

    prune = min(PRUNE_K, N)
    bfio_assign_batch(base, caps, cands, valid, n_admit,
                      prune_k=prune).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        bfio_assign_batch(base, caps, cands, valid, n_admit,
                          prune_k=prune).block_until_ready()
    batch_us = (time.time() - t0) / iters * 1e6

    def seq():
        for c in range(C):
            bfio_assign(base[c], caps[c], cands[c], valid[c], n_admit[c],
                        prune_k=prune).block_until_ready()
    seq()  # warmup
    t0 = time.time()
    for _ in range(iters):
        seq()
    seq_us = (time.time() - t0) / iters * 1e6
    return {"section": "batch", "C": C, "G": G, "N": N, "W": W,
            "prune_k": prune, "batch_us": batch_us, "sequential_us": seq_us,
            "speedup": seq_us / batch_us}


_ENGINE_STATE: dict = {}


def _engine_setup():
    """Tiny dense model shared by every engine case (params built once)."""
    if _ENGINE_STATE:
        return _ENGINE_STATE
    import jax

    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_cpu_mesh
    from repro.models import init_params, split_params

    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    _ENGINE_STATE.update(cfg=cfg, params=params, mesh=make_cpu_mesh())
    return _ENGINE_STATE


def _engine_requests(G: int, B: int, *, n_rounds: float, seed: int):
    from repro.serving import ServeRequest

    rng = np.random.default_rng(seed)
    n = int(G * B * n_rounds)
    return [
        ServeRequest(
            rid=i,
            tokens=rng.integers(1, 128, size=int(rng.integers(4, 24))),
            # geometric decode lengths: a long sparse tail, where the ref
            # engine still decodes all G*B slots every step
            max_new_tokens=int(min(3 + rng.geometric(0.12), 40)))
        for i in range(n)
    ]


def _engine_case(G: int, B: int, *, n_rounds: float = 1.5,
                 policy: str = "jsq", seed: int = 7) -> dict:
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServingEngine

    st = _engine_setup()
    out = {"section": "engine", "G": G, "B": B, "policy": policy,
           "n_requests": int(G * B * n_rounds)}
    stats = {}
    for mode, key in [("ref", "pre"), ("vec", "post")]:
        ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                          engine_mode=mode)

        def one_run(rounds):
            eng = ServingEngine(st["cfg"], st["params"], ec,
                                make_policy(policy), mesh=st["mesh"])
            for r in _engine_requests(G, B, n_rounds=rounds, seed=seed):
                eng.submit(r)
            s = eng.run(max_steps=100_000)
            return s

        # warmup: compiles are cached across engine instances.  The ref
        # path's only jit is the full-batch decode, so a tiny workload
        # warms it; the vec path replays the full workload so every
        # decode/prefill bucket it will hit is compiled before timing.
        one_run(n_rounds if mode == "vec" else min(n_rounds, 0.25))
        t0 = time.time()
        s = one_run(n_rounds)
        wall = time.time() - t0
        stats[key] = s
        out[f"{key}_steps_per_s"] = s["steps"] / max(wall, 1e-9)
        out[f"{key}_wall_s"] = wall
        out["steps"] = s["steps"]
    out["speedup"] = out["post_steps_per_s"] / out["pre_steps_per_s"]
    out["metrics_equal"] = stats["pre"] == stats["post"]
    return out


def _engine_paged_case(G: int, B: int, *, n_rounds: float = 1.0,
                       policy: str = "jsq", seed: int = 7) -> dict:
    """slot-vs-paged cache backend on the vec engine: steps/s, stats
    parity, and resident-KV bytes (the paging win: peak resident KV
    tracks actual tokens, the slot layout pins G*B*max_seq_len)."""
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServingEngine

    st = _engine_setup()
    out = {"section": "engine_paged", "kind": "grid", "G": G, "B": B,
           "policy": policy, "n_requests": int(G * B * n_rounds)}
    stats = {}
    for backend in ("slot", "paged"):
        ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                          cache_backend=backend, paged_block_size=16)

        def one_run(rounds):
            eng = ServingEngine(st["cfg"], st["params"], ec,
                                make_policy(policy), mesh=st["mesh"])
            for r in _engine_requests(G, B, n_rounds=rounds, seed=seed):
                eng.submit(r)
            s = eng.run(max_steps=100_000)
            return eng, s

        one_run(n_rounds)  # warmup: compile every bucket this run hits
        t0 = time.time()
        eng, s = one_run(n_rounds)
        wall = time.time() - t0
        stats[backend] = s
        out[f"{backend}_steps_per_s"] = s["steps"] / max(wall, 1e-9)
        out[f"{backend}_wall_s"] = wall
        out["steps"] = s["steps"]
        if backend == "paged":
            out["paged_kv_peak_bytes"] = int(eng.kv_peak_bytes)
            out["paged_pool_bytes"] = int(eng.backend.pool_bytes())
        else:
            out["slot_kv_bytes"] = int(eng.backend.resident_kv_bytes())
    out["speedup"] = out["paged_steps_per_s"] / out["slot_steps_per_s"]
    out["kv_bytes_ratio"] = (out["paged_kv_peak_bytes"]
                             / max(out["slot_kv_bytes"], 1))
    out["metrics_equal"] = stats["slot"] == stats["paged"]
    return out


def _engine_preempt_case(G: int, B: int, *, pool_frac: float = 0.5,
                         n_rounds: float = 1.5, policy: str = "jsq",
                         seed: int = 11) -> list[dict]:
    """Memory pressure: pool at ``pool_frac`` of the unconstrained peak
    resident demand; the engine completes the stream via preemption, swap
    mode bit-identical to unconstrained.  Returns one row per mode."""
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServingEngine

    st = _engine_setup()

    def one_run(mode, pool_blocks):
        ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                          cache_backend="paged", paged_block_size=16,
                          paged_pool_blocks=pool_blocks,
                          preemption_mode=mode)
        eng = ServingEngine(st["cfg"], st["params"], ec,
                            make_policy(policy), mesh=st["mesh"])
        reqs = _engine_requests(G, B, n_rounds=n_rounds, seed=seed)
        for r in reqs:
            eng.submit(r)
        s = eng.run(max_steps=200_000)
        return eng, s, [r.generated for r in reqs]

    eng0, s0, gens0 = one_run("swap", 0)      # unconstrained baseline
    blk_bytes = eng0.backend.pool_bytes() // eng0.backend.n_blocks
    peak_blocks = -(-eng0.kv_peak_bytes // blk_bytes)
    pool = max(int(peak_blocks * pool_frac), 4)
    rows = []
    for mode in ("swap", "recompute"):
        one_run(mode, pool)  # warmup: compile every bucket this run hits
        t0 = time.time()
        eng, s, gens = one_run(mode, pool)
        wall = time.time() - t0
        rows.append({
            "section": "engine_preempt", "kind": "pressure", "G": G,
            "B": B, "policy": policy, "n_requests": int(G * B * n_rounds),
            "mode": mode, "pool_frac": pool_frac, "pool_blocks": pool,
            "peak_blocks_unconstrained": int(peak_blocks),
            "steps": s["steps"], "steps_per_s": s["steps"] / max(wall, 1e-9),
            "unconstrained_steps": s0["steps"],
            "preemptions": s["preemptions"],
            "tokens_swapped": s["tokens_swapped"],
            "tokens_recomputed": s["tokens_recomputed"],
            "completed": all(len(g) > 0 for g in gens),
            "gens_equal": gens == gens0,
        })
    return rows


def _engine_prefix_case(G: int, B: int, *, shared_len: int = 32,
                        n_rounds: float = 1.5, policy: str = "jsq",
                        seed: int = 13) -> dict:
    """Prefix caching on a shared-system-prompt workload: block hit-rate,
    identical generations, and the peak-resident-KV reduction."""
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServeRequest, ServingEngine

    st = _engine_setup()
    n = int(G * B * n_rounds)

    def reqs():
        rng = np.random.default_rng(seed)
        system = rng.integers(1, 128, size=shared_len)
        return [ServeRequest(
            rid=i,
            tokens=np.concatenate(
                [system, rng.integers(1, 128,
                                      size=int(rng.integers(2, 10)))]),
            max_new_tokens=int(min(3 + rng.geometric(0.2), 20)))
            for i in range(n)]

    out = {"section": "engine_preempt", "kind": "prefix", "G": G, "B": B,
           "policy": policy, "n_requests": n, "shared_prefix_len": shared_len}
    gens = {}
    for on in (False, True):
        ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                          cache_backend="paged", paged_block_size=16,
                          prefix_cache=on)

        def one_run():
            eng = ServingEngine(st["cfg"], st["params"], ec,
                                make_policy(policy), mesh=st["mesh"])
            rs = reqs()
            for r in rs:
                eng.submit(r)
            s = eng.run(max_steps=100_000)
            return eng, s, [r.generated for r in rs]

        one_run()  # warmup
        t0 = time.time()
        eng, s, gens[on] = one_run()
        wall = time.time() - t0
        key = "on" if on else "off"
        out[f"steps_per_s_{key}"] = s["steps"] / max(wall, 1e-9)
        out[f"kv_peak_bytes_{key}"] = int(eng.kv_peak_bytes)
        if on:
            out["prefix_hits"] = s["prefix_hits"]
            out["prefix_queries"] = s["prefix_queries"]
            out["prefix_hit_rate"] = s["prefix_hit_rate"]
    out["kv_bytes_ratio"] = (out["kv_peak_bytes_on"]
                             / max(out["kv_peak_bytes_off"], 1))
    out["gens_equal"] = gens[False] == gens[True]
    return out


def _engine_persist_case(G: int, B: int, *, shared_len: int = 32,
                         n_rounds: float = 1.5, policy: str = "jsq",
                         seed: int = 17) -> dict:
    """Prefix-cache lifetime on a staggered stream: each request drains
    before the next is submitted, so under admission-scoped sharing
    every shared block dies with its last holder and the hit rate is
    exactly zero.  The persistent LRU evictor keeps refcount-0 blocks
    indexed until the pool actually needs them back, so later requests
    hit — with generations identical to the uncached run and no extra
    peak resident KV (cached blocks are reclaimable, not used)."""
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServeRequest, ServingEngine

    st = _engine_setup()
    n = int(G * B * n_rounds)

    def reqs():
        rng = np.random.default_rng(seed)
        system = rng.integers(1, 128, size=shared_len)
        return [ServeRequest(
            rid=i,
            tokens=np.concatenate(
                [system, rng.integers(1, 128,
                                      size=int(rng.integers(2, 10)))]),
            max_new_tokens=int(min(3 + rng.geometric(0.2), 20)))
            for i in range(n)]

    out = {"section": "engine_preempt", "kind": "persist", "G": G,
           "B": B, "policy": policy, "n_requests": n,
           "shared_prefix_len": shared_len}
    gens = {}
    for mode in ("off", "admission", "lru"):
        ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                          cache_backend="paged", paged_block_size=16,
                          prefix_cache=(mode != "off"),
                          prefix_evict="lru" if mode == "off" else mode)

        def one_run():
            eng = ServingEngine(st["cfg"], st["params"], ec,
                                make_policy(policy), mesh=st["mesh"])
            rs = reqs()
            s = None
            for r in rs:    # staggered: drain before the next arrives
                eng.submit(r)
                s = eng.run(max_steps=100_000)
            return eng, s, [r.generated for r in rs]

        one_run()  # warmup
        t0 = time.time()
        eng, s, gens[mode] = one_run()
        wall = time.time() - t0
        out[f"steps_per_s_{mode}"] = s["steps"] / max(wall, 1e-9)
        out[f"kv_peak_bytes_{mode}"] = int(eng.kv_peak_bytes)
        if mode != "off":
            out[f"prefix_hits_{mode}"] = s["prefix_hits"]
            out[f"prefix_queries_{mode}"] = s["prefix_queries"]
            out[f"prefix_hit_rate_{mode}"] = s["prefix_hit_rate"]
        if mode == "lru":
            out["prefix_revived"] = s["prefix_revived"]
    out["kv_bytes_ratio"] = (out["kv_peak_bytes_lru"]
                             / max(out["kv_peak_bytes_off"], 1))
    out["gens_equal"] = (gens["off"] == gens["admission"]
                         == gens["lru"])
    return out


# Fleet cases run the engines' simulated clock in the attention-dominated
# regime (step wall-time tracks the max resident load instead of being
# swamped by the constant overhead), so cross-replica imbalance shows up
# in energy exactly as the paper's barrier model prices it.
FLEET_TIMING = dict(step_overhead=1e-3, t_token=2e-4)


def _fleet_case(R: int, G: int, B: int, *, n_requests: int,
                routers=("round_robin", "bfio"), load_factor: float = 0.8,
                seed: int = 0, jsonl_dir: str | None = None) -> list[dict]:
    """Scenario sweep: every named fleet scenario once per router, all
    metrics read from the telemetry subsystem."""
    from repro.fleet import (
        FleetServer,
        FleetTelemetry,
        SLOSpec,
        make_scenario,
    )
    from repro.serving import EngineConfig

    st = _engine_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                     **FLEET_TIMING)
    rows = []
    # the five routing scenarios; "trickle" belongs to fleet_scale
    for name in ("steady", "flash_crowd", "diurnal", "agentic",
                 "long_doc"):
        sc = make_scenario(name, n_requests=n_requests, n_replicas=R,
                           n_workers=G, slots_per_worker=B, max_seq_len=64,
                           vocab_size=128, seed=seed,
                           load_factor=load_factor, **FLEET_TIMING)
        row = {"section": "fleet", "kind": "scenario", "scenario": name,
               "R": R, "G": G, "B": B, "n_requests": sc.n_requests,
               "load_factor": load_factor}
        for router in routers:
            tel = FleetTelemetry(slo=SLOSpec(ttft_s=1.0, tpot_s=0.05))
            fs = FleetServer(st["cfg"], st["params"], ec, n_replicas=R,
                             router=router, policy="bfio_h0",
                             mesh=st["mesh"], telemetry=tel)
            fs.submit_scenario(sc)
            t0 = time.time()
            stats = fs.run(max_steps=200_000)
            wall = time.time() - t0
            s = tel.summary()
            row[f"{router}_imbalance"] = s["mean_cross_imbalance"]
            row[f"{router}_energy_per_token"] = s["energy_per_token"]
            row[f"{router}_throughput_tok_s"] = stats["throughput_tok_s"]
            row[f"{router}_ttft_p95"] = s["ttft"]["p95"]
            row[f"{router}_slo_attainment"] = s["slo_attainment"]
            row[f"{router}_completed"] = s["completed"]
            row[f"{router}_failed"] = s["failed"]
            row[f"{router}_steps"] = stats["steps"]
            row[f"{router}_wall_s"] = wall
            if jsonl_dir is not None and router == "bfio":
                tel.write_jsonl(os.path.join(
                    jsonl_dir, f"fleet_telemetry_{name}.jsonl"))
        if {"round_robin", "bfio"} <= set(routers):
            row["bfio_wins"] = bool(
                row["bfio_imbalance"] < row["round_robin_imbalance"]
                and (row["bfio_energy_per_token"]
                     < row["round_robin_energy_per_token"]))
        rows.append(row)
    return rows


def _fleet_affinity_case(R: int, G: int, B: int, *, n_requests: int,
                         seed: int = 0, scenario_seed: int = 1,
                         jsonl_dir: str | None = None) -> dict:
    """Prefix-affinity routing on the multi-turn scenario: a session's
    later turns arrive after its first turn drained, so only the
    persistent LRU evictor keeps its context blocks alive — and only
    affinity-aware routing sends the return visit to the replica that
    still holds them.  One row, ``bfio`` vs ``bfio_affinity``, on a
    deterministic trace (same shape for smoke and full)."""
    from repro.fleet import (
        FleetServer,
        FleetTelemetry,
        SLOSpec,
        make_scenario,
    )
    from repro.serving import EngineConfig

    st = _engine_setup()
    # a pool with headroom: the evictor can only pay across turn gaps
    # if cached contexts survive until the session returns
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                      cache_backend="paged", paged_block_size=16,
                      paged_pool_blocks=48, prefill_chunk=8,
                      prefix_cache=True)
    sc = make_scenario("multi_turn", n_requests=n_requests, n_replicas=R,
                       n_workers=G, slots_per_worker=B, max_seq_len=64,
                       vocab_size=128, seed=scenario_seed)
    row = {"section": "fleet", "kind": "affinity",
           "scenario": "multi_turn", "R": R, "G": G, "B": B,
           "n_requests": sc.n_requests}
    for router in ("bfio", "bfio_affinity"):
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=1.0, tpot_s=0.05))
        fs = FleetServer(st["cfg"], st["params"], ec, n_replicas=R,
                         router=router, policy="bfio_h0",
                         mesh=st["mesh"], telemetry=tel, seed=seed)
        fs.submit_scenario(sc)
        t0 = time.time()
        stats = fs.run(max_steps=200_000)
        wall = time.time() - t0
        s = tel.summary()
        row[f"{router}_imbalance"] = s["mean_cross_imbalance"]
        row[f"{router}_energy_per_token"] = s["energy_per_token"]
        row[f"{router}_prefix_hits"] = stats["prefix_hits"]
        row[f"{router}_prefix_revived"] = stats["prefix_revived"]
        row[f"{router}_completed"] = s["completed"]
        row[f"{router}_failed"] = s["failed"]
        row[f"{router}_steps"] = stats["steps"]
        row[f"{router}_wall_s"] = wall
        if jsonl_dir is not None and router == "bfio_affinity":
            tel.write_jsonl(os.path.join(
                jsonl_dir, "fleet_telemetry_multi_turn.jsonl"))
    row["affinity_wins"] = bool(
        row["bfio_affinity_energy_per_token"]
        < row["bfio_energy_per_token"]
        and row["bfio_affinity_imbalance"] <= row["bfio_imbalance"])
    return row


def _fleet_parity_case(G: int, B: int, *, n_rounds: float = 1.5,
                       seed: int = 7) -> dict:
    """fleet(R=1, router=*) must be bit-identical to a bare engine on
    the same stream — the anchor tying the fleet layer to the
    exhaustively-tested single-engine semantics."""
    from repro.core import make_policy
    from repro.fleet import FleetServer
    from repro.serving import EngineConfig, ServingEngine

    st = _engine_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64)
    eng = ServingEngine(st["cfg"], st["params"], ec,
                        make_policy("bfio_h0"), mesh=st["mesh"])
    for r in _engine_requests(G, B, n_rounds=n_rounds, seed=seed):
        eng.submit(r)
    bare = eng.run(max_steps=100_000)
    routers = ("round_robin", "least_loaded", "pod2", "bfio")
    equal = True
    for router in routers:
        fs = FleetServer(st["cfg"], st["params"], ec, n_replicas=1,
                         router=router, policy="bfio_h0", mesh=st["mesh"])
        for r in _engine_requests(G, B, n_rounds=n_rounds, seed=seed):
            fs.submit(r)
        stats = fs.run(max_steps=100_000)
        equal = equal and (stats["replicas"][0] == bare)
    return {"section": "fleet", "kind": "parity", "G": G, "B": B,
            "n_requests": int(G * B * n_rounds),
            "routers": list(routers), "steps": bare["steps"],
            "stats_equal": equal}


_FLEET_SCALE_STATE: dict = {}


def _fleet_scale_setup():
    """An even smaller model than bench-tiny (1 layer, d=32): the
    fleet_scale section measures fleet-layer bookkeeping at large R,
    so per-replica model compute is pinned near the floor a CPU jit
    round-trip allows."""
    if _FLEET_SCALE_STATE:
        return _FLEET_SCALE_STATE
    import jax

    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_cpu_mesh
    from repro.models import init_params, split_params

    cfg = ModelConfig(name="bench-fleet", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=128, dtype="float32")
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    _FLEET_SCALE_STATE.update(cfg=cfg, params=params,
                              mesh=make_cpu_mesh())
    return _FLEET_SCALE_STATE


def _fleet_scale_server(st, ec, sc, *, R, router, mode, telemetry=None):
    from repro.fleet import FleetServer

    fs = FleetServer(st["cfg"], st["params"], ec, n_replicas=R,
                     router=router, policy="bfio_h0", mesh=st["mesh"],
                     fleet_mode=mode, telemetry=telemetry)
    fs.submit_scenario(sc)
    return fs


def _fleet_scale_speedup_case(R: int, G: int, B: int, *, n_requests: int,
                              routers, load_factor: float = 0.1,
                              repeats: int = 2,
                              seed: int = 0) -> list[dict]:
    """Ref-vs-vec fleet hot path on the trickle scenario: the same
    stream through the same router under both fleet modes.  Timed runs
    carry no telemetry and take the min wall over ``repeats`` with the
    GC parked (the stall-case idiom); stats equality is checked on the
    timed runs and per-step telemetry equality on a separate
    instrumented pair."""
    import gc

    from repro.fleet import FleetTelemetry, make_scenario
    from repro.serving import EngineConfig

    st = _fleet_scale_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=48,
                      prefill_chunk=16, **FLEET_TIMING)
    sc = make_scenario("trickle", n_requests=n_requests, n_replicas=R,
                       n_workers=G, slots_per_worker=B, max_seq_len=48,
                       vocab_size=128, seed=seed,
                       load_factor=load_factor, **FLEET_TIMING)
    rows = []
    for router in routers:
        # warmup: compile every shape bucket the stream hits
        _fleet_scale_server(st, ec, sc, R=R, router=router,
                            mode="vec").run(max_steps=500_000)
        walls = {}
        stats = {}
        for mode in ("ref", "vec"):
            best = float("inf")
            for _ in range(repeats):
                fs = _fleet_scale_server(st, ec, sc, R=R, router=router,
                                         mode=mode)
                gc.collect()
                gc.disable()
                try:
                    t0 = time.perf_counter()
                    stats[mode] = fs.run(max_steps=500_000)
                    best = min(best, time.perf_counter() - t0)
                finally:
                    gc.enable()
            walls[mode] = best
        tel = {}
        for mode in ("ref", "vec"):
            tel[mode] = FleetTelemetry()
            _fleet_scale_server(st, ec, sc, R=R, router=router, mode=mode,
                                telemetry=tel[mode]).run(max_steps=500_000)
        steps = stats["vec"]["steps"]
        rows.append({
            "section": "fleet_scale", "kind": "speedup",
            "scenario": "trickle", "R": R, "G": G, "B": B,
            "router": router, "n_requests": sc.n_requests,
            "load_factor": load_factor, "repeats": repeats,
            "steps": steps,
            "ref_wall_s": walls["ref"], "vec_wall_s": walls["vec"],
            "ref_steps_per_s": steps / max(walls["ref"], 1e-9),
            "vec_steps_per_s": steps / max(walls["vec"], 1e-9),
            "speedup": walls["ref"] / max(walls["vec"], 1e-9),
            "stats_equal": stats["ref"] == stats["vec"],
            "telemetry_equal": (
                tel["ref"].steps == tel["vec"].steps
                and tel["ref"].requests == tel["vec"].requests
                and tel["ref"].summary() == tel["vec"].summary()),
            "completed": stats["vec"]["completed"],
            "failed": stats["vec"]["failed"]})
    return rows


def _fleet_scale_pod_case(R: int, G: int, B: int, *, pods: int,
                          n_requests: int, load_factor: float = 0.8,
                          seed: int = 0,
                          jsonl_dir: str | None = None) -> dict:
    """Hierarchical pod routing at large R (both vec mode): flat
    round_robin vs two-level ``pod_bfio`` on the steady scenario —
    the R-in-the-hundreds deployment shape."""
    from repro.fleet import FleetTelemetry, SLOSpec, make_scenario
    from repro.serving import EngineConfig

    st = _fleet_scale_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                      prefill_chunk=16, **FLEET_TIMING)
    sc = make_scenario("steady", n_requests=n_requests, n_replicas=R,
                       n_workers=G, slots_per_worker=B, max_seq_len=64,
                       vocab_size=128, seed=seed,
                       load_factor=load_factor, **FLEET_TIMING)
    row = {"section": "fleet_scale", "kind": "pod", "scenario": "steady",
           "R": R, "G": G, "B": B, "pods": pods,
           "n_requests": sc.n_requests, "load_factor": load_factor}
    pod_router = f"pod_bfio_p{pods}"
    for router in ("round_robin", pod_router):
        key = "pod_bfio" if router == pod_router else router
        tel = FleetTelemetry(slo=SLOSpec(ttft_s=1.0, tpot_s=0.05))
        fs = _fleet_scale_server(st, ec, sc, R=R, router=router,
                                 mode="vec", telemetry=tel)
        t0 = time.perf_counter()
        stats = fs.run(max_steps=500_000)
        wall = time.perf_counter() - t0
        s = tel.summary()
        row[f"{key}_imbalance"] = s["mean_cross_imbalance"]
        row[f"{key}_energy_per_token"] = s["energy_per_token"]
        row[f"{key}_completed"] = s["completed"]
        row[f"{key}_failed"] = s["failed"]
        row[f"{key}_steps"] = stats["steps"]
        row[f"{key}_wall_s"] = wall
        row[f"{key}_steps_per_s"] = stats["steps"] / max(wall, 1e-9)
        if jsonl_dir is not None and router == pod_router:
            tel.write_jsonl(os.path.join(
                jsonl_dir, f"fleet_scale_pod_R{R}.jsonl"))
    row["pod_wins"] = bool(row["pod_bfio_imbalance"]
                           < row["round_robin_imbalance"])
    return row


def _fleet_async_compat_case(R: int, G: int, B: int, *, n_requests: int,
                             routers=("round_robin", "least_loaded",
                                      "pod2", "bfio"),
                             load_factor: float = 0.8,
                             seed: int = 0) -> list[dict]:
    """``AsyncFleetServer(barrier_compat=True)`` vs ``FleetServer`` on
    the same stream: the async subsystem's parity oracle — stats,
    telemetry, and generations must all be bit-identical, per router."""
    from repro.fleet import AsyncFleetServer, FleetTelemetry, make_scenario
    from repro.serving import EngineConfig

    st = _fleet_scale_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                      **FLEET_TIMING)
    sc = make_scenario("flash_crowd", n_requests=n_requests, n_replicas=R,
                       n_workers=G, slots_per_worker=B, max_seq_len=64,
                       vocab_size=128, seed=seed,
                       load_factor=load_factor, **FLEET_TIMING)
    rows = []
    for router in routers:
        stats, tels, gens = {}, {}, {}
        for mode in ("barrier", "compat"):
            tel = FleetTelemetry()
            if mode == "barrier":
                fs = _fleet_scale_server(st, ec, sc, R=R, router=router,
                                         mode="vec", telemetry=tel)
            else:
                fs = AsyncFleetServer(
                    st["cfg"], st["params"], ec, n_replicas=R,
                    router=router, policy="bfio_h0", mesh=st["mesh"],
                    telemetry=tel, barrier_compat=True)
                fs.submit_scenario(sc)
            stats[mode] = fs.run(max_steps=500_000)
            tels[mode] = tel
            gens[mode] = [r.generated for r in fs.requests]
        rows.append({
            "section": "fleet_async", "kind": "compat",
            "scenario": sc.name, "R": R, "G": G, "B": B,
            "router": router, "n_requests": sc.n_requests,
            "load_factor": load_factor,
            "steps": stats["barrier"]["steps"],
            "completed": stats["compat"]["completed"],
            "failed": stats["compat"]["failed"],
            "stats_equal": stats["barrier"] == stats["compat"],
            "telemetry_equal": (
                tels["barrier"].steps == tels["compat"].steps
                and tels["barrier"].requests == tels["compat"].requests
                and tels["barrier"].summary() == tels["compat"].summary()),
            "gens_equal": gens["barrier"] == gens["compat"]})
    return rows


def _fleet_async_diurnal_case(R: int, G: int, B: int, *, n_requests: int,
                              router: str = "bfio",
                              load_factor: float = 0.35,
                              target: float = 0.7,
                              interval_s: float = 0.05,
                              warmup_s: float = 0.02, seed: int = 5,
                              jsonl_dir: str | None = None) -> dict:
    """The headline claim: fixed-R barrier fleet vs autoscaled async
    fleet on the diurnal scenario, paged engines with host-staged swap
    so drain handoffs are bit-exact.  The async fleet must cut idle
    energy and energy-per-token at equal-or-better SLO attainment with
    zero failures, zero tokens lost across drains, and generations
    identical to the run that never scaled."""
    from repro.fleet import (
        AsyncFleetServer,
        FleetTelemetry,
        SLOSpec,
        TargetUtilizationAutoscaler,
        make_scenario,
    )
    from repro.serving import EngineConfig

    st = _fleet_scale_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                      cache_backend="paged", paged_block_size=16,
                      preemption_mode="swap", **FLEET_TIMING)
    sc = make_scenario("diurnal", n_requests=n_requests, n_replicas=R,
                       n_workers=G, slots_per_worker=B, max_seq_len=64,
                       vocab_size=128, seed=seed,
                       load_factor=load_factor, **FLEET_TIMING)
    slo = SLOSpec(ttft_s=0.5, tpot_s=0.1)

    tel_b = FleetTelemetry(slo=slo)
    fb = _fleet_scale_server(st, ec, sc, R=R, router=router, mode="vec",
                             telemetry=tel_b)
    stats_b = fb.run(max_steps=500_000)
    sum_b = tel_b.summary()

    tel_a = FleetTelemetry(slo=slo)
    auto = TargetUtilizationAutoscaler(
        r_min=1, r_max=R, target=target, interval_s=interval_s,
        warmup_s=warmup_s)
    fa = AsyncFleetServer(
        st["cfg"], st["params"], ec, n_replicas=R, router=router,
        policy="bfio_h0", mesh=st["mesh"], telemetry=tel_a,
        autoscaler=auto, max_snapshot_age=interval_s)
    fa.submit_scenario(sc)
    stats_a = fa.run(max_steps=500_000)
    sum_a = tel_a.summary()
    if jsonl_dir is not None:
        tel_a.write_jsonl(os.path.join(
            jsonl_dir, f"fleet_async_diurnal_R{R}.jsonl"))

    return {
        "section": "fleet_async", "kind": "diurnal",
        "scenario": sc.name, "R": R, "G": G, "B": B, "router": router,
        "n_requests": sc.n_requests, "load_factor": load_factor,
        "target_util": target, "interval_s": interval_s,
        "warmup_s": warmup_s,
        "barrier_idle_j": stats_b["idle_j"],
        "barrier_energy_per_token": stats_b["energy_per_token"],
        "barrier_slo_attainment": sum_b["slo_attainment"],
        "barrier_completed": stats_b["completed"],
        "barrier_failed": stats_b["failed"],
        "barrier_tokens": stats_b["tokens"],
        "barrier_steps": stats_b["steps"],
        "async_idle_j": stats_a["idle_j"],
        "async_energy_per_token": stats_a["energy_per_token"],
        "async_slo_attainment": sum_a["slo_attainment"],
        "async_completed": stats_a["completed"],
        "async_failed": stats_a["failed"],
        "async_tokens": stats_a["tokens"],
        "async_steps": stats_a["steps"],
        "idle_saving": 1.0 - (stats_a["idle_j"]
                              / max(stats_b["idle_j"], 1e-12)),
        "drain_handoffs": stats_a["drain_handoffs"],
        "tokens_lost": stats_a["drain_tokens_lost"],
        "scale_ups": stats_a["scale_ups"],
        "scale_downs": stats_a["scale_downs"],
        "r_on_mean": stats_a["r_on_mean"],
        "gens_equal": ([r.generated for r in fa.requests]
                       == [r.generated for r in fb.requests]),
    }


def _obs_case(R: int, G: int, B: int, *, n_requests: int,
              load_factor: float = 0.4, seed: int = 5,
              variants=("barrier", "async"),
              jsonl_dir: str | None = None) -> list[dict]:
    """Observability exactness + overhead: the same diurnal stream with
    the span recorder enabled vs disabled, per fleet tier.  Gates (all
    enforced by ``check_bench``):

    * the straggler ledger's attributed total equals ``stats['idle_j']``
      bit-exactly, and every telemetry row's ``idle_split`` left-folds
      to its ``idle_j`` bit-exactly;
    * the exported trace round-trips through the validating reader and
      every fleet-track request span's ``e2e_s`` equals the telemetry's
      per-request ``latency`` bit-exactly;
    * the disabled recorder buffers zero events and reproduces
      bit-identical stats and telemetry (observation is free when off);
    * the enabled recorder's wall-clock overhead is bounded (full runs
      only — smoke shapes are dispatch-jitter-dominated)."""
    import gc

    from repro.fleet import (
        AsyncFleetServer,
        FleetServer,
        FleetTelemetry,
        SLOSpec,
        TargetUtilizationAutoscaler,
        make_scenario,
    )
    from repro.obs import SpanRecorder, fold_sum, read_trace, write_trace
    from repro.serving import EngineConfig

    st = _fleet_scale_setup()
    ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                      cache_backend="paged", paged_block_size=16,
                      preemption_mode="swap", **FLEET_TIMING)
    sc = make_scenario("diurnal", n_requests=n_requests, n_replicas=R,
                       n_workers=G, slots_per_worker=B, max_seq_len=64,
                       vocab_size=128, seed=seed,
                       load_factor=load_factor, **FLEET_TIMING)
    slo = SLOSpec(ttft_s=0.5, tpot_s=0.1)

    def build(variant, telemetry, recorder):
        if variant == "async":
            auto = TargetUtilizationAutoscaler(
                r_min=1, r_max=R, target=0.7, interval_s=0.05,
                warmup_s=0.02)
            fs = AsyncFleetServer(
                st["cfg"], st["params"], ec, n_replicas=R,
                router="bfio", policy="bfio_h0", mesh=st["mesh"],
                telemetry=telemetry, autoscaler=auto,
                max_snapshot_age=0.05, obs=recorder)
        else:
            fs = FleetServer(
                st["cfg"], st["params"], ec, n_replicas=R,
                router="bfio", policy="bfio_h0", mesh=st["mesh"],
                telemetry=telemetry, obs=recorder)
        fs.submit_scenario(sc)
        return fs

    def timed(variant, telemetry, recorder):
        fs = build(variant, telemetry, recorder)
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            stats = fs.run(max_steps=500_000)
            wall = time.perf_counter() - t0
        finally:
            gc.enable()
        return fs, stats, wall

    rows = []
    out_dir = jsonl_dir or tempfile.mkdtemp(prefix="bench_obs_")
    for variant in variants:
        build(variant, None, None).run(max_steps=500_000)   # warmup
        rec = SpanRecorder()
        tel_on = FleetTelemetry(slo=slo)
        fs_on, stats_on, wall_on = timed(variant, tel_on, rec)
        tel_off = FleetTelemetry(slo=slo)
        fs_off, stats_off, wall_off = timed(variant, tel_off, None)

        ledger = fs_on.straggler_ledger()
        split_sums_match = all(
            fold_sum(s["idle_split"]) == s["idle_j"]
            for s in tel_on.steps)
        # trace export -> validating reader -> span/latency equality
        trace_path = os.path.join(
            out_dir, f"obs_diurnal_{variant}_R{R}.trace")
        write_trace(rec, trace_path)
        seen = read_trace(trace_path)
        lat = {q["rid"]: q["latency"] for q in tel_on.requests}
        spans_match_latency = (
            set(seen["requests"]) == set(lat)
            and all(v["e2e_s"] == lat[rid]
                    for rid, v in seen["requests"].items()))
        tel_path = os.path.join(
            out_dir, f"obs_diurnal_{variant}_R{R}.jsonl")
        tel_on.write_jsonl(tel_path)
        # read_jsonl re-validates the stored summary on the way back in
        back = FleetTelemetry.read_jsonl(tel_path)
        telemetry_roundtrip = (
            back.steps == tel_on.steps
            and json.loads(json.dumps(tel_on.summary()))
            == back.summary())
        rows.append({
            "section": "obs", "kind": "obs", "variant": variant,
            "scenario": sc.name, "R": R, "G": G, "B": B,
            "n_requests": sc.n_requests, "load_factor": load_factor,
            "wall_s_enabled": wall_on, "wall_s_disabled": wall_off,
            "overhead_ratio": wall_on / max(wall_off, 1e-12),
            "idle_j": stats_on["idle_j"],
            "ledger_total_j": ledger["total_idle_j"],
            "ledger_matches":
                ledger["total_idle_j"] == stats_on["idle_j"],
            "split_sums_match": split_sums_match,
            "by_cause": ledger["by_cause"],
            "gating_steps": ledger["gating_steps"],
            "trough_steps": ledger["trough_steps"],
            "trace_events": rec.n_events,
            "trace_spans": len(seen["requests"]),
            "trace_events_disabled": fs_off._obs_rec.n_events,
            "trace_roundtrip": seen["n_points"] == rec.n_events,
            "spans_match_latency": spans_match_latency,
            "stats_bit_identical": stats_on == stats_off,
            "telemetry_bit_identical":
                tel_on.steps == tel_off.steps
                and tel_on.requests == tel_off.requests,
            "telemetry_roundtrip": telemetry_roundtrip,
        })
    return rows


_STALL_STATE: dict = {}


def _stall_setup():
    """A deeper model for the stall measurement: with the bench-tiny
    model a decode step is ~2 ms, the same order as CPU dispatch jitter,
    so max-vs-median ratios measure the host, not the engine."""
    if _STALL_STATE:
        return _STALL_STATE
    import jax

    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_cpu_mesh
    from repro.models import init_params, split_params

    cfg = ModelConfig(name="bench-stall", family="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                      vocab_size=128, dtype="float32")
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    _STALL_STATE.update(cfg=cfg, params=params, mesh=make_cpu_mesh())
    return _STALL_STATE


def _engine_stall_case(G: int, B: int, *, chunk: int = 8,
                       prompt_len: int = 192, warm_n: int = 16,
                       repeats: int = 3, tiny_model: bool = False,
                       seed: int = 9) -> dict:
    """Admission-wave decode stall: a burst of long prompts lands while
    ``warm_n`` requests are decoding.  The synchronous path prefills the
    whole wave inside one barrier step (max step wall >> steady decode
    step); chunked prefill bounds per-step prompt work at the budget, so
    the max step stays within a small factor of steady state.

    The scenario is deterministic, so each timed step takes the min over
    ``repeats`` identical runs (with the GC parked) — the standard way
    to strip scheduler/GC spikes from per-step wall times on CPU.
    """
    import gc

    from repro.core import make_policy
    from repro.serving import EngineConfig, ServeRequest, ServingEngine

    st = _engine_setup() if tiny_model else _stall_setup()
    N = G * B
    warm_n = max(2, min(warm_n, N - 2))
    burst_n = N - warm_n

    def scenario(chunked: bool):
        ec = EngineConfig(n_workers=G, slots_per_worker=B,
                          max_seq_len=256,
                          prefill_chunk=chunk if chunked else 0)
        eng = ServingEngine(st["cfg"], st["params"], ec,
                            make_policy("jsq"), mesh=st["mesh"])
        rng = np.random.default_rng(seed)
        for i in range(warm_n):
            eng.submit(ServeRequest(
                rid=i, tokens=rng.integers(1, 128, size=8),
                max_new_tokens=100_000))  # decode throughout the scenario
        for _ in range(3):
            eng.step()
        gc.collect()
        gc.disable()
        try:
            steady = []
            for _ in range(20):
                t0 = time.perf_counter()
                eng.step()
                steady.append(time.perf_counter() - t0)
            burst = [ServeRequest(
                rid=100 + i, tokens=rng.integers(1, 128, size=prompt_len),
                max_new_tokens=2) for i in range(burst_n)]
            for r in burst:
                eng.submit(r)
            walls = []
            while not all(r.done for r in burst):
                t0 = time.perf_counter()
                eng.step()
                walls.append(time.perf_counter() - t0)
                if len(walls) > 20_000:
                    raise RuntimeError("admission burst never drained")
        finally:
            gc.enable()
        return np.asarray(steady), np.asarray(walls)

    def measure(chunked: bool):
        scenario(chunked)       # warmup: compile every shape it hits
        runs = [scenario(chunked) for _ in range(repeats)]
        n = min(len(w) for _, w in runs)
        walls = np.min([w[:n] for _, w in runs], axis=0)
        steady = float(np.median(np.min([s for s, _ in runs], axis=0)))
        return steady, float(walls.max()), n

    s_med, s_max, s_steps = measure(False)
    c_med, c_max, c_steps = measure(True)
    return {"section": "engine_paged", "kind": "stall", "G": G, "B": B,
            "prefill_chunk": chunk, "burst_prompts": burst_n,
            "prompt_len": prompt_len, "warm_decoders": warm_n,
            "repeats": repeats,
            "steady_step_ms_sync": s_med * 1e3,
            "burst_max_step_ms_sync": s_max * 1e3,
            "stall_x_sync": s_max / max(s_med, 1e-9),
            "burst_steps_sync": s_steps,
            "steady_step_ms_chunked": c_med * 1e3,
            "burst_max_step_ms_chunked": c_max * 1e3,
            "stall_x_chunked": c_max / max(c_med, 1e-9),
            "burst_steps_chunked": c_steps}


ALL_SECTIONS = ("solver", "simulator", "batch", "engine", "engine_paged",
                "engine_preempt", "fleet", "fleet_scale", "fleet_async",
                "obs")


def run(full: bool = False, smoke: bool = False,
        out_path: str | None = None, sections=None) -> dict:
    if sections is None:
        sections = ALL_SECTIONS
    sections = set(sections)
    unknown = sections - set(ALL_SECTIONS)
    if unknown:
        raise ValueError(f"unknown bench sections {sorted(unknown)} "
                         f"(have {list(ALL_SECTIONS)})")
    if smoke:
        solver_grid = [(4, 16)]
        sim_grid = [(8, 4)]
        batch_grid = [(2, 4, 8)]
        engine_grid = [(2, 2)]
        paged_grid = [(2, 2)]
        preempt_grid = [(2, 2)]
        prefix_grid = [(2, 2)]
        persist_grid = [(2, 2)]
        stall_shape = (2, 2)
        stall_kw = dict(chunk=16, prompt_len=64, warm_n=2, repeats=1,
                        tiny_model=True)
        fleet_shape = (4, 2, 2)       # R, G, B
        fleet_kw = dict(n_requests=32, routers=("round_robin", "bfio"))
        fleet_parity_shape = (2, 2)
        # deliberately NOT downsized for smoke: the affinity gate row is
        # a deterministic trace, cheap enough to run at its real shape
        fleet_affinity_shape = (3, 1, 2)    # R, G, B
        fleet_affinity_kw = dict(n_requests=36, seed=0, scenario_seed=1)
        fscale_shape = (8, 1, 2)      # R, G, B
        fscale_kw = dict(n_requests=24, repeats=1,
                         routers=("round_robin", "bfio"))
        fscale_pod_shape = (16, 1, 2)
        fscale_pod_kw = dict(pods=4, n_requests=48)
        fasync_compat_shape = (2, 1, 2)     # R, G, B
        fasync_compat_kw = dict(n_requests=12,
                                routers=("round_robin", "bfio"))
        fasync_diurnal_shape = (4, 2, 4)    # R, G, B
        fasync_diurnal_kw = dict(n_requests=24, load_factor=0.4)
        obs_shape = (4, 2, 4)               # R, G, B
        obs_kw = dict(n_requests=24, load_factor=0.4)
        n_rounds, iters = 2.0, 2
    else:
        solver_grid = [(G, N) for G in (64, 256, 1024)
                       for N in (64, 512, 2048)]
        sim_grid = [(64, 72), (256, 72), (1024, 72)]
        batch_grid = [(8, 64, 256)]
        engine_grid = [(G, B) for G in (4, 16, 64) for B in (8, 32)]
        paged_grid = [(G, B) for G in (4, 16, 64) for B in (8, 32)]
        preempt_grid = [(4, 8), (16, 8)]
        prefix_grid = [(4, 8)]
        persist_grid = [(4, 8)]
        stall_shape = (4, 8)
        stall_kw = dict(chunk=8, prompt_len=192, warm_n=16, repeats=7)
        fleet_shape = (4, 4, 4)
        fleet_kw = dict(
            n_requests=96,
            routers=("round_robin", "least_loaded", "pod2", "bfio"),
            jsonl_dir=os.path.join(ROOT, "benchmarks", "results"))
        fleet_parity_shape = (2, 4)
        fleet_affinity_shape = (3, 1, 2)
        fleet_affinity_kw = dict(
            n_requests=36, seed=0, scenario_seed=1,
            jsonl_dir=os.path.join(ROOT, "benchmarks", "results"))
        fscale_shape = (64, 1, 2)
        fscale_kw = dict(
            n_requests=128, repeats=2,
            routers=("round_robin", "least_loaded", "pod2", "bfio"))
        fscale_pod_shape = (256, 1, 2)
        fscale_pod_kw = dict(
            pods=16, n_requests=384,
            jsonl_dir=os.path.join(ROOT, "benchmarks", "results"))
        fasync_compat_shape = (8, 1, 2)
        fasync_compat_kw = dict(
            n_requests=48,
            routers=("round_robin", "least_loaded", "pod2", "bfio"))
        fasync_diurnal_shape = (8, 2, 4)
        fasync_diurnal_kw = dict(
            n_requests=96, load_factor=0.35,
            jsonl_dir=os.path.join(ROOT, "benchmarks", "results"))
        obs_shape = (8, 2, 4)
        obs_kw = dict(
            n_requests=96, load_factor=0.35,
            jsonl_dir=os.path.join(ROOT, "benchmarks", "results"))
        n_rounds, iters = 4.0, 10

    rows = []
    for G, N in solver_grid if "solver" in sections else []:
        # the dense baseline materializes (N, N, W) f32 tensors; skip it at
        # N=2048 (>150 MB per temporary) unless --full
        dense_ok = N <= 512 or full
        r = _solver_case(G, N, measure_dense=dense_ok,
                         iters=max(2, iters // (1 + N // 512)))
        rows.append(r)
        pre = f"{r['pre_dense_us']/1e3:8.1f}ms" if r["pre_dense_us"] else "    n/a "
        print(f"  solver G={G:<5d} N={N:<5d} pre={pre} "
              f"post={r['post_tiled_us']/1e3:8.1f}ms "
              f"speedup={r['speedup'] or float('nan'):5.1f}x "
              f"(refine-only {r['refine_speedup'] or float('nan'):5.1f}x) "
              f"dJ={r['quality_rel_diff'] if r['quality_rel_diff'] is not None else float('nan'):+.3%}",
              flush=True)
    for G, B in sim_grid if "simulator" in sections else []:
        r = _sim_case(G, B, n_rounds=n_rounds)
        rows.append(r)
        print(f"  sim    G={G:<5d} B={B:<3d} pre={r['pre_steps_per_s']:8.0f} "
              f"post={r['post_steps_per_s']:8.0f} steps/s "
              f"speedup={r['speedup']:5.1f}x equal={r['metrics_equal']}",
              flush=True)
    for C, G, N in batch_grid if "batch" in sections else []:
        r = _batch_case(C, G, N, iters=iters)
        rows.append(r)
        print(f"  batch  C={C} G={G} N={N} batch={r['batch_us']/1e3:.1f}ms "
              f"seq={r['sequential_us']/1e3:.1f}ms speedup={r['speedup']:.1f}x",
              flush=True)
    for G, B in engine_grid if "engine" in sections else []:
        r = _engine_case(G, B)
        rows.append(r)
        print(f"  engine G={G:<3d} B={B:<3d} pre={r['pre_steps_per_s']:7.1f} "
              f"post={r['post_steps_per_s']:7.1f} steps/s "
              f"speedup={r['speedup']:5.1f}x equal={r['metrics_equal']}",
              flush=True)
    for G, B in paged_grid if "engine_paged" in sections else []:
        r = _engine_paged_case(G, B)
        rows.append(r)
        print(f"  paged  G={G:<3d} B={B:<3d} "
              f"slot={r['slot_steps_per_s']:7.1f} "
              f"paged={r['paged_steps_per_s']:7.1f} steps/s "
              f"kv={r['kv_bytes_ratio']:.2f}x of dense "
              f"equal={r['metrics_equal']}", flush=True)
    for G, B in preempt_grid if "engine_preempt" in sections else []:
        for r in _engine_preempt_case(G, B):
            rows.append(r)
            print(f"  preempt G={G:<3d} B={B:<3d} mode={r['mode']:<9s} "
                  f"pool={r['pool_blocks']}/{r['peak_blocks_unconstrained']} "
                  f"blocks preempts={r['preemptions']:<4d} "
                  f"swapped={r['tokens_swapped']:<6d} "
                  f"recomputed={r['tokens_recomputed']:<6d} "
                  f"gens_equal={r['gens_equal']}", flush=True)
    for G, B in prefix_grid if "engine_preempt" in sections else []:
        r = _engine_prefix_case(G, B)
        rows.append(r)
        print(f"  prefix G={G:<3d} B={B:<3d} "
              f"hit_rate={r['prefix_hit_rate']:.2f} "
              f"kv={r['kv_bytes_ratio']:.2f}x of uncached "
              f"gens_equal={r['gens_equal']}", flush=True)
    for G, B in persist_grid if "engine_preempt" in sections else []:
        r = _engine_persist_case(G, B)
        rows.append(r)
        print(f"  persist G={G:<3d} B={B:<3d} "
              f"hit_rate adm={r['prefix_hit_rate_admission']:.2f} "
              f"lru={r['prefix_hit_rate_lru']:.2f} "
              f"revived={r['prefix_revived']} "
              f"kv={r['kv_bytes_ratio']:.2f}x of uncached "
              f"gens_equal={r['gens_equal']}", flush=True)
    if "engine_paged" in sections:
        r = _engine_stall_case(*stall_shape, **stall_kw)
        rows.append(r)
        print(f"  stall  G={r['G']} B={r['B']} "
              f"sync={r['stall_x_sync']:.1f}x "
              f"chunked={r['stall_x_chunked']:.1f}x of steady step "
              f"(burst of {r['burst_prompts']}x{r['prompt_len']}-token "
              f"prompts)", flush=True)
    if "fleet" in sections:
        wins = 0
        for r in _fleet_case(*fleet_shape, **fleet_kw):
            rows.append(r)
            wins += r["bfio_wins"]
            print(f"  fleet  {r['scenario']:<12s} R={r['R']} "
                  f"imb rr={r['round_robin_imbalance']:7.1f} "
                  f"bfio={r['bfio_imbalance']:7.1f}  "
                  f"J/tok rr={r['round_robin_energy_per_token']:.3f} "
                  f"bfio={r['bfio_energy_per_token']:.3f}  "
                  f"win={r['bfio_wins']}", flush=True)
        r = _fleet_parity_case(*fleet_parity_shape)
        rows.append(r)
        print(f"  fleet  parity R=1 vs bare engine over "
              f"{len(r['routers'])} routers: "
              f"stats_equal={r['stats_equal']}  "
              f"(bfio wins {wins}/5 scenarios)", flush=True)
        r = _fleet_affinity_case(*fleet_affinity_shape,
                                 **fleet_affinity_kw)
        rows.append(r)
        print(f"  fleet  multi_turn R={r['R']} hits "
              f"{r['bfio_prefix_hits']}->"
              f"{r['bfio_affinity_prefix_hits']} "
              f"J/tok {r['bfio_energy_per_token']:.3f}->"
              f"{r['bfio_affinity_energy_per_token']:.3f} "
              f"imb {r['bfio_imbalance']:.1f}->"
              f"{r['bfio_affinity_imbalance']:.1f} "
              f"win={r['affinity_wins']}", flush=True)
    if "fleet_scale" in sections:
        for r in _fleet_scale_speedup_case(*fscale_shape, **fscale_kw):
            rows.append(r)
            print(f"  fscale {r['router']:<13s} R={r['R']:<4d} "
                  f"ref={r['ref_steps_per_s']:7.1f} "
                  f"vec={r['vec_steps_per_s']:7.1f} steps/s "
                  f"speedup={r['speedup']:5.2f}x "
                  f"equal={r['stats_equal'] and r['telemetry_equal']}",
                  flush=True)
        r = _fleet_scale_pod_case(*fscale_pod_shape, **fscale_pod_kw)
        rows.append(r)
        print(f"  fscale pod R={r['R']} pods={r['pods']} "
              f"imb rr={r['round_robin_imbalance']:7.1f} "
              f"pod_bfio={r['pod_bfio_imbalance']:7.1f}  "
              f"failed={r['pod_bfio_failed']}  win={r['pod_wins']}",
              flush=True)
    if "fleet_async" in sections:
        for r in _fleet_async_compat_case(*fasync_compat_shape,
                                          **fasync_compat_kw):
            rows.append(r)
            print(f"  fasync compat {r['router']:<13s} R={r['R']} "
                  f"stats_equal={r['stats_equal']} "
                  f"telemetry_equal={r['telemetry_equal']} "
                  f"gens_equal={r['gens_equal']}", flush=True)
        r = _fleet_async_diurnal_case(*fasync_diurnal_shape,
                                      **fasync_diurnal_kw)
        rows.append(r)
        print(f"  fasync diurnal R={r['R']} "
              f"idle {r['barrier_idle_j']:7.1f}->{r['async_idle_j']:7.1f}J "
              f"J/tok {r['barrier_energy_per_token']:.3f}->"
              f"{r['async_energy_per_token']:.3f} "
              f"slo {r['barrier_slo_attainment']:.2f}->"
              f"{r['async_slo_attainment']:.2f} "
              f"handoffs={r['drain_handoffs']} lost={r['tokens_lost']} "
              f"gens_equal={r['gens_equal']}", flush=True)
    if "obs" in sections:
        for r in _obs_case(*obs_shape, **obs_kw):
            rows.append(r)
            exact = (r["ledger_matches"] and r["split_sums_match"]
                     and r["spans_match_latency"])
            print(f"  obs    {r['variant']:<8s} R={r['R']} "
                  f"idle={r['idle_j']:8.2f}J exact={exact} "
                  f"events={r['trace_events']:<5d} "
                  f"(off: {r['trace_events_disabled']}) "
                  f"free_when_off={r['stats_bit_identical']} "
                  f"overhead={r['overhead_ratio']:.2f}x", flush=True)

    doc = {
        "meta": {
            "bench": "balancer",
            "smoke": smoke,
            "sections": sorted(sections),
            "W": W,
            "swap_iters": SWAP_ITERS,
            "prune_k": PRUNE_K,
            "pre": "method='dense' solver / dispatch='instant_ref' simulator "
                   "/ engine_mode='ref' serving engine "
                   "(the pre-optimization implementations, kept in-tree)",
            "post": "tiled swap kernel with top-K pruning / vectorized "
                    "instant dispatch / slot-table engine with bucketed "
                    "compact decode / paged KV backend + chunked prefill "
                    "(engine_paged section) / preemption + prefix "
                    "caching under memory pressure (engine_preempt "
                    "section) / two-tier routing across engine replicas "
                    "(fleet section) / vectorized fleet hot path "
                    "(fleet_mode='vec') with hierarchical pod routing "
                    "at R in the hundreds (fleet_scale section) / "
                    "event-driven async fleet with SLO-driven "
                    "autoscaling and bit-exact drain handoff "
                    "(fleet_async section) / persistent LRU prefix "
                    "evictor + prefix-affinity fleet routing "
                    "(engine_preempt kind='persist' / fleet "
                    "kind='affinity' rows) / per-request tracing + "
                    "barrier straggler attribution with bit-exact "
                    "idle-energy decomposition and a free-when-off "
                    "recorder (obs section)",
        },
        "rows": rows,
    }
    if out_path is None and (smoke or sections != set(ALL_SECTIONS)):
        # never clobber the tracked full-grid artifact with smoke or
        # partial-section numbers
        out_path = os.path.join(tempfile.mkdtemp(prefix="bench_smoke_"),
                                "BENCH_balancer.json")
    path = out_path or os.path.join(ROOT, "BENCH_balancer.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"  wrote {path}")
    if not smoke and sections == set(ALL_SECTIONS):
        from .common import save_rows
        save_rows("balancer_bench", rows, meta=doc["meta"])
    return doc


def main(full: bool = False, smoke: bool = False,
         sections: str | None = None):
    run(full=full, smoke=smoke,
        sections=[s.strip() for s in sections.split(",") if s.strip()]
        if sections else None)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also measure the dense baseline at N=2048")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, schema check only")
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of sections to run "
                         f"(default: all of {','.join(ALL_SECTIONS)})")
    main(**vars(ap.parse_args()))
