"""Balancer + simulator hot-path benchmark -> BENCH_balancer.json.

Tracks the two hot paths this repo's scale story rests on, with the
pre-optimization implementations measured live (they are kept in-tree
precisely for this):

* **solver** — jitted BF-IO solve time, pre = ``method="dense"`` (the
  original O(N^2 W) ``_swap_once`` formulation) vs post = the tiled
  swap kernel with top-K candidate pruning (``method="xla"``,
  ``prune_k``).  Assignment quality (windowed imbalance J) is recorded
  for both so the speed/quality trade stays visible.
* **simulator** — instant-mode steps/sec, pre = ``dispatch="instant_ref"``
  (the original per-request Python loop) vs post = the vectorized
  ``dispatch="instant"`` path, with a bit-equality check on SimMetrics.
* **batch** — ``bfio_assign_batch`` (one vmapped call over C clusters)
  vs C sequential ``bfio_assign`` calls.
* **engine** — end-to-end ``ServingEngine`` steps/sec on a tiny dense
  model, pre = ``engine_mode="ref"`` (the original per-slot Python loops
  + per-request cache writes + always-decode-all-G*B) vs post =
  ``engine_mode="vec"`` (slot-table arrays, batched cache scatter,
  bucketed compact decode), with a stats-equality check (steps, tokens,
  energy_j, avg_imbalance bit-identical).

Run:  PYTHONPATH=src python -m benchmarks.balancer_bench [--full] [--smoke]
Writes BENCH_balancer.json at the repo root (and benchmarks/results/).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SWAP_ITERS = 8
PRUNE_K = 128
W = 9  # lookahead window H=8


def _solver_case(G: int, N: int, *, measure_dense: bool, iters: int = 10,
                 seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.core import io_solver
    from repro.core.balancer_jax import bfio_assign

    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.uniform(0, 100, (G, W)), jnp.float32)
    caps = jnp.asarray(rng.integers(4, 16, (G,)), jnp.int32)
    cands = jnp.asarray(rng.uniform(1, 50, (N, W)), jnp.float32)
    valid = jnp.ones((N,), bool)
    n_admit = jnp.int32(min(N, int(np.asarray(caps).sum())))

    def timed(swap_iters=SWAP_ITERS, **kw):
        def call():
            return bfio_assign(base, caps, cands, valid, n_admit,
                               swap_iters=swap_iters, **kw)
        a = np.asarray(call())  # warmup/compile
        t0 = time.time()
        for _ in range(iters):
            call().block_until_ready()
        us = (time.time() - t0) / iters * 1e6
        J = io_solver.objective(np.asarray(base), np.asarray(cands), a)
        return us, J

    prune = min(PRUNE_K, N)
    post_us, J_post = timed(method="xla", prune_k=prune)
    greedy_us, _ = timed(swap_iters=0)  # construction-only floor
    row = {"section": "solver", "G": G, "N": N, "W": W,
           "swap_iters": SWAP_ITERS, "prune_k": prune,
           "post_tiled_us": post_us, "J_post": J_post,
           "greedy_us": greedy_us,
           "pre_dense_us": None, "J_pre": None, "speedup": None,
           "refine_speedup": None, "quality_rel_diff": None}
    if measure_dense:
        pre_us, J_pre = timed(method="dense")
        # refinement-only ratio: subtract the shared greedy construction,
        # which no swap backend touches
        pre_ref = max(pre_us - greedy_us, 1e-9)
        post_ref = max(post_us - greedy_us, 1e-9)
        row.update(pre_dense_us=pre_us, J_pre=J_pre,
                   speedup=pre_us / post_us,
                   refine_speedup=pre_ref / post_ref,
                   quality_rel_diff=(J_post - J_pre) / max(abs(J_pre), 1e-9))
    return row


def _sim_instance(G: int, B: int, n_rounds: float, seed: int = 1):
    from repro.core import ArrivalInstance, Request

    rng = np.random.default_rng(seed)
    n = int(G * B * n_rounds)
    reqs = [
        Request(rid=i, arrival_step=int(rng.integers(0, 50)),
                prefill=float(rng.integers(1, 80)),
                decode_len=int(rng.geometric(0.1)))
        for i in range(n)
    ]
    return ArrivalInstance(requests=reqs)


def _sim_case(G: int, B: int, *, n_rounds: float = 4.0, policy: str = "jsq",
              seed: int = 1) -> dict:
    from repro.core import SimConfig, make_policy, simulate

    out = {"section": "simulator", "G": G, "B": B, "policy": policy}
    metrics = {}
    for mode, key in [("instant_ref", "pre"), ("instant", "post")]:
        inst = _sim_instance(G, B, n_rounds, seed=seed)
        t0 = time.time()
        m = simulate(inst, make_policy(policy),
                     SimConfig(G=G, B=B, dispatch=mode, max_steps=500_000))
        wall = time.time() - t0
        metrics[key] = dataclasses.asdict(m)
        out[f"{key}_steps_per_s"] = m.steps / max(wall, 1e-9)
        out[f"{key}_wall_s"] = wall
        out["steps"] = m.steps
    out["speedup"] = out["post_steps_per_s"] / out["pre_steps_per_s"]
    out["metrics_equal"] = metrics["pre"] == metrics["post"]
    return out


def _batch_case(C: int, G: int, N: int, iters: int = 5, seed: int = 2) -> dict:
    import jax.numpy as jnp

    from repro.core.balancer_jax import bfio_assign, bfio_assign_batch

    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.uniform(0, 100, (C, G, W)), jnp.float32)
    caps = jnp.asarray(rng.integers(4, 16, (C, G)), jnp.int32)
    cands = jnp.asarray(rng.uniform(1, 50, (C, N, W)), jnp.float32)
    valid = jnp.ones((C, N), bool)
    n_admit = jnp.minimum(N, caps.sum(axis=1)).astype(jnp.int32)

    prune = min(PRUNE_K, N)
    bfio_assign_batch(base, caps, cands, valid, n_admit,
                      prune_k=prune).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        bfio_assign_batch(base, caps, cands, valid, n_admit,
                          prune_k=prune).block_until_ready()
    batch_us = (time.time() - t0) / iters * 1e6

    def seq():
        for c in range(C):
            bfio_assign(base[c], caps[c], cands[c], valid[c], n_admit[c],
                        prune_k=prune).block_until_ready()
    seq()  # warmup
    t0 = time.time()
    for _ in range(iters):
        seq()
    seq_us = (time.time() - t0) / iters * 1e6
    return {"section": "batch", "C": C, "G": G, "N": N, "W": W,
            "prune_k": prune, "batch_us": batch_us, "sequential_us": seq_us,
            "speedup": seq_us / batch_us}


_ENGINE_STATE: dict = {}


def _engine_setup():
    """Tiny dense model shared by every engine case (params built once)."""
    if _ENGINE_STATE:
        return _ENGINE_STATE
    import jax

    from repro.configs.base import ModelConfig
    from repro.launch.mesh import make_cpu_mesh
    from repro.models import init_params, split_params

    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    _ENGINE_STATE.update(cfg=cfg, params=params, mesh=make_cpu_mesh())
    return _ENGINE_STATE


def _engine_requests(G: int, B: int, *, n_rounds: float, seed: int):
    from repro.serving import ServeRequest

    rng = np.random.default_rng(seed)
    n = int(G * B * n_rounds)
    return [
        ServeRequest(
            rid=i,
            tokens=rng.integers(1, 128, size=int(rng.integers(4, 24))),
            # geometric decode lengths: a long sparse tail, where the ref
            # engine still decodes all G*B slots every step
            max_new_tokens=int(min(3 + rng.geometric(0.12), 40)))
        for i in range(n)
    ]


def _engine_case(G: int, B: int, *, n_rounds: float = 1.5,
                 policy: str = "jsq", seed: int = 7) -> dict:
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServingEngine

    st = _engine_setup()
    out = {"section": "engine", "G": G, "B": B, "policy": policy,
           "n_requests": int(G * B * n_rounds)}
    stats = {}
    for mode, key in [("ref", "pre"), ("vec", "post")]:
        ec = EngineConfig(n_workers=G, slots_per_worker=B, max_seq_len=64,
                          engine_mode=mode)

        def one_run(rounds):
            eng = ServingEngine(st["cfg"], st["params"], ec,
                                make_policy(policy), mesh=st["mesh"])
            for r in _engine_requests(G, B, n_rounds=rounds, seed=seed):
                eng.submit(r)
            s = eng.run(max_steps=100_000)
            return s

        # warmup: compiles are cached across engine instances.  The ref
        # path's only jit is the full-batch decode, so a tiny workload
        # warms it; the vec path replays the full workload so every
        # decode/prefill bucket it will hit is compiled before timing.
        one_run(n_rounds if mode == "vec" else min(n_rounds, 0.25))
        t0 = time.time()
        s = one_run(n_rounds)
        wall = time.time() - t0
        stats[key] = s
        out[f"{key}_steps_per_s"] = s["steps"] / max(wall, 1e-9)
        out[f"{key}_wall_s"] = wall
        out["steps"] = s["steps"]
    out["speedup"] = out["post_steps_per_s"] / out["pre_steps_per_s"]
    out["metrics_equal"] = stats["pre"] == stats["post"]
    return out


def run(full: bool = False, smoke: bool = False,
        out_path: str | None = None) -> dict:
    if smoke:
        solver_grid = [(4, 16)]
        sim_grid = [(8, 4)]
        batch_grid = [(2, 4, 8)]
        engine_grid = [(2, 2)]
        n_rounds, iters = 2.0, 2
    else:
        solver_grid = [(G, N) for G in (64, 256, 1024)
                       for N in (64, 512, 2048)]
        sim_grid = [(64, 72), (256, 72), (1024, 72)]
        batch_grid = [(8, 64, 256)]
        engine_grid = [(G, B) for G in (4, 16, 64) for B in (8, 32)]
        n_rounds, iters = 4.0, 10

    rows = []
    for G, N in solver_grid:
        # the dense baseline materializes (N, N, W) f32 tensors; skip it at
        # N=2048 (>150 MB per temporary) unless --full
        dense_ok = N <= 512 or full
        r = _solver_case(G, N, measure_dense=dense_ok,
                         iters=max(2, iters // (1 + N // 512)))
        rows.append(r)
        pre = f"{r['pre_dense_us']/1e3:8.1f}ms" if r["pre_dense_us"] else "    n/a "
        print(f"  solver G={G:<5d} N={N:<5d} pre={pre} "
              f"post={r['post_tiled_us']/1e3:8.1f}ms "
              f"speedup={r['speedup'] or float('nan'):5.1f}x "
              f"(refine-only {r['refine_speedup'] or float('nan'):5.1f}x) "
              f"dJ={r['quality_rel_diff'] if r['quality_rel_diff'] is not None else float('nan'):+.3%}",
              flush=True)
    for G, B in sim_grid:
        r = _sim_case(G, B, n_rounds=n_rounds)
        rows.append(r)
        print(f"  sim    G={G:<5d} B={B:<3d} pre={r['pre_steps_per_s']:8.0f} "
              f"post={r['post_steps_per_s']:8.0f} steps/s "
              f"speedup={r['speedup']:5.1f}x equal={r['metrics_equal']}",
              flush=True)
    for C, G, N in batch_grid:
        r = _batch_case(C, G, N, iters=iters)
        rows.append(r)
        print(f"  batch  C={C} G={G} N={N} batch={r['batch_us']/1e3:.1f}ms "
              f"seq={r['sequential_us']/1e3:.1f}ms speedup={r['speedup']:.1f}x",
              flush=True)
    for G, B in engine_grid:
        r = _engine_case(G, B)
        rows.append(r)
        print(f"  engine G={G:<3d} B={B:<3d} pre={r['pre_steps_per_s']:7.1f} "
              f"post={r['post_steps_per_s']:7.1f} steps/s "
              f"speedup={r['speedup']:5.1f}x equal={r['metrics_equal']}",
              flush=True)

    doc = {
        "meta": {
            "bench": "balancer",
            "smoke": smoke,
            "W": W,
            "swap_iters": SWAP_ITERS,
            "prune_k": PRUNE_K,
            "pre": "method='dense' solver / dispatch='instant_ref' simulator "
                   "/ engine_mode='ref' serving engine "
                   "(the pre-optimization implementations, kept in-tree)",
            "post": "tiled swap kernel with top-K pruning / vectorized "
                    "instant dispatch / slot-table engine with bucketed "
                    "compact decode",
        },
        "rows": rows,
    }
    if out_path is None and smoke:
        # never clobber the tracked full-grid artifact with smoke numbers
        out_path = os.path.join(tempfile.mkdtemp(prefix="bench_smoke_"),
                                "BENCH_balancer.json")
    path = out_path or os.path.join(ROOT, "BENCH_balancer.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"  wrote {path}")
    if not smoke:
        from .common import save_rows
        save_rows("balancer_bench", rows, meta=doc["meta"])
    return doc


def main(full: bool = False, smoke: bool = False):
    run(full=full, smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also measure the dense baseline at N=2048")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, schema check only")
    main(**vars(ap.parse_args()))
