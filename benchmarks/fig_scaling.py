"""Figures 10 & 11: scalability with cluster size G.

Paper: FCFS imbalance grows super-linearly in G while BF-IO stays bounded;
BF-IO throughput scales near-linearly; the energy-reduction percentage
grows monotonically with G (12 % at G=16 -> 30 % at G=224)."""
from __future__ import annotations

import argparse

from repro.data import LONGBENCH_LIKE

from .common import print_csv, run_policy, save_rows, sim_config, \
    standard_instance

QUICK = dict(Gs=[8, 16, 32, 64], B=24, n_rounds=4.0)
FULL = dict(Gs=[16, 32, 64, 128, 224], B=72, n_rounds=3.0)


def run(full: bool = False, seed: int = 2) -> list[dict]:
    p = FULL if full else QUICK
    rows = []
    for G in p["Gs"]:
        inst = standard_instance(G, p["B"], p["n_rounds"], seed=seed)
        cfg = sim_config(G, p["B"])
        r_f = run_policy(inst, "fcfs", LONGBENCH_LIKE, cfg)
        r_b = run_policy(inst, "bfio_h40", LONGBENCH_LIKE, cfg)
        row = {
            "G": G, "B": p["B"],
            "fcfs_imbalance": r_f.avg_imbalance,
            "bfio_imbalance": r_b.avg_imbalance,
            "iir": r_f.avg_imbalance / max(r_b.avg_imbalance, 1e-9),
            "fcfs_throughput": r_f.throughput,
            "bfio_throughput": r_b.throughput,
            "fcfs_energy_mj": r_f.energy_mj,
            "bfio_energy_mj": r_b.energy_mj,
            "energy_reduction": 1 - r_b.energy_mj / r_f.energy_mj,
            "wall_s": r_f.wall_s + r_b.wall_s,
        }
        rows.append(row)
        print(f"  G={G:4d}: IIR={row['iir']:.2f} "
              f"thr x{row['bfio_throughput']/row['fcfs_throughput']:.2f} "
              f"dE={row['energy_reduction']:.1%}", flush=True)
    save_rows("fig_scaling_full" if full else "fig_scaling", rows,
              meta=dict(B=p["B"]))
    return rows


def main(full: bool = False):
    rows = run(full)
    print_csv("fig_scaling", rows, ["G", "iir", "energy_reduction",
                                    "bfio_throughput", "fcfs_throughput"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
