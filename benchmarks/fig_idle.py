"""Figure 1: per-step barrier idle time under the default policy.

Paper (industrial trace, 32 GPUs, 436 steps): mean and median idle both
>40 % — two-fifths of aggregate compute wasted at the barrier."""
from __future__ import annotations

import argparse

import numpy as np

from repro.data import LONGBENCH_LIKE

from .common import print_csv, run_policy, save_rows, sim_config, \
    standard_instance

QUICK = dict(G=32, B=24, n_rounds=4.0)
FULL = dict(G=32, B=72, n_rounds=4.0)   # paper's Fig 1 uses 32 workers


def run(full: bool = False, seed: int = 4) -> list[dict]:
    p = FULL if full else QUICK
    inst = standard_instance(p["G"], p["B"], p["n_rounds"], seed=seed)
    cfg = sim_config(p["G"], p["B"])
    rows = []
    for name in ["fcfs", "bfio_h40"]:
        r = run_policy(inst, name, LONGBENCH_LIKE, cfg, keep_trace=True)
        idle = np.asarray(r.trace.idle_frac)
        waiting = np.asarray(r.trace.n_waiting) > 0
        idle_s = idle[waiting] if waiting.sum() > 10 else idle
        row = r.row()
        row["idle_mean"] = float(idle_s.mean())
        row["idle_median"] = float(np.median(idle_s))
        row["idle_p90"] = float(np.percentile(idle_s, 90))
        hist, edges = np.histogram(idle_s, bins=20, range=(0, 1))
        row["idle_hist"] = hist.tolist()
        row["idle_hist_edges"] = edges.tolist()
        rows.append(row)
        print(f"  {row['policy']:>9s}: idle mean={row['idle_mean']:.1%} "
              f"median={row['idle_median']:.1%} p90={row['idle_p90']:.1%}",
              flush=True)
    save_rows("fig_idle_full" if full else "fig_idle", rows)
    return rows


def main(full: bool = False):
    rows = run(full)
    print_csv("fig_idle", rows, ["policy", "idle_mean", "idle_median",
                                 "idle_p90"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
