"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled TPU code), so wall-times compare the XLA
reference paths and validate the cost MODEL: we report us/call of the jnp
reference, the analytic FLOPs/bytes of the kernel, and the projected v5e
time from the roofline constants."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.launch.roofline import V5E

from .common import print_csv, save_rows


def _time(fn, *args, iters=5):
    # warmup (compile) once, then block on the single result
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters


def bench_decode_attention() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for (B, Hq, Hkv, hd, L) in [(8, 32, 8, 128, 4096),
                                (32, 32, 8, 128, 8192)]:
        q = jnp.asarray(rng.normal(size=(B, Hq, hd)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(B, L, Hkv, hd)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(B, L, Hkv, hd)), jnp.bfloat16)
        lens = jnp.full((B,), L, jnp.int32)
        f = jax.jit(ref.decode_attention_ref)
        t = _time(f, q, k, v, lens)
        flops = 4 * B * Hq * hd * L
        bytes_ = 2 * B * L * Hkv * hd * 2 * 2
        t_v5e = max(flops / V5E.peak_flops, bytes_ / V5E.hbm_bw)
        rows.append({"kernel": "decode_attention",
                     "shape": f"B{B}_H{Hq}/{Hkv}_hd{hd}_L{L}",
                     "wall_s": t, "flops": flops, "hbm_bytes": bytes_,
                     "v5e_projected_us": t_v5e * 1e6,
                     "bound": "memory" if bytes_ / V5E.hbm_bw
                              > flops / V5E.peak_flops else "compute"})
    return rows


def bench_ssm_scan() -> list[dict]:
    rows = []
    rng = np.random.default_rng(1)
    for (B, S, H, dk, dv) in [(8, 2048, 32, 64, 128)]:
        q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
        a = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))), jnp.float32)
        g = jnp.asarray(np.abs(rng.normal(size=(B, S, H))), jnp.float32)
        from repro.models.ssm import chunked_linear_attention
        f = jax.jit(lambda *xs: chunked_linear_attention(*xs, chunk=128))
        t = _time(f, q, k, v, a, g)
        chunk = 128
        flops = B * S * H * (2 * chunk * dk + 2 * chunk * dv
                             + 4 * dk * dv)
        bytes_ = B * S * H * (2 * dk + dv) * 4 * 2
        t_v5e = max(flops / V5E.peak_flops, bytes_ / V5E.hbm_bw)
        rows.append({"kernel": "ssm_chunk_scan",
                     "shape": f"B{B}_S{S}_H{H}_dk{dk}_dv{dv}",
                     "wall_s": t, "flops": flops, "hbm_bytes": bytes_,
                     "v5e_projected_us": t_v5e * 1e6,
                     "bound": "memory" if bytes_ / V5E.hbm_bw
                              > flops / V5E.peak_flops else "compute"})
    return rows


def bench_rms_norm() -> list[dict]:
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16384, 4096)), jnp.bfloat16)
    s = jnp.ones((4096,), jnp.float32)
    f = jax.jit(ref.rms_norm_ref)
    t = _time(f, x, s)
    bytes_ = x.size * 2 * 2
    return [{"kernel": "rms_norm", "shape": "16384x4096", "wall_s": t,
             "flops": 3 * x.size, "hbm_bytes": bytes_,
             "v5e_projected_us": bytes_ / V5E.hbm_bw * 1e6,
             "bound": "memory"}]


def run(full: bool = False) -> list[dict]:
    rows = bench_decode_attention() + bench_ssm_scan() + bench_rms_norm()
    for r in rows:
        print(f"  {r['kernel']:>18s} {r['shape']:26s} cpu={r['wall_s']*1e3:8.1f}ms "
              f"v5e~{r['v5e_projected_us']:8.1f}us ({r['bound']}-bound)",
              flush=True)
    save_rows("kernels_bench", rows)
    return rows


def main(full: bool = False):
    rows = run(full)
    print_csv("kernels", rows, ["kernel", "shape", "v5e_projected_us",
                                "bound"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
