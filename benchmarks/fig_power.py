"""Figures 2 & 8: instantaneous power and total energy over the run.

Paper: BF-IO draws near-peak power (395-400 W) but finishes the same
workload sooner; FCFS oscillates (270-360 W) and integrates to more energy
(29.1 MJ vs 20.9 MJ on their trace)."""
from __future__ import annotations

import argparse

import numpy as np

from repro.data import LONGBENCH_LIKE

from .common import print_csv, run_policy, save_rows, sim_config, \
    standard_instance

QUICK = dict(G=32, B=24, n_rounds=4.0)
FULL = dict(G=256, B=72, n_rounds=2.0)


def run(full: bool = False, seed: int = 3) -> list[dict]:
    p = FULL if full else QUICK
    inst = standard_instance(p["G"], p["B"], p["n_rounds"], seed=seed)
    cfg = sim_config(p["G"], p["B"])
    rows = []
    for name in ["fcfs", "bfio_h40"]:
        r = run_policy(inst, name, LONGBENCH_LIKE, cfg, keep_trace=True)
        tr = r.trace
        t = np.asarray(tr.t)
        pw = np.asarray(tr.avg_power)
        # downsample the power curve for the artifact
        idx = np.linspace(0, len(t) - 1, min(len(t), 400)).astype(int)
        row = r.row()
        row["power_curve_t"] = t[idx].tolist()
        row["power_curve_w"] = pw[idx].tolist()
        row["peak_power"] = float(pw.max())
        row["p5_power"] = float(np.percentile(pw[pw > 0], 5))
        rows.append(row)
        print(f"  {row['policy']:>9s}: E={row['energy_mj']:.2f} MJ  "
              f"makespan={row['makespan_s']:.1f}s  "
              f"power p5-max: {row['p5_power']:.0f}-{row['peak_power']:.0f} W",
              flush=True)
    dE = 1 - rows[1]["energy_mj"] / rows[0]["energy_mj"]
    print(f"  energy reduction: {dE:.1%}")
    save_rows("fig_power_full" if full else "fig_power", rows,
              meta={"energy_reduction": dE})
    return rows


def main(full: bool = False):
    rows = run(full)
    print_csv("fig_power", rows, ["policy", "energy_mj", "makespan_s",
                                  "peak_power", "p5_power"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
