"""Policy sweep on the REAL serving engine (not the simulator).

The simulator sweeps (fig_scaling etc.) show BF-IO's imbalance/energy win
under the paper's abstract workload model; this figure re-runs the same
fcfs/jsq/pod/bfio comparison through the actual ``ServingEngine`` — real
prefill, real KV cache, real barrier-stepped decode on a tiny dense model
— over G ∈ {4, 16, 64} workers.  CI-feasible since the vectorized engine
hot path (ROADMAP Performance, ``engine`` bench section).

Writes ``benchmarks/results/fig_engine_sweep.json`` (the table view) and,
when matplotlib is importable, ``fig_engine_sweep.png`` next to it.

    PYTHONPATH=src python -m benchmarks.fig_engine_sweep [--full]
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from .common import RESULTS_DIR, print_csv, save_rows

POLICIES = ["fcfs", "jsq", "pod2", "bfio_h0"]
# categorical slots 1-4 of the validated reference palette (light mode,
# adjacent-pair CVD dE 9.1 / normal-vision 19.6 — see the dataviz palette
# doc); color follows the policy, never its rank, and marker shape is the
# secondary encoding so identity is not color-alone
COLORS = {"fcfs": "#2a78d6", "jsq": "#eb6834",
          "pod2": "#1baf7a", "bfio_h0": "#eda100"}
MARKERS = {"fcfs": "o", "jsq": "s", "pod2": "^", "bfio_h0": "D"}

QUICK = dict(Gs=[4, 16, 64], B=8, n_rounds=2.0)
FULL = dict(Gs=[4, 16, 64], B=16, n_rounds=3.0)

# NB: in this engine FCFS (most free slots) and JSQ (fewest active) pick
# the same worker by construction — argmax(B - counts) == argmin(counts)
# with identical tie-breaks — so their lines coincide exactly; the paper
# groups them as the size-agnostic cluster.  BF-IO separates from the
# cluster as G grows (imbalance), matching the simulator sweeps.


def _requests(G: int, B: int, n_rounds: float, seed: int):
    """Bimodal prompts + geometric decode lengths: the heterogeneous
    regime where routing matters."""
    from repro.serving import ServeRequest

    rng = np.random.default_rng(seed)
    n = int(G * B * n_rounds)
    out = []
    for i in range(n):
        plen = int(rng.integers(40, 60)) if i % 3 == 0 \
            else int(rng.integers(4, 12))
        out.append(ServeRequest(
            rid=i, tokens=rng.integers(1, 128, size=plen),
            max_new_tokens=int(min(3 + rng.geometric(0.12), 40))))
    return out


def run(full: bool = False, seed: int = 11) -> list[dict]:
    from .balancer_bench import _engine_setup
    from repro.core import make_policy
    from repro.serving import EngineConfig, ServingEngine

    p = FULL if full else QUICK
    st = _engine_setup()
    rows = []
    for G in p["Gs"]:
        for name in POLICIES:
            ec = EngineConfig(n_workers=G, slots_per_worker=p["B"],
                              max_seq_len=64)
            eng = ServingEngine(st["cfg"], st["params"], ec,
                                make_policy(name), mesh=st["mesh"])
            for r in _requests(G, p["B"], p["n_rounds"], seed):
                eng.submit(r)
            t0 = time.time()
            s = eng.run(max_steps=200_000)
            wall = time.time() - t0
            row = {"G": G, "B": p["B"], "policy": s["policy"],
                   "steps": s["steps"], "time_s": s["time_s"],
                   "tokens": s["tokens"],
                   "throughput_tok_s": s["throughput_tok_s"],
                   "energy_j": s["energy_j"],
                   "energy_j_per_tok": s["energy_j"] / max(s["tokens"], 1),
                   "avg_imbalance": s["avg_imbalance"],
                   "wall_s": wall}
            rows.append(row)
            print(f"  G={G:<3d} {row['policy']:>8s}: "
                  f"imb={row['avg_imbalance']:8.1f} "
                  f"E/tok={row['energy_j_per_tok']:.3f} J "
                  f"thr={row['throughput_tok_s']:8.0f} tok/s "
                  f"({wall:.1f}s wall)", flush=True)
    save_rows("fig_engine_sweep", rows,
              meta=dict(B=p["B"], n_rounds=p["n_rounds"],
                        engine="ServingEngine vec/slot", policies=POLICIES))
    _plot(rows)
    return rows


def _plot(rows: list[dict]) -> None:
    """Three small multiples over G (one y-axis each, never dual-axis):
    imbalance, energy per token, throughput."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception as e:  # matplotlib is optional tooling
        print(f"  (figure skipped: matplotlib unavailable: {e})")
        return

    panels = [("avg_imbalance", "avg step imbalance I(k)", "log"),
              ("energy_j_per_tok", "energy per token (J)", "linear"),
              ("throughput_tok_s", "throughput (tok/s)", "linear")]
    fig, axes = plt.subplots(1, 3, figsize=(10.5, 3.4))
    Gs = sorted({r["G"] for r in rows})
    for ax, (key, label, yscale) in zip(axes, panels):
        for name in POLICIES:
            ys = [next(r[key] for r in rows
                       if r["G"] == G and r["policy"] == name) for G in Gs]
            ax.plot(Gs, ys, color=COLORS[name], marker=MARKERS[name],
                    markersize=5, linewidth=2, label=name)
        ax.set_xscale("log", base=2)
        ax.set_yscale(yscale)
        ax.set_xticks(Gs, [str(g) for g in Gs])
        ax.set_xlabel("workers G")
        ax.set_title(label, fontsize=10, color="#333")
        ax.grid(True, which="major", color="#e6e6e6", linewidth=0.7)
        ax.tick_params(colors="#555", labelsize=8)
        for side in ("top", "right"):
            ax.spines[side].set_visible(False)
        for side in ("left", "bottom"):
            ax.spines[side].set_color("#cccccc")
    axes[0].legend(frameon=False, fontsize=8, loc="upper left")
    fig.suptitle("Routing policies on the real ServingEngine "
                 "(tiny dense model, B slots/worker)", fontsize=11)
    fig.tight_layout()
    path = os.path.join(RESULTS_DIR, "fig_engine_sweep.png")
    fig.savefig(path, dpi=150)
    plt.close(fig)
    print(f"  wrote {path}")


def main(full: bool = False):
    rows = run(full)
    print_csv("fig_engine_sweep", rows,
              ["G", "policy", "avg_imbalance", "energy_j_per_tok",
               "throughput_tok_s"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
