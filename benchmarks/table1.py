"""Table 1: policy comparison on the LongBench-like workload.

Paper numbers (G=256, B=72): BF-IO(H=40) vs FCFS -> imbalance /14.9,
throughput +92 %, TPOT -44 %, energy -29 %.  ``--full`` runs the paper
scale; the default is a reduced configuration for CI-speed runs (the
qualitative ordering is scale-robust; gains grow ~ sqrt(B log G)).
"""
from __future__ import annotations

import argparse

from repro.data import LONGBENCH_LIKE

from .common import (
    print_csv,
    run_policy,
    save_rows,
    sim_config,
    standard_instance,
)

QUICK = dict(G=32, B=24, n_rounds=5.0,
             policies=["fcfs", "jsq", "rr", "pod2",
                       "bfio_h0", "bfio_h20", "bfio_h40"])
FULL = dict(G=256, B=72, n_rounds=3.0,
            policies=["fcfs", "jsq", "bfio_h0", "bfio_h20", "bfio_h40",
                      "bfio_h60", "bfio_h80", "bfio_h100"])


def run(full: bool = False, seed: int = 0) -> list[dict]:
    p = FULL if full else QUICK
    inst = standard_instance(p["G"], p["B"], p["n_rounds"], seed=seed)
    cfg = sim_config(p["G"], p["B"])
    rows = []
    base = None
    for name in p["policies"]:
        r = run_policy(inst, name, LONGBENCH_LIKE, cfg)
        row = r.row()
        if base is None:
            base = row
        row["imb_ratio_vs_fcfs"] = base["avg_imbalance"] / max(
            row["avg_imbalance"], 1e-9)
        row["thr_gain_vs_fcfs"] = row["throughput"] / base["throughput"] - 1
        row["tpot_gain_vs_fcfs"] = 1 - row["tpot"] / base["tpot"]
        row["energy_gain_vs_fcfs"] = 1 - row["energy_mj"] / base["energy_mj"]
        rows.append(row)
        print(f"  {row['policy']:>10s}: imb={row['avg_imbalance']:.3e} "
              f"(x{row['imb_ratio_vs_fcfs']:.1f}) "
              f"thr={row['throughput']:.3e} (+{row['thr_gain_vs_fcfs']:.0%}) "
              f"tpot={row['tpot']:.3f}s (-{row['tpot_gain_vs_fcfs']:.0%}) "
              f"E={row['energy_mj']:.2f}MJ (-{row['energy_gain_vs_fcfs']:.0%})",
              flush=True)
    save_rows("table1_full" if full else "table1", rows,
              meta={k: v for k, v in p.items() if k != "policies"})
    return rows


def main(full: bool = False):
    rows = run(full)
    print_csv("table1", rows,
              ["policy", "avg_imbalance", "throughput", "tpot", "energy_mj",
               "imb_ratio_vs_fcfs", "energy_gain_vs_fcfs"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
