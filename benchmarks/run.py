"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run`` runs the quick configurations (CI-sized);
``--full`` runs paper-scale (G=256, B=72 etc. — hours on this CPU).
Each benchmark prints human-readable lines plus ``name,us_per_call,derived``
CSV rows, and writes a JSON artifact under benchmarks/results/.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale configurations")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table1,fig_idle")
    args = ap.parse_args()

    from . import (fig_engine_sweep, fig_hsweep, fig_idle, fig_power,
                   fig_scaling, interface_ablation, kernels_bench, table1,
                   theory_validation)
    suites = {
        "table1": table1.main,                 # Table 1
        "fig_idle": fig_idle.main,             # Figure 1
        "fig_power": fig_power.main,           # Figures 2 & 8
        "fig_hsweep": fig_hsweep.main,         # Figures 4 & 9
        "fig_scaling": fig_scaling.main,       # Figures 10 & 11
        "fig_engine_sweep": fig_engine_sweep.main,  # real-engine sweep
        "theory": theory_validation.main,      # Thms 1-4, Cor 1
        "interface": interface_ablation.main,  # §7.3 + Thm 3 ablations
        "kernels": kernels_bench.main,         # kernel cost model
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    for name in chosen:
        print(f"\n=== {name} {'(full)' if args.full else '(quick)'} ===",
              flush=True)
        t0 = time.time()
        suites[name](full=args.full)
        print(f"=== {name} done in {time.time() - t0:.0f}s ===", flush=True)


if __name__ == "__main__":
    main()
