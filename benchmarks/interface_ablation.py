"""Beyond-paper ablations:

1. **Dispatch interface** (paper §7.3 limitation): the centralized
   waiting-pool interface vs instant dispatch into per-worker FIFO queues
   (vLLM-style).  Instant dispatch strips the router of slot-release-time
   information; the paper predicts future-aware balancing weakens — we
   measure by how much.
2. **Drift universality** (Theorem 3): BF-IO's advantage across the whole
   non-decreasing-drift family — delta=0 (SSM / classical constant
   workload), 0.16 (Zamba2 hybrid), 1 (standard KV decode), 2.5
   (speculative decoding, multiple tokens accepted per step).
"""
from __future__ import annotations

import argparse

from repro.core import SimConfig, make_policy, simulate
from repro.core.workload import (
    constant_drift,
    fractional_drift,
    scaled_drift,
    unit_drift,
)
from repro.data import LONGBENCH_LIKE, batched_rounds_instance

from .common import print_csv, save_rows

QUICK = dict(G=16, B=16, n_rounds=4.0)
FULL = dict(G=64, B=48, n_rounds=4.0)


def dispatch_ablation(p, seed=21) -> list[dict]:
    rows = []
    inst = batched_rounds_instance(LONGBENCH_LIKE, G=p["G"], B=p["B"],
                                   n_rounds=p["n_rounds"], seed=seed)
    for dispatch in ["central", "instant"]:
        cfg = SimConfig(G=p["G"], B=p["B"], dispatch=dispatch)
        m_f = simulate(inst, make_policy("fcfs"), cfg)
        m_b = simulate(inst, make_policy("bfio_h0"), cfg)
        row = {
            "dispatch": dispatch,
            "fcfs_imb": m_f.avg_imbalance,
            "bfio_imb": m_b.avg_imbalance,
            "iir": m_f.avg_imbalance / max(m_b.avg_imbalance, 1e-9),
            "bfio_throughput": m_b.throughput,
        }
        rows.append(row)
        print(f"  {dispatch:8s}: IIR={row['iir']:.2f} "
              f"(BF-IO imb {row['bfio_imb']:.3e})", flush=True)
    loss = rows[1]["iir"] / rows[0]["iir"]
    print(f"  -> instant dispatch keeps {loss:.0%} of the central-pool "
          f"IIR (paper §7.3's predicted degradation)")
    return rows


def drift_ablation(p, seed=22) -> list[dict]:
    rows = []
    for drift in [constant_drift(), fractional_drift(6.0 / 38.0),
                  unit_drift(), scaled_drift(2.5)]:
        inst = batched_rounds_instance(LONGBENCH_LIKE, G=p["G"], B=p["B"],
                                       n_rounds=p["n_rounds"], seed=seed,
                                       drift=drift)
        cfg = SimConfig(G=p["G"], B=p["B"])
        m_f = simulate(inst, make_policy("fcfs"), cfg)
        m_b = simulate(inst, make_policy("bfio_h0"), cfg)
        row = {"drift": drift.name,
               "iir": m_f.avg_imbalance / max(m_b.avg_imbalance, 1e-9),
               "fcfs_imb": m_f.avg_imbalance,
               "bfio_imb": m_b.avg_imbalance}
        rows.append(row)
        print(f"  delta={drift.name:18s}: IIR={row['iir']:.2f}", flush=True)
    return rows


def run(full: bool = False) -> dict:
    p = FULL if full else QUICK
    print(" dispatch interface (paper §7.3):")
    d1 = dispatch_ablation(p)
    print(" drift universality (Theorem 3):")
    d2 = drift_ablation(p)
    save_rows("interface_ablation_full" if full else "interface_ablation",
              d1 + d2)
    return {"dispatch": d1, "drift": d2}


def main(full: bool = False):
    out = run(full)
    print_csv("interface", out["dispatch"], ["dispatch", "iir"])
    print_csv("drift", out["drift"], ["drift", "iir"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(**vars(ap.parse_args()))
