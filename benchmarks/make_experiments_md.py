"""Assemble EXPERIMENTS.md from the dry-run / roofline / benchmark
artifacts.  Rerun after refreshing any artifact:

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.roofline import analyze_record

HERE = os.path.dirname(__file__)
DRY = os.path.join(HERE, "results", "dryrun")
RES = os.path.join(HERE, "results")
OUT = os.path.join(HERE, "..", "EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(pattern):
    out = {}
    for p in sorted(glob.glob(os.path.join(DRY, pattern))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def _fmt_bytes(n):
    return f"{n/2**30:.2f}"


def dryrun_section(recs) -> str:
    lines = [
        "## §Dry-run — 10 architectures x 4 shapes x 2 meshes (80/80 OK)",
        "",
        "Every (arch x shape) lowers and compiles with `.lower().compile()`",
        "for BOTH the single-pod 16x16 (256-chip) mesh and the multi-pod",
        "2x16x16 (512-chip) mesh (the pod axis composes with data for batch",
        "sharding; gradient all-reduce crosses pods).  Bytes are per-device.",
        "",
        "| arch | shape | mesh | ok | args GB | temp GB | collective GB | top collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in sorted(
            recs.items(), key=lambda kv: (kv[0][0], SHAPE_ORDER.index(
                kv[0][1]), kv[0][2])):
        m = r.get("memory", {})
        cc = r.get("collectives_corrected", {})
        ops = cc.get("bytes_by_op", {})
        top = ", ".join(f"{k}:{v/2**30:.1f}G" for k, v in sorted(
            ops.items(), key=lambda kv: -kv[1])[:2])
        lines.append(
            f"| {arch} | {shape} | {mesh} | "
            f"{'OK' if r.get('ok') else 'FAIL'} | "
            f"{_fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{_fmt_bytes(m.get('temp_size_in_bytes', 0))} | "
            f"{cc.get('total_bytes', 0)/2**30:.2f} | {top} |")
    lines += [
        "",
        "Notes:",
        "* `temp` comes from the **CPU** backend's buffer assignment.  The",
        "  CPU emulates bf16 dots by converting operands to f32 and hoists",
        "  those converts out of layer scans (whole stacked weight/cache",
        "  copies in f32), so temp is a ~2-3x upper bound on the TPU",
        "  number; `args` (weights + caches + optimizer state, exactly as",
        "  sharded) is exact.  Fits were additionally verified by analytic",
        "  residency accounting in §Roofline.",
        "* decode shapes lower `serve_step` (1 token against a KV cache of",
        "  seq_len); `long_500k` uses the rolling sliding-window variant",
        "  for full-attention families and native state for ssm/hybrid",
        "  (DESIGN.md §5).",
        "* this table is the PRE-optimization baseline (hd-first sharding,",
        "  grad_accum=8).  The shipped launcher defaults now include the",
        "  §Perf iteration-1/2 fixes, so re-running `dryrun.py` produces",
        "  better numbers for train/prefill; every optimized variant is a",
        "  separate `*__opt*.json` artifact.",
        "",
    ]
    return "\n".join(lines)


def roofline_section(recs) -> str:
    lines = [
        "## §Roofline — per (arch x shape), single-pod mesh, TPU v5e",
        "",
        "Terms (seconds/step): compute = analytic FLOPs / (256 x 197e12);",
        "memory = analytic HBM bytes / (256 x 819e9); collective = per-chip",
        "collective bytes (trip-count-corrected HLO parse) / 50e9.",
        "`useful` = MODEL_FLOPS (6*N_active*D) / analytic total (remat &",
        "attention overhead).  XLA `cost_analysis` counts scan bodies once,",
        "so compute/memory use exact analytic accounting; collectives are",
        "corrected by multiplying while-body collectives by loop trip",
        "counts.",
        "",
        "| arch | shape | compute ms | memory ms | collective ms | dominant | useful | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for (arch, shape, mesh), r in recs.items():
        if mesh != "single":
            continue
        a = analyze_record(r)
        rows.append(a)
    for a in sorted(rows, key=lambda a: (a["arch"],
                                         SHAPE_ORDER.index(a["shape"]))):
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']*1e3:.2f} | "
            f"{a['t_memory_s']*1e3:.2f} | {a['t_collective_s']*1e3:.1f} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | {a['tip'][:58]} |")
    lines += [
        "",
        "Baseline observations (these select the hillclimb pairs in §Perf):",
        "* Every pair is **collective-dominant at baseline** — the",
        "  hd-sharded attention layout (forced by GQA kv_heads < 16 on most",
        "  archs) inserts either per-tile score psums (prefill/train) or",
        "  f32 weight re-gathers (decode, via the RoPE half-split).",
        "* Worst absolute: qwen2-72b train_4k (313 s) and granite-34b",
        "  prefill_32k (401 s).  Most paper-representative: qwen2-72b",
        "  decode_32k (the serve_step the scheduler balances).",
        "* MoE `useful` ratios are lowest (0.21-0.62): attention FLOPs over",
        "  long caches dominate the small active-parameter compute — this",
        "  is exactly the KV-dominated workload regime the paper's",
        "  scheduler targets.",
        "",
    ]
    return "\n".join(lines)


def main() -> None:
    recs = _load("*__*__single.json")
    recs.update(_load("*__*__multi.json"))
    # exclude tagged (optimized) runs
    recs = {k: v for k, v in recs.items()}

    parts = [open(os.path.join(HERE, "experiments_header.md")).read(),
             dryrun_section(recs),
             roofline_section(recs),
             open(os.path.join(HERE, "experiments_perf.md")).read(),
             open(os.path.join(HERE, "experiments_validation.md")).read()]
    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
