"""Shared benchmark infrastructure: run policies on workloads, compute both
full-trace metrics (energy for a fixed workload, as in Fig. 2) and
sustained-phase metrics (steady-state imbalance, as in Table 1 — the paper
measures an overloaded steady state, so ramp-up/drain-out are windowed
out)."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import numpy as np

from repro.core import (
    SimConfig,
    SimTrace,
    make_policy,
    simulate,
)
from repro.core.workload import ArrivalInstance
from repro.data import LONGBENCH_LIKE, WorkloadSpec, batched_rounds_instance

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def policy_for(name: str, spec: WorkloadSpec):
    if name.startswith("bfio"):
        return make_policy(name, p_new=spec.decode_p)
    return make_policy(name)


@dataclasses.dataclass
class RunResult:
    policy: str
    wall_s: float
    # full trace
    steps: int
    energy_mj: float
    makespan_s: float
    throughput: float
    tpot: float
    # sustained window
    avg_imbalance: float
    idle_frac: float
    avg_power: float
    trace: Optional[SimTrace] = None

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("trace")
        return d


def run_policy(
    instance: ArrivalInstance,
    policy_name: str,
    spec: WorkloadSpec,
    config: SimConfig,
    keep_trace: bool = False,
) -> RunResult:
    pol = policy_for(policy_name, spec)
    tr = SimTrace()
    t0 = time.time()
    m = simulate(instance, pol, config, trace=tr)
    wall = time.time() - t0

    # sustained window: steps where the wait queue is non-empty (overload)
    waiting = np.asarray(tr.n_waiting)
    imb = np.asarray(tr.imbalance)
    idle = np.asarray(tr.idle_frac)
    power = np.asarray(tr.avg_power)
    sustained = waiting > 0
    if sustained.sum() < 10:  # light load: use the middle 80 %
        n = len(imb)
        sustained = np.zeros(n, bool)
        sustained[n // 10: 9 * n // 10] = True

    return RunResult(
        policy=pol.name,
        wall_s=wall,
        steps=m.steps,
        energy_mj=m.energy_joules / 1e6,
        makespan_s=m.makespan,
        throughput=m.throughput,
        tpot=m.tpot,
        avg_imbalance=float(imb[sustained].mean()),
        idle_frac=float(idle[sustained].mean()),
        avg_power=float(power[sustained].mean()),
        trace=tr if keep_trace else None,
    )


def standard_instance(G: int, B: int, n_rounds: float = 4.0,
                      spec: WorkloadSpec = LONGBENCH_LIKE, seed: int = 0,
                      poisson: bool = True, overload: float = 1.5):
    """The Table-1 style workload (Section 6.1): Poisson arrivals at a rate
    exceeding system capacity — the overloaded regime of Definition 1.
    ``n_rounds`` scales the total request count (~n_rounds full refills of
    the G*B slots)."""
    if not poisson:
        return batched_rounds_instance(spec, G=G, B=B, n_rounds=n_rounds,
                                       seed=seed)
    from repro.data import overload_rate, poisson_trace
    n = int(G * B * n_rounds)
    rate = overload_rate(spec, G, B, factor=overload)
    return poisson_trace(spec, n_requests=n, rate=rate, seed=seed)


def sim_config(G: int, B: int, poisson: bool = True, **kw) -> SimConfig:
    return SimConfig(G=G, B=B, time_based_arrivals=poisson, **kw)


def save_rows(name: str, rows: list[dict], meta: Optional[dict] = None):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump({"meta": meta or {}, "rows": rows}, f, indent=1)
    return path


def print_csv(name: str, rows: list[dict], cols: list[str]):
    """The run.py contract: name,us_per_call,derived CSV lines."""
    for r in rows:
        derived = ";".join(f"{c}={r.get(c)}" for c in cols)
        us = r.get("wall_s", 0.0) * 1e6
        print(f"{name},{us:.0f},{derived}")
