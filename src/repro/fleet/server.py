"""FleetServer: two-tier BF-IO serving across R engine replicas.

The repo's :class:`~repro.serving.engine.ServingEngine` is one replica —
G decode workers behind one admission scheduler.  The paper's scaling
results (imbalance reduction *grows* with system scale, >52% energy in
the G -> infinity limit) need the tier above: many replicas, heavy
arrival streams, and a router spreading traffic across them.
:class:`FleetServer` is that tier, runnable end to end:

* R independent :class:`ServingEngine` replicas (shared params — one
  compiled model serves every replica, as DP shards of one deployment),
  each with its own slot table, KV backend, wait queue, and engine-tier
  placement policy.  Replicas may be heterogeneous
  (``replica_classes``): per-replica G/B/power constants, with slot
  capacity surfaced to capacity-aware routers;
* a barrier-stepped continuous loop: release due arrivals, route them
  (:mod:`repro.fleet.router` — every waiting request is placed every
  step), then step every busy replica once; the fleet clock advances by
  the *slowest* replica's step (the barrier), and replicas that finish
  early (or idle) draw idle power for the remainder — the fleet-tier
  analogue of the per-worker barrier idle the paper's energy theorem
  prices;
* fleet-clock per-request bookkeeping (TTFT / TPOT / latency, terminal
  ``status``/``error``) streamed into
  :class:`~repro.fleet.telemetry.FleetTelemetry`.

Two fleet modes, following the repo's ref/vec pattern (``engine_mode``,
``dispatch``): ``fleet_mode="ref"`` re-gathers every replica's
:meth:`~repro.serving.engine.ServingEngine.load_snapshot` each step —
O(R) Python work per barrier, the live baseline the ``fleet_scale``
bench times against — while ``fleet_mode="vec"`` (default) keeps the
per-replica snapshot values in incrementally-updated numpy arrays,
refreshed only for replicas actually touched (routed to or stepped), so
a mostly-idle R=256 fleet pays for its busy replicas, not for R.  Both
modes feed the same values through the same arithmetic, so their stats
and telemetry are bit-identical (gated in CI across all routers).

Failure isolation: a request the engine can never serve (decode growth
past its whole pool, or a prompt rejected at submit) fails *that
request* — surfaced on ``ServeRequest.status`` / ``.error`` and in the
telemetry — while both the replica and the fleet keep serving.

``fleet(R=1, router=*)`` is bit-identical to a bare engine on the same
stream (the single replica sees the identical submission sequence), so
every fleet run is anchored to the exhaustively-tested one-replica
semantics; ``benchmarks/balancer_bench.py`` section ``fleet`` gates
that parity plus the router-tier win (BF-IO vs round-robin), and
section ``fleet_scale`` gates ref-vs-vec stats equality plus the vec
speedup, in CI.
"""
from __future__ import annotations

import heapq
from typing import Callable, Optional, Sequence, Union

import numpy as np

from ..configs.base import ModelConfig
from ..core import make_policy
from ..core.metrics import step_imbalance
from ..obs.ledger import (CAUSE_INDEX, N_CAUSES, PHASE_CAUSE,
                          StragglerLedger, attribute_step_idle,
                          reconcile_split)
from ..obs.trace import FLEET_TRACK, NULL_RECORDER
from ..serving import EngineConfig, ServeRequest, ServingEngine
from .router import FleetRouter, RouterContext, make_router
from .telemetry import FleetTelemetry

__all__ = ["FleetServer"]


class FleetServer:
    """Barrier-stepped fleet of engine replicas behind a router seam.

    ``replica_classes`` (optional) replaces the homogeneous
    ``n_replicas x engine_cfg`` fleet with a list of ``(count,
    EngineConfig)`` classes, expanded in order; per-replica capacity and
    idle power follow each class's config.  ``predictor`` (None,
    ``"oracle"``, or a callable ``ServeRequest -> float``) supplies a
    predicted output length per routing candidate, surfaced to routers
    as ``RouterContext.pred_out`` (the oracle reads
    ``req.max_new_tokens`` — an upper bound on what the request can
    decode).
    """

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 *, n_replicas: int = 4,
                 router: Union[str, FleetRouter] = "bfio",
                 policy: str = "bfio_h0", mesh=None, drift=None,
                 telemetry: Optional[FleetTelemetry] = None,
                 seed: int = 0, fleet_mode: str = "vec",
                 replica_classes: Optional[
                     Sequence[tuple[int, EngineConfig]]] = None,
                 predictor: Union[None, str,
                                  Callable[[ServeRequest], float]] = None,
                 obs=None):
        if fleet_mode not in ("ref", "vec"):
            raise ValueError(
                f"fleet_mode must be 'ref' or 'vec', got {fleet_mode!r}")
        self.fleet_mode = fleet_mode
        if replica_classes is not None:
            ecs: list[EngineConfig] = []
            for count, klass_ec in replica_classes:
                if count < 1:
                    raise ValueError(
                        f"replica class count must be >= 1, got {count}")
                ecs.extend([klass_ec] * int(count))
            if not ecs:
                raise ValueError("replica_classes is empty")
        else:
            if n_replicas < 1:
                raise ValueError(
                    f"n_replicas must be >= 1, got {n_replicas}")
            ecs = [engine_cfg] * int(n_replicas)
        self.R = len(ecs)
        self.router = make_router(router)
        # per-request tracing + straggler attribution (repro.obs); the
        # recorder is shared with every engine (each on its own track)
        self._obs_rec = obs if obs is not None else NULL_RECORDER
        self._obs_ledger = StragglerLedger()
        self.engines = [
            ServingEngine(cfg, params, ec, make_policy(policy),
                          mesh=mesh, drift=drift, obs=obs, obs_replica=i)
            for i, ec in enumerate(ecs)
        ]
        self.ec = engine_cfg
        self.telemetry = telemetry
        self.rng = np.random.default_rng(seed)
        if predictor is None:
            self._predict = None
        elif predictor == "oracle":
            self._predict = lambda r: float(r.max_new_tokens)
        elif callable(predictor):
            self._predict = predictor
        else:
            raise ValueError(
                f"predictor must be None, 'oracle', or a callable, "
                f"got {predictor!r}")
        self.t_now = 0.0
        self.steps = 0
        self.idle_j = 0.0            # barrier + between-arrival idle draw
        self.imbalance_sum = 0.0
        self.requests_failed = 0
        # (arrival_time, seq, req) min-heap of not-yet-due submissions
        # (seq breaks ties FIFO and keeps req out of the comparison)
        self._pending: list[tuple[float, int, ServeRequest]] = []
        self._seq = 0
        # (arrival_time, req): due, not yet routed
        self._queue: list[tuple[float, ServeRequest]] = []
        self._live: list[dict] = []            # routed, not finalized
        self.requests: list[ServeRequest] = []
        self.assignments: dict[int, int] = {}  # rid -> replica
        # per-replica constants (heterogeneous-safe)
        self._idle_power_vec = np.array(
            [float(e.ec.power.power(0.0)) * e.ec.n_workers
             for e in self.engines])
        self._capacity = np.array([float(e.N) for e in self.engines])
        # vec mode: cached per-replica LoadSnapshot fields, refreshed only
        # for replicas that were routed to or stepped (see _refresh)
        self._snap_res = np.zeros(self.R)
        self._snap_wait_cost = np.zeros(self.R)
        self._snap_active = np.zeros(self.R, dtype=np.int64)
        self._snap_waiting = np.zeros(self.R, dtype=np.int64)
        self._snap_free = np.array([e.N for e in self.engines],
                                   dtype=np.int64)
        self._snap_tokens = np.zeros(self.R, dtype=np.int64)
        self._snap_preempt = np.zeros(self.R, dtype=np.int64)
        self._snap_hits = np.zeros(self.R, dtype=np.int64)
        self._snap_cached = np.zeros(self.R, dtype=np.int64)
        self._snap_revived = np.zeros(self.R, dtype=np.int64)
        self._busy_mask = np.zeros(self.R, dtype=bool)
        # telemetry per-step deltas: previous cumulative fleet totals
        self._prev_preemptions = 0
        self._prev_prefix_hits = 0
        self._prev_prefix_revived = 0

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest, arrival_time: float = 0.0) -> None:
        """Queue a request for release at ``arrival_time`` on the fleet
        clock (0 = immediately)."""
        self.requests.append(req)
        if self._obs_rec.enabled:
            self._obs_rec.point(FLEET_TRACK, req.rid, "queued",
                                float(arrival_time),
                                n_prompt=len(req.tokens))
        heapq.heappush(self._pending,
                       (float(arrival_time), self._seq, req))
        self._seq += 1

    def submit_scenario(self, scenario) -> None:
        """Submit every request of a :class:`~repro.fleet.workloads.
        Scenario` at its arrival time."""
        for fr in scenario.requests:
            self.submit(fr.to_serve_request(), fr.arrival_time)

    # ------------------------------------------------------------------
    def _release_arrivals(self) -> None:
        while self._pending and self._pending[0][0] <= self.t_now:
            t, _, req = heapq.heappop(self._pending)
            self._queue.append((t, req))

    def _refresh(self, replicas) -> None:
        """Re-read :meth:`ServingEngine.load_snapshot` for the given
        replica ids into the vec-mode cache arrays.  Everything the
        fleet hot path reads per step flows through here, so vec cost
        scales with touched replicas, not R."""
        for r in replicas:
            s = self.engines[r].load_snapshot()
            self._snap_res[r] = s.resident_load
            self._snap_wait_cost[r] = s.wait_cost
            self._snap_active[r] = s.active
            self._snap_waiting[r] = s.waiting
            self._snap_free[r] = s.free_slots
            self._snap_tokens[r] = s.tokens_out
            self._snap_preempt[r] = s.preemptions
            self._snap_hits[r] = s.prefix_hits
            self._snap_cached[r] = s.prefix_cached_blocks
            self._snap_revived[r] = s.prefix_revived
            self._busy_mask[r] = s.busy

    def _pred_out(self) -> Optional[np.ndarray]:
        if self._predict is None:
            return None
        return np.array([float(self._predict(req))
                         for _, req in self._queue])

    @staticmethod
    def _req_chain(req: ServeRequest, bs: int, prefix) -> list:
        """Memoized block-hash chain for a request's full prompt at
        block size ``bs`` (``ServeRequest.prefix_keys``) — the affinity
        probe and the engine's admission share one hash walk per prompt
        per block size (gated by the hash-count regression test)."""
        chain = req.prefix_keys.get(bs)
        if chain is None:
            chain = prefix.keys_for(req.tokens, bs)
            req.prefix_keys[bs] = chain
        return chain

    def _affinity_matrix(self, eligible=None) -> Optional[np.ndarray]:
        """(R', n) predicted prefix-hit tokens: each candidate's prompt
        head hashed against each routable replica's live PrefixIndex —
        entry [j, i] counts the leading tokens of candidate i whose
        blocks are live (referenced or LRU-cached) on replica ids[j].

        Read-only probe: ``lookup`` + ``is_live`` only — never
        ``note_lookup`` (routing probes must not skew hit-rate
        accounting) and never ``touch`` (a probe is not a use; LRU
        recency belongs to admissions).  Returns None when no replica
        has an index, so plain load-only routing is unaffected."""
        ids = (list(range(self.R)) if eligible is None
               else [int(r) for r in eligible])
        n = len(self._queue)
        aff = np.zeros((len(ids), n))
        keys_by_bs: dict = {}   # block_size -> per-candidate key chains
        any_index = False
        for j, r in enumerate(ids):
            backend = self.engines[r].backend
            prefix = getattr(backend, "prefix", None)
            if prefix is None:
                continue
            any_index = True
            alloc = backend.kv.allocator
            bs = int(backend.block_size)
            if bs not in keys_by_bs:
                keys_by_bs[bs] = [self._req_chain(req, bs, prefix)
                                  for _, req in self._queue]
            for i, keys in enumerate(keys_by_bs[bs]):
                toks = 0
                for key, parent, span in keys:
                    blk = prefix.lookup(key, parent, span)
                    if blk is None or not alloc.is_live(blk):
                        break
                    toks += len(span)
                aff[j, i] = toks
        return aff if any_index else None

    def _dispatch(self, loads: np.ndarray, counts: np.ndarray,
                  free: np.ndarray, *, eligible=None,
                  snapshot_age=None) -> set:
        """Route every due candidate given the committed per-replica
        state; returns the set of replicas submitted to.  Shared by both
        fleet modes — identical context in, identical assignment out.

        ``eligible`` (optional, async fleet) restricts routing to a
        subset of replica ids: the router sees only the subset's rows
        (its world is ``len(eligible)`` replicas) and the returned
        subset-space assignment is mapped back to fleet ids here —
        draining / not-yet-warm replicas are unroutable by
        construction.  ``snapshot_age`` annotates the same rows with
        the staleness of their load views (:class:`RouterContext`)."""
        ctx = RouterContext(
            k=self.steps, loads=loads, counts=counts, free_slots=free,
            wait_sizes=np.array([float(len(r.tokens))
                                 for _, r in self._queue]),
            drift=self.engines[0].drift, rng=self.rng,
            capacity=(self._capacity if eligible is None
                      else self._capacity[eligible]),
            pred_out=self._pred_out(), snapshot_age=snapshot_age,
            # the probe walks every replica's index, so only routers
            # that opt in (affinity_weight != 0) pay for it
            affinity=(self._affinity_matrix(eligible)
                      if getattr(self.router, "affinity_weight", 0.0)
                      else None))
        assign = np.asarray(self.router.route(ctx))
        n_route = self.R if eligible is None else len(eligible)
        if assign.shape != (len(self._queue),) or (assign < 0).any() \
                or (assign >= n_route).any():
            raise ValueError(
                f"router {self.router.name!r} returned an invalid "
                f"assignment (shape {assign.shape}, range "
                f"[{assign.min() if assign.size else 0}, "
                f"{assign.max() if assign.size else 0}]) for "
                f"{len(self._queue)} candidates over {n_route} replicas")
        if eligible is not None:
            assign = np.asarray(eligible)[assign]
        touched = set()
        for (t_arrival, req), g in zip(self._queue, assign):
            g = int(g)
            self.assignments[req.rid] = g
            if self._obs_rec.enabled:
                self._obs_rec.point(FLEET_TRACK, req.rid, "routed",
                                    self.t_now, replica=g)
            rec = {"rid": req.rid, "req": req, "replica": g,
                   "t_arrival": t_arrival, "t_routed": self.t_now,
                   "ttft": None}
            try:
                self.engines[g].submit(req)
                touched.add(g)
            except ValueError as e:     # e.g. prompt can never fit the pool
                req.error = str(e)
                req.status = "failed"
                req.t_finish = self.t_now
            self._live.append(rec)
        self._queue = []
        return touched

    def _route_ref(self) -> None:
        """Per-route full re-gather from every replica (the baseline)."""
        if not self._queue:
            return
        snaps = [e.load_snapshot() for e in self.engines]
        self._dispatch(
            np.array([s.committed_load for s in snaps]),
            np.array([s.committed_count for s in snaps], dtype=np.int64),
            np.array([s.free_slots for s in snaps], dtype=np.int64))

    def _route_vec(self) -> None:
        """Route from the cached arrays; refresh only touched replicas."""
        if not self._queue:
            return
        touched = self._dispatch(
            self._snap_res + self._snap_wait_cost,
            self._snap_active + self._snap_waiting,
            self._snap_free)
        if touched:
            self._refresh(sorted(touched))

    def _finalize_requests(self) -> None:
        """Fleet-clock request bookkeeping after a barrier step."""
        still = []
        for rec in self._live:
            req = rec["req"]
            if rec["ttft"] is None and not np.isnan(req.t_first_token):
                rec["ttft"] = self.t_now - rec["t_arrival"]
            if req.done:
                if req.failed:
                    self.requests_failed += 1
                latency = self.t_now - rec["t_arrival"]
                if self._obs_rec.enabled:
                    self._obs_rec.point(
                        FLEET_TRACK, req.rid,
                        "failed" if req.failed else "completed",
                        self.t_now, replica=rec["replica"])
                n_gen = len(req.generated)
                tpot = None
                if rec["ttft"] is not None and n_gen > 1:
                    tpot = (latency - rec["ttft"]) / (n_gen - 1)
                if self.telemetry is not None:
                    self.telemetry.record_request(
                        rid=req.rid, replica=rec["replica"],
                        status=req.status, error=req.error,
                        t_arrival=rec["t_arrival"],
                        t_routed=rec["t_routed"], ttft=rec["ttft"],
                        tpot=tpot, latency=latency,
                        n_prompt=len(req.tokens), n_generated=n_gen)
            else:
                still.append(rec)
        self._live = still

    def _busy(self, eng: ServingEngine) -> bool:
        return bool(eng.wait) or bool(eng.table.active.any())

    # ------------------------------------------------------------------
    def _account(self, *, loads: np.ndarray, dts: np.ndarray,
                 de: np.ndarray, any_busy: bool, tokens: int,
                 active: list, waiting: list, preemptions: int,
                 prefix_hits: int, prefix_revived: int,
                 prefix_cached: int, queued: int,
                 phases: Optional[list] = None) -> dict:
        """Shared barrier accounting: clock/idle/imbalance update,
        request finalization, telemetry row, step info.  Both fleet
        modes call this with identical values, so every derived number
        is computed by identical arithmetic — the bit-identity gate
        rests on this.

        ``phases`` (per-replica engine step phase, ``"idle"`` for
        unstepped replicas) drives the straggler attribution: the step's
        idle joules are split by cause against the gating replica's
        phase and charged to the ledger with the *same float, in the
        same order* as ``self.idle_j`` accumulates — so the ledger total
        matches ``idle_j`` bit-exactly (see :mod:`repro.obs.ledger`)."""
        if any_busy:
            imb = step_imbalance(loads)
            dt = float(dts.max())
            self.imbalance_sum += imb
            idle = float(((dt - dts) * self._idle_power_vec).sum())
            gating = int(np.argmax(dts))
            # the replicas the gating replica kept waiting inherit its
            # phase as cause; a replica that sat fully idle while work
            # waited anywhere in the fleet is a routing miss instead
            phase = "idle" if phases is None else phases[gating]
            causes = np.full(self.R, PHASE_CAUSE.get(
                phase, CAUSE_INDEX["decode_tail"]), dtype=np.int64)
            if queued > 0:
                causes[dts == 0.0] = CAUSE_INDEX["routing_miss"]
            split = attribute_step_idle(
                idle, (dt - dts) * self._idle_power_vec, causes)
        else:
            # fleet idle: fast-forward to the next arrival
            imb = 0.0
            dt = max(self._pending[0][0] - self.t_now, 0.0) \
                if self._pending else 0.0
            idle = float(dt * self._idle_power_vec.sum())
            gating = -1
            split = np.zeros(N_CAUSES)
            split[CAUSE_INDEX["arrival_gap"]] = idle
            split = reconcile_split(idle, split)
        self.idle_j += idle
        self._obs_ledger.charge(idle, split, gating)
        self.t_now += dt
        self.steps += 1
        self._finalize_requests()
        d_preempt = preemptions - self._prev_preemptions
        d_hits = prefix_hits - self._prev_prefix_hits
        d_revived = prefix_revived - self._prev_prefix_revived
        self._prev_preemptions = preemptions
        self._prev_prefix_hits = prefix_hits
        self._prev_prefix_revived = prefix_revived
        if self.telemetry is not None:
            self.telemetry.record_step(
                step=self.steps, t=self.t_now, dt=dt,
                replica_loads=loads,
                replica_active=active, replica_waiting=waiting,
                cross_imbalance=imb, energy_j=float(de.sum()),
                idle_j=idle, tokens=tokens,
                preemptions=d_preempt, prefix_hits=d_hits,
                replica_count=self.R, replica_busy=dts,
                prefix_revived=d_revived,
                prefix_cached_blocks=prefix_cached,
                gating_replica=gating, idle_split=split)
        return {"t": self.t_now, "dt": dt, "imbalance": imb,
                "tokens": tokens, "idle_j": idle,
                "waiting": len(self._pending) + len(self._queue) + queued,
                "replica_waiting": waiting}

    def _step_ref(self) -> dict:
        """Reference barrier step: every per-replica quantity is
        re-gathered from the engines via Python loops — O(R) per step
        regardless of how many replicas are busy."""
        self._release_arrivals()
        self._route_ref()
        snaps = [e.load_snapshot() for e in self.engines]
        loads = np.array([s.resident_load for s in snaps])
        tokens0 = sum(s.tokens_out for s in snaps)
        dts = np.zeros(self.R)
        de = np.zeros(self.R)
        phases = ["idle"] * self.R
        any_busy = False
        for r, eng in enumerate(self.engines):
            if not snaps[r].busy:
                continue
            any_busy = True
            t0, e0 = eng.t_now, eng.energy_j
            info = eng.step()
            phases[r] = info["phase"]
            dts[r] = eng.t_now - t0
            de[r] = eng.energy_j - e0
        post = [e.load_snapshot() for e in self.engines]
        return self._account(
            loads=loads, dts=dts, de=de, any_busy=any_busy,
            tokens=sum(s.tokens_out for s in post) - tokens0,
            active=[s.active for s in post],
            waiting=[s.waiting for s in post],
            preemptions=sum(s.preemptions for s in post),
            prefix_hits=sum(s.prefix_hits for s in post),
            prefix_revived=sum(s.prefix_revived for s in post),
            prefix_cached=sum(s.prefix_cached_blocks for s in post),
            queued=sum(s.waiting for s in post), phases=phases)

    def _step_vec(self) -> dict:
        """Vectorized barrier step: per-replica state lives in cached
        arrays refreshed only for touched replicas, and all fleet
        bookkeeping is array ops over R."""
        self._release_arrivals()
        self._route_vec()
        # pre-step loads: copy before the post-step refresh overwrites
        loads = self._snap_res.copy()
        tokens0 = int(self._snap_tokens.sum())
        dts = np.zeros(self.R)
        de = np.zeros(self.R)
        phases = ["idle"] * self.R
        busy_idx = np.flatnonzero(self._busy_mask)
        for r in busy_idx:
            eng = self.engines[r]
            t0, e0 = eng.t_now, eng.energy_j
            info = eng.step()
            phases[r] = info["phase"]
            dts[r] = eng.t_now - t0
            de[r] = eng.energy_j - e0
        if busy_idx.size:
            self._refresh(busy_idx)
        return self._account(
            loads=loads, dts=dts, de=de, any_busy=busy_idx.size > 0,
            tokens=int(self._snap_tokens.sum()) - tokens0,
            active=self._snap_active.tolist(),
            waiting=self._snap_waiting.tolist(),
            preemptions=int(self._snap_preempt.sum()),
            prefix_hits=int(self._snap_hits.sum()),
            prefix_revived=int(self._snap_revived.sum()),
            prefix_cached=int(self._snap_cached.sum()),
            queued=int(self._snap_waiting.sum()), phases=phases)

    def step(self) -> dict:
        """One fleet barrier step: release due arrivals, route, step
        every busy replica, advance the fleet clock by the slowest
        replica's step and charge idle power for the slack."""
        if self.fleet_mode == "vec":
            return self._step_vec()
        return self._step_ref()

    def _any_busy(self) -> bool:
        if self.fleet_mode == "vec":
            return bool(self._busy_mask.any())
        return any(self._busy(e) for e in self.engines)

    def run(self, max_steps: int = 100_000) -> dict:
        """Step until every submitted request reaches a terminal state."""
        while self._pending or self._queue or self._any_busy():
            if self.steps >= max_steps:
                raise RuntimeError("fleet exceeded max_steps")
            self.step()
        return self.stats()

    # ------------------------------------------------------------------
    def straggler_ledger(self) -> dict:
        """JSON-native report of the cause-attributed idle ledger (see
        :class:`repro.obs.ledger.StragglerLedger`); its
        ``total_idle_j`` equals :attr:`idle_j` bit-exactly."""
        return self._obs_ledger.report()

    def format_straggler_ledger(self) -> str:
        """Human-readable ledger table (per-cause joules + gating
        replicas) — the serve-cluster demo print."""
        return self._obs_ledger.format()

    def stats(self) -> dict:
        rep = [e.stats() for e in self.engines]
        tokens = sum(r["tokens"] for r in rep)
        engine_j = sum(r["energy_j"] for r in rep)
        energy = engine_j + self.idle_j
        return {
            "router": self.router.name,
            "n_replicas": self.R,
            "steps": self.steps,
            "time_s": self.t_now,
            "tokens": tokens,
            "throughput_tok_s": tokens / max(self.t_now, 1e-12),
            "engine_energy_j": engine_j,
            "idle_j": self.idle_j,
            "energy_j": energy,
            "energy_per_token": energy / max(tokens, 1),
            "avg_cross_imbalance": self.imbalance_sum / max(self.steps, 1),
            "completed": sum(1 for r in self.requests
                             if r.status == "done"),
            "failed": self.requests_failed,
            "preemptions": sum(r["preemptions"] for r in rep),
            "prefix_hits": sum(r["prefix_hits"] for r in rep),
            "prefix_revived": sum(r["prefix_revived"] for r in rep),
            "prefix_cached_blocks": sum(r["prefix_cached_blocks"]
                                        for r in rep),
            "replicas": rep,
        }
