"""FleetServer: two-tier BF-IO serving across R engine replicas.

The repo's :class:`~repro.serving.engine.ServingEngine` is one replica —
G decode workers behind one admission scheduler.  The paper's scaling
results (imbalance reduction *grows* with system scale, >52% energy in
the G -> infinity limit) need the tier above: many replicas, heavy
arrival streams, and a router spreading traffic across them.
:class:`FleetServer` is that tier, runnable end to end:

* R independent :class:`ServingEngine` replicas (shared params — one
  compiled model serves every replica, as DP shards of one deployment),
  each with its own slot table, KV backend, wait queue, and engine-tier
  placement policy;
* a barrier-stepped continuous loop: release due arrivals, route them
  (:mod:`repro.fleet.router` — every waiting request is placed every
  step), then step every busy replica once; the fleet clock advances by
  the *slowest* replica's step (the barrier), and replicas that finish
  early (or idle) draw idle power for the remainder — the fleet-tier
  analogue of the per-worker barrier idle the paper's energy theorem
  prices;
* fleet-clock per-request bookkeeping (TTFT / TPOT / latency, terminal
  ``status``/``error``) streamed into
  :class:`~repro.fleet.telemetry.FleetTelemetry`.

Failure isolation: a request the engine can never serve (decode growth
past its whole pool, or a prompt rejected at submit) fails *that
request* — surfaced on ``ServeRequest.status`` / ``.error`` and in the
telemetry — while both the replica and the fleet keep serving.

``fleet(R=1, router=*)`` is bit-identical to a bare engine on the same
stream (the single replica sees the identical submission sequence), so
every fleet run is anchored to the exhaustively-tested one-replica
semantics; ``benchmarks/balancer_bench.py`` section ``fleet`` gates
that parity plus the router-tier win (BF-IO vs round-robin) in CI.
"""
from __future__ import annotations

import heapq
from typing import Optional, Union

import numpy as np

from ..configs.base import ModelConfig
from ..core import make_policy
from ..core.metrics import step_imbalance
from ..serving import EngineConfig, ServeRequest, ServingEngine
from .router import FleetRouter, RouterContext, make_router
from .telemetry import FleetTelemetry

__all__ = ["FleetServer"]


class FleetServer:
    """Barrier-stepped fleet of engine replicas behind a router seam."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 *, n_replicas: int = 4,
                 router: Union[str, FleetRouter] = "bfio",
                 policy: str = "bfio_h0", mesh=None, drift=None,
                 telemetry: Optional[FleetTelemetry] = None,
                 seed: int = 0):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.R = int(n_replicas)
        self.router = make_router(router)
        self.engines = [
            ServingEngine(cfg, params, engine_cfg, make_policy(policy),
                          mesh=mesh, drift=drift)
            for _ in range(self.R)
        ]
        self.ec = engine_cfg
        self.telemetry = telemetry
        self.rng = np.random.default_rng(seed)
        self.t_now = 0.0
        self.steps = 0
        self.idle_j = 0.0            # barrier + between-arrival idle draw
        self.imbalance_sum = 0.0
        self.requests_failed = 0
        # (arrival_time, seq, req) min-heap of not-yet-due submissions
        # (seq breaks ties FIFO and keeps req out of the comparison)
        self._pending: list[tuple[float, int, ServeRequest]] = []
        self._seq = 0
        # (arrival_time, req): due, not yet routed
        self._queue: list[tuple[float, ServeRequest]] = []
        self._live: list[dict] = []            # routed, not finalized
        self.requests: list[ServeRequest] = []
        self.assignments: dict[int, int] = {}  # rid -> replica

    # ------------------------------------------------------------------
    @property
    def _idle_power(self) -> float:
        """Idle draw of ONE replica (all its workers at u=0)."""
        return float(self.ec.power.power(0.0)) * self.ec.n_workers

    def submit(self, req: ServeRequest, arrival_time: float = 0.0) -> None:
        """Queue a request for release at ``arrival_time`` on the fleet
        clock (0 = immediately)."""
        self.requests.append(req)
        heapq.heappush(self._pending,
                       (float(arrival_time), self._seq, req))
        self._seq += 1

    def submit_scenario(self, scenario) -> None:
        """Submit every request of a :class:`~repro.fleet.workloads.
        Scenario` at its arrival time."""
        for fr in scenario.requests:
            self.submit(fr.to_serve_request(), fr.arrival_time)

    # ------------------------------------------------------------------
    def _release_arrivals(self) -> None:
        while self._pending and self._pending[0][0] <= self.t_now:
            t, _, req = heapq.heappop(self._pending)
            self._queue.append((t, req))

    def _committed(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(loads, counts, free_slots) per replica; committed = resident
        + queued-at-replica (see RouterContext)."""
        loads = np.zeros(self.R)
        counts = np.zeros(self.R, dtype=np.int64)
        free = np.zeros(self.R, dtype=np.int64)
        for r, eng in enumerate(self.engines):
            active = int(eng.table.active.sum())
            loads[r] = float(eng._loads().sum()) \
                + sum(eng._req_cost(w) for w in eng.wait)
            counts[r] = active + len(eng.wait)
            free[r] = eng.N - active
        return loads, counts, free

    def _route(self) -> None:
        if not self._queue:
            return
        loads, counts, free = self._committed()
        ctx = RouterContext(
            k=self.steps, loads=loads, counts=counts, free_slots=free,
            wait_sizes=np.array([float(len(r.tokens))
                                 for _, r in self._queue]),
            drift=self.engines[0].drift, rng=self.rng)
        assign = np.asarray(self.router.route(ctx))
        if assign.shape != (len(self._queue),) or (assign < 0).any() \
                or (assign >= self.R).any():
            raise ValueError(
                f"router {self.router.name!r} returned an invalid "
                f"assignment (shape {assign.shape}, range "
                f"[{assign.min() if assign.size else 0}, "
                f"{assign.max() if assign.size else 0}]) for "
                f"{len(self._queue)} candidates over {self.R} replicas")
        for (t_arrival, req), g in zip(self._queue, assign):
            g = int(g)
            self.assignments[req.rid] = g
            rec = {"rid": req.rid, "req": req, "replica": g,
                   "t_arrival": t_arrival, "t_routed": self.t_now,
                   "ttft": None}
            try:
                self.engines[g].submit(req)
            except ValueError as e:     # e.g. prompt can never fit the pool
                req.error = str(e)
                req.status = "failed"
                req.t_finish = self.t_now
            self._live.append(rec)
        self._queue = []

    def _finalize_requests(self) -> None:
        """Fleet-clock request bookkeeping after a barrier step."""
        still = []
        for rec in self._live:
            req = rec["req"]
            if rec["ttft"] is None and not np.isnan(req.t_first_token):
                rec["ttft"] = self.t_now - rec["t_arrival"]
            if req.done:
                if req.failed:
                    self.requests_failed += 1
                latency = self.t_now - rec["t_arrival"]
                n_gen = len(req.generated)
                tpot = None
                if rec["ttft"] is not None and n_gen > 1:
                    tpot = (latency - rec["ttft"]) / (n_gen - 1)
                if self.telemetry is not None:
                    self.telemetry.record_request(
                        rid=req.rid, replica=rec["replica"],
                        status=req.status, error=req.error,
                        t_arrival=rec["t_arrival"],
                        t_routed=rec["t_routed"], ttft=rec["ttft"],
                        tpot=tpot, latency=latency,
                        n_prompt=len(req.tokens), n_generated=n_gen)
            else:
                still.append(rec)
        self._live = still

    def _busy(self, eng: ServingEngine) -> bool:
        return bool(eng.wait) or bool(eng.table.active.any())

    def step(self) -> dict:
        """One fleet barrier step: release due arrivals, route, step
        every busy replica, advance the fleet clock by the slowest
        replica's step and charge idle power for the slack."""
        self._release_arrivals()
        self._route()
        loads = np.array([float(e._loads().sum()) for e in self.engines])
        imb = step_imbalance(loads)
        dts = np.zeros(self.R)
        de = np.zeros(self.R)
        tokens0 = sum(e.tokens_out for e in self.engines)
        any_busy = False
        for r, eng in enumerate(self.engines):
            if not self._busy(eng):
                continue
            any_busy = True
            t0, e0 = eng.t_now, eng.energy_j
            eng.step()
            dts[r] = eng.t_now - t0
            de[r] = eng.energy_j - e0
        if any_busy:
            dt = float(dts.max())
            self.imbalance_sum += imb
        else:
            # fleet idle: fast-forward to the next arrival
            imb = 0.0
            dt = max(self._pending[0][0] - self.t_now, 0.0) \
                if self._pending else 0.0
            dts[:] = dt     # every replica idles the whole gap
        idle = float(((dt - dts) * self._idle_power).sum())
        if not any_busy:
            idle = dt * self._idle_power * self.R
        self.idle_j += idle
        self.t_now += dt
        self.steps += 1
        self._finalize_requests()
        tokens = sum(e.tokens_out for e in self.engines) - tokens0
        if self.telemetry is not None:
            self.telemetry.record_step(
                step=self.steps, t=self.t_now, dt=dt,
                replica_loads=loads,
                replica_active=[int(e.table.active.sum())
                                for e in self.engines],
                replica_waiting=[len(e.wait) for e in self.engines],
                cross_imbalance=imb, energy_j=float(de.sum()),
                idle_j=idle, tokens=tokens,
                preemptions=sum(e.preemptions for e in self.engines),
                prefix_hits=sum(e.stats()["prefix_hits"]
                                for e in self.engines))
        return {"t": self.t_now, "dt": dt, "imbalance": imb,
                "tokens": tokens, "idle_j": idle,
                "waiting": len(self._queue) + len(self._pending)}

    def run(self, max_steps: int = 100_000) -> dict:
        """Step until every submitted request reaches a terminal state."""
        while (self._pending or self._queue
               or any(self._busy(e) for e in self.engines)):
            if self.steps >= max_steps:
                raise RuntimeError("fleet exceeded max_steps")
            self.step()
        return self.stats()

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        rep = [e.stats() for e in self.engines]
        tokens = sum(r["tokens"] for r in rep)
        engine_j = sum(r["energy_j"] for r in rep)
        energy = engine_j + self.idle_j
        return {
            "router": self.router.name,
            "n_replicas": self.R,
            "steps": self.steps,
            "time_s": self.t_now,
            "tokens": tokens,
            "throughput_tok_s": tokens / max(self.t_now, 1e-12),
            "engine_energy_j": engine_j,
            "idle_j": self.idle_j,
            "energy_j": energy,
            "energy_per_token": energy / max(tokens, 1),
            "avg_cross_imbalance": self.imbalance_sum / max(self.steps, 1),
            "completed": sum(1 for r in self.requests
                             if r.status == "done"),
            "failed": self.requests_failed,
            "preemptions": sum(r["preemptions"] for r in rep),
            "prefix_hits": sum(r["prefix_hits"] for r in rep),
            "replicas": rep,
        }
