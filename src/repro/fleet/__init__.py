"""Fleet serving layer: two-tier BF-IO routing across engine replicas.

The tier above :mod:`repro.serving` — R independent engine replicas
behind a pluggable :class:`~repro.fleet.router.FleetRouter`
(round-robin / least-loaded / power-of-two / BF-IO via the batched
solver / two-level hierarchical pod BF-IO for R in the hundreds),
driven barrier-stepped by :class:`~repro.fleet.server.FleetServer`
(``fleet_mode="vec"`` hot path with a bit-identical ``"ref"``
baseline) or event-driven by
:class:`~repro.fleet.async_server.AsyncFleetServer` (per-replica
clocks, staleness-bounded routing, optional
:mod:`repro.fleet.autoscale` policies with bit-exact drain handoff,
and a ``barrier_compat`` parity oracle), fed by the named scenario
traces of :mod:`repro.fleet.workloads`, and observed through the
JSONL-exporting :mod:`repro.fleet.telemetry` subsystem."""
from .async_server import AsyncFleetServer  # noqa: F401
from .autoscale import (  # noqa: F401
    Autoscaler,
    SLOAutoscaler,
    TargetUtilizationAutoscaler,
    make_autoscaler,
)
from .router import (  # noqa: F401
    BFIORouter,
    FleetRouter,
    LeastLoadedRouter,
    PodBFIORouter,
    PowerOfDRouter,
    RoundRobinRouter,
    RouterContext,
    make_router,
)
from .server import FleetServer  # noqa: F401
from .telemetry import FleetTelemetry, SLOSpec, percentiles  # noqa: F401
from .workloads import (  # noqa: F401
    SCENARIOS,
    FleetRequest,
    Scenario,
    make_scenario,
    validate_scenario,
)
