"""Autoscaling policies for the event-driven fleet.

The async fleet (:mod:`repro.fleet.async_server`) closes a control loop
the barrier fleet cannot: replica count R becomes a *decision variable*.
At every decision boundary (``interval_s`` on the fleet clock) the
server hands the active :class:`Autoscaler` a dict of signals derived
from the same telemetry stream the offline scorecard reads —
utilization over the window, queue depth, windowed SLO attainment —
and the policy returns a target replica count in ``[r_min, r_max]``.
The server then warms cold replicas (they join after ``warmup_s`` with
*shared* params — one compiled model serves every replica, so scale-up
costs no recompilation) or drains active ones (resident requests hand
off bit-exactly via the engine's host-staged swap path and re-enter the
fleet queue; see ``AsyncFleetServer._drain_now``).

Policies mirror production autoscalers:

* :class:`TargetUtilizationAutoscaler` — hold busy-fraction near a
  target (the classic CPU-target loop): R rises when the fleet runs
  hot or a queue builds, falls on the diurnal trough when replicas sit
  idle drawing ``P_idle`` — the paper's waste term, removed at the
  fleet tier by powering the idle replicas off;
* :class:`SLOAutoscaler` — scale on the *outcome* instead of the
  proxy: windowed SLO attainment below target (or a building queue)
  adds replicas, sustained low utilization at healthy attainment
  removes them.

Both are deliberately deterministic pure functions of the signal dict,
so autoscaled runs are reproducible end to end.
"""
from __future__ import annotations

import math

__all__ = [
    "Autoscaler",
    "TargetUtilizationAutoscaler",
    "SLOAutoscaler",
    "make_autoscaler",
]


class Autoscaler:
    """Decision protocol: signals in, target replica count out.

    ``signals`` (all fleet-clock / windowed since the last decision):

    * ``t`` — fleet clock (s);
    * ``n_active`` — replicas currently active (serving or drainable);
    * ``n_on`` — replicas drawing power (active + warming + draining);
    * ``utilization`` — busy-seconds / powered-seconds over the window,
      or None when the window had no powered time;
    * ``queue_depth`` — requests waiting at the fleet router plus
      requests queued inside replicas;
    * ``window_slo`` — SLO attainment over requests finished in the
      window, or None when none finished;
    * ``pending`` — not-yet-due future arrivals still scheduled.

    ``decide`` may return any int; the server clips it to
    ``[r_min, min(r_max, R)]``.
    """

    name = "base"

    def __init__(self, r_min: int = 1, r_max: int = 8,
                 interval_s: float = 0.5, warmup_s: float = 0.25):
        if r_min < 1:
            raise ValueError(f"r_min must be >= 1, got {r_min}")
        if r_max < r_min:
            raise ValueError(
                f"r_max ({r_max}) must be >= r_min ({r_min})")
        self.r_min = int(r_min)
        self.r_max = int(r_max)
        self.interval_s = float(interval_s)
        self.warmup_s = float(warmup_s)

    def decide(self, signals: dict) -> int:
        raise NotImplementedError


class TargetUtilizationAutoscaler(Autoscaler):
    """Hold windowed busy-fraction near ``target``.

    Want = ceil(n_active * utilization / target): the replica count at
    which the window's observed busy-seconds would have run at exactly
    the target utilization.  A non-empty queue with the fleet already
    at-or-above target bumps the want by one (the queue is demand the
    busy-fraction has not absorbed yet).  With no utilization signal
    (nothing powered in the window) the policy holds R steady.
    """

    name = "util"

    def __init__(self, r_min: int = 1, r_max: int = 8,
                 target: float = 0.6, interval_s: float = 0.5,
                 warmup_s: float = 0.25):
        super().__init__(r_min=r_min, r_max=r_max,
                         interval_s=interval_s, warmup_s=warmup_s)
        if not 0.0 < target <= 1.0:
            raise ValueError(
                f"target utilization must be in (0, 1], got {target}")
        self.target = float(target)

    def decide(self, signals: dict) -> int:
        util = signals.get("utilization")
        n_active = int(signals["n_active"])
        if util is None:
            return n_active
        want = max(int(math.ceil(n_active * util / self.target)), 1)
        if signals.get("queue_depth", 0) > 0 and util >= self.target:
            want = max(want, n_active + 1)
        return want


class SLOAutoscaler(Autoscaler):
    """Scale on windowed SLO attainment (the outcome) with a
    low-utilization scale-down guard.

    * attainment below ``attain_target`` (or a queue at least as deep
      as the active replica count) -> add a replica;
    * attainment healthy *and* utilization under ``low_util`` with an
    empty queue -> remove one;
    * otherwise hold.  Missing signals (no requests finished, nothing
      powered) never trigger a move on their own.
    """

    name = "slo"

    def __init__(self, r_min: int = 1, r_max: int = 8,
                 attain_target: float = 0.95, low_util: float = 0.35,
                 interval_s: float = 0.5, warmup_s: float = 0.25):
        super().__init__(r_min=r_min, r_max=r_max,
                         interval_s=interval_s, warmup_s=warmup_s)
        self.attain_target = float(attain_target)
        self.low_util = float(low_util)

    def decide(self, signals: dict) -> int:
        n_active = int(signals["n_active"])
        slo = signals.get("window_slo")
        util = signals.get("utilization")
        queue = signals.get("queue_depth", 0)
        if (slo is not None and slo < self.attain_target) \
                or queue >= max(n_active, 1):
            return n_active + 1
        if (slo is None or slo >= self.attain_target) \
                and util is not None and util < self.low_util \
                and queue == 0:
            return n_active - 1
        return n_active


def make_autoscaler(name, **kw) -> Autoscaler:
    """Factory mirroring :func:`~repro.fleet.router.make_router`:
    ``"util"`` / ``"slo"`` (an :class:`Autoscaler` instance passes
    through)."""
    if isinstance(name, Autoscaler):
        return name
    name = str(name).lower()
    if name in ("util", "utilization", "target_util"):
        return TargetUtilizationAutoscaler(**kw)
    if name == "slo":
        return SLOAutoscaler(**kw)
    raise ValueError(f"unknown autoscaler {name!r}")
