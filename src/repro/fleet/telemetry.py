"""Fleet telemetry: structured per-step and per-request metrics with
JSONL export.

:class:`FleetTelemetry` is the observability seam of the fleet layer —
:class:`~repro.fleet.server.FleetServer` feeds it one record per barrier
step (per-replica loads, cross-replica imbalance, energy split into
serving vs barrier-idle, token counts, per-step preemption/prefix-hit
deltas) and
one record per finished request (fleet-clock TTFT / TPOT / end-to-end
latency, terminal status, error text), and :meth:`summary` folds them
into the serving scorecard: latency percentiles, SLO attainment,
energy-per-token, mean imbalance.

Export is line-delimited JSON (one self-describing record per line,
``kind`` in {``meta``, ``step``, ``request``, ``summary``}) so a run can
be streamed to disk while serving and post-processed with standard
tooling; :meth:`read_jsonl` round-trips a file back into an equivalent
telemetry object (gated by ``tests/test_fleet.py``).  The ``fleet``
section of ``benchmarks/balancer_bench.py`` consumes these summaries.

The meta record carries ``schema_version`` (:data:`SCHEMA_VERSION`);
the reader accepts any version in :data:`ACCEPTED_VERSIONS` and rejects
everything else up front, instead of failing later with an opaque
``KeyError`` on a reshaped record.  Bump the constant whenever a
record's key set changes.

Version history:

* **1** — per-step fleet records + per-request records (PR 5);
* **2** — step records gain ``replica_count`` (routable replicas when
  the row was cut — the autoscaler's R-over-time series) and
  ``replica_busy`` (per-replica busy seconds in the interval), and
  :meth:`summary` derives ``replica_count`` stats and per-replica
  utilization from them.  Version-1 files (no such keys) read back
  unchanged — the derived fields are simply absent, so their stored
  summaries still validate.
* **3** — step records gain ``prefix_revived`` (per-step delta of
  cached blocks re-pinned by a later hit — the persistent evictor's
  signature signal) and ``prefix_cached_blocks`` (fleet-wide gauge of
  reclaimable LRU-cached blocks when the row was cut);
  :meth:`summary` totals the former and reports the peak of the
  latter, guarded exactly like the v2 fields so v1/v2 files read back
  unchanged.
* **4** — step records gain ``gating_replica`` (the replica whose step
  gated the barrier; ``-1`` for arrival-gap troughs and async tick
  rows) and ``idle_split`` (the step's idle joules decomposed by cause,
  aligned with :data:`repro.obs.IDLE_CAUSES`; its left-fold sum
  reproduces the row's ``idle_j`` bit-exactly — see
  :mod:`repro.obs.ledger`).  :meth:`summary` derives ``idle_by_cause``
  totals and per-replica ``gating_steps`` counts, guarded exactly like
  the v2/v3 fields so v1–v3 files read back unchanged.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

from ..obs.ledger import IDLE_CAUSES

__all__ = ["SLOSpec", "FleetTelemetry", "percentiles",
           "SCHEMA_VERSION", "ACCEPTED_VERSIONS"]

SCHEMA_VERSION = 4
ACCEPTED_VERSIONS = (1, 2, 3, 4)


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Per-request service-level objective: a request attains the SLO
    when its TTFT and its TPOT are both within bounds (failed requests
    never attain)."""

    ttft_s: float = 1.0
    tpot_s: float = 0.1


def percentiles(xs, ps=(50, 95, 99)) -> dict:
    """{"p50": ..., "p95": ...} over finite entries (None when empty —
    JSON-native, and round-trip comparable where NaN would not be)."""
    xs = np.asarray([x for x in xs if x is not None], dtype=np.float64)
    xs = xs[np.isfinite(xs)]
    if xs.size == 0:
        return {f"p{p}": None for p in ps}
    return {f"p{p}": float(np.percentile(xs, p)) for p in ps}


def _jsonify(x):
    """Recursively coerce numpy scalars/arrays into JSON-native types."""
    if isinstance(x, dict):
        return {k: _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, np.ndarray):
        return [_jsonify(v) for v in x.tolist()]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    return x


class FleetTelemetry:
    """Collects step/request records; summarizes; round-trips JSONL."""

    STEP_KEYS = ("step", "t", "dt", "replica_loads", "replica_active",
                 "replica_waiting", "cross_imbalance", "energy_j",
                 "idle_j", "tokens", "preemptions", "prefix_hits",
                 "replica_count", "replica_busy",
                 "prefix_revived", "prefix_cached_blocks",
                 "gating_replica", "idle_split")
    REQUEST_KEYS = ("rid", "replica", "status", "error", "t_arrival",
                    "t_routed", "ttft", "tpot", "latency", "n_prompt",
                    "n_generated")

    def __init__(self, slo: Optional[SLOSpec] = None,
                 record_steps: bool = True):
        self.slo = slo or SLOSpec()
        self.record_steps = record_steps
        self.steps: list[dict] = []
        self.requests: list[dict] = []

    # -- ingestion ------------------------------------------------------
    def record_step(self, **kw) -> None:
        if not self.record_steps:
            return
        rec = {k: _jsonify(kw.get(k)) for k in self.STEP_KEYS}
        self.steps.append(rec)

    def record_request(self, **kw) -> None:
        rec = {k: _jsonify(kw.get(k)) for k in self.REQUEST_KEYS}
        self.requests.append(rec)

    # -- aggregation ----------------------------------------------------
    def summary(self) -> dict:
        reqs = self.requests
        done = [r for r in reqs if r["status"] == "done"]
        failed = [r for r in reqs if r["status"] == "failed"]
        tokens = sum(s["tokens"] for s in self.steps) if self.steps \
            else sum(r["n_generated"] or 0 for r in done)
        energy = sum(s["energy_j"] + s["idle_j"] for s in self.steps)
        imb = [s["cross_imbalance"] for s in self.steps]
        attained = [
            r for r in done
            if r["ttft"] is not None and r["ttft"] <= self.slo.ttft_s
            and (r["tpot"] is None or r["tpot"] <= self.slo.tpot_s)
        ]
        out = {
            "n_requests": len(reqs),
            "completed": len(done),
            "failed": len(failed),
            "steps": len(self.steps),
            "time_s": self.steps[-1]["t"] if self.steps else 0.0,
            "tokens": tokens,
            "energy_j": energy,
            "energy_per_token": energy / max(tokens, 1),
            "mean_cross_imbalance": float(np.mean(imb)) if imb else 0.0,
            "slo_attainment": len(attained) / max(len(reqs), 1),
            "slo": dataclasses.asdict(self.slo),
            # step rows carry per-step deltas (not running totals), so
            # the run totals are their sums
            "preemptions": sum(s["preemptions"] for s in self.steps),
            "prefix_hits": sum(s["prefix_hits"] for s in self.steps),
        }
        for key in ("ttft", "tpot", "latency"):
            out[key] = percentiles([r[key] for r in done])
        # v2 series (absent from v1 files: the derived fields are then
        # omitted, so v1 stored summaries still validate on read-back)
        counts = [s.get("replica_count") for s in self.steps]
        if counts and all(c is not None for c in counts):
            out["replica_count"] = {
                "mean": float(np.mean(counts)),
                "min": int(min(counts)), "max": int(max(counts)),
            }
        busy = [s.get("replica_busy") for s in self.steps]
        if busy and all(b is not None for b in busy):
            per = np.asarray(busy, dtype=np.float64).sum(axis=0)
            t = max(self.steps[-1]["t"], 1e-12)
            out["replica_utilization"] = [float(x) for x in per / t]
        # v3 series (same guard: absent from v1/v2 files)
        revived = [s.get("prefix_revived") for s in self.steps]
        if revived and all(x is not None for x in revived):
            out["prefix_revived"] = sum(revived)
        cached = [s.get("prefix_cached_blocks") for s in self.steps]
        if cached and all(x is not None for x in cached):
            out["prefix_cached_blocks_peak"] = int(max(cached))
        # v4 series (same guard: absent from v1/v2/v3 files)
        splits = [s.get("idle_split") for s in self.steps]
        if splits and all(x is not None for x in splits):
            per = np.asarray(splits, dtype=np.float64).sum(axis=0)
            out["idle_by_cause"] = {
                name: float(per[i])
                for i, name in enumerate(IDLE_CAUSES)}
        gating = [s.get("gating_replica") for s in self.steps]
        if gating and all(g is not None for g in gating):
            counts: dict[str, int] = {}
            for g in gating:
                if g >= 0:
                    counts[str(g)] = counts.get(str(g), 0) + 1
            out["gating_steps"] = counts
        return _jsonify(out)

    # -- JSONL export / import -----------------------------------------
    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(json.dumps(
                {"kind": "meta", "schema_version": SCHEMA_VERSION,
                 "slo": dataclasses.asdict(self.slo),
                 "record_steps": self.record_steps}) + "\n")
            for s in self.steps:
                f.write(json.dumps({"kind": "step", **s}) + "\n")
            for r in self.requests:
                f.write(json.dumps({"kind": "request", **r}) + "\n")
            f.write(json.dumps({"kind": "summary",
                                **self.summary()}) + "\n")

    @classmethod
    def read_jsonl(cls, path: str) -> "FleetTelemetry":
        """Rebuild a telemetry object from a JSONL export; the trailing
        summary line is validated against the recomputed summary."""
        tel: Optional[FleetTelemetry] = None
        summary = None
        with open(path) as f:
            for line in f:
                rec = json.loads(line)
                kind = rec.pop("kind")
                if kind == "meta":
                    version = rec.get("schema_version")
                    if version not in ACCEPTED_VERSIONS:
                        raise ValueError(
                            f"{path}: telemetry schema_version "
                            f"{version!r} not supported (reader "
                            f"accepts {ACCEPTED_VERSIONS}); re-export "
                            "the run with a supported version")
                    tel = cls(slo=SLOSpec(**rec["slo"]),
                              record_steps=rec["record_steps"])
                elif kind == "step":
                    tel.steps.append(rec)
                elif kind == "request":
                    tel.requests.append(rec)
                elif kind == "summary":
                    summary = rec
        if tel is None:
            raise ValueError(f"{path}: no meta record")
        if summary is not None:
            recomputed = json.loads(json.dumps(tel.summary()))
            if recomputed != summary:
                raise ValueError(
                    f"{path}: stored summary does not match records")
        return tel
