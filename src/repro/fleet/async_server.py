"""Event-driven fleet: each replica steps on its own clock.

:class:`~repro.fleet.server.FleetServer` barrier-steps all R replicas —
the fleet clock rides the *slowest* batch every step and replicas that
finish early idle at ``P_idle``, the paper's waste mechanism operating
one tier up.  :class:`AsyncFleetServer` removes the barrier: a min-heap
of completion events advances the earliest-finishing replica, the
router places due arrivals against *staleness-bounded* snapshot views
(per-replica ``load_snapshot()`` caches refreshed on step completion,
their age surfaced to routers as ``RouterContext.snapshot_age``; ages
past ``max_snapshot_age`` force a refresh before routing — the model of
a load-report RPC), and an optional :class:`~repro.fleet.autoscale.
Autoscaler` turns R into a decision variable.

Replica lifecycle: ``COLD`` (powered off, drawing nothing — the energy
win) -> ``WARMING`` (powered, joins after ``warmup_s``; params are
shared across replicas and the jitted model functions are cached
per-shape, so a scale-up replica joins with zero recompilation) ->
``ACTIVE`` (routable) -> ``DRAINING`` (never routed to; resident
requests hand off *bit-exactly* via the engine's host-staged swap path
— :meth:`ServingEngine.drain` stages every victim's KV through
``serving/preemption.py`` and the re-routed replica restores it
block-for-block, so generations are identical to a run that never
scaled; the slot backend has no swap machinery, so its drains hand off
only queued work and let residents finish in place).

``barrier_compat=True`` is the parity oracle in the spirit of every
ref/vec seam in this repo: ``step()`` delegates to the inherited
barrier loop, so stats and telemetry are bit-identical to
:class:`FleetServer` on the same stream (gated in CI across all
routers).  Accounting invariant shared with the barrier fleet: every
joule is either engine energy (covered by step intervals) or idle
draw (charged to powered, non-stepping replicas as the clock
advances), and the per-tick telemetry rows sum to exactly
``stats()["energy_j"]``.  The ``fleet_async`` section of
``benchmarks/balancer_bench.py`` gates the headline claim: on the
diurnal scenario the autoscaled async fleet cuts idle energy and
energy-per-token versus the fixed-R barrier fleet at equal-or-better
SLO attainment, with zero failures and zero tokens lost across drains.
"""
from __future__ import annotations

import heapq
from typing import Optional, Union

import numpy as np

from ..core.metrics import step_imbalance
from ..obs.ledger import CAUSE_INDEX, N_CAUSES, reconcile_split
from ..obs.trace import FLEET_TRACK
from .autoscale import Autoscaler, make_autoscaler
from .server import FleetServer

__all__ = ["AsyncFleetServer", "COLD", "WARMING", "ACTIVE", "DRAINING"]

# replica lifecycle states
COLD, WARMING, ACTIVE, DRAINING = 0, 1, 2, 3
# event kinds on the heap: step completion / warmup completion
EV_STEP, EV_WARM = 0, 1


class AsyncFleetServer(FleetServer):
    """Event-driven fleet with optional autoscaling.

    Extra knobs over :class:`FleetServer`:

    * ``barrier_compat`` — delegate stepping to the inherited barrier
      loop (bit-identical stats/telemetry; no async state is touched);
    * ``autoscaler`` — an :class:`~repro.fleet.autoscale.Autoscaler`
      (or a factory name, ``"util"`` / ``"slo"``); None fixes R;
    * ``max_snapshot_age`` — seconds a replica's cached load view may
      trail the fleet clock before routing forces a refresh (0.0 =
      always fresh, the barrier fleet's implicit contract);
    * ``record_routes`` — append one audit entry per routing round to
      ``route_log`` (replica states, eligibility, assignments) for the
      staleness property tests.
    """

    def __init__(self, cfg, params, engine_cfg, *,
                 barrier_compat: bool = False,
                 autoscaler: Union[None, str, Autoscaler] = None,
                 max_snapshot_age: float = 0.0,
                 record_routes: bool = False, **kw):
        super().__init__(cfg, params, engine_cfg, **kw)
        if barrier_compat and autoscaler is not None:
            raise ValueError(
                "barrier_compat reproduces the fixed-R barrier fleet "
                "bit-for-bit; it cannot autoscale")
        self.barrier_compat = bool(barrier_compat)
        self.autoscaler: Optional[Autoscaler] = (
            None if autoscaler is None else make_autoscaler(autoscaler))
        self.max_snapshot_age = float(max_snapshot_age)
        self.record_routes = bool(record_routes)
        self.route_log: list[dict] = []
        # (t, seq, kind, replica) min-heap; seq keeps pops FIFO at ties
        self._ev_heap: list[tuple[float, int, int, int]] = []
        self._ev_seq = 0
        # per-replica lifecycle + clock state (everyone starts ACTIVE;
        # the first autoscale decision sheds what the load can't use)
        self._rs_state = np.full(self.R, ACTIVE, dtype=np.int64)
        self._rs_t_ready = np.zeros(self.R)
        self._rs_t_acc = np.zeros(self.R)    # power accounted up to
        self._rs_stepping = np.zeros(self.R, dtype=bool)
        # eager-step results carried from start to completion event
        self._rs_dt = np.zeros(self.R)
        self._rs_de = np.zeros(self.R)
        self._rs_dtok = np.zeros(self.R, dtype=np.int64)
        self._rs_busy_s = np.zeros(self.R)
        self._rs_on_s = np.zeros(self.R)
        # fleet-clock timestamp of each replica's cached load snapshot
        self._snap_time = np.zeros(self.R)
        # tick accumulators, flushed into one telemetry row per tick
        self._tick_t = 0.0
        self._tick_de = 0.0
        self._tick_idle = 0.0
        self._tick_tokens = 0
        self._tick_busy = np.zeros(self.R)
        self._tick_completions = 0
        # per-cause idle within the tick (repro.obs.IDLE_CAUSES order);
        # reconciled against _tick_idle at the row flush
        self._tick_cause = np.zeros(N_CAUSES)
        # autoscaler bookkeeping (windowed signals + audit counters)
        self._as_next_decision = (self.autoscaler.interval_s
                                  if self.autoscaler is not None
                                  else np.inf)
        self._as_win_busy = 0.0
        self._as_win_on = 0.0
        self._as_req_mark = 0
        self._as_carry_ttft: dict[int, float] = {}
        self._as_drain_handoffs = 0
        self._as_drain_tokens_lost = 0
        self._as_scale_ups = 0
        self._as_scale_downs = 0
        self._as_warm_cancels = 0
        self._as_on_integral = 0.0           # integral of n_on over time

    # ------------------------------------------------------------- clock
    def _next_time(self) -> Optional[float]:
        """Next fleet-clock instant anything can happen: the earliest
        event, the next pending arrival, or (when autoscaling) the next
        decision boundary — fast-forwards through an idle trough are
        clamped at decision boundaries so scale-down actually runs."""
        cands = []
        if self._ev_heap:
            cands.append(self._ev_heap[0][0])
        if self._pending:
            cands.append(self._pending[0][0])
        if cands and self.autoscaler is not None:
            cands.append(float(self._as_next_decision))
        if self._queue:                      # defensive: route now
            cands.append(self.t_now)
        if not cands:
            return None
        return min(cands)

    def _advance(self, t: float) -> None:
        """Advance the fleet clock to ``t``, charging idle draw to every
        powered, non-stepping replica for the interval (stepping
        replicas' intervals are covered by their engine's step
        energy)."""
        t = max(float(t), self.t_now)
        idle_idx = np.flatnonzero((self._rs_state != COLD)
                                  & ~self._rs_stepping)
        any_stepping = bool(self._rs_stepping.any())
        for r in idle_idx:
            dt_r = float(t - self._rs_t_acc[r])
            if dt_r > 0:
                idle = dt_r * float(self._idle_power_vec[r])
                self.idle_j += idle
                # single-cause attribution per powered, non-stepping
                # replica (charged with the same float, right after the
                # idle_j accumulation — the ledger-total exactness gate)
                st = int(self._rs_state[r])
                if st == WARMING:
                    c = CAUSE_INDEX["warmup"]
                elif st == DRAINING:
                    c = CAUSE_INDEX["preempt_swap"]
                elif self._queue:
                    c = CAUSE_INDEX["routing_miss"]
                elif any_stepping:
                    c = CAUSE_INDEX["decode_tail"]
                else:
                    c = CAUSE_INDEX["arrival_gap"]
                self._obs_ledger.charge_one(idle, c)
                self._tick_cause[c] += idle
                self._tick_idle += idle
                self._rs_on_s[r] += dt_r
                self._as_win_on += dt_r
            self._rs_t_acc[r] = t
        n_on = int((self._rs_state != COLD).sum())
        self._as_on_integral += (t - self.t_now) * n_on
        self.t_now = t

    # ------------------------------------------------------------ events
    def _start_step(self, r: int) -> None:
        """Eager-step replica ``r``: the engine state advances now (so
        dt / energy / tokens are known), the *fleet* observes the
        results only at the completion event — that deferral is exactly
        the bounded snapshot staleness the router tolerates."""
        eng = self.engines[r]
        t0, e0, k0 = eng.t_now, eng.energy_j, eng.tokens_out
        eng.step()
        self._rs_dt[r] = eng.t_now - t0
        self._rs_de[r] = eng.energy_j - e0
        self._rs_dtok[r] = eng.tokens_out - k0
        self._rs_stepping[r] = True
        self._ev_seq += 1
        heapq.heappush(
            self._ev_heap,
            (self.t_now + float(self._rs_dt[r]), self._ev_seq,
             EV_STEP, r))

    def _complete_step(self, r: int) -> None:
        dt = float(self._rs_dt[r])
        self._rs_stepping[r] = False
        self._rs_busy_s[r] += dt
        self._rs_on_s[r] += dt
        self._as_win_busy += dt
        self._as_win_on += dt
        self._rs_t_acc[r] = self.t_now
        self._tick_de += float(self._rs_de[r])
        self._tick_tokens += int(self._rs_dtok[r])
        self._tick_busy[r] += dt
        self._tick_completions += 1
        self._refresh([r])
        self._snap_time[r] = self.t_now
        if self._rs_state[r] == DRAINING:
            self._drain_now(r)

    def _complete_warm(self, r: int) -> None:
        if self._rs_state[r] != WARMING:
            return                           # canceled while warming
        self._rs_state[r] = ACTIVE
        self._refresh([r])
        self._snap_time[r] = self.t_now

    def _pop_events(self) -> None:
        while self._ev_heap and self._ev_heap[0][0] <= self.t_now:
            _, _, kind, r = heapq.heappop(self._ev_heap)
            if kind == EV_STEP:
                self._complete_step(r)
            else:
                self._complete_warm(r)

    def _start_pending(self) -> None:
        """Start a step on every replica with work: routable replicas,
        plus slot-backend drainers finishing their residents in
        place."""
        for r in np.flatnonzero(~self._rs_stepping & self._busy_mask):
            r = int(r)
            st = int(self._rs_state[r])
            if st == ACTIVE or (st == DRAINING
                                and not self.engines[r]._paged):
                self._start_step(r)

    # ----------------------------------------------------------- routing
    def _route_async(self) -> None:
        """Route due arrivals over the ACTIVE subset against the cached
        (staleness-bounded) snapshot views.  Eligibility masking is the
        staleness property's guarantee: draining and not-yet-warm
        replicas are simply absent from the router's world."""
        if not self._queue:
            return
        elig = np.flatnonzero(self._rs_state == ACTIVE)
        if elig.size == 0:                   # r_min >= 1 prevents this
            return
        age = self.t_now - self._snap_time
        stale = [int(r) for r in elig if age[r] > self.max_snapshot_age]
        if stale:                            # the load-report RPC
            self._refresh(stale)
            self._snap_time[stale] = self.t_now
            age[stale] = 0.0
        entry = None
        if self.record_routes:
            entry = {"t": self.t_now, "eligible": elig.tolist(),
                     "states": self._rs_state.tolist(),
                     "snapshot_age": age[elig].tolist(),
                     "rids": [req.rid for _, req in self._queue]}
        touched = self._dispatch(
            self._snap_res[elig] + self._snap_wait_cost[elig],
            self._snap_active[elig] + self._snap_waiting[elig],
            self._snap_free[elig],
            eligible=elig, snapshot_age=age[elig])
        if touched:
            tl = sorted(touched)
            self._refresh(tl)
            self._snap_time[tl] = self.t_now
        if self._as_carry_ttft:
            # drained residents keep their original first-token time
            for rec in self._live:
                if rec["ttft"] is None \
                        and rec["rid"] in self._as_carry_ttft:
                    rec["ttft"] = self._as_carry_ttft.pop(rec["rid"])
        if entry is not None:
            entry["assigned"] = [self.assignments[rid]
                                 for rid in entry["rids"]]
            self.route_log.append(entry)

    # ------------------------------------------------------- autoscaling
    def _drain_now(self, r: int) -> None:
        """Evict replica ``r``'s work back into the fleet queue.  On the
        paged backend every resident's KV is host-staged by the swap
        path and restored bit-for-bit wherever the router re-lands the
        request; the slot backend hands off only queued work (residents
        finish in place) and the replica powers off once empty."""
        eng = self.engines[r]
        tr0 = eng.tokens_recomputed
        handoff = eng.drain()
        self._as_drain_tokens_lost += eng.tokens_recomputed - tr0
        self._as_drain_handoffs += len(handoff)
        if handoff:
            if self._obs_rec.enabled:
                for req in handoff:
                    self._obs_rec.point(FLEET_TRACK, req.rid,
                                        "drain-handoff", self.t_now,
                                        from_replica=r)
            ids = {id(req) for req in handoff}
            arrival = {}
            still = []
            for rec in self._live:
                if id(rec["req"]) in ids:
                    arrival[id(rec["req"])] = rec["t_arrival"]
                    if rec["ttft"] is not None:
                        self._as_carry_ttft[rec["rid"]] = rec["ttft"]
                else:
                    still.append(rec)
            self._live = still
            for req in handoff:
                self._queue.append(
                    (arrival.get(id(req), self.t_now), req))
        self._refresh([r])
        self._snap_time[r] = self.t_now
        if not self._busy_mask[r]:
            self._rs_state[r] = COLD

    def _window_slo(self) -> Optional[float]:
        """SLO attainment over requests finalized since the last
        decision (None when none finished or telemetry is off)."""
        if self.telemetry is None:
            return None
        window = self.telemetry.requests[self._as_req_mark:]
        self._as_req_mark = len(self.telemetry.requests)
        if not window:
            return None
        slo = self.telemetry.slo
        ok = sum(
            1 for q in window
            if q["status"] == "done" and q["ttft"] is not None
            and q["ttft"] <= slo.ttft_s
            and (q["tpot"] is None or q["tpot"] <= slo.tpot_s))
        return ok / len(window)

    def _autoscale(self) -> None:
        a = self.autoscaler
        n_active = int((self._rs_state == ACTIVE).sum())
        n_on = int((self._rs_state != COLD).sum())
        util = (self._as_win_busy / self._as_win_on
                if self._as_win_on > 0 else None)
        queue_depth = len(self._queue) + int(
            self._snap_waiting[self._rs_state == ACTIVE].sum())
        signals = {"t": self.t_now, "n_active": n_active, "n_on": n_on,
                   "utilization": util, "queue_depth": queue_depth,
                   "window_slo": self._window_slo(),
                   "pending": len(self._pending)}
        target = int(np.clip(a.decide(signals), a.r_min,
                             min(a.r_max, self.R)))
        n_up = n_active + int((self._rs_state == WARMING).sum())
        if target > n_up:
            cold = np.flatnonzero(self._rs_state == COLD)
            for r in cold[:target - n_up]:
                r = int(r)
                self._rs_state[r] = WARMING
                self._rs_t_ready[r] = self.t_now + a.warmup_s
                self._rs_t_acc[r] = self.t_now   # draws idle while warm
                self._ev_seq += 1
                heapq.heappush(
                    self._ev_heap,
                    (float(self._rs_t_ready[r]), self._ev_seq,
                     EV_WARM, r))
                self._as_scale_ups += 1
        elif target < n_up:
            excess = n_up - target
            # cancel in-flight warmups first (newest first) — their
            # stale heap entries are ignored by the state check
            warming = np.flatnonzero(self._rs_state == WARMING)
            for r in warming[::-1][:excess]:
                self._rs_state[int(r)] = COLD
                self._as_warm_cancels += 1
                excess -= 1
            if excess > 0:
                # drain the least-committed actives; target >= r_min
                # keeps at least r_min replicas routable throughout
                act = np.flatnonzero(self._rs_state == ACTIVE)
                commit = (self._snap_res + self._snap_wait_cost)[act]
                for r in act[np.argsort(commit, kind="stable")][:excess]:
                    r = int(r)
                    self._rs_state[r] = DRAINING
                    self._as_scale_downs += 1
                    if not self._rs_stepping[r]:
                        self._drain_now(r)
        self._as_win_busy = 0.0
        self._as_win_on = 0.0

    def _autoscale_due(self) -> None:
        if self.autoscaler is None:
            return
        while self.t_now >= self._as_next_decision:
            self._autoscale()
            self._as_next_decision += self.autoscaler.interval_s

    # -------------------------------------------------------------- tick
    def _record_tick(self) -> dict:
        """Close the tick: finalize requests, flush the accumulators
        into one telemetry row (same row schema as the barrier fleet,
        plus the v2 replica-count / per-replica-busy series)."""
        self.steps += 1
        self._finalize_requests()
        dt = self.t_now - self._tick_t
        self._tick_t = self.t_now
        imb = 0.0
        on = self._rs_state != COLD
        if self._tick_completions and int(on.sum()) > 0:
            imb = step_imbalance(self._snap_res[on])
            self.imbalance_sum += imb
        d_preempt = int(self._snap_preempt.sum()) - self._prev_preemptions
        d_hits = int(self._snap_hits.sum()) - self._prev_prefix_hits
        d_revived = (int(self._snap_revived.sum())
                     - self._prev_prefix_revived)
        self._prev_preemptions += d_preempt
        self._prev_prefix_hits += d_hits
        self._prev_prefix_revived += d_revived
        # per-tick cause split: reconcile so the row's idle_split folds
        # to its idle_j bit-exactly (async rows have no gating replica)
        split = reconcile_split(self._tick_idle, self._tick_cause)
        if self.telemetry is not None:
            self.telemetry.record_step(
                step=self.steps, t=self.t_now, dt=dt,
                replica_loads=self._snap_res.copy(),
                replica_active=self._snap_active.tolist(),
                replica_waiting=self._snap_waiting.tolist(),
                cross_imbalance=imb, energy_j=self._tick_de,
                idle_j=self._tick_idle, tokens=self._tick_tokens,
                preemptions=d_preempt, prefix_hits=d_hits,
                replica_count=int((self._rs_state == ACTIVE).sum()),
                replica_busy=self._tick_busy.copy(),
                prefix_revived=d_revived,
                prefix_cached_blocks=int(self._snap_cached.sum()),
                gating_replica=-1, idle_split=split)
        info = {"t": self.t_now, "dt": dt, "imbalance": imb,
                "tokens": self._tick_tokens, "idle_j": self._tick_idle,
                "waiting": (len(self._pending) + len(self._queue)
                            + int(self._snap_waiting.sum())),
                "replica_waiting": self._snap_waiting.tolist()}
        self._tick_de = 0.0
        self._tick_idle = 0.0
        self._tick_tokens = 0
        self._tick_busy[:] = 0.0
        self._tick_completions = 0
        self._tick_cause[:] = 0.0
        return info

    # ----------------------------------------------------------- driving
    def _step_barrier(self) -> dict:
        """The parity oracle: one inherited barrier step, untouched."""
        return FleetServer.step(self)

    def _step_async(self) -> dict:
        """One event tick: advance to the next instant anything can
        happen, complete due events, release + route arrivals over the
        eligible subset, catch up autoscale decisions, start new
        steps."""
        t_next = self._next_time()
        if t_next is None:
            raise RuntimeError(
                "async fleet stuck: queued work but no events, "
                "arrivals, or routable replicas")
        self._advance(t_next)
        self._pop_events()
        self._release_arrivals()
        self._autoscale_due()
        self._route_async()
        self._start_pending()
        return self._record_tick()

    def step(self) -> dict:
        if self.barrier_compat:
            return self._step_barrier()
        return self._step_async()

    def _any_busy(self) -> bool:
        if self.barrier_compat:
            return FleetServer._any_busy(self)
        # every in-flight step and warmup is on the heap; nothing can
        # happen once it is empty and no arrivals remain
        return bool(self._ev_heap)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        out = super().stats()
        if self.barrier_compat:
            return out
        busy = self._rs_busy_s
        on = self._rs_on_s
        out.update({
            "fleet_kind": "async",
            "drain_handoffs": self._as_drain_handoffs,
            "drain_tokens_lost": int(self._as_drain_tokens_lost),
            "scale_ups": self._as_scale_ups,
            "scale_downs": self._as_scale_downs,
            "warm_cancels": self._as_warm_cancels,
            "replica_busy_s": [float(x) for x in busy],
            "replica_on_s": [float(x) for x in on],
            "utilization": float(busy.sum() / max(on.sum(), 1e-12)),
            "r_on_mean": float(self._as_on_integral
                               / max(self.t_now, 1e-12)),
        })
        return out
