"""Fleet scenario trace suite: named, seed-reproducible request streams.

Each scenario turns an arrival-process generator from
:mod:`repro.data.traces` (stationary Poisson, BurstGPT-style bursts, the
diurnal sinusoidal ramp) plus a :class:`~repro.data.synthetic.
WorkloadSpec` length model into a concrete stream of
:class:`FleetRequest`\\ s — wall-clock arrival times, materialized prompt
token ids, and decode budgets — sized to a given fleet shape
(R replicas x G workers x B slots).  The scenarios cover the load
shapes a fleet router must ride:

* ``steady`` — stationary Poisson at ~1.3x capacity (Definition 1's
  overloaded regime): the baseline routing setting.
* ``flash_crowd`` — alternating calm / 6x-rate burst episodes: the
  regime where a burst must be *spread*, not dumped on whoever looked
  idle when it began.
* ``diurnal`` — sinusoidal day/night rate swing: sustained ramps up and
  down rather than shocks.
* ``agentic`` — shared-system-prefix prompts with longer decodes
  (multi-turn agent swarms): near-identical prefill sizes, so
  count-based and load-based routing genuinely differ, and the stream
  exercises prefix caching when the paged backend is on.
* ``long_doc`` — document-scale prompts with short summaries: maximal
  prefill dispersion, the size-aware router's best case.
* ``trickle`` — sparse arrivals (at most ~one request in flight
  fleet-wide) with short prompts and long decode budgets: the large-R
  probe regime (background scoring / agent traffic spread over
  hundreds of mostly-idle replicas), where per-step fleet bookkeeping
  — not model compute — dominates wall clock.  The ``fleet_scale``
  bench section times its ref-vs-vec hot path on this shape.
* ``multi_turn`` — staggered agentic sessions: each session reuses a
  per-session shared context across several turns, and turn t+1
  arrives only *after* turn t's estimated finish.  Every turn's
  context blocks are refcount-0 when the next turn lands, so an
  admission-scoped prefix cache measures ~0% hits here — the workload
  the persistent LRU evictor (and ``bfio_affinity`` routing) is
  CI-gated on.

Every generator is a pure function of its arguments (seed included), so
scenarios are bit-reproducible across runs and machines — the property
the ``fleet`` bench section and ``tests/test_fleet.py`` gate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..data.synthetic import WorkloadSpec
from ..data.traces import bursty_trace, diurnal_trace, poisson_trace
from ..serving import ServeRequest

__all__ = ["FleetRequest", "Scenario", "SCENARIOS", "make_scenario",
           "validate_scenario"]


@dataclasses.dataclass
class FleetRequest:
    """One materialized request of a scenario trace."""

    rid: int
    arrival_time: float          # seconds on the fleet clock
    tokens: np.ndarray           # prompt token ids, int32
    max_new_tokens: int

    def to_serve_request(self) -> ServeRequest:
        return ServeRequest(rid=self.rid, tokens=self.tokens.copy(),
                            max_new_tokens=self.max_new_tokens)


@dataclasses.dataclass
class Scenario:
    """A named request stream plus the knobs that produced it."""

    name: str
    requests: list[FleetRequest]
    meta: dict

    @property
    def n_requests(self) -> int:
        return len(self.requests)


def _fleet_rate(spec: WorkloadSpec, R: int, G: int, B: int, *,
                factor: float, step_overhead: float,
                t_token: float) -> float:
    """Arrival rate at ``factor`` x the fleet's crude service capacity
    (the single-engine estimate of traces.overload_rate, times R)."""
    e_o = 1.0 / spec.decode_p
    dt = step_overhead + t_token * B * (spec.mu_s + 0.5 * e_o)
    return factor * R * G * B / (e_o * dt)


def _materialize(name: str, inst, *, vocab_size: int, max_prompt: int,
                 max_new: int, seed: int, meta: dict) -> Scenario:
    """Turn an ArrivalInstance (arrival times + prefill/decode lengths)
    into concrete token streams.  Token ids come from a dedicated rng so
    prompt *content* is independent of the arrival-process draws."""
    rng = np.random.default_rng(seed + 0x5EED)
    out = []
    for r in inst.requests:
        L = int(np.clip(r.prefill, 1, max_prompt))
        out.append(FleetRequest(
            rid=r.rid, arrival_time=float(r.arrival_time),
            tokens=rng.integers(1, vocab_size, size=L).astype(np.int32),
            max_new_tokens=int(np.clip(r.decode_len, 1, max_new))))
    return Scenario(name=name, requests=out, meta=meta)


def _spec(name: str, mean: float, sigma: float, s_min: int, s_max: int,
          decode_p: float, o_max: int) -> WorkloadSpec:
    return WorkloadSpec(name=name, prefill_log_mean=float(np.log(mean)),
                        prefill_log_sigma=sigma, s_min=s_min, s_max=s_max,
                        decode_p=decode_p, o_max=o_max)


def _steady(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    spec = _spec("fleet-steady", mean=max_seq / 4, sigma=0.8, s_min=2,
                 s_max=max_seq - 1, decode_p=1 / 8, o_max=24)
    rate = _fleet_rate(spec, R, G, B, factor=1.3 * factor,
                       step_overhead=c, t_token=tt)
    inst = poisson_trace(spec, n_requests=n, rate=rate, seed=seed)
    return _materialize("steady", inst, vocab_size=vocab,
                        max_prompt=max_seq - 1, max_new=24, seed=seed,
                        meta={"rate": rate, "spec": spec.name})


def _flash_crowd(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    spec = _spec("fleet-flash", mean=max_seq / 4, sigma=1.0, s_min=2,
                 s_max=max_seq - 1, decode_p=1 / 8, o_max=24)
    rate = _fleet_rate(spec, R, G, B, factor=1.1 * factor,
                       step_overhead=c, t_token=tt)
    period = max(n / rate / 3.0, 1e-3)   # ~3 burst cycles over the trace
    inst = bursty_trace(spec, n_requests=n, rate=rate, burst_factor=4.0,
                        burst_frac=0.25, period=period, seed=seed)
    return _materialize("flash_crowd", inst, vocab_size=vocab,
                        max_prompt=max_seq - 1, max_new=24, seed=seed,
                        meta={"rate": rate, "period": period,
                              "spec": spec.name})


def _diurnal(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    spec = _spec("fleet-diurnal", mean=max_seq / 4, sigma=0.9, s_min=2,
                 s_max=max_seq - 1, decode_p=1 / 8, o_max=24)
    rate = _fleet_rate(spec, R, G, B, factor=1.2 * factor,
                       step_overhead=c, t_token=tt)
    period = max(n / rate / 2.0, 1e-3)   # ~2 day/night cycles
    inst = diurnal_trace(spec, n_requests=n, rate=rate, amplitude=0.8,
                         period=period, seed=seed)
    return _materialize("diurnal", inst, vocab_size=vocab,
                        max_prompt=max_seq - 1, max_new=24, seed=seed,
                        meta={"rate": rate, "period": period,
                              "spec": spec.name})


def _agentic(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    """Shared system prefix + short per-agent suffix, longer decodes."""
    spec = _spec("fleet-agentic", mean=max(max_seq / 8, 2), sigma=0.4,
                 s_min=2, s_max=max(max_seq // 4, 2), decode_p=1 / 16,
                 o_max=32)
    rate = _fleet_rate(spec, R, G, B, factor=1.3 * factor,
                       step_overhead=c, t_token=tt)
    inst = poisson_trace(spec, n_requests=n, rate=rate, seed=seed)
    rng = np.random.default_rng(seed + 0xA6E)
    prefix_len = max(max_seq // 2, 1)
    prefix = rng.integers(1, vocab, size=prefix_len).astype(np.int32)
    out = []
    for r in inst.requests:
        sfx = int(np.clip(r.prefill, 1, max(max_seq - 1 - prefix_len, 1)))
        toks = np.concatenate(
            [prefix, rng.integers(1, vocab, size=sfx).astype(np.int32)])
        out.append(FleetRequest(
            rid=r.rid, arrival_time=float(r.arrival_time), tokens=toks,
            max_new_tokens=int(np.clip(r.decode_len, 1, 32))))
    return Scenario(name="agentic", requests=out,
                    meta={"rate": rate, "shared_prefix_len": prefix_len,
                          "spec": spec.name})


def _long_doc(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    """Document-scale prompts, short outputs: maximal prefill dispersion
    relative to the cache (uniform over the upper half of max_seq)."""
    spec = _spec("fleet-longdoc", mean=max_seq * 0.6, sigma=0.5,
                 s_min=max(max_seq // 3, 2), s_max=max_seq - 1,
                 decode_p=1 / 4, o_max=12)
    rate = _fleet_rate(spec, R, G, B, factor=0.9 * factor,
                       step_overhead=c, t_token=tt)
    inst = poisson_trace(spec, n_requests=n, rate=rate, seed=seed)
    return _materialize("long_doc", inst, vocab_size=vocab,
                        max_prompt=max_seq - 1, max_new=12, seed=seed,
                        meta={"rate": rate, "spec": spec.name})


def _trickle(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    """Sparse single-file arrivals, short prompts, long decode budgets:
    at any instant only a handful of replicas are busy regardless of R,
    so fleet-layer per-step cost is laid bare (the ``fleet_scale``
    regime)."""
    s_max = max(max_seq // 6, 2)
    o_max = max(max_seq - s_max - 1, 1)
    spec = _spec("fleet-trickle", mean=max(max_seq / 12, 2), sigma=0.6,
                 s_min=2, s_max=s_max, decode_p=1 / 24, o_max=o_max)
    # Unlike every other scenario the rate does NOT scale with the
    # fleet shape: a trickle keeps at most ~one request in flight
    # fleet-wide, so adding replicas only adds idle bookkeeping — the
    # quantity the fleet_scale bench isolates.
    e_o = 1.0 / spec.decode_p
    dt = c + tt * B * (spec.mu_s + 0.5 * e_o)
    rate = factor * 0.8 / (e_o * dt)
    inst = poisson_trace(spec, n_requests=n, rate=rate, seed=seed)
    return _materialize("trickle", inst, vocab_size=vocab,
                        max_prompt=s_max, max_new=o_max, seed=seed,
                        meta={"rate": rate, "spec": spec.name})


def _multi_turn(n, R, G, B, max_seq, vocab, seed, factor, c, tt) -> Scenario:
    """Staggered multi-turn agentic sessions: every turn of a session
    shares the session's context tokens, and turn t+1 arrives after
    turn t's *estimated finish* (service-time model plus slack) — so
    when the next turn lands, the previous turn has drained and its
    context blocks sit at refcount 0.  An admission-scoped prefix cache
    gets ~0% hits on this stream; a persistent LRU evictor turns every
    later turn into a context-length hit, and per-session contexts
    differ so affinity routing can tell *which* replica holds them."""
    turns = 3
    sessions = max(-(-n // turns), 1)
    rng = np.random.default_rng(seed + 0x717)
    ctx_len = max(max_seq // 2, 1)
    sfx_max = max(max_seq - 1 - ctx_len, 2)
    spec = _spec("fleet-multiturn", mean=max(max_seq / 8, 2), sigma=0.4,
                 s_min=2, s_max=sfx_max, decode_p=1 / 8, o_max=16)
    e_o = 1.0 / spec.decode_p
    dt = c + tt * B * (spec.mu_s + 0.5 * e_o)
    # session starts: Poisson, rate sized so ~R sessions run at once
    rate = factor * R * G * B / (e_o * dt) / turns
    starts = np.cumsum(rng.exponential(1.0 / rate, size=sessions))
    turn_gap = 2.0 * e_o * dt
    out: list[FleetRequest] = []
    rid = 0
    for s in range(sessions):
        ctxt = rng.integers(1, vocab, size=ctx_len).astype(np.int32)
        t_arr = float(starts[s])
        for _ in range(turns):
            if rid >= n:
                break
            sfx = int(rng.integers(1, sfx_max + 1))
            dec = int(rng.integers(1, spec.o_max + 1))
            out.append(FleetRequest(
                rid=rid, arrival_time=t_arr,
                tokens=np.concatenate(
                    [ctxt,
                     rng.integers(1, vocab, size=sfx).astype(np.int32)]),
                max_new_tokens=dec))
            rid += 1
            # next turn lands after this one's estimated finish
            t_arr += (ctx_len + sfx + dec) * dt + turn_gap
    out.sort(key=lambda r: r.arrival_time)    # global arrival order
    return Scenario(name="multi_turn", requests=out,
                    meta={"sessions": sessions, "turns": turns,
                          "shared_ctx_len": ctx_len, "rate": rate,
                          "turn_gap": turn_gap, "spec": spec.name})


SCENARIOS = {
    "steady": _steady,
    "flash_crowd": _flash_crowd,
    "diurnal": _diurnal,
    "agentic": _agentic,
    "long_doc": _long_doc,
    "trickle": _trickle,
    "multi_turn": _multi_turn,
}


def make_scenario(name: str, *, n_requests: int, n_replicas: int,
                  n_workers: int, slots_per_worker: int,
                  max_seq_len: int = 64, vocab_size: int = 128,
                  seed: int = 0, load_factor: float = 1.0,
                  step_overhead: float = 9.775e-3,
                  t_token: float = 1.005e-7) -> Scenario:
    """Build a named scenario sized to a fleet shape.  ``load_factor``
    scales every scenario's arrival rate around its calibrated
    overload point."""
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r} (have {sorted(SCENARIOS)})")
    sc = builder(n_requests, n_replicas, n_workers, slots_per_worker,
                 max_seq_len, vocab_size, seed, load_factor,
                 step_overhead, t_token)
    sc.meta.update(n_requests=n_requests, n_replicas=n_replicas,
                   n_workers=n_workers, slots_per_worker=slots_per_worker,
                   max_seq_len=max_seq_len, vocab_size=vocab_size,
                   seed=seed, load_factor=load_factor)
    return sc


def validate_scenario(sc: Scenario, *, max_seq_len: int,
                      vocab_size: int) -> None:
    """Schema check: raise AssertionError on any malformed stream."""
    assert sc.name in SCENARIOS, sc.name
    assert sc.requests, "empty scenario"
    rids = [r.rid for r in sc.requests]
    assert len(set(rids)) == len(rids), "duplicate rids"
    prev = 0.0
    for r in sc.requests:
        assert r.arrival_time >= prev >= 0.0, "arrivals not sorted"
        prev = r.arrival_time
        assert r.tokens.dtype == np.int32
        assert 1 <= len(r.tokens) <= max_seq_len, len(r.tokens)
        assert (r.tokens >= 1).all() and (r.tokens < vocab_size).all()
        assert r.max_new_tokens >= 1
