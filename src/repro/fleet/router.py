"""Fleet-tier routing: which *replica* serves a request.

The paper's balancing principle is scale-free: the same decision problem
that places a request on a decode worker inside one engine reappears one
tier up when heavy traffic is spread across R independent engine
replicas.  :class:`FleetRouter` is that tier's seam — it sees only
fleet-level observables (per-replica committed load/count/capacity and
the waiting candidates' prefill sizes) and maps every waiting request to
a replica.  The replica's own admission scheduler
(:mod:`repro.serving.scheduler` + a :class:`~repro.core.policies.Policy`)
then picks the worker slot, so with the BF-IO router *and* a BF-IO
engine policy the principle acts at both levels.

Routing is **total**: every candidate is placed every step (replicas
queue internally; the fleet never holds requests back).  That is what
makes ``fleet(R=1, router=*)`` bit-identical to a bare
:class:`~repro.serving.engine.ServingEngine` on the same stream — the
single replica receives the identical submission sequence — and it
matches how real fleet LBs work: forward on arrival, queue at the
replica.  Load-aware routers therefore balance *committed* load
(resident work plus queued prefill), not just resident work.

Routers mirror the engine-policy taxonomy (Appendix A.1):

* ``round_robin`` — cyclic, size- and load-agnostic;
* ``least_loaded`` — sequential argmin of committed load, counting each
  placement's prefill size (size-aware JSQ analogue);
* ``pod2`` — power-of-d choices on committed request counts;
* ``bfio`` — the paper's Algorithm 1 at fleet scope: one batched
  windowed-imbalance solve over all waiting candidates via the existing
  :func:`~repro.core.balancer_jax.bfio_assign_batch` (a leading cluster
  axis of 1 here; multi-cluster fleets batch many routing solves into
  the same compiled call).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.workload import DriftModel, unit_drift

__all__ = [
    "RouterContext",
    "FleetRouter",
    "RoundRobinRouter",
    "LeastLoadedRouter",
    "PowerOfDRouter",
    "BFIORouter",
    "make_router",
]


@dataclasses.dataclass
class RouterContext:
    """Fleet-level observables at barrier step k.

    ``loads``/``counts`` are *committed* quantities: resident work on the
    replica's workers plus the prefill work already queued at (but not
    yet admitted by) the replica — the router's placements from earlier
    steps must count against a replica even before its scheduler admits
    them, or a burst would pile onto whichever replica looked idle when
    it began."""

    k: int
    loads: np.ndarray        # (R,) committed load per replica
    counts: np.ndarray       # (R,) committed request count per replica
    free_slots: np.ndarray   # (R,) currently free engine slots
    wait_sizes: np.ndarray   # (n,) candidate prefill sizes, arrival order
    drift: DriftModel = dataclasses.field(default_factory=unit_drift)
    rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0))

    @property
    def R(self) -> int:
        return int(self.loads.shape[0])

    @property
    def n_wait(self) -> int:
        return int(self.wait_sizes.shape[0])


class FleetRouter:
    """Maps every waiting request to a replica (total assignment)."""

    name = "base"

    def reset(self) -> None:  # pragma: no cover - stateless default
        pass

    def route(self, ctx: RouterContext) -> np.ndarray:
        """(n_wait,) replica id per candidate — every entry in
        [0, R)."""
        raise NotImplementedError


class RoundRobinRouter(FleetRouter):
    """Cyclic dispatch, irrespective of size and load."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def reset(self) -> None:
        self._next = 0

    def route(self, ctx: RouterContext) -> np.ndarray:
        out = np.empty(ctx.n_wait, dtype=np.int64)
        for i in range(ctx.n_wait):
            out[i] = self._next % ctx.R
            self._next += 1
        return out


class LeastLoadedRouter(FleetRouter):
    """Sequential argmin of committed load; each placement adds its
    prefill size to the running estimate (ties: lowest index)."""

    name = "least_loaded"

    def route(self, ctx: RouterContext) -> np.ndarray:
        out = np.empty(ctx.n_wait, dtype=np.int64)
        loads = ctx.loads.astype(np.float64).copy()
        for i in range(ctx.n_wait):
            g = int(np.argmin(loads))
            out[i] = g
            loads[g] += float(ctx.wait_sizes[i])
        return out


class PowerOfDRouter(FleetRouter):
    """Sample d replicas, route to the least-committed-count one —
    size-agnostic like the engine-tier PowerOfDPolicy."""

    name = "pod"

    def __init__(self, d: int = 2) -> None:
        self.d = int(d)
        self.name = f"pod{d}"

    def route(self, ctx: RouterContext) -> np.ndarray:
        out = np.empty(ctx.n_wait, dtype=np.int64)
        counts = ctx.counts.astype(np.int64).copy()
        for i in range(ctx.n_wait):
            d = min(self.d, ctx.R)
            sample = ctx.rng.choice(ctx.R, size=d, replace=False)
            g = int(sample[np.argmin(counts[sample])])
            out[i] = g
            counts[g] += 1
        return out


def _pad_bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two bucket >= n (bounds jit recompiles across the
    varying per-step candidate counts)."""
    b = lo
    while b < n:
        b *= 2
    return b


class BFIORouter(FleetRouter):
    """BF-IO at fleet scope (Algorithm 1, replicas as the machines).

    One batched solve per routing step: base trajectories are each
    replica's committed load grown by ``counts * drift`` over the
    window, candidates contribute their prefill size plus drift, and
    :func:`~repro.core.balancer_jax.bfio_assign_batch` (cluster axis 1)
    returns the windowed-imbalance-minimizing total assignment.  Caps
    are set to the candidate count — the fleet tier is total, capacity
    is the replica scheduler's concern.
    """

    def __init__(self, H: int = 0, swap_iters: int = 8) -> None:
        self.H = int(H)
        self.swap_iters = int(swap_iters)
        self.name = f"bfio_h{H}" if H else "bfio"

    def _growth(self, ctx: RouterContext) -> np.ndarray:
        g = np.zeros(self.H + 1)
        for h in range(1, self.H + 1):
            g[h] = g[h - 1] + ctx.drift.increment(ctx.k + h)
        return g

    def route(self, ctx: RouterContext) -> np.ndarray:
        import jax.numpy as jnp

        from ..core.balancer_jax import bfio_assign_batch

        n, R = ctx.n_wait, ctx.R
        growth = self._growth(ctx)                       # (W,)
        base = (ctx.loads[:, None]
                + ctx.counts[:, None] * growth[None, :])  # (R, W)
        npad = _pad_bucket(n)
        cands = np.zeros((npad, self.H + 1))
        cands[:n] = ctx.wait_sizes[:, None] + growth[None, :]
        valid = np.zeros(npad, dtype=bool)
        valid[:n] = True
        a = bfio_assign_batch(
            jnp.asarray(base, jnp.float32)[None],
            jnp.full((1, R), npad, jnp.int32),
            jnp.asarray(cands, jnp.float32)[None],
            jnp.asarray(valid)[None],
            jnp.asarray([n], jnp.int32),
            swap_iters=self.swap_iters)
        out = np.asarray(a)[0, :n].astype(np.int64)
        if (out < 0).any():   # defensive: caps are ample, so never hit
            fallback = LeastLoadedRouter().route(ctx)
            out = np.where(out < 0, fallback, out)
        return out


def make_router(name, **kw) -> FleetRouter:
    if isinstance(name, FleetRouter):
        return name
    name = name.lower()
    if name in ("rr", "round_robin"):
        return RoundRobinRouter()
    if name in ("ll", "least_loaded"):
        return LeastLoadedRouter()
    if name.startswith("pod"):
        d = int(name[3:]) if len(name) > 3 else kw.pop("d", 2)
        return PowerOfDRouter(d=d)
    if name.startswith("bfio"):
        if "_h" in name:
            kw.setdefault("H", int(name.split("_h")[1]))
        return BFIORouter(**kw)
    raise ValueError(f"unknown fleet router {name!r}")
