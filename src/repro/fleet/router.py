"""Fleet-tier routing: which *replica* serves a request.

The paper's balancing principle is scale-free: the same decision problem
that places a request on a decode worker inside one engine reappears one
tier up when heavy traffic is spread across R independent engine
replicas.  :class:`FleetRouter` is that tier's seam — it sees only
fleet-level observables (per-replica committed load/count/capacity and
the waiting candidates' prefill sizes) and maps every waiting request to
a replica.  The replica's own admission scheduler
(:mod:`repro.serving.scheduler` + a :class:`~repro.core.policies.Policy`)
then picks the worker slot, so with the BF-IO router *and* a BF-IO
engine policy the principle acts at both levels.

Routing is **total**: every candidate is placed every step (replicas
queue internally; the fleet never holds requests back).  That is what
makes ``fleet(R=1, router=*)`` bit-identical to a bare
:class:`~repro.serving.engine.ServingEngine` on the same stream — the
single replica receives the identical submission sequence — and it
matches how real fleet LBs work: forward on arrival, queue at the
replica.  Load-aware routers therefore balance *committed* load
(resident work plus queued prefill), not just resident work.

Routers mirror the engine-policy taxonomy (Appendix A.1):

* ``round_robin`` — cyclic, size- and load-agnostic;
* ``least_loaded`` — sequential argmin of committed load, counting each
  placement's prefill size (size-aware JSQ analogue);
* ``pod2`` — power-of-d choices on committed request counts;
* ``bfio`` — the paper's Algorithm 1 at fleet scope: one batched
  windowed-imbalance solve over all waiting candidates via the existing
  :func:`~repro.core.balancer_jax.bfio_assign_batch` (a leading cluster
  axis of 1 here; multi-cluster fleets batch many routing solves into
  the same compiled call);
* ``pod_bfio`` — two-level hierarchical BF-IO for R in the hundreds:
  level 1 spreads candidates over P pods of replicas
  (capacity-normalized least-loaded, so heterogeneous pods fill
  proportionally), level 2 runs ONE ``bfio_assign_batch`` call whose
  cluster axis is the pods — the vmap that existed all along, now
  carrying real traffic.  Solve cost scales with the pod size, not R.

Load-aware routers optionally fold in a predicted output length per
candidate (``RouterContext.pred_out`` x ``pred_weight``) — the
predictive-scheduling signal — and see per-replica slot capacity for
heterogeneous fleets (``RouterContext.capacity``).

``bfio_affinity`` (and ``pod_bfio_*_affinity``) additionally folds
prefix-cache locality into the same objective: the fleet surfaces
per-(replica, candidate) predicted hit tokens through
``RouterContext.affinity`` (the prompt head hashed against each
replica's live :class:`~repro.serving.paged_cache.PrefixIndex`), and a
post-solve refinement discounts a candidate's effective size on a
replica by ``affinity_weight * predicted_hit_tokens`` — cache hits skip
prefill compute, so the discounted size is the *true* work the
placement adds there.  Locality and load balance trade inside one
windowed-imbalance objective instead of a sticky-session override; at
``affinity_weight=0`` the refinement is skipped entirely and the router
is bit-identical to plain ``bfio``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.workload import DriftModel, unit_drift

__all__ = [
    "RouterContext",
    "FleetRouter",
    "RoundRobinRouter",
    "LeastLoadedRouter",
    "PowerOfDRouter",
    "BFIORouter",
    "PodBFIORouter",
    "make_router",
]


@dataclasses.dataclass
class RouterContext:
    """Fleet-level observables at barrier step k.

    ``loads``/``counts`` are *committed* quantities: resident work on the
    replica's workers plus the prefill work already queued at (but not
    yet admitted by) the replica — the router's placements from earlier
    steps must count against a replica even before its scheduler admits
    them, or a burst would pile onto whichever replica looked idle when
    it began."""

    k: int
    loads: np.ndarray        # (R,) committed load per replica
    counts: np.ndarray       # (R,) committed request count per replica
    free_slots: np.ndarray   # (R,) currently free engine slots
    wait_sizes: np.ndarray   # (n,) candidate prefill sizes, arrival order
    drift: DriftModel = dataclasses.field(default_factory=unit_drift)
    rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0))
    # (R,) total engine slots per replica — equal for homogeneous fleets,
    # the normalizer for capacity-aware (hierarchical) routing when
    # replica classes differ.  None means "assume homogeneous".
    capacity: Optional[np.ndarray] = None
    # (n,) predicted output length per candidate (arrival order), or None
    # when the fleet has no predictor.  Routers that opt in (pred_weight
    # > 0) add it to each candidate's placement size.
    pred_out: Optional[np.ndarray] = None
    # (R,) seconds since each routable replica's load view was last
    # refreshed.  The barrier fleet routes against just-gathered
    # snapshots (None == implicitly fresh); the async fleet refreshes
    # on step completion, so its router sees bounded-stale loads and
    # this field says how stale.  Routers may discount accordingly.
    snapshot_age: Optional[np.ndarray] = None
    # (R, n) predicted prefix-cache hit tokens: entry [r, i] is how many
    # leading prompt tokens of candidate i are live (referenced or
    # LRU-cached) in replica r's PrefixIndex right now.  None when the
    # fleet has no prefix caches or the router did not ask
    # (affinity_weight == 0 — the probe is not free, so the server only
    # computes it for routers that opt in).
    affinity: Optional[np.ndarray] = None

    @property
    def R(self) -> int:
        return int(self.loads.shape[0])

    @property
    def n_wait(self) -> int:
        return int(self.wait_sizes.shape[0])


class FleetRouter:
    """Maps every waiting request to a replica (total assignment)."""

    name = "base"

    def reset(self) -> None:  # pragma: no cover - stateless default
        pass

    def route(self, ctx: RouterContext) -> np.ndarray:
        """(n_wait,) replica id per candidate — every entry in
        [0, R)."""
        raise NotImplementedError


class RoundRobinRouter(FleetRouter):
    """Cyclic dispatch, irrespective of size and load."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def reset(self) -> None:
        self._next = 0

    def route(self, ctx: RouterContext) -> np.ndarray:
        out = np.empty(ctx.n_wait, dtype=np.int64)
        for i in range(ctx.n_wait):
            out[i] = self._next % ctx.R
            self._next += 1
        return out


class LeastLoadedRouter(FleetRouter):
    """Sequential argmin of committed load; each placement adds its
    prefill size to the running estimate (ties: lowest index)."""

    name = "least_loaded"

    def route(self, ctx: RouterContext) -> np.ndarray:
        out = np.empty(ctx.n_wait, dtype=np.int64)
        loads = ctx.loads.astype(np.float64).copy()
        for i in range(ctx.n_wait):
            g = int(np.argmin(loads))
            out[i] = g
            loads[g] += float(ctx.wait_sizes[i])
        return out


class PowerOfDRouter(FleetRouter):
    """Sample d replicas, route to the least-committed-count one —
    size-agnostic like the engine-tier PowerOfDPolicy."""

    name = "pod"

    def __init__(self, d: int = 2) -> None:
        self.d = int(d)
        self.name = f"pod{d}"

    def route(self, ctx: RouterContext) -> np.ndarray:
        out = np.empty(ctx.n_wait, dtype=np.int64)
        counts = ctx.counts.astype(np.int64).copy()
        for i in range(ctx.n_wait):
            d = min(self.d, ctx.R)
            sample = ctx.rng.choice(ctx.R, size=d, replace=False)
            g = int(sample[np.argmin(counts[sample])])
            out[i] = g
            counts[g] += 1
        return out


def _pad_bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two bucket >= n (bounds jit recompiles across the
    varying per-step candidate counts)."""
    b = lo
    while b < n:
        b *= 2
    return b


class BFIORouter(FleetRouter):
    """BF-IO at fleet scope (Algorithm 1, replicas as the machines).

    One batched solve per routing step: base trajectories are each
    replica's committed load grown by ``counts * drift`` over the
    window, candidates contribute their prefill size plus drift, and
    :func:`~repro.core.balancer_jax.bfio_assign_batch` (cluster axis 1)
    returns the windowed-imbalance-minimizing total assignment.  Caps
    are set to the candidate count — the fleet tier is total, capacity
    is the replica scheduler's concern.

    ``pred_weight`` > 0 folds ``pred_weight * ctx.pred_out`` into each
    candidate's size: a request predicted to decode long is placed as if
    it were that much heavier now.  The default 0.0 is an exact no-op.

    ``affinity_weight`` > 0 (``router="bfio_affinity"``) adds the
    prefix-locality term: after the batched solve, a bounded greedy
    refinement moves single candidates whenever the move lowers the
    affinity-discounted windowed-max objective
    ``J = sum_h max_r traj[r, h]``, where candidate i contributes
    ``max(size_i - affinity_weight * affinity[r, i], 1) + growth`` on
    replica r.  ``affinity_weight=1`` is the physical discount — a
    predicted hit token is a prompt token whose prefill compute the
    replica skips.  At 0.0 (or ``ctx.affinity is None``) the refinement
    is skipped entirely: bit-identical to plain ``bfio``.
    """

    def __init__(self, H: int = 0, swap_iters: int = 8,
                 pred_weight: float = 0.0,
                 affinity_weight: float = 0.0) -> None:
        self.H = int(H)
        self.swap_iters = int(swap_iters)
        self.pred_weight = float(pred_weight)
        self.affinity_weight = float(affinity_weight)
        self.name = f"bfio_h{H}" if H else "bfio"
        if self.affinity_weight != 0.0:
            self.name += "_affinity"

    def _growth(self, ctx: RouterContext) -> np.ndarray:
        g = np.zeros(self.H + 1)
        for h in range(1, self.H + 1):
            g[h] = g[h - 1] + ctx.drift.increment(ctx.k + h)
        return g

    def _sizes(self, ctx: RouterContext) -> np.ndarray:
        """(n,) effective candidate sizes: prefill size plus (optionally)
        the weighted predicted output length."""
        sizes = ctx.wait_sizes.astype(np.float64)
        if self.pred_weight != 0.0 and ctx.pred_out is not None:
            sizes = sizes + self.pred_weight * np.asarray(
                ctx.pred_out, dtype=np.float64)
        return sizes

    def _affinity_refine(self, ctx: RouterContext,
                         out: np.ndarray) -> np.ndarray:
        """Greedy single-candidate move descent on the affinity-
        discounted lexicographic objective (see class docstring):
        primary ``J1 = sum_h max_r traj[r, h]`` (the solver's windowed
        peak, with candidate contributions affinity-discounted),
        secondary ``J2 = sum_i eff[out[i], i]`` (total effective
        prefill work — the compute that predicted hits save).  A move
        is taken when it lowers J1, or keeps J1 and lowers J2 — so
        cache-locality moves off the peak replica are *free* (J1
        untouched, J2 drops by the discount) while balance stays the
        binding constraint: the peak never degrades.

        Each pass evaluates every (candidate, target replica) move via
        a top-2 column-max trick (O(n * R * W) per pass) and applies
        the lexicographically best strictly-improving one; at most
        ``2n`` passes.  Exact no-op when ``affinity_weight == 0`` or
        the fleet supplied no affinity matrix.
        """
        lam = self.affinity_weight
        if lam == 0.0 or ctx.affinity is None:
            return out
        n, R = ctx.n_wait, ctx.R
        if n == 0 or R < 2:
            return out
        growth = self._growth(ctx)                         # (W,)
        aff = np.asarray(ctx.affinity, dtype=np.float64)   # (R, n)
        # effective contribution of candidate i on replica r: prefill
        # size with predicted-hit tokens discounted (hits skip chunk
        # compute), floored at one token so no placement looks free
        eff = np.maximum(self._sizes(ctx)[None, :] - lam * aff,
                         1.0)                              # (R, n)
        traj = (ctx.loads[:, None].astype(np.float64)
                + ctx.counts[:, None] * growth[None, :])   # (R, W)
        out = out.copy()
        for i in range(n):
            traj[out[i]] += eff[out[i], i] + growth
        rows = np.arange(R)
        for _ in range(2 * n):
            J1 = traj.max(axis=0).sum()
            J2 = float(sum(eff[out[i], i] for i in range(n)))
            eps = 1e-9 * (1.0 + abs(J1))   # fp slack, relative scale
            best = (J1 - eps, J2 - eps, None)
            for i in range(n):
                src = int(out[i])
                t = traj.copy()
                t[src] -= eff[src, i] + growth             # i removed
                am = t.argmax(axis=0)                      # (W,)
                m1 = t[am, np.arange(t.shape[1])]
                t[am, np.arange(t.shape[1])] = -np.inf
                m2 = t.max(axis=0)                         # second max
                t[am, np.arange(t.shape[1])] = m1
                # per-column max over the *other* rows when row r takes i
                other = np.where(am[None, :] == rows[:, None],
                                 m2[None, :], m1[None, :])  # (R, W)
                add = eff[:, i][:, None] + growth[None, :]  # (R, W)
                newJ1 = np.maximum(other, t + add).sum(axis=1)
                newJ1[src] = np.inf
                newJ2 = J2 - eff[src, i] + eff[:, i]        # (R,)
                r = int(np.lexsort((newJ2, newJ1))[0])
                nj1, nj2 = float(newJ1[r]), float(newJ2[r])
                b1, b2, _ = best
                if nj1 < b1 - eps or (nj1 <= b1 + eps and nj2 < b2):
                    best = (nj1, nj2, (i, src, r))
            if best[2] is None:
                break
            i, src, r = best[2]
            traj[src] -= eff[src, i] + growth
            traj[r] += eff[r, i] + growth
            out[i] = r
        return out

    def route(self, ctx: RouterContext) -> np.ndarray:
        import jax.numpy as jnp

        from ..core.balancer_jax import bfio_assign_batch

        n, R = ctx.n_wait, ctx.R
        growth = self._growth(ctx)                       # (W,)
        base = (ctx.loads[:, None]
                + ctx.counts[:, None] * growth[None, :])  # (R, W)
        npad = _pad_bucket(n)
        cands = np.zeros((npad, self.H + 1))
        cands[:n] = self._sizes(ctx)[:, None] + growth[None, :]
        valid = np.zeros(npad, dtype=bool)
        valid[:n] = True
        a = bfio_assign_batch(
            jnp.asarray(base, jnp.float32)[None],
            jnp.full((1, R), npad, jnp.int32),
            jnp.asarray(cands, jnp.float32)[None],
            jnp.asarray(valid)[None],
            jnp.asarray([n], jnp.int32),
            swap_iters=self.swap_iters)
        out = np.asarray(a)[0, :n].astype(np.int64)
        if (out < 0).any():   # defensive: caps are ample, so never hit
            fallback = LeastLoadedRouter().route(ctx)
            out = np.where(out < 0, fallback, out)
        return self._affinity_refine(ctx, out)


class PodBFIORouter(BFIORouter):
    """Two-level hierarchical BF-IO: replicas are grouped into ``pods``
    contiguous pods (sizes differ by at most one when R % pods != 0).

    Level 1 assigns each candidate to a pod by capacity-normalized
    least-loaded (sequential, each placement updates the running
    estimate); level 2 solves all pods' placements in ONE
    :func:`~repro.core.balancer_jax.bfio_assign_batch` call with the pod
    axis as the cluster axis — solver cost grows with the pod size and
    per-pod candidate count, not with R.  With ``pods=1`` the solver
    sees bit-identical inputs to the flat :class:`BFIORouter` (a unit
    test pins this), so the hierarchy is a pure scaling knob.
    """

    def __init__(self, pods: int = 4, H: int = 0, swap_iters: int = 8,
                 pred_weight: float = 0.0,
                 affinity_weight: float = 0.0) -> None:
        super().__init__(H=H, swap_iters=swap_iters,
                         pred_weight=pred_weight,
                         affinity_weight=affinity_weight)
        self.pods = int(pods)
        if self.pods < 1:
            raise ValueError(f"pods must be >= 1, got {pods}")
        self.name = (f"pod_bfio_p{self.pods}"
                     + (f"_h{self.H}" if self.H else "")
                     + ("_affinity" if self.affinity_weight else ""))

    def route(self, ctx: RouterContext) -> np.ndarray:
        import jax.numpy as jnp

        from ..core.balancer_jax import bfio_assign_batch

        n, R = ctx.n_wait, ctx.R
        if n == 0:
            return np.empty(0, dtype=np.int64)
        P = min(self.pods, R)
        members = np.array_split(np.arange(R), P)
        sizes = self._sizes(ctx)
        growth = self._growth(ctx)                       # (W,)
        W = self.H + 1

        # level 1: capacity-normalized least-loaded pod, sequential so a
        # burst spreads instead of piling onto one pod.
        cap = (np.asarray(ctx.capacity, dtype=np.float64)
               if ctx.capacity is not None else np.ones(R))
        pod_cap = np.array([max(cap[m].sum(), 1e-12) for m in members])
        run = np.array([ctx.loads[m].sum() for m in members]) / pod_cap
        pod_of = np.empty(n, dtype=np.int64)
        for i in range(n):
            p = int(np.argmin(run))
            pod_of[i] = p
            run[p] += sizes[i] / pod_cap[p]
        order = [np.flatnonzero(pod_of == p) for p in range(P)]
        per = np.array([o.size for o in order], dtype=np.int64)

        # level 2: one batched solve, pods on the cluster axis.  Pods
        # smaller than the widest get zero caps + huge base loads on
        # their padding machine rows so the solver never picks them.
        npad = _pad_bucket(int(per.max()))
        rmax = max(m.size for m in members)
        base = np.full((P, rmax, W), 1e30)
        caps = np.zeros((P, rmax), dtype=np.int32)
        cands = np.zeros((P, npad, W))
        valid = np.zeros((P, npad), dtype=bool)
        for p, m in enumerate(members):
            base[p, :m.size] = (ctx.loads[m][:, None]
                                + ctx.counts[m][:, None] * growth[None, :])
            caps[p, :m.size] = npad
            idx = order[p]
            cands[p, :idx.size] = sizes[idx][:, None] + growth[None, :]
            valid[p, :idx.size] = True
        a = np.asarray(bfio_assign_batch(
            jnp.asarray(base, jnp.float32),
            jnp.asarray(caps),
            jnp.asarray(cands, jnp.float32),
            jnp.asarray(valid),
            jnp.asarray(per, jnp.int32),
            swap_iters=self.swap_iters))

        out = np.empty(n, dtype=np.int64)
        for p, m in enumerate(members):
            idx = order[p]
            if idx.size == 0:
                continue
            ap = a[p, :idx.size].astype(np.int64)
            bad = (ap < 0) | (ap >= m.size)
            if bad.any():   # defensive: caps are ample, so never hit
                ap = np.where(bad, int(np.argmin(ctx.loads[m])), ap)
            out[idx] = m[ap]
        return self._affinity_refine(ctx, out)


def make_router(name, **kw) -> FleetRouter:
    if isinstance(name, FleetRouter):
        return name
    name = name.lower()
    if name in ("rr", "round_robin"):
        return RoundRobinRouter()
    if name in ("ll", "least_loaded"):
        return LeastLoadedRouter()
    if name.startswith("pod_bfio"):
        # pod_bfio[_pP][_hH][_affinity], e.g. pod_bfio_p16 or
        # pod_bfio_p8_h2_affinity
        for part in name[len("pod_bfio"):].split("_"):
            if not part:
                continue
            if part == "affinity":
                kw.setdefault("affinity_weight", 1.0)
            elif part[0] == "p" and part[1:].isdigit():
                kw.setdefault("pods", int(part[1:]))
            elif part[0] == "h" and part[1:].isdigit():
                kw.setdefault("H", int(part[1:]))
            else:
                raise ValueError(
                    f"unknown pod_bfio suffix {part!r} in {name!r}")
        return PodBFIORouter(**kw)
    if name.startswith("pod"):
        d = int(name[3:]) if len(name) > 3 else kw.pop("d", 2)
        return PowerOfDRouter(d=d)
    if name.startswith("bfio"):
        # bfio[_hH][_affinity]; the affinity token must be parsed
        # explicitly — startswith("bfio") would otherwise swallow
        # "bfio_affinity" into a plain BFIORouter silently
        if "affinity" in name:
            kw.setdefault("affinity_weight", 1.0)
        if "_h" in name:
            kw.setdefault("H", int(name.split("_h")[1].split("_")[0]))
        return BFIORouter(**kw)
    raise ValueError(f"unknown fleet router {name!r}")
