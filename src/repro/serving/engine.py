"""Multi-worker decode serving engine with pluggable routing.

This is the paper's system diagram (Fig. 3) as a runnable engine:

  * G decode workers (the DP shards), each with B KV-cache slots;
  * prefill produces a request's cache entry; the *router* (FCFS / JSQ /
    BF-IO / ...) assigns it to a worker — sticky thereafter;
  * every engine step decodes ONE token for all active requests on all
    workers (the barrier-synchronized step), with per-worker wall-time
    modeled as ``c + t_token * L_g`` and the step gated by max_g L_g;
  * completions free slots; the router refills them from the wait queue.

For CPU-testable end-to-end runs the workers share one jitted model and
the per-worker batches are stacked; on a production mesh the worker axis
is the "data" mesh axis (each DP shard holds its own slots) and the same
engine code drives the device-sharded batch.  The router's decision
problem is *identical* in both cases — that is the point of the paper.

Two hot-path implementations are kept in-tree (``EngineConfig.engine_mode``):

* ``"vec"`` (default) — numpy array state over the shared
  :class:`~repro.serving.slot_table.SlotTable`, one batched gather/scatter
  per cache leaf per admitted batch, and bucketed *compact decode*: only
  the active slots (rounded up to a small set of batch buckets, so jit
  recompiles stay bounded) are decoded instead of all G*B rows.
* ``"ref"`` — the original per-slot Python loops and per-request cache
  writes, kept as a live-measured regression oracle
  (``benchmarks/balancer_bench.py`` section ``engine`` times both and
  asserts stats parity).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.energy import A100_POWER, PowerModel
from ..core.metrics import step_imbalance
from ..core.policies import Policy, SchedulerContext
from ..core.workload import DriftModel, drift_for_family
from ..models import decode_fn, init_cache, prefill_fn
from .slot_table import SlotTable, cap_assignment

__all__ = ["ServeRequest", "EngineConfig", "ServingEngine"]


@dataclasses.dataclass
class ServeRequest:
    rid: int
    tokens: np.ndarray              # prompt token ids
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never stops early
    # filled by the engine:
    worker: int = -1
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = float("nan")
    t_finish: float = float("nan")

    @property
    def done(self) -> bool:
        return not np.isnan(self.t_finish)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 4              # G
    slots_per_worker: int = 8       # B
    max_seq_len: int = 256
    prefill_pad: int = 64           # prompts padded to this for prefill
    step_overhead: float = 9.775e-3
    t_token: float = 1.005e-7
    power: PowerModel = A100_POWER
    greedy: bool = True             # greedy sampling
    engine_mode: str = "vec"        # "vec" (array hot path) | "ref" (seed)


# ----------------------------------------------------------------------
# Jitted decode variants, cached at module level so engines over the same
# (cfg, mesh) share compilations (the benchmark builds many engines).
# ----------------------------------------------------------------------

def _gather_rows(cache, idx):
    """Gather cache rows ``idx``: batch is dim 0 for 1-d leaves (lengths),
    dim 1 for stacked (layers, batch, ...) leaves."""
    return jax.tree.map(
        lambda a: a[idx] if a.ndim == 1 else a[:, idx], cache)


def _scatter_rows(cache, sub, dst):
    """Write sub-batch rows back at ``dst`` (out-of-bounds entries of
    ``dst`` are dropped by JAX scatter semantics — used for padding)."""
    def put(full, part):
        if full.ndim == 1:
            return full.at[dst].set(part.astype(full.dtype))
        return full.at[:, dst].set(part.astype(full.dtype))
    return jax.tree.map(put, cache, sub)


@functools.lru_cache(maxsize=None)
def _jitted_decode(cfg: ModelConfig, mesh):
    """Seed-path decode: full G*B batch, returns (logits, cache)."""
    return jax.jit(lambda p, c, t: decode_fn(cfg, p, c, t, mesh=mesh))


@functools.lru_cache(maxsize=None)
def _jitted_decode_full(cfg: ModelConfig, mesh):
    """Full-batch decode with fused greedy sampling: (tokens, cache).

    The cache argument is donated: the caller always replaces its cache
    with the returned one, so the old buffers can be reused in place."""
    def f(p, c, t):
        logits, c2 = decode_fn(cfg, p, c, t, mesh=mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), c2
    return jax.jit(f, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _jitted_prefill(cfg: ModelConfig, mesh, max_len: int):
    """Jitted prefill (vec path; the ref path keeps the seed's eager
    prefill).  Callers bucket the batch-size dim to bound recompiles."""
    return jax.jit(functools.partial(prefill_fn, cfg, max_len=max_len,
                                     mesh=mesh))


@functools.lru_cache(maxsize=None)
def _jitted_decode_compact(cfg: ModelConfig, mesh):
    """Compact decode: gather rows ``idx`` out of the flat cache, decode
    only those, scatter the updated rows back at ``dst``.  Padding rows
    carry ``dst == N`` so their writes are dropped."""
    def f(p, cache, toks, idx, dst):
        sub = _gather_rows(cache, idx)
        logits, new_sub = decode_fn(cfg, p, sub, toks, mesh=mesh)
        return (jnp.argmax(logits, -1).astype(jnp.int32),
                _scatter_rows(cache, new_sub, dst))
    return jax.jit(f, donate_argnums=(1,))


def _decode_buckets(N: int) -> list[int]:
    """Sub-batch sizes the compact decode path may run at.  A small
    geometric ladder bounds jit recompiles while keeping the drain-phase
    decode cost proportional to the active count."""
    buckets = {N}
    b = N
    while b > 4:
        b = max(4, (b + 3) // 4)
        buckets.add(b)
    return sorted(buckets)


class ServingEngine:
    """Continuous-batching decode engine over G logical workers."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 policy: Policy, *, mesh=None, drift: DriftModel = None):
        if engine_cfg.engine_mode not in ("vec", "ref"):
            raise ValueError(
                f"engine_mode must be 'vec' or 'ref', got "
                f"{engine_cfg.engine_mode!r}")
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.policy = policy
        self.mesh = mesh
        self.drift = drift or drift_for_family(cfg.family)
        G, B = engine_cfg.n_workers, engine_cfg.slots_per_worker
        self.G, self.B = G, B
        N = G * B
        self.N = N
        # one flat cache over all slots; slot s belongs to worker s // B
        self.cache = init_cache(cfg, N, engine_cfg.max_seq_len)
        self.table = SlotTable(G, B)
        self.slot_req: list[Optional[ServeRequest]] = [None] * N
        self.slot_tokens = np.zeros(N, dtype=np.int32)   # next input token
        self.slot_load = self.table.load                 # workload proxy
        # vec-mode per-slot request scalars (mirrors of the ServeRequest
        # fields the scheduler context needs, so ctx build is one gather)
        self.slot_age = np.zeros(N, dtype=np.int64)      # len(generated)
        self.slot_max_new = np.zeros(N, dtype=np.int64)
        self.slot_eos = np.full(N, -1, dtype=np.int64)
        self.wait: list[ServeRequest] = []
        self.t_now = 0.0
        self.steps = 0
        self.energy_j = 0.0
        self.imbalance_sum = 0.0
        self.tokens_out = 0
        self.rng = np.random.default_rng(0)

        self._decode = _jitted_decode(cfg, mesh)
        self._decode_full = _jitted_decode_full(cfg, mesh)
        self._decode_compact = _jitted_decode_compact(cfg, mesh)
        self._prefill = _jitted_prefill(cfg, mesh, engine_cfg.max_seq_len)
        self._buckets = _decode_buckets(N)

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        req.t_submit = self.t_now
        self.wait.append(req)

    def _worker_of(self, slot: int) -> int:
        return slot // self.B

    def _loads(self) -> np.ndarray:
        if self.ec.engine_mode == "vec":
            return self.table.loads()
        loads = np.zeros(self.G)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                loads[self._worker_of(s)] += self.slot_load[s]
        return loads

    def _counts(self) -> np.ndarray:
        if self.ec.engine_mode == "vec":
            return self.table.counts()
        counts = np.zeros(self.G, dtype=np.int64)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                counts[self._worker_of(s)] += 1
        return counts

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Router step: assign waiting requests to free slots."""
        if not self.wait:
            return
        counts = self._counts()
        caps = self.B - counts
        if caps.sum() <= 0:
            return
        loads = self._loads()
        if self.ec.engine_mode == "vec":
            act_idx = self.table.active_indices()
            active_worker = self.table.worker[act_idx]
            active_w = self.table.load[act_idx]
            active_age = self.slot_age[act_idx]
            active_remaining = np.maximum(
                self.slot_max_new[act_idx] - active_age, 1)
        else:
            act = [(s, r) for s, r in enumerate(self.slot_req)
                   if r is not None]
            active_worker = np.array([self._worker_of(s) for s, _ in act],
                                     dtype=np.int64)
            active_w = np.array([self.slot_load[s] for s, _ in act])
            active_age = np.array([len(r.generated) for _, r in act],
                                  dtype=np.int64)
            active_remaining = np.array(
                [max(r.max_new_tokens - len(r.generated), 1)
                 for _, r in act], dtype=np.int64)
        ctx = SchedulerContext(
            k=self.steps,
            loads=loads,
            counts=counts,
            caps=caps.astype(np.int64),
            wait_prefill=np.array([len(r.tokens) for r in self.wait],
                                  dtype=np.float64),
            active_worker=active_worker,
            active_w=active_w,
            active_age=active_age,
            active_remaining=active_remaining,
            drift=self.drift,
            rng=self.rng,
        )
        # a policy may over-subscribe a worker beyond its free slots; the
        # excess requests simply keep waiting instead of crashing placement
        assignment = cap_assignment(
            np.asarray(self.policy.assign(ctx)), caps)
        to_admit: list[tuple[ServeRequest, int]] = []
        for pos, g in enumerate(assignment):
            if g >= 0:
                to_admit.append((self.wait[pos], int(g)))
        if not to_admit:
            return
        admitted = {id(r) for r, _ in to_admit}
        self.wait = [r for r in self.wait if id(r) not in admitted]
        self._prefill_batch(to_admit)

    def _prefill_batch(self, items: list[tuple["ServeRequest", int]]) -> None:
        """Run prefill for admitted requests and write their cache slots.

        Prompts longer than ``max_seq_len`` are truncated to it (the cache
        cannot hold more); the prefill pad never exceeds ``max_seq_len``.
        """
        ec = self.ec
        vec = ec.engine_mode == "vec"
        pad = min(max(ec.prefill_pad,
                      max(len(r.tokens) for r, _ in items)),
                  ec.max_seq_len)
        if vec:
            # round the pad up to a multiple of prefill_pad so the jitted
            # prefill sees few distinct sequence lengths
            pad = min(-(-pad // ec.prefill_pad) * ec.prefill_pad,
                      ec.max_seq_len)
        nb = len(items)
        # vec: bucket the batch dim too (same ladder as compact decode)
        nbp = next(b for b in self._buckets if b >= nb) if vec else nb
        toks = np.zeros((nbp, pad), dtype=np.int32)
        lens = np.zeros(nbp, dtype=np.int32)
        for i, (r, _) in enumerate(items):
            L = min(len(r.tokens), pad)
            toks[i, :L] = r.tokens[:L]
            lens[i] = L
        batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (nbp, self.cfg.patch_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (nbp, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if vec:
            logits, mini_cache = self._prefill(self.params, batch)
        else:
            logits, mini_cache = prefill_fn(self.cfg, self.params, batch,
                                            max_len=ec.max_seq_len,
                                            mesh=self.mesh)
        first = np.asarray(jnp.argmax(logits, -1), dtype=np.int32)

        # place each request into a free slot of its assigned worker
        workers = np.array([g for _, g in items], dtype=np.int64)
        if ec.engine_mode == "vec":
            slots = self.table.allocate(workers)
        else:
            slots = np.empty(nb, dtype=np.int64)
            for i, (r, g) in enumerate(items):
                free = [s for s in range(g * self.B, (g + 1) * self.B)
                        if self.slot_req[s] is None]
                if not free:
                    raise RuntimeError(
                        f"worker {g} has no free slot for request {r.rid} "
                        f"(policy assignment not capped?)")
                slots[i] = free[0]
                self.slot_req[free[0]] = r
            self.table.active[slots] = True
        for i, (r, g) in enumerate(items):
            slot = int(slots[i])
            r.worker, r.slot = g, slot
            if vec:
                self.slot_req[slot] = r  # ref set it during the free scan
            self.slot_tokens[slot] = first[i]
            self.slot_load[slot] = float(lens[i])
            self.slot_age[slot] = 1
            self.slot_max_new[slot] = r.max_new_tokens
            self.slot_eos[slot] = r.eos_id
            r.generated.append(int(first[i]))
            if np.isnan(r.t_first_token):
                r.t_first_token = self.t_now
        if ec.engine_mode == "vec":
            self._copy_cache_batch(mini_cache, np.arange(nb), slots)
        else:
            for i in range(nb):
                self._copy_cache_slot(mini_cache, i, int(slots[i]))

    def _copy_cache_batch(self, mini_cache, src: np.ndarray,
                          dst: np.ndarray) -> None:
        """Copy admitted requests' cache entries into the flat cache:
        ONE gather + scatter per cache leaf for the whole batch.

        Cache leaves are stacked (layers, batch, ...): batch is dim 1,
        except 'lengths' (batch is dim 0)."""
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)

        def copy(dst_leaf, src_leaf):
            if dst_leaf.ndim == 1:       # lengths
                return dst_leaf.at[dst].set(
                    src_leaf[src].astype(dst_leaf.dtype))
            s = src_leaf[:, src]
            if s.shape[0] != dst_leaf.shape[0]:
                raise ValueError("layer-count mismatch")
            tail = dst_leaf.shape[2:]
            if s.shape[2:] != tail:
                # mini cache may carry a shorter kv-length dim (prefill pad)
                pads = [(0, 0), (0, 0)] + [
                    (0, tail[i] - s.shape[2 + i]) for i in range(len(tail))]
                s = jnp.pad(s, pads)
            return dst_leaf.at[:, dst].set(s.astype(dst_leaf.dtype))

        self.cache = jax.tree.map(copy, self.cache, mini_cache)

    def _copy_cache_slot(self, mini_cache, src: int, dst: int) -> None:
        """Seed path: copy one request's cache entry (one dispatch per
        leaf per request — the vec path batches this)."""
        def copy(dst_leaf, src_leaf):
            if dst_leaf.ndim == 1:       # lengths
                return dst_leaf.at[dst].set(src_leaf[src])
            # (layers, batch, ...): maybe shorter kv length in mini cache
            s = src_leaf[:, src]
            if s.shape[0] != dst_leaf.shape[0]:
                raise ValueError("layer-count mismatch")
            d = dst_leaf[:, dst]
            if s.shape != d.shape:
                # pad kv length dim (dim 0 after the two indexes -> dim 0
                # of s is layers... kv len is axis 1 of s)
                pads = [(0, d.shape[i] - s.shape[i]) for i in range(s.ndim)]
                s = jnp.pad(s, pads)
            return dst_leaf.at[:, dst].set(s.astype(dst_leaf.dtype))

        self.cache = jax.tree.map(copy, self.cache, mini_cache)

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One barrier-synchronized decode step for all active requests."""
        self._admit()
        vec = self.ec.engine_mode == "vec"
        if vec:
            active_idx = self.table.active_indices()
            n_active = active_idx.size
        else:
            active = [s for s, r in enumerate(self.slot_req)
                      if r is not None]
            n_active = len(active)
        loads = self._loads()
        lmax = float(loads.max()) if n_active else 0.0
        dt = self.ec.step_overhead + self.ec.t_token * lmax
        u = loads / lmax if lmax > 0 else np.zeros(self.G)
        self.energy_j += dt * float(self.ec.power.power(u).sum())
        imb = step_imbalance(loads) if n_active else 0.0
        self.imbalance_sum += imb
        self.t_now += dt
        self.steps += 1

        if n_active:
            if vec:
                self._decode_step_vec(active_idx)
            else:
                self._decode_step_ref(active)
        return {"t": self.t_now, "active": n_active,
                "waiting": len(self.wait), "max_load": lmax,
                "imbalance": imb}

    def _decode_step_ref(self, active: list[int]) -> None:
        """Seed decode path: always decode all G*B slots, per-slot loop."""
        tokens = jnp.asarray(self.slot_tokens)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, -1), dtype=np.int32)
        for s in active:
            r = self.slot_req[s]
            tok = int(nxt[s])
            r.generated.append(tok)
            self.slot_tokens[s] = tok
            self.tokens_out += 1
            self.slot_load[s] += self.drift.increment(self.steps)
            if (len(r.generated) >= r.max_new_tokens
                    or tok == r.eos_id):
                r.t_finish = self.t_now
                self.slot_req[s] = None
                self.slot_load[s] = 0.0
                self.table.active[s] = False

    def _decode_step_vec(self, active_idx: np.ndarray) -> None:
        """Vectorized decode path: compact the active slots into the
        smallest decode bucket and run the model only on those rows."""
        n = active_idx.size
        nb = next(b for b in self._buckets if b >= n)
        if nb >= self.N:
            nxt_all, self.cache = self._decode_full(
                self.params, self.cache, jnp.asarray(self.slot_tokens))
            nxt = np.asarray(nxt_all)[active_idx]
        else:
            idx = np.zeros(nb, dtype=np.int32)
            idx[:n] = active_idx
            dst = np.full(nb, self.N, dtype=np.int32)  # pads: dropped writes
            dst[:n] = active_idx
            nxt_sub, self.cache = self._decode_compact(
                self.params, self.cache,
                jnp.asarray(self.slot_tokens[idx]),
                jnp.asarray(idx), jnp.asarray(dst))
            nxt = np.asarray(nxt_sub)[:n]

        self.slot_tokens[active_idx] = nxt
        self.slot_load[active_idx] += self.drift.increment(self.steps)
        self.slot_age[active_idx] += 1
        self.tokens_out += n
        for pos, s in enumerate(active_idx):
            self.slot_req[s].generated.append(int(nxt[pos]))
        done = ((self.slot_age[active_idx] >= self.slot_max_new[active_idx])
                | (nxt.astype(np.int64) == self.slot_eos[active_idx]))
        if done.any():
            done_idx = active_idx[done]
            for s in done_idx:
                r = self.slot_req[s]
                r.t_finish = self.t_now
                self.slot_req[s] = None
            self.table.release(done_idx)

    def run(self, max_steps: int = 10_000) -> dict:
        """Step until all submitted requests finish."""
        while self.wait or self.table.active.any():
            if self.steps >= max_steps:
                raise RuntimeError("engine exceeded max_steps")
            self.step()
        return self.stats()

    def stats(self) -> dict:
        return {
            "steps": self.steps,
            "time_s": self.t_now,
            "tokens": self.tokens_out,
            "throughput_tok_s": self.tokens_out / max(self.t_now, 1e-12),
            "energy_j": self.energy_j,
            "avg_imbalance": self.imbalance_sum / max(self.steps, 1),
            "policy": self.policy.name,
        }
