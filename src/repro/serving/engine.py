"""Multi-worker decode serving engine with pluggable routing, cache
layout, and admission scheduling.

This is the paper's system diagram (Fig. 3) as a runnable engine:

  * G decode workers (the DP shards), each with B KV-cache slots;
  * prefill produces a request's cache entry; the *router* (FCFS / JSQ /
    BF-IO / ...) assigns it to a worker — sticky thereafter;
  * every engine step decodes ONE token for all active requests on all
    workers (the barrier-synchronized step), with per-worker wall-time
    modeled as ``c + t_token * L_g`` and the step gated by max_g L_g;
  * completions free slots; the router refills them from the wait queue.

For CPU-testable end-to-end runs the workers share one jitted model and
the per-worker batches are stacked; on a production mesh the worker axis
is the "data" mesh axis (each DP shard holds its own slots) and the same
engine code drives the device-sharded batch.  The router's decision
problem is *identical* in both cases — that is the point of the paper.

``ServingEngine.step()`` is a thin driver over three seams:

* :class:`~repro.serving.scheduler.Scheduler` — wait queue, admission,
  and the chunked-prefill budget (``EngineConfig.prefill_chunk`` /
  ``prefill_budget``): with chunking on, an admission wave's prompts are
  processed a bounded number of tokens per barrier step, interleaved
  with decode, instead of stalling every active request for one huge
  synchronous prefill.
* :class:`~repro.serving.cache_backend.CacheBackend` — the memory
  layout (``EngineConfig.cache_backend``): ``"slot"`` is the contiguous
  per-slot cache (compact decode by row gather/scatter), ``"paged"`` is
  vLLM-style block paging where resident KV tracks actual tokens and
  compact decode follows block tables instead of copying rows.
* ``EngineConfig.engine_mode``: ``"vec"`` (default) is the array hot
  path over the shared :class:`~repro.serving.slot_table.SlotTable`;
  ``"ref"`` is the original per-slot Python loops and per-request cache
  writes, kept as a live-measured regression oracle
  (``benchmarks/balancer_bench.py`` sections ``engine`` and
  ``engine_paged`` time the variants and assert stats parity).

On the paged backend the engine also drives the memory-pressure
subsystem (:mod:`repro.serving.preemption`): admission is gated on free
pool blocks, every growth path (decode block crossings, copy-on-write,
prefill chunks) pre-declares its block demand and victims are preempted
— swapped host-side or dropped for recompute-on-resume — until it fits
(``EngineConfig.preemption_mode`` / ``preemption_policy``), and
``EngineConfig.prefix_cache`` shares identical prompt-prefix blocks
across concurrent requests.  ``benchmarks/balancer_bench.py`` section
``engine_preempt`` and ``tests/test_preemption.py`` gate the invariants
(completion under a half-sized pool, bit-identical swap generations,
refcount drain, hit-rate with unchanged outputs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.energy import A100_POWER, PowerModel
from ..core.metrics import step_imbalance
from ..core.policies import Policy, SchedulerContext
from ..core.workload import DriftModel, drift_for_family
from ..models import decode_fn, prefill_fn, supports_paged_stack
from ..obs.trace import NULL_RECORDER
from .cache_backend import make_cache_backend
from .preemption import (
    PreemptContext,
    PreemptedState,
    make_preemption_policy,
)
from .scheduler import Scheduler
from .slot_table import SlotTable

__all__ = ["ServeRequest", "EngineConfig", "ServingEngine"]


@dataclasses.dataclass
class ServeRequest:
    rid: int
    tokens: np.ndarray              # prompt token ids
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never stops early
    # filled by the engine:
    worker: int = -1
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = float("nan")
    t_finish: float = float("nan")
    # per-request completion/error channel: "queued" -> "active" ->
    # "done" | "failed" (a preempted request returns to "queued").  A
    # failure (``error`` set) is terminal for THIS request only — the
    # engine keeps serving the rest of the stream; callers (and the
    # fleet router) read ``status``/``error`` instead of catching
    # engine-wide exceptions.
    status: str = "queued"
    error: Optional[str] = None
    # set while the request sits preempted in the wait queue (swap-staged
    # KV or recompute bookkeeping, see serving/preemption.py); None once
    # (re-)admitted
    preempted: Optional[PreemptedState] = None
    # memoized chained content-hash triples of the full prompt, keyed by
    # block size: the fleet's prefix-affinity probe computes the chain
    # at routing and admission reuses it instead of re-hashing
    # (PrefixIndex.keys_for); valid because `tokens` is immutable after
    # submission
    prefix_keys: dict = dataclasses.field(default_factory=dict)

    @property
    def done(self) -> bool:
        return not np.isnan(self.t_finish)

    @property
    def failed(self) -> bool:
        return self.error is not None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 4              # G
    slots_per_worker: int = 8       # B
    max_seq_len: int = 256
    prefill_pad: int = 64           # prompts padded to this for prefill
    step_overhead: float = 9.775e-3
    t_token: float = 1.005e-7
    power: PowerModel = A100_POWER
    greedy: bool = True             # greedy sampling
    engine_mode: str = "vec"        # "vec" (array hot path) | "ref" (seed)
    cache_backend: str = "slot"     # "slot" (contiguous) | "paged" (vLLM)
    # chunked prefill: 0 = synchronous (a request's whole prompt prefills
    # in its admission step); c > 0 = at most c prompt tokens per job per
    # step, interleaved with decode.  Setting only prefill_budget also
    # turns chunking on, with chunk == budget.
    prefill_chunk: int = 0
    prefill_budget: int = 0         # total prompt tokens/step (0 -> chunk)
    # paged-backend knobs
    paged_block_size: int = 16      # tokens per KV block (divides max_seq)
    paged_pool_blocks: int = 0      # 0 -> capacity for all slots at max_seq
    paged_attn_impl: str = "gather"  # "gather" | "ref" | "pallas"
    # memory pressure (paged backend): when the block pool cannot serve a
    # growth/admission request, a victim is preempted instead of raising
    # MemoryError.  "swap" stages the victim's blocks host-side and
    # restores them bit-for-bit on resume; "recompute" drops them and
    # re-prefills prompt + generated tokens through the (chunked) prefill
    # path.  The victim re-enters the wait queue at the front with its
    # generated tokens preserved.  preemption_policy picks the victim
    # ("lifo" default / "fifo" / "largest", see serving/preemption.py).
    preemption_mode: str = "swap"   # "swap" | "recompute"
    preemption_policy: str = "lifo"
    # prefix caching (paged backend): share identical prompt-prefix KV
    # blocks across requests via a content-hash index, copy-on-write on
    # the first divergent append.  Chunked admissions consult the index
    # too: leading full-block hits are pinned copy-free and the chunk
    # job starts past them, skipping recompute of the hit prefix.
    prefix_cache: bool = False
    # eviction lifetime of the prefix index: "lru" (default) retains
    # refcount-0 indexed blocks on an LRU cached list, reclaimed only
    # when the free list runs dry — hits survive their last resident
    # holder (multi-turn sessions); "admission" is the legacy scope:
    # entries die with the last holder's release.
    prefix_evict: str = "lru"


# ----------------------------------------------------------------------
# Jitted model entry points kept at engine level (the ref decode path and
# prefill are scheduling concerns, not cache-layout concerns); cached at
# module level so engines over the same (cfg, mesh) share compilations.
# ----------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted_decode(cfg: ModelConfig, mesh):
    """Seed-path decode: full G*B batch, returns (logits, cache)."""
    return jax.jit(lambda p, c, t: decode_fn(cfg, p, c, t, mesh=mesh))


@functools.lru_cache(maxsize=None)
def _jitted_prefill(cfg: ModelConfig, mesh, max_len: int):
    """Jitted prefill (vec path; the ref path keeps the seed's eager
    prefill).  Callers bucket the batch-size dim to bound recompiles."""
    return jax.jit(functools.partial(prefill_fn, cfg, max_len=max_len,
                                     mesh=mesh))


def _decode_buckets(N: int) -> list[int]:
    """Sub-batch sizes the compact decode path may run at.  A small
    geometric ladder bounds jit recompiles while keeping the drain-phase
    decode cost proportional to the active count."""
    buckets = {N}
    b = N
    while b > 4:
        b = max(4, (b + 3) // 4)
        buckets.add(b)
    return sorted(buckets)


@dataclasses.dataclass(frozen=True)
class LoadSnapshot:
    """One engine's load state as seen by a fleet router — the public
    seam between :class:`ServingEngine` and the fleet layer, so routing
    and per-step fleet bookkeeping never reach into engine internals
    (``_loads()``, ``_req_cost()``, ``scheduler.wait``)."""

    resident_load: float   # sum of per-worker resident KV loads
    wait_cost: float       # summed prefill-size proxy of waiting requests
    active: int            # occupied slots
    waiting: int           # requests queued at this engine
    free_slots: int        # N - active
    tokens_out: int        # cumulative generated tokens
    preemptions: int       # cumulative preemption count
    prefix_hits: int       # cumulative prefix-cache hits
    prefix_cached_blocks: int = 0   # refcount-0 blocks on the LRU list
    prefix_revived: int = 0         # cumulative cached-block revivals

    @property
    def committed_load(self) -> float:
        """Resident plus queued load — what a router should balance."""
        return self.resident_load + self.wait_cost

    @property
    def committed_count(self) -> int:
        return self.active + self.waiting

    @property
    def busy(self) -> bool:
        return self.active > 0 or self.waiting > 0


class ServingEngine:
    """Continuous-batching decode engine over G logical workers."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 policy: Policy, *, mesh=None, drift: DriftModel = None,
                 obs=None, obs_replica: int = 0):
        ec = engine_cfg
        if ec.engine_mode not in ("vec", "ref"):
            raise ValueError(
                f"engine_mode must be 'vec' or 'ref', got "
                f"{ec.engine_mode!r}")
        # a budget alone turns chunking on (one chunk == the budget)
        chunk = ec.prefill_chunk or ec.prefill_budget
        if ec.engine_mode == "ref" and (ec.cache_backend != "slot"
                                        or chunk):
            raise ValueError(
                "engine_mode='ref' is the seed oracle: it supports only "
                "cache_backend='slot' with synchronous prefill")
        if chunk and (cfg.family not in ("dense", "moe")
                      or not supports_paged_stack(cfg)):
            raise ValueError(
                "chunked prefill needs a homogeneous attention decoder "
                "without a sliding window whose prompt embeds tokens "
                f"only (dense/moe); got family={cfg.family!r} "
                f"sliding_window={cfg.sliding_window}")
        if ec.preemption_mode not in ("swap", "recompute"):
            raise ValueError(
                f"preemption_mode must be 'swap' or 'recompute', got "
                f"{ec.preemption_mode!r}")
        if ec.prefix_cache and ec.cache_backend != "paged":
            raise ValueError(
                "prefix_cache=True needs cache_backend='paged' (the "
                "contiguous slot layout has no shareable blocks)")
        if ec.prefix_evict not in ("lru", "admission"):
            raise ValueError(
                f"prefix_evict must be 'lru' or 'admission', got "
                f"{ec.prefix_evict!r}")
        self.cfg = cfg
        self.params = params
        self.ec = ec
        self.policy = policy
        self.mesh = mesh
        self.drift = drift or drift_for_family(cfg.family)
        G, B = ec.n_workers, ec.slots_per_worker
        self.G, self.B = G, B
        N = G * B
        self.N = N
        self.backend = make_cache_backend(ec.cache_backend, cfg, params,
                                          ec, mesh)
        self._paged = ec.cache_backend == "paged"
        self.scheduler = Scheduler(
            policy, prefill_chunk=min(chunk, ec.max_seq_len),
            prefill_budget=ec.prefill_budget,
            preemption=make_preemption_policy(ec.preemption_policy))
        self.table = SlotTable(G, B)
        self.slot_req: list[Optional[ServeRequest]] = [None] * N
        self.slot_tokens = np.zeros(N, dtype=np.int32)   # next input token
        self.slot_load = self.table.load                 # workload proxy
        # vec-mode per-slot request scalars (mirrors of the ServeRequest
        # fields the scheduler context needs, so ctx build is one gather)
        self.slot_age = np.zeros(N, dtype=np.int64)      # len(generated)
        self.slot_max_new = np.zeros(N, dtype=np.int64)
        self.slot_eos = np.full(N, -1, dtype=np.int64)
        # monotonic admission order per slot (LIFO victim selection)
        self.slot_admit_seq = np.zeros(N, dtype=np.int64)
        self._admit_seq = 0
        self.t_now = 0.0
        self.steps = 0
        self.energy_j = 0.0
        self.imbalance_sum = 0.0
        self.tokens_out = 0
        self.kv_peak_bytes = 0
        # memory-pressure accounting (paged backend)
        self.requests_failed = 0
        self.preemptions = 0
        self.tokens_swapped = 0      # KV tokens staged host-side
        self.tokens_recomputed = 0   # KV tokens dropped for re-prefill
        self.rng = np.random.default_rng(0)
        # span recorder (repro.obs): NULL_RECORDER is a no-op, so an
        # untraced run buffers nothing and stays bit-identical;
        # obs_replica is this engine's trace track (fleet replica id)
        self._obs_rec = obs if obs is not None else NULL_RECORDER
        self._obs_replica = int(obs_replica)

        self._decode = _jitted_decode(cfg, mesh)
        self._prefill = _jitted_prefill(cfg, mesh, ec.max_seq_len)
        self._buckets = _decode_buckets(N)

    # ------------------------------------------------------------------
    @property
    def cache(self):
        """The slot backend's flat cache pytree (ref-path and test
        access); the paged backend holds pools instead."""
        return self.backend.cache

    @cache.setter
    def cache(self, value):
        self.backend.cache = value

    @property
    def wait(self) -> list:
        return self.scheduler.wait

    def submit(self, req: ServeRequest) -> None:
        """Queue a request.  On the paged backend, a prompt whose KV can
        never fit the block pool — even with every other request
        preempted — is rejected here instead of surfacing as a
        ``MemoryError`` (or an admission livelock) mid-prefill."""
        if self._paged:
            L = min(len(req.tokens), self.ec.max_seq_len)
            need = self.backend.blocks_for(L)
            if need > self.backend.n_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt of {L} tokens needs "
                    f"{need} KV blocks but the pool holds only "
                    f"{self.backend.n_blocks} "
                    f"(block_size={self.backend.block_size}) — it can "
                    "never be admitted")
        req.t_submit = self.t_now
        req.status = "queued"
        if self._obs_rec.enabled:
            self._obs_rec.point(self._obs_replica, req.rid, "queued",
                                self.t_now, n_prompt=len(req.tokens))
        self.scheduler.submit(req)

    def _worker_of(self, slot: int) -> int:
        return slot // self.B

    def _loads(self) -> np.ndarray:
        if self.ec.engine_mode == "vec":
            return self.table.loads()
        loads = np.zeros(self.G)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                loads[self._worker_of(s)] += self.slot_load[s]
        return loads

    def _counts(self) -> np.ndarray:
        if self.ec.engine_mode == "vec":
            return self.table.counts()
        counts = np.zeros(self.G, dtype=np.int64)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                counts[self._worker_of(s)] += 1
        return counts

    def load_snapshot(self) -> LoadSnapshot:
        """Cheap public summary of this engine's load state (see
        :class:`LoadSnapshot`).  Both fleet modes route and account from
        these values, which keeps ``fleet_mode="ref"`` and ``"vec"``
        bit-identical: identical inputs feed identical arithmetic."""
        wait = self.wait
        active = int(self.table.active.sum())
        prefix = getattr(self.backend, "prefix", None)
        alloc = getattr(getattr(self.backend, "kv", None),
                        "allocator", None)
        return LoadSnapshot(
            resident_load=float(self._loads().sum()),
            wait_cost=float(sum(self._req_cost(r) for r in wait)),
            active=active,
            waiting=len(wait),
            free_slots=self.N - active,
            tokens_out=self.tokens_out,
            preemptions=self.preemptions,
            prefix_hits=prefix.hits if prefix is not None else 0,
            prefix_cached_blocks=alloc.n_cached if alloc else 0,
            prefix_revived=alloc.blocks_revived if alloc else 0,
        )

    # ------------------------------------------------------------------
    def _admit_tokens(self, r: "ServeRequest") -> np.ndarray:
        """Token sequence a (re-)admission must prefill: the truncated
        prompt, or — for a recompute-on-resume request — the prompt plus
        every generated token except the last (which is the pending
        decode input, preserved in ``r.preempted.next_token``)."""
        prompt = np.asarray(r.tokens, dtype=np.int64)[:self.ec.max_seq_len]
        if r.preempted is not None:
            toks = np.concatenate(
                [prompt, np.asarray(r.generated[:-1], dtype=np.int64)])
            return toks[:self.ec.max_seq_len].astype(np.int32)
        return prompt.astype(np.int32)

    def _admit_len(self, r: "ServeRequest") -> int:
        """len(:meth:`_admit_tokens`) without materializing the array —
        called per waiting request per admission step (block gating)."""
        L = min(len(r.tokens), self.ec.max_seq_len)
        if r.preempted is not None:
            L = min(L + max(len(r.generated) - 1, 0),
                    self.ec.max_seq_len)
        return L

    def _req_cost(self, r: "ServeRequest") -> float:
        """Prefill-size proxy a routing policy sees for a waiting
        request (resumed victims bring their resident KV length)."""
        if r.preempted is not None:
            return float(r.preempted.length)
        return float(len(r.tokens))

    def _blocks_needed(self, r: "ServeRequest") -> int:
        """KV blocks admission must be able to allocate for ``r``."""
        if r.preempted is not None and r.preempted.mode == "swap":
            return r.preempted.n_blocks
        return self.backend.blocks_for(self._admit_len(r))

    def _prefix_chain(self, r: "ServeRequest", toks) -> Optional[list]:
        """Memoized chained content-hash triples for an admission's
        token sequence, shared with the fleet's prefix-affinity probe
        via ``ServeRequest.prefix_keys`` — the probe hashes the prompt
        at routing and admission reuses the chain instead of re-hashing
        it.  Only a full untruncated prompt is cacheable (resume
        sequences and truncations hash different content); those fall
        back to ``keys_for`` inside the backend (chain=None)."""
        prefix = getattr(self.backend, "prefix", None)
        if prefix is None or len(toks) != len(r.tokens):
            return None
        bs = int(self.backend.block_size)
        chain = r.prefix_keys.get(bs)
        if chain is None:
            chain = prefix.keys_for(toks, bs)
            r.prefix_keys[bs] = chain
        return chain

    def _admit(self) -> tuple[int, int]:
        """Router step: assign waiting requests to free slots; returns
        ``(fresh, resumed)`` admission counts (the step-phase signal the
        straggler attribution classifies barrier slack by)."""
        if not self.wait:
            return 0, 0
        counts = self._counts()
        caps = self.B - counts
        if caps.sum() <= 0:
            return 0, 0
        loads = self._loads()
        if self.ec.engine_mode == "vec":
            act_idx = self.table.active_indices()
            active_worker = self.table.worker[act_idx]
            active_w = self.table.load[act_idx]
            active_age = self.slot_age[act_idx]
            active_remaining = np.maximum(
                self.slot_max_new[act_idx] - active_age, 1)
            prefill_remaining = self.table.prefill_left[act_idx]
        else:
            act = [(s, r) for s, r in enumerate(self.slot_req)
                   if r is not None]
            active_worker = np.array([self._worker_of(s) for s, _ in act],
                                     dtype=np.int64)
            active_w = np.array([self.slot_load[s] for s, _ in act])
            active_age = np.array([len(r.generated) for _, r in act],
                                  dtype=np.int64)
            active_remaining = np.array(
                [max(r.max_new_tokens - len(r.generated), 1)
                 for _, r in act], dtype=np.int64)
            prefill_remaining = np.zeros(len(act), dtype=np.int64)
        ctx = SchedulerContext(
            k=self.steps,
            loads=loads,
            counts=counts,
            caps=caps.astype(np.int64),
            wait_prefill=np.array([self._req_cost(r) for r in self.wait],
                                  dtype=np.float64),
            active_worker=active_worker,
            active_w=active_w,
            active_age=active_age,
            active_remaining=active_remaining,
            drift=self.drift,
            rng=self.rng,
            active_prefill_remaining=prefill_remaining,
        )
        gate = {}
        if self._paged:
            # admit only what the pool can hold after reserving this
            # step's decode growth — admission itself never preempts, so
            # a wave larger than the free pool degrades to waiting
            budget = (self.backend.free_blocks
                      - self.backend.decode_block_demand(
                          self.table.decode_indices()))
            gate = dict(block_budget=max(int(budget), 0),
                        blocks_of=self._blocks_needed)
        to_admit = self.scheduler.admit(ctx, caps, **gate)
        if not to_admit:
            return 0, 0
        resumed = [(r, g) for r, g in to_admit
                   if r.preempted is not None
                   and r.preempted.mode == "swap"]
        fresh = [(r, g) for r, g in to_admit
                 if r.preempted is None or r.preempted.mode != "swap"]
        if resumed:
            self._resume_swapped(resumed)
        if not fresh:
            return 0, len(resumed)
        if self.scheduler.chunked:
            # empty prompts have no chunk work to schedule; the
            # synchronous path already handles them (prefill over an
            # all-padding row), so route them there
            empty = [(r, g) for r, g in fresh if self._admit_len(r) == 0]
            chunked = [(r, g) for r, g in fresh if self._admit_len(r) > 0]
            if chunked:
                self._admit_chunked(chunked)
            if empty:
                self._prefill_batch(empty)
        else:
            self._prefill_batch(fresh)
        return len(fresh), len(resumed)

    def _admit_chunked(self, items: list[tuple["ServeRequest", int]]) -> None:
        """Chunked admission: claim slots and register prefill jobs; no
        model work happens here — chunks run under the per-step budget.
        Recompute-on-resume requests re-prefill prompt + generated tokens
        with their pending decode token carried on the job.  With the
        prefix cache on, a fresh prompt's leading full-block hits are
        pinned copy-free and the job starts *past* them
        (``CacheBackend.seed_chunk_prefix``) — the hit prefix is neither
        re-stored nor recomputed."""
        workers = np.array([g for _, g in items], dtype=np.int64)
        slots = self.table.allocate(workers)
        for i, (r, g) in enumerate(items):
            slot = int(slots[i])
            # first admission of this request?  A preempt-restarted job
            # re-seeds below, but re-counting its lookup would
            # double-count the admission in the hit-rate denominators
            first_admit = int(r.slot) < 0
            r.worker, r.slot = g, slot
            r.status = "active"
            self.slot_req[slot] = r
            self.slot_age[slot] = 0
            self.slot_max_new[slot] = r.max_new_tokens
            self.slot_eos[slot] = r.eos_id
            self.slot_admit_seq[slot] = self._admit_seq
            self._admit_seq += 1
            toks = self._admit_tokens(r)
            resume_token = resume_length = None
            done = 0
            if r.preempted is not None:
                resume_token = int(r.preempted.next_token)
                resume_length = int(r.preempted.length)
                r.preempted = None
                if self._obs_rec.enabled:
                    self._obs_rec.point(self._obs_replica, r.rid,
                                        "resumed", self.t_now,
                                        slot=slot, mode="recompute")
            elif self._paged and self.backend.prefix is not None:
                done = self.backend.seed_chunk_prefix(
                    slot, toks, count=first_admit,
                    chain=self._prefix_chain(r, toks))
            if self._obs_rec.enabled and resume_token is None:
                self._obs_rec.point(self._obs_replica, r.rid,
                                    "admitted", self.t_now,
                                    worker=g, slot=slot, seeded=done)
            self.slot_load[slot] = float(done)
            self.table.prefill_left[slot] = len(toks) - done
            self.scheduler.register_job(slot, r, toks, done=done,
                                        seeded=done,
                                        resume_token=resume_token,
                                        resume_length=resume_length)

    def _resume_swapped(self, items: list[tuple["ServeRequest", int]]) -> None:
        """Re-admit swap-preempted requests: claim a slot, restore the
        host-staged KV blocks bit-for-bit, and continue exactly where the
        victim stopped — decoding from its pending token, or its chunked
        prefill job at the preserved offset.  No model work runs here."""
        workers = np.array([g for _, g in items], dtype=np.int64)
        slots = self.table.allocate(workers)
        for i, (r, g) in enumerate(items):
            slot = int(slots[i])
            st = r.preempted
            self.backend.swap_in(slot, st)
            if self._obs_rec.enabled:
                self._obs_rec.point(self._obs_replica, r.rid, "resumed",
                                    self.t_now, slot=slot, mode="swap")
            r.worker, r.slot = g, slot
            r.status = "active"
            self.slot_req[slot] = r
            self.slot_max_new[slot] = r.max_new_tokens
            self.slot_eos[slot] = r.eos_id
            self.slot_admit_seq[slot] = self._admit_seq
            self._admit_seq += 1
            if st.prefill_done >= 0:      # victim was mid-prefill
                self.slot_load[slot] = float(st.prefill_done)
                self.slot_age[slot] = 0
                self.table.prefill_left[slot] = \
                    len(st.prefill_tokens) - st.prefill_done
                self.scheduler.register_job(
                    slot, r, st.prefill_tokens, done=st.prefill_done,
                    resume_token=st.resume_token,
                    resume_length=st.resume_length)
            else:                         # victim was decoding
                self.slot_load[slot] = float(st.length)
                self.slot_tokens[slot] = int(st.next_token)
                self.slot_age[slot] = len(r.generated)
            r.preempted = None

    # -- memory pressure ------------------------------------------------
    def _preempt_one(self) -> bool:
        """Free pool capacity by preempting one victim (chosen by the
        scheduler's preemption policy); False when no active request is
        left to preempt."""
        cand = self.table.active_indices()
        if cand.size == 0:
            return False
        kv = self.backend.kv
        ctx = PreemptContext(
            slots=cand,
            admit_seq=self.slot_admit_seq[cand],
            kv_tokens=kv.lengths[cand].astype(np.int64),
            blocks_held=np.array(
                [len(kv.req_blocks.get(int(s), [])) for s in cand],
                dtype=np.int64),
            prefilling=self.table.prefill_left[cand] > 0)
        victim = self.scheduler.select_victim(ctx)
        if victim is None:
            return False
        self._preempt_slot(int(victim))
        return True

    def _preempt_slot(self, slot: int) -> None:
        """Evict the request on ``slot``: swap its KV host-side or drop
        it for recompute, preserve the generated tokens, and requeue the
        request at the front of the wait queue."""
        r = self.slot_req[slot]
        job = self.scheduler.drop_job(slot)
        L = int(self.backend.kv.lengths[slot])
        if self.ec.preemption_mode == "swap":
            state = self.backend.swap_out(slot)
            self.tokens_swapped += L
            if job is not None:           # mid-prefill: resume the job
                state.prefill_done = job.done
                state.prefill_tokens = job.tokens
                state.resume_token = job.resume_token
                state.resume_length = job.resume_length
            else:
                state.next_token = int(self.slot_tokens[slot])
            r.preempted = state
        else:
            self.backend.discard(slot)
            # seeded prefix tokens were pinned copy-free, never computed
            # — dropping them forces no recompute
            self.tokens_recomputed += (job.done - job.seeded) \
                if job is not None else L
            if job is not None and job.resume_token is None:
                r.preempted = None        # plain prompt: restart prefill
            elif job is not None:         # re-preempted mid-rebuild
                r.preempted = PreemptedState(
                    mode="recompute",
                    length=job.resume_length or len(job.tokens),
                    next_token=int(job.resume_token))
            else:
                r.preempted = PreemptedState(
                    mode="recompute", length=L,
                    next_token=int(self.slot_tokens[slot]))
        self.slot_req[slot] = None
        self.table.release(np.asarray([slot]))
        r.status = "queued"
        self.scheduler.requeue(r)
        self.preemptions += 1
        if self._obs_rec.enabled:
            self._obs_rec.point(self._obs_replica, r.rid, "preempted",
                                self.t_now, slot=slot,
                                mode=self.ec.preemption_mode)

    def drain(self) -> list:
        """Evict everything this engine holds for fleet-tier re-routing
        (the async fleet's scale-down path): every paged resident is
        preempted through the configured preemption path — ``"swap"``
        stages its KV host-side so the receiving replica restores it
        bit-for-bit — then the wait queue is handed off in order.
        Residents leave in admission order so the handoff sequence is
        deterministic.  The slot backend has no swap machinery, so its
        drain hands off only queued work and residents finish in place.
        Returns the evicted requests, oldest first."""
        handoff = []
        if self._paged:
            order = self.table.active_indices()
            order = order[np.argsort(self.slot_admit_seq[order],
                                     kind="stable")]
            for slot in order:
                handoff.append(self._free(int(slot)))
        while self.scheduler.wait:
            handoff.append(self.scheduler.wait.pop(0))
        return handoff

    def _free(self, slot: int) -> "ServeRequest":
        """Drain-path eviction of one resident: the preempt path stages
        its KV (swap mode) and releases the pool blocks, then the victim
        is popped straight back off the wait queue (``requeue``
        front-inserts it) so the caller can hand it to another
        replica."""
        self._preempt_slot(slot)
        return self.scheduler.wait.pop(0)

    def _fail_slot(self, slot: int, msg: str) -> None:
        """Per-request failure channel: mark the request on ``slot``
        failed (``status``/``error``), release its slot and KV, and keep
        the rest of the stream serving.  The seed engine raised here and
        killed the whole step; a fleet router needs the error surfaced
        per request so one doomed request cannot take down its replica."""
        r = self.slot_req[slot]
        self.scheduler.drop_job(slot)
        r.error = msg
        r.status = "failed"
        r.t_finish = self.t_now
        r.preempted = None
        self.slot_req[slot] = None
        self.table.release(np.asarray([slot]))
        self.backend.release(np.asarray([slot]))
        self.requests_failed += 1
        if self._obs_rec.enabled:
            self._obs_rec.point(self._obs_replica, r.rid, "failed",
                                self.t_now)

    def _ensure_decode_capacity(self) -> None:
        """Preempt until the pool can serve this step's decode growth
        (boundary crossings + copy-on-write blocks).  Preempting shrinks
        the decode set, so demand is recomputed after every victim.

        A slot already holding the *entire* pool that still needs to
        grow can never be served — preempting it would only requeue it
        into an identical dead end (admit, grow back, self-preempt,
        repeat until ``max_steps``).  That request alone *fails*
        (``status="failed"``, ``error`` set, KV released) and everything
        else keeps serving — the seed raised ``MemoryError`` here and
        killed the engine step."""
        kv = self.backend.kv
        while True:
            decode_idx = self.table.decode_indices()
            need = self.backend.decode_block_demand(decode_idx)
            if need <= self.backend.free_blocks:
                return
            failed_one = False
            for s in decode_idx:
                s = int(s)
                held = len(kv.req_blocks.get(s, []))
                if (held + 1 > self.backend.n_blocks
                        and kv.append_demand(np.asarray([s])) > 0):
                    r = self.slot_req[s]
                    self._fail_slot(s, (
                        f"request {r.rid}: resident KV ({held} blocks) "
                        f"plus one growth block exceeds the entire pool "
                        f"({self.backend.n_blocks} blocks) — preemption "
                        "cannot help; size the pool for at least one "
                        "full request (prompt + max_new_tokens)"))
                    failed_one = True
            if failed_one:
                continue        # demand changed; re-evaluate before preempting
            if not self._preempt_one():
                raise MemoryError(
                    f"KV pool exhausted with no preemptable victim: "
                    f"decode growth needs {need} blocks, "
                    f"{self.backend.free_blocks} free of "
                    f"{self.backend.n_blocks}")

    def _run_chunks(self) -> int:
        """Advance mid-prefill jobs by at most the step budget; returns
        the number of prompt tokens processed this step.  On the paged
        backend, capacity for the planned chunks is secured *first* by
        preempting victims (a preempted victim may itself be a planned
        job, so the plan is rebuilt after every preemption)."""
        plan = self.scheduler.plan_chunks()
        if self._paged:
            while True:
                need = self.backend.chunk_block_demand(plan)
                if need <= self.backend.free_blocks:
                    break
                if not self._preempt_one():
                    raise MemoryError(
                        f"KV pool exhausted with no preemptable victim: "
                        f"prefill chunks need {need} blocks, "
                        f"{self.backend.free_blocks} free of "
                        f"{self.backend.n_blocks}")
                plan = self.scheduler.plan_chunks()
        if not plan:
            return 0
        rows = len(plan)
        nbp = next(b for b in self._buckets if b >= rows)
        C = self.scheduler.chunk
        toks = np.zeros((nbp, C), dtype=np.int32)
        offs = np.zeros(nbp, dtype=np.int32)
        clens = np.zeros(nbp, dtype=np.int32)
        slots = np.full(nbp, -1, dtype=np.int64)
        for j, (slot, off, n) in enumerate(plan):
            job = self.scheduler.job(slot)
            toks[j, :n] = job.tokens[off:off + n]
            offs[j], clens[j], slots[j] = off, n, slot
        logits = self.backend.prefill_chunk(toks, offs, clens, slots)
        total = 0
        for j, (slot, off, n) in enumerate(plan):
            total += n
            job = self.scheduler.job(slot)
            if self._obs_rec.enabled:
                self._obs_rec.point(self._obs_replica,
                                    self.slot_req[slot].rid,
                                    "prefill-chunk", self.t_now,
                                    slot=slot, offset=off, tokens=n)
            finished = self.scheduler.advance(slot, n)
            done = off + n
            self.slot_load[slot] = float(done)
            self.table.prefill_left[slot] = 0 if finished else \
                job.remaining
            if finished:
                r = self.slot_req[slot]
                if job.resume_token is not None:
                    # recompute-on-resume rebuild: the next decode input
                    # was generated before the preemption — no fresh
                    # first token is sampled
                    self.slot_tokens[slot] = int(job.resume_token)
                    self.slot_age[slot] = len(r.generated)
                    if (job.resume_length is not None
                            and job.resume_length > done):
                        # the victim had decoded past max_seq_len on
                        # frozen KV: keep its RoPE position counter
                        # instead of restarting it at the cap
                        self.backend.kv.set_length(slot,
                                                   job.resume_length)
                    continue
                if self._paged and self.backend.prefix is not None:
                    # index the finished prompt's blocks for later
                    # arrivals (sync admissions register at write_prefill;
                    # chunked jobs allocate lazily, so register here)
                    self.backend.register_chunk_prefix(
                        slot, job.tokens,
                        chain=self._prefix_chain(r, job.tokens))
                first = int(np.argmax(logits[j]))
                self.slot_tokens[slot] = first
                self.slot_age[slot] = 1
                r.generated.append(first)
                if np.isnan(r.t_first_token):
                    r.t_first_token = self.t_now
                    if self._obs_rec.enabled:
                        self._obs_rec.point(self._obs_replica, r.rid,
                                            "decode", self.t_now,
                                            slot=slot)
                if (len(r.generated) >= r.max_new_tokens
                        or first == r.eos_id):
                    self._finish_at_prefill(slot, r)
        return total

    def _finish_at_prefill(self, slot: int, r: "ServeRequest") -> None:
        """A request whose budget (or eos) is already met by its first
        token completes at prefill instead of burning a decode step on a
        token past its budget."""
        r.t_finish = self.t_now
        r.status = "done"
        self.slot_req[slot] = None
        self.table.release(np.asarray([slot]))
        self.backend.release(np.asarray([slot]))
        if self._obs_rec.enabled:
            self._obs_rec.point(self._obs_replica, r.rid, "completed",
                                self.t_now, n_generated=len(r.generated))

    def _prefill_batch(self, items: list[tuple["ServeRequest", int]]) -> None:
        """Run prefill for admitted requests and write their cache slots.

        Prompts longer than ``max_seq_len`` are truncated to it (the cache
        cannot hold more); the prefill pad never exceeds ``max_seq_len``.
        """
        ec = self.ec
        vec = ec.engine_mode == "vec"
        seqs = [self._admit_tokens(r) for r, _ in items]
        pad = min(max(ec.prefill_pad, max(len(t) for t in seqs)),
                  ec.max_seq_len)
        if vec:
            # round the pad up to a multiple of prefill_pad so the jitted
            # prefill sees few distinct sequence lengths
            pad = min(-(-pad // ec.prefill_pad) * ec.prefill_pad,
                      ec.max_seq_len)
        nb = len(items)
        # vec: bucket the batch dim too (same ladder as compact decode)
        nbp = next(b for b in self._buckets if b >= nb) if vec else nb
        toks = np.zeros((nbp, pad), dtype=np.int32)
        lens = np.zeros(nbp, dtype=np.int32)
        for i, t in enumerate(seqs):
            L = min(len(t), pad)
            toks[i, :L] = t[:L]
            lens[i] = L
        batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (nbp, self.cfg.patch_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (nbp, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if vec:
            logits, mini_cache = self._prefill(self.params, batch)
        else:
            logits, mini_cache = prefill_fn(self.cfg, self.params, batch,
                                            max_len=ec.max_seq_len,
                                            mesh=self.mesh)
        first = np.asarray(jnp.argmax(logits, -1), dtype=np.int32)

        # place each request into a free slot of its assigned worker
        workers = np.array([g for _, g in items], dtype=np.int64)
        if ec.engine_mode == "vec":
            slots = self.table.allocate(workers)
        else:
            slots = np.empty(nb, dtype=np.int64)
            for i, (r, g) in enumerate(items):
                free = [s for s in range(g * self.B, (g + 1) * self.B)
                        if self.slot_req[s] is None]
                if not free:
                    raise RuntimeError(
                        f"worker {g} has no free slot for request {r.rid} "
                        f"(policy assignment not capped?)")
                slots[i] = free[0]
                self.slot_req[free[0]] = r
            self.table.active[slots] = True
        done_slots = []
        length_fix = []
        for i, (r, g) in enumerate(items):
            slot = int(slots[i])
            r.worker, r.slot = g, slot
            r.status = "active"
            if vec:
                self.slot_req[slot] = r  # ref set it during the free scan
            self.slot_load[slot] = float(lens[i])
            self.slot_max_new[slot] = r.max_new_tokens
            self.slot_eos[slot] = r.eos_id
            self.slot_admit_seq[slot] = self._admit_seq
            self._admit_seq += 1
            if r.preempted is not None:
                # recompute-on-resume: KV rebuilt, the pending decode
                # input was generated before the preemption
                self.slot_tokens[slot] = int(r.preempted.next_token)
                self.slot_age[slot] = len(r.generated)
                if r.preempted.length > int(lens[i]):
                    # victim had decoded past max_seq_len on frozen KV:
                    # restore its RoPE position counter after the
                    # backend re-admits the slot below
                    length_fix.append((slot, int(r.preempted.length)))
                r.preempted = None
                if self._obs_rec.enabled:
                    self._obs_rec.point(self._obs_replica, r.rid,
                                        "resumed", self.t_now,
                                        slot=slot, mode="recompute")
                continue
            if self._obs_rec.enabled:
                self._obs_rec.point(self._obs_replica, r.rid,
                                    "admitted", self.t_now,
                                    worker=g, slot=slot, seeded=0)
            first_tok = int(first[i])
            self.slot_tokens[slot] = first_tok
            self.slot_age[slot] = 1
            r.generated.append(first_tok)
            if np.isnan(r.t_first_token):
                r.t_first_token = self.t_now
                if self._obs_rec.enabled:
                    self._obs_rec.point(self._obs_replica, r.rid,
                                        "decode", self.t_now, slot=slot)
            if (len(r.generated) >= r.max_new_tokens
                    or first_tok == r.eos_id):
                done_slots.append((slot, r))
        if ec.engine_mode == "vec":
            chains = [self._prefix_chain(r, toks[i, :int(lens[i])])
                      for i, (r, _) in enumerate(items)]
            self.backend.write_prefill(mini_cache, np.arange(nb), slots,
                                       tokens=toks, chains=chains)
        else:
            for i in range(nb):
                self._copy_cache_slot(mini_cache, i, int(slots[i]))
        for slot, length in length_fix:    # paged-only (resume path)
            self.backend.kv.set_length(slot, length)
        for slot, r in done_slots:
            self._finish_at_prefill(slot, r)

    def _copy_cache_slot(self, mini_cache, src: int, dst: int) -> None:
        """Seed path: copy one request's cache entry (one dispatch per
        leaf per request — the vec path batches this via
        ``CacheBackend.write_prefill``)."""
        def copy(dst_leaf, src_leaf):
            if dst_leaf.ndim == 1:       # lengths
                return dst_leaf.at[dst].set(src_leaf[src])
            # (layers, batch, ...): maybe shorter kv length in mini cache
            s = src_leaf[:, src]
            if s.shape[0] != dst_leaf.shape[0]:
                raise ValueError("layer-count mismatch")
            d = dst_leaf[:, dst]
            if s.shape != d.shape:
                # pad kv length dim (dim 0 after the two indexes -> dim 0
                # of s is layers... kv len is axis 1 of s)
                pads = [(0, d.shape[i] - s.shape[i]) for i in range(s.ndim)]
                s = jnp.pad(s, pads)
            return dst_leaf.at[:, dst].set(s.astype(dst_leaf.dtype))

        self.cache = jax.tree.map(copy, self.cache, mini_cache)

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One barrier-synchronized step: admission, at most
        ``prefill_budget`` chunked-prefill tokens, and one decode token
        for every active (non-prefilling) request.

        The returned info dict carries ``phase`` — the dominant work
        class of the step (``preempt`` > ``prefill`` > ``decode`` >
        ``idle``) — which the fleet's straggler attribution maps to an
        idle cause when this replica gates a barrier step."""
        p0 = self.preemptions
        fresh, resumed = self._admit()
        chunk_tokens = self._run_chunks() if self.scheduler.chunked else 0
        vec = self.ec.engine_mode == "vec"
        if self._paged:
            # secure this step's decode growth (block crossings + COW)
            # before the barrier: preempt victims rather than letting the
            # allocator raise mid-decode
            self._ensure_decode_capacity()
        if vec:
            active_idx = self.table.active_indices()
            decode_idx = self.table.decode_indices() \
                if self.scheduler.chunked else active_idx
            n_active = active_idx.size
        else:
            decode_idx = [s for s, r in enumerate(self.slot_req)
                          if r is not None]
            n_active = len(decode_idx)
        loads = self._loads()
        lmax = float(loads.max()) if n_active else 0.0
        dt = self.ec.step_overhead + self.ec.t_token * lmax
        u = loads / lmax if lmax > 0 else np.zeros(self.G)
        self.energy_j += dt * float(self.ec.power.power(u).sum())
        imb = step_imbalance(loads) if n_active else 0.0
        self.imbalance_sum += imb
        self.t_now += dt
        self.steps += 1

        n_decode = len(decode_idx)
        if n_decode:
            if vec:
                self._decode_step_vec(np.asarray(decode_idx))
            else:
                self._decode_step_ref(decode_idx)
        if self.ec.cache_backend == "paged":
            self.kv_peak_bytes = max(self.kv_peak_bytes,
                                     self.backend.resident_kv_bytes())
        if self.preemptions > p0 or resumed:
            phase = "preempt"
        elif chunk_tokens or fresh:
            phase = "prefill"
        elif n_decode:
            phase = "decode"
        else:
            phase = "idle"
        return {"t": self.t_now, "active": n_active,
                "waiting": len(self.wait), "max_load": lmax,
                "imbalance": imb, "decoded": n_decode,
                "prefill_tokens": chunk_tokens,
                "prefilling": self.scheduler.n_prefilling,
                "phase": phase}

    def _decode_step_ref(self, active: list[int]) -> None:
        """Seed decode path: always decode all G*B slots, per-slot loop."""
        tokens = jnp.asarray(self.slot_tokens)    # ra: ignore[RA104] — ref oracle is deliberately eager
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(jnp.argmax(logits, -1), dtype=np.int32)  # ra: ignore[RA104] — ref oracle is deliberately eager
        for s in active:
            r = self.slot_req[s]
            tok = int(nxt[s])
            r.generated.append(tok)
            self.slot_tokens[s] = tok
            self.tokens_out += 1
            self.slot_load[s] += self.drift.increment(self.steps)
            if (len(r.generated) >= r.max_new_tokens
                    or tok == r.eos_id):
                r.t_finish = self.t_now
                r.status = "done"
                if self._obs_rec.enabled:
                    self._obs_rec.point(self._obs_replica, r.rid,
                                        "completed", self.t_now,
                                        n_generated=len(r.generated))
                self.slot_req[s] = None
                self.slot_load[s] = 0.0
                self.table.active[s] = False

    def _decode_step_vec(self, active_idx: np.ndarray) -> None:
        """Vectorized decode path: compact the active slots into the
        smallest decode bucket and let the cache backend run the model
        only on those rows (row gather/scatter for the slot backend,
        block-table indirection for the paged backend)."""
        n = active_idx.size
        nb = next(b for b in self._buckets if b >= n)
        nxt = self.backend.decode(self.slot_tokens, active_idx, nb)

        self.slot_tokens[active_idx] = nxt
        self.slot_load[active_idx] += self.drift.increment(self.steps)
        self.slot_age[active_idx] += 1
        self.tokens_out += n
        for pos, s in enumerate(active_idx):
            self.slot_req[s].generated.append(int(nxt[pos]))
        done = ((self.slot_age[active_idx] >= self.slot_max_new[active_idx])
                | (nxt.astype(np.int64) == self.slot_eos[active_idx]))
        if done.any():
            done_idx = active_idx[done]
            for s in done_idx:
                r = self.slot_req[s]
                r.t_finish = self.t_now
                r.status = "done"
                if self._obs_rec.enabled:
                    self._obs_rec.point(self._obs_replica, r.rid,
                                        "completed", self.t_now,
                                        n_generated=len(r.generated))
                self.slot_req[s] = None
            self.table.release(done_idx)
            self.backend.release(done_idx)

    def run(self, max_steps: int = 10_000) -> dict:
        """Step until all submitted requests finish."""
        while self.wait or self.table.active.any():
            if self.steps >= max_steps:
                raise RuntimeError("engine exceeded max_steps")
            self.step()
        return self.stats()

    def stats(self) -> dict:
        prefix = getattr(self.backend, "prefix", None)
        hits = prefix.hits if prefix is not None else 0
        queries = prefix.queries if prefix is not None else 0
        # three-state allocator counters (paged backend; zeros on the
        # slot layout so slot/paged stats dicts stay key-compatible)
        alloc = getattr(getattr(self.backend, "kv", None),
                        "allocator", None)
        return {
            "steps": self.steps,
            "time_s": self.t_now,
            "tokens": self.tokens_out,
            "throughput_tok_s": self.tokens_out / max(self.t_now, 1e-12),
            "energy_j": self.energy_j,
            "avg_imbalance": self.imbalance_sum / max(self.steps, 1),
            "policy": self.policy.name,
            "requests_failed": self.requests_failed,
            "preemptions": self.preemptions,
            "tokens_swapped": self.tokens_swapped,
            "tokens_recomputed": self.tokens_recomputed,
            "prefix_hits": hits,
            "prefix_queries": queries,
            "prefix_hit_rate": hits / queries if queries else 0.0,
            "prefix_cached_blocks": alloc.n_cached if alloc else 0,
            "prefix_revived": alloc.blocks_revived if alloc else 0,
            "prefix_reclaimed": alloc.blocks_reclaimed if alloc else 0,
        }
