"""Multi-worker decode serving engine with pluggable routing.

This is the paper's system diagram (Fig. 3) as a runnable engine:

  * G decode workers (the DP shards), each with B KV-cache slots;
  * prefill produces a request's cache entry; the *router* (FCFS / JSQ /
    BF-IO / ...) assigns it to a worker — sticky thereafter;
  * every engine step decodes ONE token for all active requests on all
    workers (the barrier-synchronized step), with per-worker wall-time
    modeled as ``c + t_token * L_g`` and the step gated by max_g L_g;
  * completions free slots; the router refills them from the wait queue.

For CPU-testable end-to-end runs the workers share one jitted model and
the per-worker batches are stacked; on a production mesh the worker axis
is the "data" mesh axis (each DP shard holds its own slots) and the same
engine code drives the device-sharded batch.  The router's decision
problem is *identical* in both cases — that is the point of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.energy import A100_POWER, PowerModel
from ..core.metrics import step_imbalance
from ..core.policies import Policy, SchedulerContext
from ..core.workload import DriftModel, drift_for_family
from ..models import decode_fn, init_cache, prefill_fn

__all__ = ["ServeRequest", "EngineConfig", "ServingEngine"]


@dataclasses.dataclass
class ServeRequest:
    rid: int
    tokens: np.ndarray              # prompt token ids
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never stops early
    # filled by the engine:
    worker: int = -1
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first_token: float = float("nan")
    t_finish: float = float("nan")

    @property
    def done(self) -> bool:
        return not np.isnan(self.t_finish)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 4              # G
    slots_per_worker: int = 8       # B
    max_seq_len: int = 256
    prefill_pad: int = 64           # prompts padded to this for prefill
    step_overhead: float = 9.775e-3
    t_token: float = 1.005e-7
    power: PowerModel = A100_POWER
    greedy: bool = True             # greedy sampling


class ServingEngine:
    """Continuous-batching decode engine over G logical workers."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 policy: Policy, *, mesh=None, drift: DriftModel = None):
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.policy = policy
        self.mesh = mesh
        self.drift = drift or drift_for_family(cfg.family)
        G, B = engine_cfg.n_workers, engine_cfg.slots_per_worker
        self.G, self.B = G, B
        N = G * B
        # one flat cache over all slots; slot s belongs to worker s // B
        self.cache = init_cache(cfg, N, engine_cfg.max_seq_len)
        self.slot_req: list[Optional[ServeRequest]] = [None] * N
        self.slot_tokens = np.zeros(N, dtype=np.int32)   # next input token
        self.slot_load = np.zeros(N, dtype=np.float64)   # workload proxy
        self.wait: list[ServeRequest] = []
        self.t_now = 0.0
        self.steps = 0
        self.energy_j = 0.0
        self.imbalance_sum = 0.0
        self.tokens_out = 0
        self.rng = np.random.default_rng(0)

        self._decode = jax.jit(
            lambda p, c, t: decode_fn(cfg, p, c, t, mesh=mesh))

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        req.t_submit = self.t_now
        self.wait.append(req)

    def _worker_of(self, slot: int) -> int:
        return slot // self.B

    def _loads(self) -> np.ndarray:
        loads = np.zeros(self.G)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                loads[self._worker_of(s)] += self.slot_load[s]
        return loads

    def _counts(self) -> np.ndarray:
        counts = np.zeros(self.G, dtype=np.int64)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                counts[self._worker_of(s)] += 1
        return counts

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Router step: assign waiting requests to free slots."""
        if not self.wait:
            return
        counts = self._counts()
        caps = self.B - counts
        if caps.sum() <= 0:
            return
        loads = self._loads()
        act = [(s, r) for s, r in enumerate(self.slot_req) if r is not None]
        ctx = SchedulerContext(
            k=self.steps,
            loads=loads,
            counts=counts,
            caps=caps.astype(np.int64),
            wait_prefill=np.array([len(r.tokens) for r in self.wait],
                                  dtype=np.float64),
            active_worker=np.array([self._worker_of(s) for s, _ in act],
                                   dtype=np.int64),
            active_w=np.array([self.slot_load[s] for s, _ in act]),
            active_age=np.array([len(r.generated) for _, r in act],
                                dtype=np.int64),
            active_remaining=np.array(
                [max(r.max_new_tokens - len(r.generated), 1)
                 for _, r in act], dtype=np.int64),
            drift=self.drift,
            rng=self.rng,
        )
        assignment = self.policy.assign(ctx)
        to_admit: list[tuple[ServeRequest, int]] = []
        for pos, g in enumerate(assignment):
            if g >= 0:
                to_admit.append((self.wait[pos], int(g)))
        if not to_admit:
            return
        admitted = {id(r) for r, _ in to_admit}
        self.wait = [r for r in self.wait if id(r) not in admitted]
        self._prefill_batch(to_admit)

    def _prefill_batch(self, items: list[tuple["ServeRequest", int]]) -> None:
        """Run prefill for admitted requests and write their cache slots."""
        ec = self.ec
        pad = max(ec.prefill_pad,
                  max(len(r.tokens) for r, _ in items))
        nb = len(items)
        toks = np.zeros((nb, pad), dtype=np.int32)
        lens = np.zeros(nb, dtype=np.int32)
        for i, (r, _) in enumerate(items):
            L = min(len(r.tokens), pad)
            toks[i, :L] = r.tokens[:L]
            lens[i] = L
        batch = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (nb, self.cfg.patch_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (nb, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        logits, mini_cache = prefill_fn(self.cfg, self.params, batch,
                                        max_len=ec.max_seq_len,
                                        mesh=self.mesh)
        first = np.asarray(jnp.argmax(logits, -1), dtype=np.int32)

        # place each request into a free slot of its assigned worker
        for i, (r, g) in enumerate(items):
            slot = next(s for s in range(g * self.B, (g + 1) * self.B)
                        if self.slot_req[s] is None)
            r.worker, r.slot = g, slot
            self.slot_req[slot] = r
            self.slot_tokens[slot] = first[i]
            self.slot_load[slot] = float(lens[i])
            r.generated.append(int(first[i]))
            if np.isnan(r.t_first_token):
                r.t_first_token = self.t_now
            self._copy_cache_slot(mini_cache, i, slot)

    def _copy_cache_slot(self, mini_cache, src: int, dst: int) -> None:
        """Copy one request's cache entry into the engine's flat cache.

        Cache leaves are stacked (layers, batch, ...): batch is dim 1,
        except 'lengths' (batch is dim 0)."""
        def copy(dst_leaf, src_leaf):
            if dst_leaf.ndim >= 2 and src_leaf.shape[0] != dst_leaf.shape[0]:
                pass
            if dst_leaf.ndim == 1:       # lengths
                return dst_leaf.at[dst].set(src_leaf[src])
            # (layers, batch, ...): maybe shorter kv length in mini cache
            s = src_leaf[:, src]
            if s.shape[0] != dst_leaf.shape[0]:
                raise ValueError("layer-count mismatch")
            d = dst_leaf[:, dst]
            if s.shape != d.shape:
                # pad kv length dim (dim 0 after the two indexes -> dim 0
                # of s is layers... kv len is axis 1 of s)
                pads = [(0, d.shape[i] - s.shape[i]) for i in range(s.ndim)]
                s = jnp.pad(s, pads)
            return dst_leaf.at[:, dst].set(s.astype(dst_leaf.dtype))

        self.cache = jax.tree.map(copy, self.cache, mini_cache)

    # ------------------------------------------------------------------
    def step(self) -> dict:
        """One barrier-synchronized decode step for all active requests."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        loads = self._loads()
        lmax = float(loads.max()) if len(active) else 0.0
        dt = self.ec.step_overhead + self.ec.t_token * lmax
        u = loads / lmax if lmax > 0 else np.zeros(self.G)
        self.energy_j += dt * float(self.ec.power.power(u).sum())
        self.imbalance_sum += step_imbalance(loads) if len(active) else 0.0
        self.t_now += dt
        self.steps += 1

        if active:
            tokens = jnp.asarray(self.slot_tokens)
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens)
            nxt = np.asarray(jnp.argmax(logits, -1), dtype=np.int32)
            for s in active:
                r = self.slot_req[s]
                tok = int(nxt[s])
                r.generated.append(tok)
                self.slot_tokens[s] = tok
                self.tokens_out += 1
                self.slot_load[s] += self.drift.increment(self.steps)
                if (len(r.generated) >= r.max_new_tokens
                        or tok == r.eos_id):
                    r.t_finish = self.t_now
                    self.slot_req[s] = None
                    self.slot_load[s] = 0.0
        return {"t": self.t_now, "active": len(active),
                "waiting": len(self.wait), "max_load": lmax,
                "imbalance": step_imbalance(loads) if active else 0.0}

    def run(self, max_steps: int = 10_000) -> dict:
        """Step until all submitted requests finish."""
        while (self.wait or any(r is not None for r in self.slot_req)):
            if self.steps >= max_steps:
                raise RuntimeError("engine exceeded max_steps")
            self.step()
        return self.stats()

    def stats(self) -> dict:
        return {
            "steps": self.steps,
            "time_s": self.t_now,
            "tokens": self.tokens_out,
            "throughput_tok_s": self.tokens_out / max(self.t_now, 1e-12),
            "energy_j": self.energy_j,
            "avg_imbalance": self.imbalance_sum / max(self.steps, 1),
            "policy": self.policy.name,
        }
