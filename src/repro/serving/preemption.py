"""Memory-pressure subsystem for the paged KV backend: victim selection,
swap staging, and resume state.

When the :class:`~repro.serving.paged_cache.BlockAllocator` cannot serve a
growth or admission request, the engine asks the
:class:`~repro.serving.scheduler.Scheduler` for a *victim* among the
active slots (:class:`PreemptionPolicy`, LIFO by default — the vLLM
choice: the most recently admitted request has the least sunk work and,
under FCFS-ish admission, the longest expected wait ahead of it anyway).
The victim's blocks are then either

* **swapped** to a host-side staging buffer (``preemption_mode="swap"``) —
  a tiled device→host copy in the style of the BF-IO swap kernel's block
  tiling (:func:`swap_out_blocks` / :func:`swap_in_blocks`; plain numpy on
  CPU, bounded staging-buffer peak at ``SWAP_TILE_BLOCKS`` blocks per
  transfer), restored bit-for-bit on resume; or
* **dropped** for recompute-on-resume (``preemption_mode="recompute"``) —
  the request re-enters admission and its KV is rebuilt by re-prefilling
  ``prompt + generated[:-1]`` through the existing (chunked) prefill path.

Either way the victim keeps its generated tokens and re-enters the wait
queue at the front; :class:`PreemptedState` carries everything resume
needs.  Swap-resume is bit-exact on dense models (no arithmetic happens —
the probe for this is ``tests/test_preemption.py``); recompute-resume is
numerically equivalent but not bit-pinned (prefill chunk boundaries on
the rebuilt prefix differ from the original incremental decode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = [
    "SWAP_TILE_BLOCKS",
    "PreemptContext",
    "PreemptionPolicy",
    "LIFOPreemption",
    "FIFOPreemption",
    "LargestPreemption",
    "make_preemption_policy",
    "PreemptedState",
    "swap_out_blocks",
    "swap_in_blocks",
]

#: Blocks moved per host<->device transfer when swapping a victim's KV.
#: Bounds the staging buffer at tile * block_size * Hkv * hd * layers
#: elements regardless of how long the victim's context is.
SWAP_TILE_BLOCKS = 32


# ----------------------------------------------------------------------
# Victim selection
# ----------------------------------------------------------------------

@dataclasses.dataclass
class PreemptContext:
    """What a victim-selection policy may observe.  All arrays are
    aligned over the candidate slots (active requests only)."""

    slots: np.ndarray        # (m,) flat slot ids of the candidates
    admit_seq: np.ndarray    # (m,) monotonic admission sequence number
    kv_tokens: np.ndarray    # (m,) tokens resident in the pool
    blocks_held: np.ndarray  # (m,) KV blocks held
    prefilling: np.ndarray   # (m,) bool: slot is mid-(chunked-)prefill


class PreemptionPolicy:
    """Pick which active request loses its KV under memory pressure."""

    name = "base"

    def select(self, ctx: PreemptContext) -> int:
        raise NotImplementedError


class LIFOPreemption(PreemptionPolicy):
    """Evict the most recently admitted request (vLLM's default): least
    sunk prefill work, and its re-queue-at-front slot in the wait queue
    restores arrival order almost exactly."""

    name = "lifo"

    def select(self, ctx: PreemptContext) -> int:
        return int(ctx.slots[int(np.argmax(ctx.admit_seq))])


class FIFOPreemption(PreemptionPolicy):
    """Evict the oldest request — pathological on purpose (starves the
    head of the line); useful as an adversarial baseline in benchmarks."""

    name = "fifo"

    def select(self, ctx: PreemptContext) -> int:
        return int(ctx.slots[int(np.argmin(ctx.admit_seq))])


class LargestPreemption(PreemptionPolicy):
    """Evict the request holding the most KV blocks (frees the most pool
    per preemption; ties broken toward the most recently admitted)."""

    name = "largest"

    def select(self, ctx: PreemptContext) -> int:
        held = ctx.blocks_held.astype(np.int64)
        score = held * (ctx.admit_seq.max() + 1) + ctx.admit_seq
        return int(ctx.slots[int(np.argmax(score))])


_POLICIES = {p.name: p for p in
             (LIFOPreemption, FIFOPreemption, LargestPreemption)}


def make_preemption_policy(name: str) -> PreemptionPolicy:
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown preemption policy {name!r} "
            f"(expected one of {sorted(_POLICIES)})") from None


# ----------------------------------------------------------------------
# Resume state
# ----------------------------------------------------------------------

@dataclasses.dataclass
class PreemptedState:
    """Everything a preempted request needs to resume.

    ``mode="swap"`` carries the victim's KV blocks in host memory
    (``k_host``/``v_host``, shape (layers, n_blocks, block, Hkv, hd));
    ``mode="recompute"`` carries only the bookkeeping and the KV is
    rebuilt by re-prefilling on resume.  ``prefill_done >= 0`` marks a
    victim taken mid-(chunked-)prefill: resume re-registers its prefill
    job at that offset instead of entering decode.
    """

    mode: str                # "swap" | "recompute"
    length: int              # KV tokens resident at preemption
    next_token: int = -1     # pending decode input (decode-phase victims)
    k_host: Optional[np.ndarray] = None
    v_host: Optional[np.ndarray] = None
    prefill_done: int = -1   # -1: victim was decoding
    prefill_tokens: Optional[np.ndarray] = None
    resume_token: Optional[int] = None   # carried PrefillJob.resume_token
    resume_length: Optional[int] = None  # carried PrefillJob.resume_length

    @property
    def n_blocks(self) -> int:
        return 0 if self.k_host is None else int(self.k_host.shape[1])


# ----------------------------------------------------------------------
# Tiled swap copies
# ----------------------------------------------------------------------

def swap_out_blocks(pool, blocks, tile: int = SWAP_TILE_BLOCKS):
    """Copy ``blocks`` of a device pool (layers, n_blocks, block, Hkv, hd)
    to one host array, ``tile`` blocks per transfer so the staging buffer
    stays bounded (the bfio_swap tiling discipline; on CPU each tile is a
    numpy gather)."""
    blocks = np.asarray(blocks, np.int32)
    if blocks.size == 0:
        return None
    outs = [np.asarray(pool[:, blocks[i:i + tile]])
            for i in range(0, blocks.size, tile)]
    return outs[0] if len(outs) == 1 else np.concatenate(outs, axis=1)


def swap_in_blocks(pool, blocks, host, tile: int = SWAP_TILE_BLOCKS):
    """Scatter a host array from :func:`swap_out_blocks` back into fresh
    ``blocks`` of the device pool, tile by tile.  Returns the new pool."""
    blocks = np.asarray(blocks, np.int32)
    if blocks.size == 0:
        return pool
    for i in range(0, blocks.size, tile):
        idx = jnp.asarray(blocks[i:i + tile])
        pool = pool.at[:, idx].set(
            jnp.asarray(host[:, i:i + tile], pool.dtype))
    return pool
