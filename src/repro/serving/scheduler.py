"""Admission scheduler: wait queue, routing, and the chunked-prefill
budget.

The engine's barrier step used to own all of this inline; it is now a
seam so admission *policy* (what the router decides) and admission
*mechanics* (when prefill work actually runs) can evolve independently of
the engine and of the cache layout.

:class:`Scheduler` owns

* the **wait queue** (arrival order preserved — candidate indices handed
  to routing policies are queue positions);
* **admission**: build nothing itself — the engine constructs the
  :class:`~repro.core.policies.SchedulerContext` (it owns the slot
  arrays) and the scheduler runs the policy, caps the assignment to free
  capacity (:func:`~repro.serving.slot_table.cap_assignment`), and
  removes the admitted requests from the queue;
* **chunked prefill** bookkeeping: admitted requests become
  :class:`PrefillJob`\\ s that are advanced at most ``chunk`` tokens per
  job and ``budget`` tokens per barrier step (FCFS in admission order),
  so one admission wave never runs its whole prompt volume inside a
  single step — prefill chunks interleave with decode instead of
  stalling it.

The chunk-budget knob
---------------------
``EngineConfig.prefill_chunk = 0`` (default) keeps the synchronous seed
semantics: a request's entire (padded) prompt is prefilled in its
admission step.  With ``prefill_chunk = c > 0`` each job advances at most
``c`` prompt tokens per step, and ``prefill_budget`` (default ``c``)
bounds the *total* prompt tokens processed per step across jobs — the
knob that trades time-to-first-token against the decode stall: per-step
wall time is bounded by one decode plus ``budget`` prefill tokens,
instead of one decode plus an entire admission wave.  Policies observe
in-flight jobs via ``SchedulerContext.active_prefill_remaining``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..core.policies import Policy, SchedulerContext
from .preemption import LIFOPreemption, PreemptContext, PreemptionPolicy
from .slot_table import cap_assignment

__all__ = ["PrefillJob", "Scheduler"]


@dataclasses.dataclass
class PrefillJob:
    """A mid-prefill request occupying a slot.

    ``resume_token`` is set for recompute-on-resume prefills (the request
    was preempted while decoding and its KV is being rebuilt): when the
    job finishes, the engine feeds this preserved token back into decode
    instead of sampling a fresh first token from the prefill logits —
    the request already generated it before the preemption.
    ``resume_length`` preserves the victim's KV length when it exceeded
    what the rebuilt (``max_seq_len``-truncated) token sequence covers —
    a request that decoded past the cap on frozen KV must keep its RoPE
    position counter, not restart it at the cap.  ``seeded`` is the
    prefix-cache hit offset the job started at (those tokens were pinned
    copy-free, never computed — recompute accounting must not charge
    them).
    """

    req: object                  # ServeRequest
    tokens: np.ndarray           # prompt (already truncated to max_seq_len)
    done: int = 0                # tokens prefilled so far
    seeded: int = 0              # leading tokens covered by prefix hits
    resume_token: Optional[int] = None
    resume_length: Optional[int] = None

    @property
    def total(self) -> int:
        return len(self.tokens)

    @property
    def remaining(self) -> int:
        return self.total - self.done


class Scheduler:
    """Wait queue + admission + chunked-prefill budget + victim selection
    under memory pressure (see module doc)."""

    def __init__(self, policy: Policy, *, prefill_chunk: int = 0,
                 prefill_budget: int = 0,
                 preemption: Optional[PreemptionPolicy] = None):
        self.policy = policy
        self.chunk = int(prefill_chunk)
        self.budget = int(prefill_budget) or self.chunk
        self.preemption = preemption or LIFOPreemption()
        self.wait: list = []
        self._jobs: dict[int, PrefillJob] = {}   # slot -> job, FCFS order

    @property
    def chunked(self) -> bool:
        return self.chunk > 0

    @property
    def n_prefilling(self) -> int:
        return len(self._jobs)

    # -- queue ----------------------------------------------------------
    def submit(self, req) -> None:
        self.wait.append(req)

    def requeue(self, req) -> None:
        """Return a preempted request to the *front* of the wait queue:
        it was admitted once already, so it outranks everything that
        arrived after it (the vLLM recompute-preemption discipline)."""
        self.wait.insert(0, req)

    # -- admission ------------------------------------------------------
    def admit(self, ctx: SchedulerContext, caps: np.ndarray, *,
              block_budget: Optional[int] = None,
              blocks_of: Optional[Callable] = None) -> list:
        """Run the routing policy and return [(req, worker), ...] for the
        admitted requests (removed from the queue).  A policy may
        over-subscribe a worker beyond its free slots; the excess requests
        simply keep waiting instead of crashing placement.

        ``block_budget``/``blocks_of`` gate admission on KV-pool capacity
        (paged backend): requests are admitted in assignment order only
        while their cumulative block demand fits the budget, and the gate
        is *strict FCFS* — the first request that does not fit stops
        admission for the step (no head-of-line bypass), so an oversized
        pool-pressure wave degrades to waiting instead of to a
        ``MemoryError`` mid-prefill."""
        assignment = cap_assignment(
            np.asarray(self.policy.assign(ctx)), caps)
        to_admit = []
        left = block_budget
        for pos, g in enumerate(assignment):
            if g < 0:
                continue
            req = self.wait[pos]
            if left is not None:
                need = blocks_of(req)
                if need > left:
                    break
                left -= need
            to_admit.append((req, int(g)))
        if to_admit:
            admitted = {id(r) for r, _ in to_admit}
            self.wait = [r for r in self.wait if id(r) not in admitted]
        return to_admit

    # -- memory pressure ------------------------------------------------
    def select_victim(self, ctx: PreemptContext) -> Optional[int]:
        """Pick the active slot to preempt (None if no candidates)."""
        if ctx.slots.size == 0:
            return None
        return self.preemption.select(ctx)

    # -- chunked prefill ------------------------------------------------
    def register_job(self, slot: int, req, tokens: np.ndarray, *,
                     done: int = 0, seeded: int = 0,
                     resume_token: Optional[int] = None,
                     resume_length: Optional[int] = None) -> None:
        """Track a mid-prefill request on ``slot``.  ``done`` resumes a
        preempted-and-swapped-back job at its old offset; ``seeded``
        marks how much of ``done`` came from prefix-cache pins rather
        than compute; ``resume_token``/``resume_length`` mark a
        recompute-on-resume prefill (see :class:`PrefillJob`)."""
        self._jobs[int(slot)] = PrefillJob(req=req, tokens=tokens,
                                           done=int(done),
                                           seeded=int(seeded),
                                           resume_token=resume_token,
                                           resume_length=resume_length)

    def job(self, slot: int) -> Optional[PrefillJob]:
        return self._jobs.get(int(slot))

    def drop_job(self, slot: int) -> Optional[PrefillJob]:
        """Remove and return the job on ``slot`` (victim preempted or
        request finished mid-prefill); None if the slot has no job."""
        return self._jobs.pop(int(slot), None)

    def plan_chunks(self) -> list[tuple[int, int, int]]:
        """Pick this step's chunk work: [(slot, offset, n_tokens), ...],
        FCFS in admission order, each job capped at ``chunk`` tokens and
        the step capped at ``budget`` tokens total.  Advancing ``done``
        is the caller's job (after the compute succeeds)."""
        out = []
        left = self.budget
        for slot, job in self._jobs.items():
            if left <= 0:
                break
            n = min(self.chunk, job.remaining, left)
            if n <= 0:
                continue
            out.append((slot, job.done, n))
            left -= n
        return out

    def advance(self, slot: int, n: int) -> bool:
        """Record ``n`` prefilled tokens for the job on ``slot``; returns
        True (and drops the job) when its prompt is fully prefilled."""
        job = self._jobs[int(slot)]
        job.done += n
        if job.done >= job.total:
            del self._jobs[int(slot)]
            return True
        return False
