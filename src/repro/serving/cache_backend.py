"""Pluggable KV-cache backends for the serving engine.

The engine's barrier-step loop needs exactly four things from its memory
substrate: write an admitted request's prefill KV, advance a prompt chunk
(chunked prefill), decode one token for a compacted set of slots, and
release a finished slot.  :class:`CacheBackend` is that seam; the engine
(:mod:`repro.serving.engine`) owns scheduling and request bookkeeping and
never touches cache layout.

Two implementations ship in-tree, selected by
``EngineConfig.cache_backend``:

* :class:`SlotCacheBackend` (``"slot"``) — the contiguous per-slot layout
  the engine grew up with: one flat ``init_cache`` pytree over all
  ``G * B`` slots, compact decode by gather/scatter of whole cache rows.
  Simple, but reserves ``max_seq_len`` KV per slot forever and copies
  full rows to compact.
* :class:`PagedCacheBackend` (``"paged"``) — vLLM-style paging over
  :class:`~repro.serving.paged_cache.PagedKVCache`: fixed-size KV blocks
  from a shared pool, per-slot block tables, resident KV proportional to
  *actual* tokens.  Decode runs through the paged attention path
  (:func:`repro.models.paged_decode_fn`): the ``"gather"`` oracle on CPU
  (bit-identical to the slot backend by construction), the Pallas kernel
  (:mod:`repro.kernels.paged_attention`) on TPU.  Attention-family models
  only (dense / moe / vlm) — recurrent-state families have no paged
  layout.

Adding a backend
----------------
Subclass :class:`CacheBackend`, implement the five abstract methods, and
register a name in :func:`make_cache_backend`.  The contract the engine
relies on:

* ``decode`` is called with a *bucketed* batch size ``nb >= n`` (the
  engine pads compact batches to a small ladder of sizes so jit
  recompiles stay bounded); rows beyond ``n`` are padding whose writes
  must be dropped and whose outputs are discarded.
* ``prefill_chunk`` rows with ``slots[i] < 0`` are padding under the same
  convention.
* All methods are synchronous with respect to the host arrays the engine
  reads (``lengths`` bookkeeping must be visible immediately after the
  call returns).
"""
from __future__ import annotations

import abc
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import (
    chunk_prefill_fn,
    decode_fn,
    init_cache,
    paged_chunk_prefill_fn,
    paged_decode_fn,
    supports_paged_stack,
)
from .paged_cache import PagedKVCache, PrefixIndex
from .preemption import PreemptedState, swap_in_blocks, swap_out_blocks

__all__ = ["CacheBackend", "SlotCacheBackend", "PagedCacheBackend",
           "make_cache_backend"]


# ----------------------------------------------------------------------
# Shared gather/scatter helpers + jitted model entry points (cached at
# module level so engines over the same (cfg, mesh) share compilations).
# ----------------------------------------------------------------------

def gather_rows(cache, idx):
    """Gather cache rows ``idx``: batch is dim 0 for 1-d leaves (lengths),
    dim 1 for stacked (layers, batch, ...) leaves."""
    return jax.tree.map(
        lambda a: a[idx] if a.ndim == 1 else a[:, idx], cache)


def scatter_rows(cache, sub, dst):
    """Write sub-batch rows back at ``dst`` (out-of-bounds entries of
    ``dst`` are dropped by JAX scatter semantics — used for padding)."""
    def put(full, part):
        if full.ndim == 1:
            return full.at[dst].set(part.astype(full.dtype))
        return full.at[:, dst].set(part.astype(full.dtype))
    return jax.tree.map(put, cache, sub)


@functools.lru_cache(maxsize=None)
def _jitted_decode_full(cfg: ModelConfig, mesh):
    """Full-batch decode with fused greedy sampling: (tokens, cache).

    The cache argument is donated: the caller always replaces its cache
    with the returned one, so the old buffers can be reused in place."""
    def f(p, c, t):
        logits, c2 = decode_fn(cfg, p, c, t, mesh=mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), c2
    return jax.jit(f, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _jitted_decode_compact(cfg: ModelConfig, mesh):
    """Compact decode: gather rows ``idx`` out of the flat cache, decode
    only those, scatter the updated rows back at ``dst``.  Padding rows
    carry ``dst == N`` so their writes are dropped."""
    def f(p, cache, toks, idx, dst):
        sub = gather_rows(cache, idx)
        logits, new_sub = decode_fn(cfg, p, sub, toks, mesh=mesh)
        return (jnp.argmax(logits, -1).astype(jnp.int32),
                scatter_rows(cache, new_sub, dst))
    return jax.jit(f, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _jitted_chunk_prefill(cfg: ModelConfig, mesh):
    """Chunked prefill over contiguous rows: gather the chunking slots'
    rows, advance one chunk, scatter back (pads at ``dst == N``)."""
    def f(p, cache, toks, offs, clens, idx, dst):
        sub = gather_rows(cache, idx)
        logits, new_sub = chunk_prefill_fn(cfg, p, sub, toks, offs, clens,
                                           mesh=mesh)
        return logits, scatter_rows(cache, new_sub, dst)
    return jax.jit(f, donate_argnums=(1,))


def _install_impl(cache, mini, src, dst):
    """Copy rows ``src`` of the prefill mini cache into slots ``dst`` of
    the flat cache — one fused program instead of eager per-leaf
    gather/scatter (which dominated request admission cost).  Cache
    leaves are stacked (layers, batch, ...) except 'lengths' (batch is
    dim 0); the mini cache may carry a shorter kv-length dim (prefill
    pad), zero-padded up to the flat cache's."""
    def copy(dst_leaf, src_leaf):
        if dst_leaf.ndim == 1:       # lengths
            return dst_leaf.at[dst].set(src_leaf[src].astype(dst_leaf.dtype))
        s = src_leaf[:, src]
        tail = dst_leaf.shape[2:]
        if s.shape[2:] != tail:
            pads = [(0, 0), (0, 0)] + [
                (0, tail[i] - s.shape[2 + i]) for i in range(len(tail))]
            s = jnp.pad(s, pads)
        return dst_leaf.at[:, dst].set(s.astype(dst_leaf.dtype))
    return jax.tree.map(copy, cache, mini)


_INSTALL = jax.jit(_install_impl, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _jitted_paged_decode(cfg: ModelConfig, mesh, block_size: int,
                         attn_impl: str):
    def f(p, kp, vp, tables, lengths, blk, off, toks):
        return paged_decode_fn(cfg, p, kp, vp, tables, lengths, blk, off,
                               toks, block_size=block_size,
                               attn_impl=attn_impl, mesh=mesh)
    return jax.jit(f, donate_argnums=(1, 2))


@functools.lru_cache(maxsize=None)
def _jitted_paged_chunk(cfg: ModelConfig, mesh, block_size: int):
    def f(p, kp, vp, tables, toks, offs, clens, wblk, woff):
        return paged_chunk_prefill_fn(cfg, p, kp, vp, tables, toks, offs,
                                      clens, wblk, woff,
                                      block_size=block_size, mesh=mesh)
    return jax.jit(f, donate_argnums=(1, 2))


# ----------------------------------------------------------------------
# Protocol
# ----------------------------------------------------------------------

class CacheBackend(abc.ABC):
    """Memory-layout seam between the serving engine and the model.

    Implementations own the physical KV storage and the model calls that
    read/write it; the engine owns slots, scheduling, and metrics.  See
    the module docstring for the padding conventions.
    """

    name: str = "base"

    @abc.abstractmethod
    def write_prefill(self, mini_cache, src: np.ndarray, dst: np.ndarray,
                      tokens: Optional[np.ndarray] = None,
                      chains: Optional[list] = None) -> None:
        """Install prefill output: copy rows ``src`` of ``mini_cache``
        (a ``prefill_fn`` cache over the admitted batch) into slots
        ``dst``.  ``tokens`` (rows aligned with the mini cache) carries
        the prompt token ids so content-addressed backends can dedup
        shared prefixes; layout-only backends ignore it.  ``chains``
        (aligned with ``src`` rows) optionally carries each row's
        precomputed block-hash chain (``PrefixIndex.keys_for`` output,
        memoized on the request) so the prompt is hashed once per
        lifetime, not once per consumer."""

    @abc.abstractmethod
    def prefill_chunk(self, toks: np.ndarray, offs: np.ndarray,
                      clens: np.ndarray, slots: np.ndarray) -> np.ndarray:
        """Advance one prompt chunk per row and return each row's logits
        at its final chunk position, shape (rows, vocab).  ``toks`` is
        (rows, C) right-padded; ``offs``/``clens`` give each chunk's
        start position and valid length; ``slots[i] < 0`` marks padding
        rows."""

    @abc.abstractmethod
    def decode(self, slot_tokens: np.ndarray, active_idx: np.ndarray,
               bucket: int) -> np.ndarray:
        """One greedy decode token for each slot in ``active_idx``
        (batched at size ``bucket``); returns (n,) int32 next tokens and
        updates the stored KV in place."""

    @abc.abstractmethod
    def release(self, slots: np.ndarray) -> None:
        """Free finished slots' KV."""

    @abc.abstractmethod
    def resident_kv_bytes(self) -> int:
        """Bytes of KV currently held for live requests."""


# ----------------------------------------------------------------------
# Contiguous per-slot backend (the extracted seed layout)
# ----------------------------------------------------------------------

class SlotCacheBackend(CacheBackend):
    """Contiguous per-slot cache: one flat ``init_cache`` pytree over all
    N slots, compact decode via gather/scatter of whole cache rows.

    This is the seed engine's layout extracted behind the protocol; the
    ref engine mode drives ``self.cache`` directly (its per-slot loops
    are the live parity oracle)."""

    name = "slot"

    def __init__(self, cfg: ModelConfig, params, ec, mesh):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.N = ec.n_workers * ec.slots_per_worker
        self.cache = init_cache(cfg, self.N, ec.max_seq_len)
        self._decode_full = _jitted_decode_full(cfg, mesh)
        self._decode_compact = _jitted_decode_compact(cfg, mesh)
        self._chunk = _jitted_chunk_prefill(cfg, mesh)
        self._bytes = int(sum(
            a.nbytes for a in jax.tree.leaves(self.cache)))

    def write_prefill(self, mini_cache, src, dst, tokens=None,
                      chains=None) -> None:
        """One fused jitted gather/scatter over the whole admitted batch
        and all cache leaves (see :func:`_install_impl`; the old cache is
        donated).  ``tokens``/``chains`` are unused (the contiguous
        layout is not content-addressed)."""
        self.cache = _INSTALL(self.cache, mini_cache,
                              jnp.asarray(src, jnp.int32),
                              jnp.asarray(dst, jnp.int32))

    def prefill_chunk(self, toks, offs, clens, slots) -> np.ndarray:
        idx = np.maximum(slots, 0).astype(np.int32)
        dst = np.where(slots >= 0, slots, self.N).astype(np.int32)
        logits, self.cache = self._chunk(
            self.params, self.cache, np.asarray(toks, np.int32),
            np.asarray(offs, np.int32), np.asarray(clens, np.int32),
            idx, dst)
        return np.asarray(logits)

    def decode(self, slot_tokens, active_idx, bucket) -> np.ndarray:
        # numpy args go straight into the jitted calls: the jit dispatch
        # fastpath converts them far cheaper than an eager jnp.asarray
        # per array (which dominated small-model decode steps), and
        # dtype canonicalization is identical either way.
        n = active_idx.size
        if bucket >= self.N:
            nxt_all, self.cache = self._decode_full(
                self.params, self.cache, slot_tokens)
            return np.asarray(nxt_all)[active_idx]
        idx = np.zeros(bucket, dtype=np.int32)
        idx[:n] = active_idx
        dst = np.full(bucket, self.N, dtype=np.int32)  # pads: dropped
        dst[:n] = active_idx
        nxt_sub, self.cache = self._decode_compact(
            self.params, self.cache, slot_tokens[idx], idx, dst)
        return np.asarray(nxt_sub)[:n]

    def release(self, slots) -> None:
        # rows are simply abandoned in place (stale KV is masked by
        # lengths on the next occupant), exactly as the seed engine did
        pass

    def resident_kv_bytes(self) -> int:
        return self._bytes


# ----------------------------------------------------------------------
# Paged backend (vLLM block tables over a shared pool)
# ----------------------------------------------------------------------

class PagedCacheBackend(CacheBackend):
    """Paged KV: fixed-size blocks from a shared pool, per-slot block
    tables, resident KV tracking actual tokens.

    ``EngineConfig`` knobs: ``paged_block_size`` (tokens per block;
    must divide ``max_seq_len`` so the gathered contiguous view matches
    the slot layout bit-for-bit), ``paged_pool_blocks`` (0 = capacity for
    every slot at ``max_seq_len``; smaller pools oversubscribe memory —
    the engine preempts victims on pressure instead of crashing, see
    :mod:`repro.serving.preemption`), ``paged_attn_impl`` (``"gather"``
    CPU oracle / ``"ref"`` standalone jnp oracle / ``"pallas"`` TPU
    kernel), and ``prefix_cache`` (share identical prompt-prefix blocks
    across requests via :class:`~repro.serving.paged_cache.PrefixIndex`,
    copy-on-write on the first divergent append).

    The preemption surface the engine drives: the ``*_demand`` methods
    report how many blocks an operation is about to allocate (so the
    engine can free capacity *first* and the allocator never raises
    mid-step), and ``swap_out`` / ``swap_in`` / ``discard`` move a
    victim's blocks to host staging and back (or drop them for
    recompute-on-resume)."""

    name = "paged"

    def __init__(self, cfg: ModelConfig, params, ec, mesh):
        if not supports_paged_stack(cfg):
            raise ValueError(
                "cache_backend='paged' supports only attention-family "
                f"models (dense/moe/vlm, no sliding window); got "
                f"{cfg.family!r}")
        bs = int(ec.paged_block_size)
        if ec.max_seq_len % bs != 0:
            raise ValueError(
                f"paged_block_size {bs} must divide max_seq_len "
                f"{ec.max_seq_len}")
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.N = ec.n_workers * ec.slots_per_worker
        self.block_size = bs
        self.max_blocks = ec.max_seq_len // bs
        n_blocks = int(ec.paged_pool_blocks) or self.N * self.max_blocks
        self.kv = PagedKVCache.create(
            n_layers=cfg.n_layers, n_blocks=n_blocks, block_size=bs,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            max_requests=self.N, max_blocks_per_req=self.max_blocks,
            dtype=jnp.dtype(cfg.dtype),
            prefix_evict=getattr(ec, "prefix_evict", "lru"))
        self.prefix: Optional[PrefixIndex] = None
        if getattr(ec, "prefix_cache", False):
            self.prefix = PrefixIndex()
            self.kv.prefix = self.prefix
        self._decode_jit = _jitted_paged_decode(cfg, mesh, bs,
                                                ec.paged_attn_impl)
        self._chunk_jit = _jitted_paged_chunk(cfg, mesh, bs)

    # -- helpers --------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.kv.allocator.n_blocks

    @property
    def free_blocks(self) -> int:
        """Blocks an allocation can be served from right now: the free
        list plus the reclaimable LRU-cached list.  Admission block
        budgets and the decode/chunk preemption gates charge against
        this — a warm persistent prefix cache is reusable capacity, not
        pressure, so it must never false-trigger preemption or
        ``MemoryError``."""
        return self.kv.allocator.n_reclaimable

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained on the prefix-cache LRU list."""
        return self.kv.allocator.n_cached

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks a request with ``n_tokens`` of KV occupies (>= 1)."""
        return -(-max(int(n_tokens), 1) // self.block_size)

    def pool_bytes(self) -> int:
        return int(self.kv.k_pool.nbytes + self.kv.v_pool.nbytes)

    def _tables_for(self, slots: np.ndarray) -> np.ndarray:
        out = np.full((slots.size, self.max_blocks), -1, np.int32)
        valid = slots >= 0
        out[valid] = self.kv.block_tables[slots[valid]]
        return out

    # -- protocol -------------------------------------------------------
    def _shared_prefix(self, toks_row: np.ndarray,
                       chain: Optional[list] = None) -> tuple[list, list]:
        """Longest leading run of prefix-cache hits for a prompt: returns
        (keys, shared_blocks) where ``keys`` covers every block of the
        prompt (chained content-hash triples) and ``shared_blocks`` is
        the hit run (possibly empty).  Only content-verified *live*
        blocks count — referenced by a resident holder or retained on
        the allocator's LRU cached list.  A cached hit is touched here
        (LRU recency) and revived when ``admit`` pins it moments later
        (``add_ref`` on a cached block re-pins it atomically; no
        allocation happens in between, so the hit cannot be reclaimed
        out from under the admit).  ``chain`` optionally supplies the
        precomputed ``keys_for`` triples (memoized on the request) so
        the prompt is not re-hashed per consumer."""
        keys = chain if chain is not None \
            else self.prefix.keys_for(toks_row, self.block_size)
        alloc = self.kv.allocator
        shared = []
        for key, parent, span in keys:
            blk = self.prefix.lookup(key, parent, span)
            if blk is None or not alloc.is_live(blk):
                break
            alloc.touch(blk)
            shared.append(blk)
        self.prefix.note_lookup(len(keys), len(shared))
        return keys, shared

    def write_prefill(self, mini_cache, src, dst, tokens=None,
                      chains=None) -> None:
        """Scatter the admitted batch's prefill KV into freshly allocated
        blocks: ONE gather + scatter per pool (k and v) for the whole
        batch, indexed block-wise.  With the prefix cache on (and
        ``tokens`` provided), each request's leading blocks whose chained
        token-content hash is already indexed are reused copy-free via
        ``add_ref`` — their writes are skipped (the resident KV for an
        identical prefix is identical) — and the request's own blocks are
        registered for later arrivals."""
        src = np.asarray(src)
        dst = np.asarray(dst)
        lens = np.asarray(mini_cache["lengths"])
        bs = self.block_size
        rows, blkpos, blocks = [], [], []
        for n, (i, s) in enumerate(zip(src, dst)):
            s = int(s)
            L = int(lens[i])
            keys: list = []
            shared: list = []
            if self.prefix is not None and tokens is not None and L > 0:
                keys, shared = self._shared_prefix(
                    tokens[int(i), :L],
                    chain=chains[n] if chains is not None else None)
            self.kv.admit(s, L, shared=tuple(shared))
            bl = self.kv.req_blocks[s]
            for j, (key, parent, span) in enumerate(keys):
                self.prefix.register(key, parent, span, bl[j])
            skip = len(shared)
            rows.extend([int(i)] * (len(bl) - skip))
            blkpos.extend(range(skip, len(bl)))
            blocks.extend(bl[skip:])
        k = mini_cache["blocks"]["k"]          # (layers, nb, S, Hkv, hd)
        v = mini_cache["blocks"]["v"]
        S = k.shape[2]
        pad = (-S) % bs
        if pad:
            cfgpad = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
            k = jnp.pad(k, cfgpad)
            v = jnp.pad(v, cfgpad)
        nblk = (S + pad) // bs
        kb = k.reshape(k.shape[0], k.shape[1], nblk, bs, *k.shape[3:])
        vb = v.reshape(*kb.shape)
        if not blocks:       # every block shared: nothing to write
            return
        rows = np.asarray(rows, np.int32)
        blkpos = np.asarray(blkpos, np.int32)
        blocks = np.asarray(blocks, np.int32)
        dt = self.kv.k_pool.dtype
        self.kv.k_pool = self.kv.k_pool.at[:, blocks].set(
            kb[:, rows, blkpos].astype(dt))
        self.kv.v_pool = self.kv.v_pool.at[:, blocks].set(
            vb[:, rows, blkpos].astype(dt))

    def seed_chunk_prefix(self, slot: int, toks: np.ndarray,
                          count: bool = True,
                          chain: Optional[list] = None) -> int:
        """Chunked-admission prefix hit: pin the longest run of *full*
        indexed blocks matching the prompt's leading content into
        ``slot`` (``add_ref``, copy-free) and return the token count they
        cover — the chunk job then starts at that offset, skipping
        recompute of the hit prefix (a TTFT win on top of the memory
        dedup).  Restricted to full blocks: chunk writes land directly in
        pool blocks (no copy-on-write on the prefill path), so a shared
        partial tail could be corrupted by the first chunk's scatter —
        full blocks strictly before the chunk offset are never written.
        At least the prompt's final token is always left uncovered so the
        finishing chunk computes the logits the first sampled token needs
        (generations stay bit-identical on dense models).

        ``count=False`` skips the hit-rate counters: a preempt-restarted
        job re-seeds the same admission, and counting that re-walk again
        would double-count the admission's lookup (the engine passes
        ``count`` = first-admission)."""
        if self.prefix is None:
            return 0
        L = len(toks)
        keys = chain if chain is not None \
            else self.prefix.keys_for(toks, self.block_size)
        alloc = self.kv.allocator
        shared: list[int] = []
        for key, parent, span in keys:
            if len(span) < self.block_size:
                break               # partial tail: never shared pre-write
            blk = self.prefix.lookup(key, parent, span)
            if blk is None or not alloc.is_live(blk):
                break
            alloc.touch(blk)
            shared.append(blk)
        # keep the last prompt token out of the shared run (see above)
        while shared and len(shared) * self.block_size >= L:
            shared.pop()
        if count:
            self.prefix.note_lookup(len(keys), len(shared))
        if not shared:
            return 0
        for b in shared:
            alloc.add_ref(b)
        covered = len(shared) * self.block_size
        self.kv.adopt_blocks(slot, shared, covered)
        return covered

    def register_chunk_prefix(self, slot: int, toks: np.ndarray,
                              chain: Optional[list] = None) -> None:
        """Index a chunk-prefilled prompt's blocks for later arrivals
        (the synchronous path registers at :meth:`write_prefill`; chunked
        jobs allocate lazily, so registration happens when the prompt
        completes).  Includes the partial tail — a later *synchronous*
        admission may share it (decode appends into it copy-on-write).
        ``chain`` optionally supplies the precomputed ``keys_for``
        triples (memoized on the request)."""
        if self.prefix is None:
            return
        bl = self.kv.req_blocks.get(int(slot), [])
        keys = chain if chain is not None \
            else self.prefix.keys_for(toks, self.block_size)
        for j, (key, parent, span) in enumerate(keys):
            if j >= len(bl):
                break
            self.prefix.register(key, parent, span, bl[j])

    def prefill_chunk(self, toks, offs, clens, slots) -> np.ndarray:
        bs = self.block_size
        nb, C = toks.shape
        for j in range(nb):
            if slots[j] >= 0:
                self.kv.ensure_capacity(int(slots[j]),
                                        int(offs[j] + clens[j]))
        tables = self._tables_for(slots)
        posm = offs[:, None] + np.arange(C)[None, :]
        validm = np.arange(C)[None, :] < clens[:, None]
        # positions past a full block table have no block to land in
        # (frozen KV, see ensure_capacity): drop those writes like the
        # decode path's in_cap clamp instead of corrupting the last block
        in_cap = posm < self.max_blocks * bs
        bidx = np.clip(posm // bs, 0, self.max_blocks - 1)
        wblk = np.where(validm & in_cap,
                        np.take_along_axis(tables, bidx, axis=1),
                        self.n_blocks).astype(np.int32)
        woff = (posm % bs).astype(np.int32)
        logits, kp, vp = self._chunk_jit(
            self.params, self.kv.k_pool, self.kv.v_pool,
            jnp.asarray(tables), jnp.asarray(toks, jnp.int32),
            jnp.asarray(offs, jnp.int32), jnp.asarray(clens, jnp.int32),
            jnp.asarray(wblk), jnp.asarray(woff))
        self.kv.k_pool, self.kv.v_pool = kp, vp
        return np.asarray(logits)

    def decode(self, slot_tokens, active_idx, bucket) -> np.ndarray:
        n = active_idx.size
        self.kv.append_tokens(active_idx)
        lens = np.zeros(bucket, np.int32)
        lens[:n] = self.kv.lengths[active_idx]
        tables = np.full((bucket, self.max_blocks), -1, np.int32)
        tables[:n] = self.kv.block_tables[active_idx]
        pos = np.maximum(lens - 1, 0)
        blk = np.full(bucket, self.n_blocks, np.int32)  # pads: dropped
        # requests that outgrew max_seq_len keep decoding on frozen KV
        # (write dropped), matching the slot layout's scatter overflow
        in_cap = pos[:n] < self.max_blocks * self.block_size
        blk[:n][in_cap] = tables[np.flatnonzero(in_cap),
                                 pos[:n][in_cap] // self.block_size]
        off = (pos % self.block_size).astype(np.int32)
        toks = np.zeros(bucket, np.int32)
        toks[:n] = slot_tokens[active_idx]
        nxt, kp, vp = self._decode_jit(
            self.params, self.kv.k_pool, self.kv.v_pool,
            jnp.asarray(tables), jnp.asarray(lens), jnp.asarray(blk),
            jnp.asarray(off), jnp.asarray(toks))
        self.kv.k_pool, self.kv.v_pool = kp, vp
        return np.asarray(nxt)[:n]

    # -- memory pressure (engine-driven preemption) ---------------------
    def decode_block_demand(self, active_idx: np.ndarray) -> int:
        """Blocks the next decode step over ``active_idx`` will allocate
        (boundary crossings + copy-on-write of shared tail blocks)."""
        return self.kv.append_demand(active_idx)

    def chunk_block_demand(self, plan) -> int:
        """Blocks a chunk plan [(slot, off, n), ...] will allocate."""
        need = 0
        for slot, off, n in plan:
            have = len(self.kv.req_blocks.get(int(slot), []))
            need += max(self.blocks_for(off + n) - have, 0)
        return need

    def swap_out(self, slot: int) -> PreemptedState:
        """Move a victim's KV blocks to host staging (tiled copy) and
        return them to the pool; the returned state restores the blocks
        bit-for-bit via :meth:`swap_in`.  Staging happens *before* the
        release, so a prefix-indexed block whose last reference drops
        here may coherently enter the cached state: its device content
        is untouched until reclaim (which evicts its index entry first),
        and the resume path restores from the host copy into fresh
        blocks — the two can never alias."""
        slot = int(slot)
        blocks = self.kv.req_blocks.get(slot, [])
        state = PreemptedState(
            mode="swap", length=int(self.kv.lengths[slot]),
            k_host=swap_out_blocks(self.kv.k_pool, blocks),
            v_host=swap_out_blocks(self.kv.v_pool, blocks))
        self.kv.release(slot)
        return state

    def swap_in(self, slot: int, state: PreemptedState) -> None:
        """Restore a swapped victim into fresh blocks on ``slot``.  The
        blocks are private (a shared prefix is not re-deduped on resume);
        admission block-gating guarantees the allocation fits."""
        slot = int(slot)
        n = state.n_blocks
        blocks = self.kv.allocator.alloc(n)
        self.kv.adopt_blocks(slot, blocks, state.length)
        if n:
            self.kv.k_pool = swap_in_blocks(self.kv.k_pool, blocks,
                                            state.k_host)
            self.kv.v_pool = swap_in_blocks(self.kv.v_pool, blocks,
                                            state.v_host)

    def discard(self, slot: int) -> None:
        """Drop a victim's KV for recompute-on-resume."""
        self.kv.release(int(slot))

    def release(self, slots) -> None:
        for s in np.asarray(slots):
            self.kv.release(int(s))

    def resident_kv_bytes(self) -> int:
        return self.kv.resident_bytes()


def make_cache_backend(name: str, cfg: ModelConfig, params, ec,
                       mesh) -> CacheBackend:
    if name == "slot":
        return SlotCacheBackend(cfg, params, ec, mesh)
    if name == "paged":
        return PagedCacheBackend(cfg, params, ec, mesh)
    raise ValueError(f"unknown cache backend {name!r} "
                     "(expected 'slot' or 'paged')")
