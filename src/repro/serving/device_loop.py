"""Device-side routed decode loop: BF-IO fused into a jitted multi-step
serving loop.

The host engine (engine.py) calls the router between device steps — the
realistic deployment.  This module shows the *other* integration the
jittable balancer (repro.core.balancer_jax) enables: an entire
admit→decode→complete loop under one ``jax.lax`` program, so a TPU can run
many serving steps without host round-trips (useful for simulation at
device speed and for offline batch inference).

State is fixed-shape: a slot table (G*B slots, the same flat layout as
:mod:`repro.serving.slot_table` — slot s belongs to worker s // B), a
bounded waiting buffer, and the BF-IO assignment runs as traced code each
step.  Workload dynamics follow the paper's model (unit KV drift,
known-at-admission prefill sizes, completion at a fixed per-request decode
length).

``kv_pool > 0`` adds the host engine's memory-pressure model to the
traced program: per-slot resident KV is approximated by the absorbed load,
and whenever the active total exceeds the pool, the most recently admitted
slot is preempted (LIFO, recompute model — its absorbed work returns to
the wait buffer, its decode progress is preserved) until the total fits.
``tot_preempts`` counts the evictions, mirroring the host engine's
``preemptions`` stat so policies can be compared on preemption churn at
device speed.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.balancer_jax import bfio_assign
from .slot_table import slot_worker_map

__all__ = ["LoopState", "make_device_serving_loop"]


class LoopState(NamedTuple):
    slot_active: jnp.ndarray    # (G*B,) bool
    slot_load: jnp.ndarray      # (G*B,) f32 current per-step workload
    slot_remaining: jnp.ndarray  # (G*B,) i32 decode steps left
    wait_prefill: jnp.ndarray   # (W,) f32, 0 = empty entry
    wait_remaining: jnp.ndarray  # (W,) i32
    tot_imbalance: jnp.ndarray  # () f32
    tot_steps: jnp.ndarray      # () i32
    slot_prefill_left: jnp.ndarray  # (G*B,) f32 prompt work not yet done
    slot_admit_step: jnp.ndarray    # (G*B,) i32 admission step (LIFO key)
    tot_preempts: jnp.ndarray   # () i32 memory-pressure evictions


def make_device_serving_loop(G: int, B: int, wait_cap: int,
                             swap_iters: int = 4,
                             prefill_budget: float = 0.0,
                             kv_pool: float = 0.0):
    """Returns jitted ``run(state, n_steps) -> state`` executing the
    admit/decode/complete loop fully on device.

    ``prefill_budget > 0`` models chunked prefill (the host engine's
    ``EngineConfig.prefill_chunk``): admitted slots start at zero load
    and absorb at most ``prefill_budget`` prompt tokens per step
    (greedily in flat slot order); a slot decodes only once its prefill
    drains.  ``0`` keeps the seed semantics — the whole prompt lands in
    the admission step.

    ``kv_pool > 0`` models the paged backend's finite block pool (see the
    module doc): LIFO preemption with recompute-on-resume whenever the
    active resident KV exceeds the pool.  Both flags are python
    constants, so the all-zero path traces to exactly the original
    program.
    """
    S = G * B
    slot_worker = jnp.asarray(slot_worker_map(G, B))
    chunked = prefill_budget > 0
    pooled = kv_pool > 0

    def step(state: LoopState, _):
        # --- current loads ------------------------------------------------
        loads = jax.ops.segment_sum(
            jnp.where(state.slot_active, state.slot_load, 0.0),
            slot_worker, num_segments=G)                       # (G,)
        counts = jax.ops.segment_sum(
            state.slot_active.astype(jnp.int32), slot_worker,
            num_segments=G)
        caps = B - counts

        # --- BF-IO admission (H=0, jitted) ---------------------------------
        valid = state.wait_prefill > 0
        n_admit = jnp.minimum(valid.sum(), caps.sum()).astype(jnp.int32)
        assign = bfio_assign(loads[:, None], caps,
                             state.wait_prefill[:, None], valid, n_admit,
                             swap_iters=swap_iters)            # (W,)

        # place admitted candidates into free slots of their worker:
        # slot rank within worker == assignment rank within worker
        def place(carry, i):
            slot_active, slot_load, slot_rem, wp, wr, pl, adm = carry
            g = assign[i]

            def do_place(args):
                slot_active, slot_load, slot_rem, wp, wr, pl, adm = args
                free = (~slot_active) & (slot_worker == g)
                idx = jnp.argmax(free)          # first free slot of g
                ok = free[idx]
                slot_active = slot_active.at[idx].set(
                    jnp.where(ok, True, slot_active[idx]))
                # chunked: admitted slots start empty and absorb their
                # prompt under the per-step budget below
                load0 = 0.0 if chunked else wp[i]
                slot_load = slot_load.at[idx].set(
                    jnp.where(ok, load0, slot_load[idx]))
                if chunked:
                    pl = pl.at[idx].set(jnp.where(ok, wp[i], pl[idx]))
                if pooled:
                    adm = adm.at[idx].set(
                        jnp.where(ok, state.tot_steps, adm[idx]))
                slot_rem = slot_rem.at[idx].set(
                    jnp.where(ok, wr[i], slot_rem[idx]))
                wp = wp.at[i].set(jnp.where(ok, 0.0, wp[i]))
                wr = wr.at[i].set(jnp.where(ok, 0, wr[i]))
                return slot_active, slot_load, slot_rem, wp, wr, pl, adm

            return jax.lax.cond(g >= 0, do_place, lambda a: a,
                                (slot_active, slot_load, slot_rem, wp,
                                 wr, pl, adm)), None

        (slot_active, slot_load, slot_rem, wp, wr, pl, adm), _ = \
            jax.lax.scan(
                place,
                (state.slot_active, state.slot_load, state.slot_remaining,
                 state.wait_prefill, state.wait_remaining,
                 state.slot_prefill_left, state.slot_admit_step),
                jnp.arange(wait_cap))

        # --- chunked prefill: drain at most prefill_budget tokens ----------
        if chunked:
            left = jnp.where(slot_active, pl, 0.0)
            cum = jnp.cumsum(left)
            take = jnp.clip(prefill_budget - (cum - left), 0.0, left)
            pl = pl - take
            slot_load = slot_load + take
            decoding = slot_active & (pl <= 0)
        else:
            decoding = slot_active

        # --- barrier step metrics ------------------------------------------
        loads = jax.ops.segment_sum(
            jnp.where(slot_active, slot_load, 0.0), slot_worker,
            num_segments=G)
        imb = G * loads.max() - loads.sum()

        # --- token generation / completion / drift -------------------------
        slot_rem = jnp.where(decoding, slot_rem - 1, slot_rem)
        done = decoding & (slot_rem <= 0)
        slot_active = slot_active & ~done
        slot_load = jnp.where(slot_active,
                              jnp.where(decoding & ~done,
                                        slot_load + 1.0, slot_load),
                              0.0)

        # --- memory pressure: LIFO preempt until resident KV fits ----------
        n_pre = state.tot_preempts
        if pooled:
            def over(c):
                sa, sl, srem, wp2, wr2, pl2, npre = c
                # resident KV = absorbed tokens only; queued prefill
                # (pl2) has not been written anywhere yet
                total = jnp.sum(jnp.where(sa, sl, 0.0))
                return (total > kv_pool) & jnp.any(sa) & jnp.any(wp2 <= 0)

            def evict(c):
                sa, sl, srem, wp2, wr2, pl2, npre = c
                victim = jnp.argmax(jnp.where(sa, adm, -1))
                widx = jnp.argmax(wp2 <= 0)     # first free wait entry
                # recompute model: every absorbed token must be redone,
                # so the whole load (plus unfinished prefill) requeues
                back = sl[victim] + pl2[victim]
                wp2 = wp2.at[widx].set(jnp.maximum(back, 1.0))
                wr2 = wr2.at[widx].set(jnp.maximum(srem[victim], 1))
                sa = sa.at[victim].set(False)
                sl = sl.at[victim].set(0.0)
                pl2 = pl2.at[victim].set(0.0)
                return sa, sl, srem, wp2, wr2, pl2, npre + 1

            (slot_active, slot_load, slot_rem, wp, wr, pl, n_pre) = \
                jax.lax.while_loop(
                    over, evict,
                    (slot_active, slot_load, slot_rem, wp, wr, pl, n_pre))

        return LoopState(slot_active, slot_load, slot_rem, wp, wr,
                         state.tot_imbalance + imb,
                         state.tot_steps + 1, pl, adm, n_pre), None

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def run(state: LoopState, n_steps: int) -> LoopState:
        state, _ = jax.lax.scan(step, state, None, length=n_steps)
        return state

    return run


def init_loop_state(G: int, B: int, wait_prefill, wait_remaining,
                    wait_cap: int) -> LoopState:
    S = G * B
    W = wait_cap
    wp = jnp.zeros((W,), jnp.float32).at[:len(wait_prefill)].set(
        jnp.asarray(wait_prefill, jnp.float32))
    wr = jnp.zeros((W,), jnp.int32).at[:len(wait_remaining)].set(
        jnp.asarray(wait_remaining, jnp.int32))
    return LoopState(
        slot_active=jnp.zeros((S,), bool),
        slot_load=jnp.zeros((S,), jnp.float32),
        slot_remaining=jnp.zeros((S,), jnp.int32),
        wait_prefill=wp,
        wait_remaining=wr,
        tot_imbalance=jnp.zeros((), jnp.float32),
        tot_steps=jnp.zeros((), jnp.int32),
        slot_prefill_left=jnp.zeros((S,), jnp.float32),
        slot_admit_step=jnp.full((S,), -1, jnp.int32),
        tot_preempts=jnp.zeros((), jnp.int32),
    )
