"""Shared fixed-shape slot-table state for the serving runtimes.

Both the host engine (:mod:`repro.serving.engine`) and the device-side
loop (:mod:`repro.serving.device_loop`) model the fleet as G workers x B
KV-cache slots, flattened into one table of ``N = G * B`` slots where
slot ``s`` belongs to worker ``s // B``.  This module is the single
definition of that layout:

* :func:`slot_worker_map` — the static slot -> worker index map;
* :class:`SlotTable` — numpy array state (``active``, ``load``, per-slot
  request bookkeeping) with vectorized per-worker reductions and free-slot
  allocation, replacing the per-slot Python loops of the seed engine;
* :func:`cap_assignment` — clamp a policy's worker assignment to the
  available per-worker capacities (a policy that over-subscribes a worker
  keeps the excess requests waiting instead of crashing placement).
"""
from __future__ import annotations

import numpy as np

__all__ = ["slot_worker_map", "SlotTable", "cap_assignment"]


def slot_worker_map(G: int, B: int) -> np.ndarray:
    """(G*B,) int64: worker owning each flat slot (slot s -> s // B)."""
    return np.repeat(np.arange(G, dtype=np.int64), B)


def cap_assignment(assignment: np.ndarray, caps: np.ndarray) -> np.ndarray:
    """Clamp ``assignment`` (candidate -> worker id, -1 = keep waiting) so
    that at most ``caps[g]`` candidates map to worker g, keeping the
    earliest candidates (arrival order).  Returns a new array with the
    excess entries reset to -1."""
    assignment = np.asarray(assignment, dtype=np.int64)
    out = assignment.copy()
    sel = np.flatnonzero(assignment >= 0)
    if sel.size == 0:
        return out
    g = assignment[sel]
    # running rank of each candidate within its worker (stable in order)
    order = np.argsort(g, kind="stable")
    gs = g[order]
    is_start = np.r_[True, gs[1:] != gs[:-1]]
    group_start = np.maximum.accumulate(
        np.where(is_start, np.arange(gs.size), 0))
    rank_sorted = np.arange(gs.size) - group_start
    rank = np.empty_like(rank_sorted)
    rank[order] = rank_sorted
    caps = np.asarray(caps, dtype=np.int64)
    out[sel[rank >= caps[g]]] = -1
    return out


class SlotTable:
    """Vectorized host-side slot state over the flat G*B table.

    Pure bookkeeping — holds no request objects, only per-slot scalars, so
    every per-worker reduction the engine hot path needs (loads, counts,
    caps, active set) is one numpy op instead of a Python loop over slots.
    """

    def __init__(self, G: int, B: int):
        self.G, self.B = int(G), int(B)
        N = self.G * self.B
        self.N = N
        self.worker = slot_worker_map(G, B)
        self.active = np.zeros(N, dtype=bool)
        self.load = np.zeros(N, dtype=np.float64)
        # chunked prefill: prompt tokens of each slot not yet prefilled.
        # An active slot with prefill_left > 0 holds a mid-prefill request
        # (occupies capacity, contributes its partial load, does not
        # decode).  Always zero when the engine runs synchronous prefill.
        self.prefill_left = np.zeros(N, dtype=np.int64)

    # -- per-worker reductions -----------------------------------------
    def loads(self) -> np.ndarray:
        """(G,) sum of active slot loads per worker."""
        return np.bincount(self.worker,
                           weights=np.where(self.active, self.load, 0.0),
                           minlength=self.G)

    def counts(self) -> np.ndarray:
        """(G,) number of active slots per worker."""
        return np.bincount(self.worker[self.active],
                           minlength=self.G).astype(np.int64)

    def caps(self) -> np.ndarray:
        """(G,) free slots per worker."""
        return self.B - self.counts()

    def active_indices(self) -> np.ndarray:
        """Ascending flat indices of active slots."""
        return np.flatnonzero(self.active)

    def decode_indices(self) -> np.ndarray:
        """Ascending flat indices of slots that are active AND done
        prefilling — the set a barrier decode step runs over."""
        return np.flatnonzero(self.active & (self.prefill_left == 0))

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    # -- mutation -------------------------------------------------------
    def allocate(self, workers: np.ndarray) -> np.ndarray:
        """Claim one free slot per entry of ``workers`` (worker ids, may
        repeat) and mark them active.  Returns the flat slot indices, in
        the same order as ``workers``.  Raises RuntimeError if any worker
        lacks enough free slots (callers should cap assignments first —
        see :func:`cap_assignment`)."""
        workers = np.asarray(workers, dtype=np.int64)
        slots = np.empty(workers.size, dtype=np.int64)
        for g in np.unique(workers):
            mask = workers == g
            lo, hi = g * self.B, (g + 1) * self.B
            free = np.flatnonzero(~self.active[lo:hi]) + lo
            need = int(mask.sum())
            if need > free.size:
                raise RuntimeError(
                    f"worker {g} over-subscribed: {need} placements for "
                    f"{free.size} free slots (policy assignment not capped?)")
            slots[mask] = free[:need]
        self.active[slots] = True
        return slots

    def release(self, slots: np.ndarray) -> None:
        self.active[slots] = False
        self.load[slots] = 0.0
        self.prefill_left[slots] = 0
