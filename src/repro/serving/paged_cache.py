"""Paged KV cache: vLLM-style block-table memory management in JAX.

The engine's naive cache reserves max_seq_len per slot; under the paper's
workload (geometric decode lengths, heavy prefill dispersion) that wastes
most of HBM.  Paging allocates fixed-size KV blocks from a shared pool and
maps request -> [block ids], so resident KV equals actual tokens (rounded
to the block size).  This is the memory substrate that makes the paper's
B=72-slots-per-worker batching feasible at 32k contexts.

Host-side allocator (python, like real engines' schedulers) + device-side
paged gather/attention (see repro.kernels.paged_attention for the Pallas
kernel; the jnp path here is the oracle and CPU path).

Layout: pool tensors k/v of shape (n_blocks, block_size, Hkv, hd); block
tables (B, max_blocks) int32 (-1 = unallocated).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockAllocator", "PagedKVCache", "paged_decode_attention_ref"]


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks (host side).

    Blocks are reference-counted: ``alloc`` hands out blocks at refcount 1,
    ``add_ref`` pins a block for sharing (prefix caching), and ``free``
    decrements — a block returns to the free list only when its last
    reference drops.  Freeing a block that is not allocated (double-free)
    raises instead of silently pushing a duplicate id onto the free list,
    which would later hand the same physical block to two requests and
    corrupt both caches.
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, -1, -1))
        self._refs = np.zeros(n_blocks, dtype=np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def ref_count(self, block: int) -> int:
        return int(self._refs[block])

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free):
            raise MemoryError(
                f"KV pool exhausted: want {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._refs[out] = 1
        return out

    def add_ref(self, block: int) -> None:
        """Pin an allocated block (shared prefix): one more ``free`` is
        then needed before the block returns to the pool."""
        if block < 0 or block >= self.n_blocks:
            raise ValueError(f"bad block id {block}")
        if self._refs[block] <= 0:
            raise ValueError(f"add_ref on unallocated block {block}")
        self._refs[block] += 1

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b < 0 or b >= self.n_blocks:
                raise ValueError(f"bad block id {b}")
            if self._refs[b] <= 0:
                raise ValueError(
                    f"double free of block {b} (refcount already 0)")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)


@dataclasses.dataclass
class PagedKVCache:
    """One layer-stacked paged cache + per-request block tables."""

    k_pool: jnp.ndarray          # (layers, n_blocks, block, Hkv, hd)
    v_pool: jnp.ndarray
    block_tables: np.ndarray     # (B, max_blocks) int32, host-managed
    lengths: np.ndarray          # (B,) int32, host mirror
    block_size: int
    allocator: BlockAllocator
    req_blocks: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def create(cls, *, n_layers: int, n_blocks: int, block_size: int,
               n_kv_heads: int, head_dim: int, max_requests: int,
               max_blocks_per_req: int, dtype=jnp.bfloat16):
        z = jnp.zeros((n_layers, n_blocks, block_size, n_kv_heads,
                       head_dim), dtype)
        return cls(
            k_pool=z, v_pool=jnp.zeros_like(z),
            block_tables=np.full((max_requests, max_blocks_per_req), -1,
                                 dtype=np.int32),
            lengths=np.zeros(max_requests, dtype=np.int32),
            block_size=block_size,
            allocator=BlockAllocator(n_blocks),
        )

    # -- host-side bookkeeping -------------------------------------------
    def admit(self, slot: int, prompt_len: int) -> None:
        """Reserve blocks for a request's prompt KV (after prefill)."""
        n = -(-max(prompt_len, 1) // self.block_size)
        blocks = self.allocator.alloc(n)
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :n] = blocks
        self.lengths[slot] = prompt_len
        self.req_blocks[slot] = blocks

    def append_token(self, slot: int) -> None:
        """Grow by one token; allocate a new block at block boundaries.
        Same freeze-at-capacity overflow semantics as
        :meth:`append_tokens` (a full block table stops growing)."""
        self.lengths[slot] += 1
        L = int(self.lengths[slot])
        n_have = len(self.req_blocks.get(slot, []))
        n_need = min(-(-L // self.block_size), self.block_tables.shape[1])
        if n_need > n_have:
            new = self.allocator.alloc(n_need - n_have)
            self.block_tables[slot, n_have:n_need] = new
            self.req_blocks[slot].extend(new)

    def append_tokens(self, slots: np.ndarray) -> None:
        """Batched :meth:`append_token`: grow every slot in ``slots`` by
        one token, allocating a block only for rows crossing a block
        boundary (1/block_size of decode steps per slot).

        A slot whose block table is already full stops growing: its
        length keeps counting (positions matter for RoPE) but the
        overflow token's KV has nowhere to land and is dropped — the
        same freeze-at-capacity behavior as the contiguous slot layout,
        whose writes past ``max_seq_len`` fall off the scatter."""
        slots = np.asarray(slots)
        self.lengths[slots] += 1
        crossing = (self.lengths[slots] - 1) % self.block_size == 0
        max_blocks = self.block_tables.shape[1]
        for s in slots[crossing]:
            s = int(s)
            blocks = self.req_blocks[s]
            if len(blocks) >= max_blocks:
                continue  # table full: decode continues on frozen KV
            new = self.allocator.alloc(1)
            self.block_tables[s, len(blocks)] = new[0]
            blocks.extend(new)

    def ensure_capacity(self, slot: int, new_len: int) -> None:
        """Grow a slot's block list to cover ``new_len`` tokens (chunked
        prefill: blocks are allocated chunk by chunk, not all at
        admission) and set its length."""
        blocks = self.req_blocks.setdefault(slot, [])
        need = -(-max(new_len, 1) // self.block_size)
        if need > len(blocks):
            new = self.allocator.alloc(need - len(blocks))
            self.block_tables[slot, len(blocks):need] = new
            blocks.extend(new)
        self.lengths[slot] = new_len

    def release(self, slot: int) -> None:
        blocks = self.req_blocks.pop(slot, [])
        self.allocator.free(blocks)
        self.block_tables[slot, :] = -1
        self.lengths[slot] = 0

    @property
    def used_blocks(self) -> int:
        return self.allocator.n_blocks - self.allocator.n_free

    def resident_bytes(self) -> int:
        """Bytes of KV actually occupied by live requests (both pools,
        all layers) — the paging win is this scaling with tokens rather
        than with n_slots * max_seq_len."""
        layers = self.k_pool.shape[0]
        per_block = int(np.prod(self.k_pool.shape[2:]))
        return 2 * self.used_blocks * layers * per_block \
            * self.k_pool.dtype.itemsize

    def utilization(self) -> float:
        return self.used_blocks / max(self.allocator.n_blocks, 1)

    # -- device-side ops ---------------------------------------------------
    def write_prompt(self, layer: int, slot: int, k: jnp.ndarray,
                     v: jnp.ndarray) -> None:
        """Scatter a prompt's KV (S, Hkv, hd) into this request's blocks."""
        S = k.shape[0]
        bs = self.block_size
        n = -(-S // bs)
        pad = n * bs - S
        if pad:
            k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
        kb = k.reshape(n, bs, *k.shape[1:])
        vb = v.reshape(n, bs, *v.shape[1:])
        idx = jnp.asarray(self.block_tables[slot, :n], jnp.int32)
        self.k_pool = self.k_pool.at[layer, idx].set(
            kb.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, idx].set(
            vb.astype(self.v_pool.dtype))

    def write_token(self, layer: int, slot: int, k: jnp.ndarray,
                    v: jnp.ndarray) -> None:
        """Write one token's KV (Hkv, hd) at the current length position."""
        pos = int(self.lengths[slot]) - 1
        blk = self.block_tables[slot, pos // self.block_size]
        off = pos % self.block_size
        self.k_pool = self.k_pool.at[layer, blk, off].set(
            k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, blk, off].set(
            v.astype(self.v_pool.dtype))


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               block_size: int):
    """One-token GQA attention over a paged cache (jnp oracle).

    q: (B, Hq, hd); k_pool/v_pool: (n_blocks, block, Hkv, hd) for ONE
    layer; block_tables: (B, max_blocks) int32; lengths: (B,).
    """
    B, hq, hd = q.shape
    hkv = k_pool.shape[2]
    g = hq // hkv
    max_blocks = block_tables.shape[1]
    L = max_blocks * block_size
    # gather each request's blocks into a contiguous view (oracle only;
    # the Pallas kernel streams blocks without materializing this)
    bt = jnp.clip(block_tables, 0, k_pool.shape[0] - 1)
    k = k_pool[bt]                          # (B, max_blocks, bs, Hkv, hd)
    v = v_pool[bt]
    k = k.reshape(B, L, hkv, hd)
    v = v.reshape(B, L, hkv, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.reshape(B, hkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,blhd->bhgl", qf, k.astype(jnp.float32))
    pos = jnp.arange(L)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, hq, hd).astype(q.dtype)
