"""Paged KV cache: vLLM-style block-table memory management in JAX.

The engine's naive cache reserves max_seq_len per slot; under the paper's
workload (geometric decode lengths, heavy prefill dispersion) that wastes
most of HBM.  Paging allocates fixed-size KV blocks from a shared pool and
maps request -> [block ids], so resident KV equals actual tokens (rounded
to the block size).  This is the memory substrate that makes the paper's
B=72-slots-per-worker batching feasible at 32k contexts.

Host-side allocator (python, like real engines' schedulers) + device-side
paged gather/attention (see repro.kernels.paged_attention for the Pallas
kernel; the jnp path here is the oracle and CPU path).

Block lifecycle (three states, vLLM-evictor style):

* **referenced** — refcount > 0; owned by one or more resident requests.
* **cached** — refcount 0 but still prefix-indexed: with
  ``evict="lru"`` (default) a prefix-indexed block whose last reference
  drops moves onto an LRU *cached* list instead of the free list.  Its
  KV content stays valid (nobody writes a refcount-0 block), so a later
  request with the same prompt prefix can *revive* it via ``add_ref``
  even though every original holder has finished — the lifetime bug the
  admission-scoped mode (``evict="admission"``) suffers from.
* **free** — on the free list; content is garbage.

``alloc`` serves from the free list first and reclaims LRU-cached
blocks only when the free list is empty; *reclaim* (not release) is the
transition that evicts the block's :class:`PrefixIndex` entry, always
before the block is handed back out.  Admission and preemption gates
must therefore budget against free + cached (``n_reclaimable``), not
``n_free`` alone.

Layout: pool tensors k/v of shape (n_blocks, block_size, Hkv, hd); block
tables (B, max_blocks) int32 (-1 = unallocated).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockAllocator", "PrefixIndex", "PagedKVCache",
           "paged_decode_attention_ref"]


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks (host side).

    Blocks are reference-counted: ``alloc`` hands out blocks at refcount 1,
    ``add_ref`` pins a block for sharing (prefix caching), and ``free``
    decrements — a block leaves the referenced state only when its last
    reference drops.  Freeing a block that is not allocated (double-free)
    raises instead of silently pushing a duplicate id onto the free list,
    which would later hand the same physical block to two requests and
    corrupt both caches.

    With a :class:`PrefixIndex` attached (``self.prefix``) and
    ``evict="lru"``, a prefix-indexed block whose last reference drops
    is *retained* on an LRU cached list (refcount 0, content intact,
    still indexed) instead of being freed; ``alloc`` reclaims cached
    blocks oldest-first only once the free list is empty and evicts
    their index entries at that moment — so an index entry can point at
    a referenced or a cached block, never at a recycled one.
    ``add_ref`` on a cached block *revives* it (back to refcount 1).
    ``evict="admission"`` keeps the legacy lifetime: the cached list
    stays empty and every last-ref drop is released (and evicted by the
    owning :class:`PagedKVCache`) immediately.  Without an attached
    index both modes behave identically, bit-for-bit.
    """

    def __init__(self, n_blocks: int, evict: str = "lru"):
        if evict not in ("lru", "admission"):
            raise ValueError(
                f"evict must be 'lru' or 'admission', got {evict!r}")
        self.n_blocks = n_blocks
        self.evict = evict
        self._free = list(range(n_blocks - 1, -1, -1))
        self._refs = np.zeros(n_blocks, dtype=np.int32)
        # LRU cached list: block id -> None, oldest first (insertion
        # order; touch() re-inserts at the MRU end)
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self.prefix: Optional["PrefixIndex"] = None
        self.blocks_reclaimed = 0    # cumulative cached -> reallocated
        self.blocks_revived = 0      # cumulative cached -> re-pinned

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    @property
    def n_reclaimable(self) -> int:
        """Blocks ``alloc`` can serve right now: free + cached (cached
        blocks are reclaimed LRU-first when the free list runs dry).
        Admission/preemption budgets must gate on this, not ``n_free``,
        or a warm cache would false-trigger ``MemoryError``."""
        return len(self._free) + len(self._cached)

    def ref_count(self, block: int) -> int:
        return int(self._refs[block])

    def is_live(self, block: int) -> bool:
        """True when the block's content is valid to share: referenced
        by a resident request, or retained on the cached list."""
        return self._refs[block] > 0 or block in self._cached

    def touch(self, block: int) -> None:
        """Refresh a cached block's LRU recency (a prefix-cache hit)."""
        if block in self._cached:
            self._cached.move_to_end(block)

    def alloc(self, n: int = 1) -> list[int]:
        if n > len(self._free) + len(self._cached):
            raise MemoryError(
                f"KV pool exhausted: want {n}, have {len(self._free)} "
                f"free + {len(self._cached)} reclaimable-cached")
        out: list[int] = []
        reclaimed: list[int] = []
        for _ in range(n):
            if self._free:
                out.append(self._free.pop())
            else:
                b, _ = self._cached.popitem(last=False)   # LRU victim
                reclaimed.append(b)
                out.append(b)
        if reclaimed:
            # reclaim (not release) evicts: the entry dies exactly when
            # the block's content is about to be overwritten
            self.blocks_reclaimed += len(reclaimed)
            if self.prefix is not None:
                self.prefix.evict(reclaimed)
        self._refs[out] = 1
        return out

    def add_ref(self, block: int) -> None:
        """Pin a block for sharing (prefix hit): one more ``free`` is
        then needed before the block leaves the referenced state.  On a
        *cached* block this revives it — off the LRU list, refcount 1 —
        which is how a hit outlives its original holders."""
        if block < 0 or block >= self.n_blocks:
            raise ValueError(f"bad block id {block}")
        if self._refs[block] > 0:
            self._refs[block] += 1
            return
        if block in self._cached:
            del self._cached[block]
            self._refs[block] = 1
            self.blocks_revived += 1
            return
        raise ValueError(f"add_ref on unallocated block {block}")

    # RA202 sees no release verb here because the free list is a plain
    # python list (``_free.append``) — the method IS the pool's release
    # primitive; everything above it (PagedKVCache._free, swap_out,
    # discard) satisfies the contract by calling it.
    def free(self, blocks: list[int]) -> list[int]:  # ra: ignore[RA202]
        """Drop one reference per block; returns the blocks whose last
        reference dropped *and* went back to the free list — callers
        holding a prefix index must evict exactly those.  In ``"lru"``
        mode a prefix-indexed block is retained on the cached list
        instead (MRU end) and is absent from the returned list: its
        index entry stays valid until the block is reclaimed."""
        released = []
        for b in blocks:
            if b < 0 or b >= self.n_blocks:
                raise ValueError(
                    f"bad block id {b} (pool has {self.n_blocks} blocks)")
            if self._refs[b] <= 0:
                raise ValueError(
                    f"double free of block {b}: refcount is "
                    f"{int(self._refs[b])}, block is not allocated")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                if (self.evict == "lru" and self.prefix is not None
                        and self.prefix.contains_block(b)):
                    self._cached[b] = None
                else:
                    self._free.append(b)
                    released.append(b)
        return released


class PrefixIndex:
    """Block-granular prefix cache: chained content hashes -> block ids.

    A block's key hashes (parent_key, its token ids); equality of the
    64-bit hash alone is NOT trusted — every entry stores its (parent,
    tokens) pair and :meth:`lookup` verifies them, so a hash collision
    degrades to a miss instead of silently serving another prompt's KV.
    With the parent verified inductively, a hit proves the *entire*
    token prefix up to and including that block is equal — and therefore
    (causal attention) the KV content is too.  Full prompt blocks and
    the partial tail block are both indexed; a partial-tail hit is what
    later forces copy-on-write when the sharer appends its first
    divergent token (:meth:`PagedKVCache.append_tokens`).

    Entries never pin blocks: the index holds no reference, and
    :meth:`evict` must be called with every block returning to the free
    list or being reclaimed off the cached list
    (``BlockAllocator.free`` returns the former; ``alloc`` evicts the
    latter itself), so a key can never resolve to a block that was
    recycled to another request.  An entry *may* point at a refcount-0
    block as long as it sits on the allocator's cached list — that is
    the persistent-cache state; check ``BlockAllocator.is_live`` before
    sharing.
    """

    def __init__(self):
        self._by_key: dict = {}     # key -> (block, parent, span)
        self._by_block: dict = {}   # block id -> key
        self.hits = 0
        self.queries = 0

    def __len__(self) -> int:
        return len(self._by_key)

    def note_lookup(self, queries: int, hits: int) -> None:
        """Record a batch of lookup outcomes in the hit-rate counters
        (kept behind a method so backends never write index state)."""
        self.queries += queries
        self.hits += hits

    @staticmethod
    def chain(parent: Optional[int], tokens) -> int:
        """Key of the block holding ``tokens``, whose predecessor block
        (None for the first) hashed to ``parent``."""
        return hash((parent, tuple(int(t) for t in np.asarray(tokens))))

    def keys_for(self, tokens, block_size: int) -> list[tuple]:
        """Chained ``(key, parent, span)`` triples for a prompt: one per
        full block plus one for the partial tail (if any), in block
        order.  ``span`` is the block's token tuple — lookup/register
        verify it so hash collisions cannot alias prefixes."""
        tokens = np.asarray(tokens)
        out: list[tuple] = []
        parent = None
        for start in range(0, len(tokens), block_size):
            span = tuple(int(t) for t in tokens[start:start + block_size])
            key = self.chain(parent, span)
            out.append((key, parent, span))
            parent = key
        return out

    def lookup(self, key: int, parent: Optional[int],
               span: tuple) -> Optional[int]:
        """Block id whose verified content chain matches, else None."""
        entry = self._by_key.get(key)
        if entry is None:
            return None
        block, p, s = entry
        if p != parent or s != span:
            return None             # 64-bit hash collision: a miss
        return block

    def register(self, key: int, parent: Optional[int], span: tuple,
                 block: int) -> None:
        """First registration wins; a block maps to at most one key."""
        if key not in self._by_key and block not in self._by_block:
            self._by_key[key] = (block, parent, span)
            self._by_block[block] = key

    def contains_block(self, block: int) -> bool:
        """True when ``block`` backs an index entry (referenced or
        cached holder of some prefix span)."""
        return block in self._by_block

    def evict(self, blocks) -> None:
        for b in blocks:
            key = self._by_block.pop(b, None)
            if key is not None:
                del self._by_key[key]


@dataclasses.dataclass
class PagedKVCache:
    """One layer-stacked paged cache + per-request block tables."""

    k_pool: jnp.ndarray          # (layers, n_blocks, block, Hkv, hd)
    v_pool: jnp.ndarray
    block_tables: np.ndarray     # (B, max_blocks) int32, host-managed
    lengths: np.ndarray          # (B,) int32, host mirror
    block_size: int
    allocator: BlockAllocator
    req_blocks: dict = dataclasses.field(default_factory=dict)

    # optional prefix cache (see PrefixIndex): when set, appends into
    # shared blocks copy-on-write first and last-ref drops either evict
    # (evict="admission") or retain on the allocator's LRU cached list
    # (evict="lru").  The index lives on the allocator so the
    # cached-state machinery (retain / revive / reclaim-evict) and the
    # index can never disagree about a block's liveness.
    @property
    def prefix(self) -> Optional[PrefixIndex]:
        return self.allocator.prefix

    @prefix.setter
    def prefix(self, value: Optional[PrefixIndex]) -> None:
        self.allocator.prefix = value

    @classmethod
    def create(cls, *, n_layers: int, n_blocks: int, block_size: int,
               n_kv_heads: int, head_dim: int, max_requests: int,
               max_blocks_per_req: int, dtype=jnp.bfloat16,
               prefix_evict: str = "lru"):
        z = jnp.zeros((n_layers, n_blocks, block_size, n_kv_heads,
                       head_dim), dtype)
        return cls(
            k_pool=z, v_pool=jnp.zeros_like(z),
            block_tables=np.full((max_requests, max_blocks_per_req), -1,
                                 dtype=np.int32),
            lengths=np.zeros(max_requests, dtype=np.int32),
            block_size=block_size,
            allocator=BlockAllocator(n_blocks, evict=prefix_evict),
        )

    # -- host-side bookkeeping -------------------------------------------
    def _free(self, blocks: list[int]) -> None:
        # blocks the allocator actually returned to the free list must
        # leave the index; indexed last-ref drops in "lru" mode are
        # retained (cached) by the allocator and stay indexed until
        # reclaim evicts them
        released = self.allocator.free(blocks)
        if self.prefix is not None and released:
            self.prefix.evict(released)

    def admit(self, slot: int, prompt_len: int,
              shared: tuple[int, ...] = ()) -> None:
        """Reserve blocks for a request's prompt KV (after prefill).

        ``shared`` is a leading run of already-populated block ids (a
        prefix-cache hit, see :class:`PrefixIndex`): they are pinned via
        ``add_ref`` and become this request's first blocks copy-free; only
        the remaining blocks are freshly allocated."""
        n = -(-max(prompt_len, 1) // self.block_size)
        shared = list(shared[:n])
        pinned: list[int] = []
        try:
            for b in shared:
                self.allocator.add_ref(b)
                pinned.append(b)
            blocks = shared + self.allocator.alloc(n - len(shared))
        except (MemoryError, ValueError):
            # roll back the pins so a failed admit leaks nothing (RA205)
            self._free(pinned)
            raise
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :n] = blocks
        self.lengths[slot] = prompt_len
        self.req_blocks[slot] = blocks

    def set_length(self, slot: int, length: int) -> None:
        """Set ``slot``'s written-KV length (resume paths where the
        victim decoded past the cap on frozen KV keep their RoPE
        position counter instead of restarting at the cap)."""
        self.lengths[slot] = int(length)

    def adopt_blocks(self, slot: int, blocks: list[int],
                     length: int) -> None:
        """Point ``slot`` at ``blocks`` — already owned by the caller
        via ``alloc``/``add_ref`` — and set its length.  This is the
        supported way for backends to rebind a slot's table (swap-in,
        chunk-prefix seeding) without touching pool internals."""
        blocks = list(blocks)
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :len(blocks)] = blocks
        self.req_blocks[slot] = blocks
        self.lengths[slot] = int(length)

    def _cow(self, slot: int, bi: int) -> tuple[int, int]:
        """Copy-on-write block ``bi`` of ``slot``: allocate a private
        copy, repoint the table, drop the shared reference.  Returns the
        (old, new) ids; the caller batches the pool copies."""
        blocks = self.req_blocks[slot]
        old = blocks[bi]
        new = self.allocator.alloc(1)[0]
        self._free([old])   # refcount > 1 here, so never released
        blocks[bi] = new
        self.block_tables[slot, bi] = new
        return old, new

    def _apply_cow(self, pairs: list[tuple[int, int]]) -> None:
        if not pairs:
            return
        old = jnp.asarray([p[0] for p in pairs], jnp.int32)
        new = jnp.asarray([p[1] for p in pairs], jnp.int32)
        self.k_pool = self.k_pool.at[:, new].set(self.k_pool[:, old])
        self.v_pool = self.v_pool.at[:, new].set(self.v_pool[:, old])

    def append_token(self, slot: int) -> None:
        """Grow by one token; allocate a new block at block boundaries
        and copy-on-write a shared last block before the append lands in
        it.  Same freeze-at-capacity overflow semantics as
        :meth:`append_tokens` (a full block table stops growing)."""
        self.append_tokens(np.asarray([slot]))

    def append_tokens(self, slots: np.ndarray) -> None:
        """Batched grow-by-one-token for every slot in ``slots``: a block
        is allocated only for rows crossing a block boundary
        (1/block_size of decode steps per slot), and a row about to
        append into a *shared* block (refcount > 1 — prefix-cache
        partial-tail hit) first copies it on write so the divergent
        token never corrupts the other holders.

        A slot whose block table is already full stops growing: its
        length keeps counting (positions matter for RoPE) but the
        overflow token's KV has nowhere to land and is dropped — the
        same freeze-at-capacity behavior as the contiguous slot layout,
        whose writes past ``max_seq_len`` fall off the scatter."""
        slots = np.asarray(slots)
        self.lengths[slots] += 1
        crossing = (self.lengths[slots] - 1) % self.block_size == 0
        max_blocks = self.block_tables.shape[1]
        for s in slots[crossing]:
            s = int(s)
            blocks = self.req_blocks[s]
            need = min(-(-int(self.lengths[s]) // self.block_size),
                       max_blocks)
            if len(blocks) >= need:
                # table full (frozen KV) or the crossing position is
                # already covered (admit() reserves >= 1 block even for
                # an empty prompt, whose first token lands at pos 0)
                continue
            new = self.allocator.alloc(1)
            self.block_tables[s, len(blocks)] = new[0]
            blocks.extend(new)
        cow_pairs = []
        for s in slots[~crossing]:
            s = int(s)
            bi = (int(self.lengths[s]) - 1) // self.block_size
            blocks = self.req_blocks.get(s, [])
            if bi < len(blocks) and self.allocator.ref_count(blocks[bi]) > 1:
                cow_pairs.append(self._cow(s, bi))
        self._apply_cow(cow_pairs)

    def ensure_capacity(self, slot: int, new_len: int) -> None:
        """Grow a slot's block list to cover ``new_len`` tokens (chunked
        prefill: blocks are allocated chunk by chunk, not all at
        admission) and set its length.  ``need`` is clamped to the block
        table's width: growth past a full table freezes the block list
        (same freeze-at-capacity semantics as :meth:`append_tokens` —
        the length keeps counting, overflow writes are dropped) instead
        of raising a shape-mismatch ``ValueError`` on the table row."""
        blocks = self.req_blocks.setdefault(slot, [])
        need = min(-(-max(new_len, 1) // self.block_size),
                   self.block_tables.shape[1])
        if need > len(blocks):
            new = self.allocator.alloc(need - len(blocks))
            self.block_tables[slot, len(blocks):need] = new
            blocks.extend(new)
        self.lengths[slot] = new_len

    def append_demand(self, slots: np.ndarray) -> int:
        """Blocks :meth:`append_tokens` would allocate for ``slots`` —
        boundary crossings plus copy-on-write of shared last blocks.  The
        engine pre-budgets this and preempts until the pool can serve it,
        so the allocator never raises mid-decode."""
        slots = np.asarray(slots)
        if slots.size == 0:
            return 0
        max_blocks = self.block_tables.shape[1]
        need = 0
        for s in slots:
            s = int(s)
            pos = int(self.lengths[s])          # write position after +1
            blocks = self.req_blocks.get(s, [])
            if pos % self.block_size == 0:
                covered = min(-(-(pos + 1) // self.block_size),
                              max_blocks)
                need += len(blocks) < covered
            else:
                bi = pos // self.block_size
                need += (bi < len(blocks)
                         and self.allocator.ref_count(blocks[bi]) > 1)
        return need

    def release(self, slot: int) -> None:
        blocks = self.req_blocks.pop(slot, [])
        self._free(blocks)
        self.block_tables[slot, :] = -1
        self.lengths[slot] = 0

    @property
    def used_blocks(self) -> int:
        """Blocks referenced by live requests.  Cached (refcount-0,
        reclaimable) blocks are excluded: they are opportunistic reuse
        of memory nobody demands, not resident footprint — a warm
        persistent cache must not read as KV pressure."""
        return (self.allocator.n_blocks - self.allocator.n_free
                - self.allocator.n_cached)

    def resident_bytes(self) -> int:
        """Bytes of KV actually occupied by live requests (both pools,
        all layers) — the paging win is this scaling with tokens rather
        than with n_slots * max_seq_len."""
        layers = self.k_pool.shape[0]
        per_block = int(np.prod(self.k_pool.shape[2:]))
        return 2 * self.used_blocks * layers * per_block \
            * self.k_pool.dtype.itemsize

    def utilization(self) -> float:
        return self.used_blocks / max(self.allocator.n_blocks, 1)

    # -- device-side ops ---------------------------------------------------
    def write_prompt(self, layer: int, slot: int, k: jnp.ndarray,
                     v: jnp.ndarray) -> None:
        """Scatter a prompt's KV (S, Hkv, hd) into this request's blocks."""
        S = k.shape[0]
        bs = self.block_size
        n = -(-S // bs)
        pad = n * bs - S
        if pad:
            k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
        kb = k.reshape(n, bs, *k.shape[1:])
        vb = v.reshape(n, bs, *v.shape[1:])
        idx = jnp.asarray(self.block_tables[slot, :n], jnp.int32)
        self.k_pool = self.k_pool.at[layer, idx].set(
            kb.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, idx].set(
            vb.astype(self.v_pool.dtype))

    def write_token(self, layer: int, slot: int, k: jnp.ndarray,
                    v: jnp.ndarray) -> None:
        """Write one token's KV (Hkv, hd) at the current length position.

        A frozen slot (length counted past a full block table — see
        :meth:`append_tokens`) has nowhere for the write to land: it is
        dropped, matching the batched decode path's ``in_cap`` clamp in
        ``cache_backend.py`` instead of indexing off the table row."""
        pos = int(self.lengths[slot]) - 1
        if pos // self.block_size >= self.block_tables.shape[1]:
            return                   # frozen KV: overflow write dropped
        blk = self.block_tables[slot, pos // self.block_size]
        off = pos % self.block_size
        self.k_pool = self.k_pool.at[layer, blk, off].set(
            k.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[layer, blk, off].set(
            v.astype(self.v_pool.dtype))


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               block_size: int):
    """One-token GQA attention over a paged cache (jnp oracle).

    q: (B, Hq, hd); k_pool/v_pool: (n_blocks, block, Hkv, hd) for ONE
    layer; block_tables: (B, max_blocks) int32; lengths: (B,).
    """
    B, hq, hd = q.shape
    hkv = k_pool.shape[2]
    g = hq // hkv
    max_blocks = block_tables.shape[1]
    L = max_blocks * block_size
    # gather each request's blocks into a contiguous view (oracle only;
    # the Pallas kernel streams blocks without materializing this)
    bt = jnp.clip(block_tables, 0, k_pool.shape[0] - 1)
    k = k_pool[bt]                          # (B, max_blocks, bs, Hkv, hd)
    v = v_pool[bt]
    k = k.reshape(B, L, hkv, hd)
    v = v.reshape(B, L, hkv, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.reshape(B, hkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,blhd->bhgl", qf, k.astype(jnp.float32))
    pos = jnp.arange(L)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, hq, hd).astype(q.dtype)
