"""Serving runtime: the multi-worker decode engine with router-integrated
load balancing (the paper's system, runnable), pluggable cache backends
(contiguous slots / vLLM-style paged KV with prefix caching), the
admission scheduler with chunked prefill and preemption under memory
pressure, and the device-side routed serving loop."""
from .engine import (  # noqa: F401
    EngineConfig,
    LoadSnapshot,
    ServeRequest,
    ServingEngine,
)
from .cache_backend import (  # noqa: F401
    CacheBackend,
    PagedCacheBackend,
    SlotCacheBackend,
    make_cache_backend,
)
from .device_loop import init_loop_state, make_device_serving_loop  # noqa: F401
from .paged_cache import BlockAllocator, PagedKVCache, PrefixIndex  # noqa: F401
from .preemption import (  # noqa: F401
    FIFOPreemption,
    LargestPreemption,
    LIFOPreemption,
    PreemptContext,
    PreemptedState,
    PreemptionPolicy,
    make_preemption_policy,
)
from .scheduler import PrefillJob, Scheduler  # noqa: F401
from .slot_table import SlotTable, cap_assignment, slot_worker_map  # noqa: F401
