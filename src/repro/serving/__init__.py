"""Serving runtime: the multi-worker decode engine with router-integrated
load balancing (the paper's system, runnable), paged KV cache memory
management, and the device-side routed serving loop."""
from .engine import EngineConfig, ServeRequest, ServingEngine  # noqa: F401
from .device_loop import init_loop_state, make_device_serving_loop  # noqa: F401
from .paged_cache import BlockAllocator, PagedKVCache  # noqa: F401
from .slot_table import SlotTable, cap_assignment, slot_worker_map  # noqa: F401
