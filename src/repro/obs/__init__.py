"""Per-request tracing and barrier straggler attribution.

The fleet's telemetry (:mod:`repro.fleet.telemetry`) records *aggregate*
idle energy and imbalance per step.  This package is the microscope
underneath those totals — two instruments threaded through
:class:`~repro.serving.engine.ServingEngine`,
:class:`~repro.fleet.server.FleetServer`, and
:class:`~repro.fleet.async_server.AsyncFleetServer`:

**Per-request spans** (:mod:`repro.obs.trace`).  Every request emits
lifecycle point events on the deterministic sim clock; the exporter
derives duration spans from them and writes Chrome trace-event /
Perfetto JSON (``--trace-out`` on ``launch/serve.py``;
:func:`read_trace` is the validating reader).  The span taxonomy:

* ``queued`` — the request enters the fleet (at its arrival time) or a
  bare engine's wait queue;
* ``routed`` — the fleet router assigns it to a replica;
* ``admitted`` — the engine claims a slot (sync prefill or a chunked
  prefill job);
* ``prefill-chunk`` — one chunked-prefill advance (offset + token
  count in args);
* ``decode`` — the first token lands; decode begins;
* ``preempted`` — swap-out or recompute-drop under memory pressure
  (mode in args);
* ``resumed`` — a preempted victim re-enters a slot (swap-in restore or
  recompute re-admission);
* ``drain-handoff`` — a draining replica hands the request back to the
  fleet queue (async scale-down);
* ``completed`` / ``failed`` — terminal.

Fleet-tier events (track ``FLEET_TRACK``) are timestamped on the fleet
clock; engine-tier events on the owning replica's local clock (replicas
step independently between barriers, so the two clocks intentionally
differ — each Perfetto process row is self-consistent).  The derived
``request`` span on the fleet track carries ``e2e_s`` computed by the
same subtraction as telemetry's ``latency``, so the two are bit-equal.

The default recorder is :data:`NULL_RECORDER`, a no-op: with tracing
disabled no event is ever buffered and engine/fleet stats are
bit-identical to an uninstrumented run (gated by the ``obs`` bench
section).

**Straggler attribution** (:mod:`repro.obs.ledger`).  Each barrier step
the fleet identifies the *gating* replica (the ``argmax`` of the
per-replica step durations — the one every other replica waits for) and
decomposes each replica's barrier-idle joules by cause
(:data:`IDLE_CAUSES`):

* ``prefill_wave`` — the gating replica was processing prefill work
  (fresh admissions or chunked-prefill tokens);
* ``decode_tail`` — the gating replica was decoding a long tail;
* ``preempt_swap`` — the gating replica was preempting / swap-restoring
  victims (async: a DRAINING replica's idle);
* ``routing_miss`` — the replica sat completely idle while work waited
  elsewhere in the fleet (a routable request existed it could have
  served);
* ``warmup`` — a WARMING replica's idle draw before it joins (async
  autoscaling only);
* ``arrival_gap`` — fleet-wide idle between arrival waves (no work
  anywhere; the fast-forward branch of the barrier accounting).

Per step the split is reconciled so its left-fold sum reproduces the
step's idle joules *bit-exactly* (:func:`reconcile_split`); the
fleet-wide :class:`StragglerLedger` folds charges in the same order as
``FleetServer.idle_j``, so ``ledger.total_idle_j == fleet.idle_j`` to
the last bit.  Telemetry schema v4 surfaces the per-step split
(``idle_split``, aligned with :data:`IDLE_CAUSES`) and the gating
replica id (``gating_replica``; ``-1`` for trough and async tick rows).

Workflow: ``launch/serve.py --scenario diurnal --trace-out run.trace
--telemetry-out run.jsonl`` writes both artifacts;
``read_trace("run.trace")`` validates and summarizes the spans;
``FleetTelemetry.read_jsonl`` + ``summary()["idle_by_cause"]`` recovers
the ledger from the telemetry alone.  ``benchmarks/balancer_bench.py
--sections obs`` gates every exactness claim in CI.
"""
from .ledger import (
    IDLE_CAUSES,
    StragglerLedger,
    attribute_step_idle,
    fold_sum,
    reconcile_split,
)
from .trace import (
    FLEET_TRACK,
    NULL_RECORDER,
    NullRecorder,
    SpanRecorder,
    read_trace,
    to_chrome_trace,
    write_trace,
)

__all__ = [
    "IDLE_CAUSES", "StragglerLedger", "attribute_step_idle",
    "fold_sum", "reconcile_split",
    "FLEET_TRACK", "NULL_RECORDER", "NullRecorder", "SpanRecorder",
    "read_trace", "to_chrome_trace", "write_trace",
]
