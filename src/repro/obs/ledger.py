"""Straggler ledger: cause-decomposed barrier-idle accounting.

The fleet's energy theorem prices barrier idle as
``sum_r (dt - dt_r) * P_idle_r`` per step (plus the between-arrival
fast-forward).  This module decomposes those joules by *cause* without
perturbing them: every step's split is reconciled so that a plain
left-fold sum over :data:`IDLE_CAUSES` order reproduces the step's idle
total bit-exactly, and the fleet-wide ledger folds charges in the same
order as ``FleetServer.idle_j`` accumulates — so the two totals are
equal to the last bit, by construction rather than by tolerance.

All arithmetic is plain Python floats + numpy (the charge sites sit on
``host_hot`` paths — see ``repro/analysis/registry.py``).
"""
from __future__ import annotations

import numpy as np

__all__ = ["IDLE_CAUSES", "StragglerLedger", "attribute_step_idle",
           "fold_sum", "reconcile_split"]

# Cause taxonomy (order is the wire order of telemetry v4 `idle_split`
# rows — append-only; see repro.obs package docstring for semantics).
IDLE_CAUSES = ("prefill_wave", "decode_tail", "preempt_swap",
               "routing_miss", "warmup", "arrival_gap")
N_CAUSES = len(IDLE_CAUSES)
CAUSE_INDEX = {name: i for i, name in enumerate(IDLE_CAUSES)}

# engine step phase -> cause charged to the replicas the gating
# (slowest) replica kept waiting
PHASE_CAUSE = {"preempt": CAUSE_INDEX["preempt_swap"],
               "prefill": CAUSE_INDEX["prefill_wave"]}
_DECODE = CAUSE_INDEX["decode_tail"]


def fold_sum(xs) -> float:
    """Left-fold float sum starting at 0.0 — the canonical
    reconstruction order every exactness gate uses.  (``np.sum`` uses
    pairwise summation and ``math.fsum`` compensated summation; both
    may round differently from the sequential ``+=`` the fleet's
    accumulators perform.)"""
    total = 0.0
    for x in xs:
        total += float(x)
    return total


def reconcile_split(total: float, split: np.ndarray) -> np.ndarray:
    """Return a copy of ``split`` whose :func:`fold_sum` reproduces
    ``total`` bit-exactly: one entry absorbs the (at most few-ulp)
    residual between the independently-summed causes and the
    sequentially-accumulated total.

    The preferred fix-up point is the *last nonzero* entry — it is the
    final inexact term of the fold (trailing ``+ 0.0`` are exact), so
    adjusting it never re-rounds a later addition.  A single entry can
    still provably miss: when it shares ``total``'s binade, the
    reachable fold values step by one ulp of ``total`` and the target
    can fall in a gap.  Each candidate entry has a differently-phased
    rounding grid, so on a miss the fix-up cascades through the
    remaining indices; no real fleet step has ever needed the cascade
    (same-step slack magnitudes are homogeneous), but adversarial
    inputs spanning many decades do (see ``tests/test_obs.py``)."""
    out0 = np.asarray(split, dtype=np.float64).copy()
    nz = np.nonzero(out0)[0]
    last = int(nz[-1]) if nz.size else N_CAUSES - 1
    order = [last] + [k for k in range(N_CAUSES - 1, -1, -1)
                      if k != last]
    for j in order:
        out = out0.copy()
        scale = 1.0
        prev = None
        for _ in range(64):
            delta = float(total) - fold_sum(out)
            if delta == 0.0:
                return out
            if prev is not None and abs(delta) >= prev:
                scale *= 0.5        # overshot: damp onto the target
                if scale == 0.0:
                    break
            prev = abs(delta)
            out[j] += delta * scale
    raise ArithmeticError(
        f"idle split failed to reconcile with total={total!r} "
        f"(split={out0.tolist()!r})")


def attribute_step_idle(idle: float, slack: np.ndarray,
                        causes: np.ndarray) -> np.ndarray:
    """Split one barrier step's idle joules by cause.

    ``slack[r]`` is replica r's idle joules this step and ``causes[r]``
    its cause index; the per-cause masked sums are reconciled against
    ``idle`` (the step total the fleet actually accumulated) so the
    split's fold reproduces it bit-exactly."""
    split = np.zeros(N_CAUSES)
    for c in np.unique(causes):
        split[int(c)] = float(slack[causes == c].sum())
    return reconcile_split(idle, split)


class StragglerLedger:
    """Fleet-wide accumulation of cause-attributed idle charges.

    ``charge`` is called exactly once per ``idle_j += ...`` site in the
    fleet (the barrier accounting's per-step charge; the async fleet's
    per-replica advance charges), with the same float, in the same
    order — so :attr:`total_idle_j` folds to ``FleetServer.idle_j``
    bit-exactly.  ``gating_steps`` counts how often each replica gated
    a barrier step (``-1`` charges — troughs, async ticks — land in
    :attr:`trough_steps`)."""

    def __init__(self):
        self.total_idle_j = 0.0
        self.cause_j = np.zeros(N_CAUSES)
        self.gating_steps: dict[int, int] = {}
        self.trough_steps = 0
        self.charges = 0

    def charge(self, idle: float, split: np.ndarray,
               gating: int = -1) -> None:
        """One attributed idle charge: ``split`` must fold to ``idle``
        (see :func:`attribute_step_idle` / :func:`reconcile_split`)."""
        self.total_idle_j += float(idle)
        self.cause_j += split
        if gating >= 0:
            self.gating_steps[gating] = \
                self.gating_steps.get(gating, 0) + 1
        else:
            self.trough_steps += 1
        self.charges += 1

    def charge_one(self, idle: float, cause: int) -> None:
        """Single-cause charge (the async fleet's per-replica advance):
        the whole charge lands on one cause, trivially exact."""
        split = np.zeros(N_CAUSES)
        split[int(cause)] = float(idle)
        self.charge(idle, split)

    def report(self) -> dict:
        """JSON-native ledger summary."""
        return {
            "total_idle_j": float(self.total_idle_j),
            "by_cause": {name: float(self.cause_j[i])
                         for i, name in enumerate(IDLE_CAUSES)},
            "gating_steps": {str(r): int(n) for r, n
                             in sorted(self.gating_steps.items())},
            "trough_steps": int(self.trough_steps),
            "charges": int(self.charges),
        }

    def format(self) -> str:
        """Human-readable ledger table (the serve-cluster demo print)."""
        lines = [f"straggler ledger: {self.total_idle_j:.3f} J idle "
                 f"over {self.charges} charges"]
        tot = max(self.total_idle_j, 1e-300)
        for i, name in enumerate(IDLE_CAUSES):
            j = float(self.cause_j[i])
            if j != 0.0:
                lines.append(f"  {name:<13s} {j:12.3f} J "
                             f"({100.0 * j / tot:5.1f}%)")
        if self.gating_steps:
            top = sorted(self.gating_steps.items(),
                         key=lambda kv: -kv[1])[:5]
            lines.append("  gating replicas: " + ", ".join(
                f"r{r}x{n}" for r, n in top))
        return "\n".join(lines)
