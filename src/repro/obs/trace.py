"""Per-request span recording and Chrome trace-event export.

:class:`SpanRecorder` buffers lifecycle *point* events ``(track, rid,
name, t, args)`` on the deterministic sim clock; the exporter derives
duration spans from them (a ``request`` span per rid from ``queued`` to
its terminal event, a ``decode`` span from first token to terminal) and
writes Chrome trace-event JSON loadable in Perfetto / chrome://tracing.
``track`` is :data:`FLEET_TRACK` for fleet-tier events (fleet clock)
or a replica id for engine-tier events (that replica's local clock);
tracks map to trace ``pid`` rows so each process timeline is
self-consistent.

Timestamps in the trace are microseconds (the trace-event wire unit);
the derived spans *also* carry their duration in sim seconds in
``args`` (``e2e_s`` / ``decode_s``), computed by the same subtraction
the fleet's telemetry performs — the bit-exact span-vs-latency gate
reads those, never the (scaled) ``ts``/``dur`` floats.

:data:`NULL_RECORDER` is the disabled default: every hook is a no-op,
no event is ever buffered, and instrumented runs are bit-identical to
uninstrumented ones (gated by the ``obs`` bench section).
"""
from __future__ import annotations

import json

__all__ = ["FLEET_TRACK", "SpanRecorder", "NullRecorder",
           "NULL_RECORDER", "to_chrome_trace", "write_trace",
           "read_trace"]

FLEET_TRACK = -1          # fleet-tier events (fleet clock); pid 0
_TERMINAL = ("completed", "failed")
_POINT_NAMES = frozenset({
    "queued", "routed", "admitted", "prefill-chunk", "decode",
    "preempted", "resumed", "drain-handoff", "completed", "failed"})


class SpanRecorder:
    """Buffering recorder: ``point`` appends one lifecycle event."""

    enabled = True

    def __init__(self):
        self.events: list[tuple] = []   # (track, rid, name, t, args)

    def point(self, track: int, rid: int, name: str, t: float,
              **args) -> None:
        self.events.append((int(track), int(rid), name, float(t),
                            args or None))

    @property
    def n_events(self) -> int:
        return len(self.events)

    def clear(self) -> None:
        self.events.clear()


class NullRecorder:
    """No-op recorder (tracing disabled): zero buffering, zero rows."""

    enabled = False
    events: tuple = ()
    n_events = 0

    def point(self, track, rid, name, t, **args) -> None:
        pass

    def clear(self) -> None:
        pass


NULL_RECORDER = NullRecorder()


def _pid(track: int) -> int:
    return 0 if track < 0 else int(track) + 1


def to_chrome_trace(recorder) -> dict:
    """Chrome trace-event document: one instant event per recorded
    point, plus derived ``request`` / ``decode`` complete spans per
    (track, rid), plus process-name metadata rows."""
    events = []
    tracks = sorted({track for track, *_ in recorder.events})
    for track in tracks:
        events.append({"name": "process_name", "ph": "M",
                       "pid": _pid(track), "tid": 0,
                       "args": {"name": ("fleet" if track < 0
                                         else f"replica {track}")}})
    # per-(track, rid) lifecycle endpoints for the derived spans
    first: dict[tuple, tuple] = {}       # (track, rid) -> (t, name)
    decode0: dict[tuple, float] = {}
    terminal: dict[tuple, tuple] = {}
    for track, rid, name, t, args in recorder.events:
        ev = {"name": name, "ph": "i", "s": "t", "ts": t * 1e6,
              "pid": _pid(track), "tid": rid}
        if args:
            ev["args"] = dict(args)
        events.append(ev)
        key = (track, rid)
        if key not in first:
            first[key] = (t, name)
        if name == "decode" and key not in decode0:
            decode0[key] = t
        if name in _TERMINAL:
            terminal[key] = (t, name)
    for key, (t1, status) in terminal.items():
        track, rid = key
        t0, name0 = first[key]
        if name0 == "queued":
            events.append({"name": "request", "ph": "X",
                           "ts": t0 * 1e6, "dur": (t1 - t0) * 1e6,
                           "pid": _pid(track), "tid": rid,
                           "args": {"e2e_s": t1 - t0,
                                    "status": status}})
        if key in decode0:
            td = decode0[key]
            events.append({"name": "decode-span", "ph": "X",
                           "ts": td * 1e6, "dur": (t1 - td) * 1e6,
                           "pid": _pid(track), "tid": rid,
                           "args": {"decode_s": t1 - td,
                                    "status": status}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs",
                          "clock": "sim-seconds (ts in us)"}}


def write_trace(recorder, path: str) -> dict:
    """Export ``recorder`` to ``path`` as trace-event JSON; returns the
    document (handy for immediate validation)."""
    doc = to_chrome_trace(recorder)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _validate_event(i: int, ev) -> None:
    if not isinstance(ev, dict):
        raise ValueError(f"traceEvents[{i}]: not an object")
    for field, types in (("name", str), ("ph", str),
                         ("pid", int), ("tid", int)):
        if not isinstance(ev.get(field), types):
            raise ValueError(
                f"traceEvents[{i}]: missing/invalid {field!r}")
    ph = ev["ph"]
    if ph not in ("i", "X", "M"):
        raise ValueError(f"traceEvents[{i}]: unknown phase {ph!r}")
    if ph == "M":
        return
    ts = ev.get("ts")
    if not isinstance(ts, (int, float)) or not ts == ts or ts < 0:
        raise ValueError(f"traceEvents[{i}]: bad ts {ts!r}")
    if ph == "X":
        dur = ev.get("dur")
        if not isinstance(dur, (int, float)) or not dur == dur \
                or dur < 0:
            raise ValueError(f"traceEvents[{i}]: bad dur {dur!r}")
    if ph == "i" and ev["name"] not in _POINT_NAMES:
        raise ValueError(
            f"traceEvents[{i}]: unknown span event {ev['name']!r}")


def read_trace(path: str) -> dict:
    """Validating trace reader: checks every event's schema, rebuilds
    the per-request fleet-track lifecycle, and returns::

        {"n_events": ..., "n_points": ..., "requests":
            {rid: {"queued_s", "end_s", "e2e_s", "status"}}}

    ``e2e_s`` comes from the derived ``request`` span's args — the
    value the exporter computed with fleet-clock subtraction — and is
    cross-checked (to float32-ish tolerance only) against the scaled
    ``ts``/``dur`` pair."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: no traceEvents list")
    requests: dict[int, dict] = {}
    n_points = 0
    for i, ev in enumerate(events):
        _validate_event(i, ev)
        if ev["ph"] == "i":
            n_points += 1
        if ev["ph"] == "X" and ev["name"] == "request" \
                and ev["pid"] == 0:
            args = ev.get("args") or {}
            e2e = args.get("e2e_s")
            if not isinstance(e2e, (int, float)):
                raise ValueError(
                    f"traceEvents[{i}]: request span without e2e_s")
            if abs(ev["dur"] - e2e * 1e6) > 1e-3 + 1e-6 * ev["dur"]:
                raise ValueError(
                    f"traceEvents[{i}]: dur/e2e_s mismatch "
                    f"({ev['dur']!r} us vs {e2e!r} s)")
            rid = ev["tid"]
            if rid in requests:
                raise ValueError(
                    f"traceEvents[{i}]: duplicate request span for "
                    f"rid {rid}")
            requests[rid] = {"queued_s": ev["ts"] / 1e6,
                             "end_s": (ev["ts"] + ev["dur"]) / 1e6,
                             "e2e_s": float(e2e),
                             "status": args.get("status")}
    return {"n_events": len(events), "n_points": n_points,
            "requests": requests}
