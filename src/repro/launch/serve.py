"""Serving launcher: ``python -m repro.launch.serve --arch <id> --policy
bfio_h20`` — drives the BF-IO-routed multi-worker engine end to end.

Fleet mode (``--replicas R`` with R > 1, or ``--scenario``): drives R
engine replicas behind a fleet router (``--router round_robin |
least_loaded | pod2 | bfio | pod_bfio_pP``) on a named scenario trace
(``--scenario steady | flash_crowd | diurnal | agentic | long_doc |
trickle``; omitted = the same synthetic stream as single-engine mode,
all arriving at t=0).  ``--telemetry-out run.jsonl`` streams the
telemetry subsystem's per-step / per-request records plus the summary
to JSONL.  Fleet scaling knobs:

* ``--fleet-mode vec|ref`` picks the vectorized fleet hot path
  (incrementally-updated per-replica load arrays; the default) or the
  reference per-step O(R) re-gather loop kept for the bit-identity
  bench gate — both produce identical stats and telemetry.
* ``--pods P`` with P > 1 shortcuts ``--router pod_bfio_pP``:
  two-level hierarchical routing (capacity-normalized pod pick, then
  one batched BF-IO solve across all pods) for R in the hundreds.
* ``--replica-classes 2xg1b2,2xg2b4`` builds a heterogeneous fleet —
  each ``CxgGbB`` group adds C replicas with G workers x B slots
  (overriding ``--replicas/--workers/--slots``); the router sees
  per-replica capacity and the BF-IO tier balances load against it.
* ``--predictor oracle`` feeds the router each request's decode budget
  as a predicted output length (the BF-IO growth term then prices
  decode, not just prefill).

Async / autoscaling knobs (event-driven fleet):

* ``--async`` swaps the barrier-stepped fleet for the event-driven
  :class:`~repro.fleet.async_server.AsyncFleetServer` — per-replica
  clocks, staleness-bounded routing snapshots.
* ``--autoscale util|slo`` (implies ``--async``) closes the replica-
  count control loop: ``util`` holds windowed busy-fraction near a
  target, ``slo`` scales on windowed SLO attainment.  ``--r-min`` /
  ``--r-max`` bound the fleet size (``--r-max 0`` = ``--replicas``);
  draining replicas hand resident requests off bit-exactly via the
  paged backend's host-staged swap path.
* ``--slo-ttft`` / ``--slo-tpot`` set the SLO the telemetry scorecard
  (and the ``slo`` autoscaler) attains against.

Memory-pressure knobs (``--cache-backend paged`` only):

* ``--pool-blocks N`` sizes the shared KV block pool below the
  every-slot-at-max-seq default, oversubscribing memory the way real
  engines do; on exhaustion the engine *preempts* a victim instead of
  crashing.
* ``--preemption-mode swap|recompute`` picks what happens to the
  victim's KV: staged host-side and restored bit-for-bit on resume
  (swap), or dropped and re-prefilled from prompt + generated tokens
  (recompute).  ``--preemption-policy lifo|fifo|largest`` picks the
  victim.
* ``--prefix-cache`` shares identical prompt-prefix KV blocks across
  requests (content-hash index, copy-on-write on the first divergent
  append) — resident KV then scales with *unique* prefix content.
"""
from __future__ import annotations

import argparse
import dataclasses
import re

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import make_policy
from ..fleet import (
    AsyncFleetServer,
    FleetServer,
    FleetTelemetry,
    SLOSpec,
    make_autoscaler,
    make_scenario,
)
from ..fleet.workloads import SCENARIOS as FLEET_SCENARIOS
from ..models import init_params, split_params
from ..obs import SpanRecorder, read_trace, write_trace
from ..serving import EngineConfig, ServeRequest, ServingEngine
from .mesh import make_cpu_mesh, make_production_mesh


def parse_replica_classes(spec: str, engine_cfg):
    """``"2xg1b2,2xg2b4"`` -> [(2, ec(G=1,B=2)), (2, ec(G=2,B=4))]:
    each ``CxgGbB`` group adds C replicas with G workers x B slots,
    inheriting every other knob from the base engine config."""
    out = []
    for part in spec.split(","):
        m = re.fullmatch(r"(\d+)xg(\d+)b(\d+)", part.strip())
        if not m:
            raise ValueError(
                f"bad replica class {part!r} (want e.g. '2xg1b2')")
        count, g, b = (int(x) for x in m.groups())
        out.append((count, dataclasses.replace(
            engine_cfg, n_workers=g, slots_per_worker=b)))
    return out


def serve_fleet(args, cfg, params, engine_cfg, mesh) -> None:
    """Fleet mode: R replicas behind the router, scenario arrivals,
    telemetry export."""
    router = args.router
    if args.pods > 1:
        router = f"pod_bfio_p{args.pods}"
    classes = parse_replica_classes(args.replica_classes, engine_cfg) \
        if args.replica_classes else None
    n_replicas = sum(c for c, _ in classes) if classes \
        else args.replicas
    telemetry = FleetTelemetry(
        slo=SLOSpec(ttft_s=args.slo_ttft, tpot_s=args.slo_tpot))
    recorder = SpanRecorder() if args.trace_out else None
    common = dict(n_replicas=args.replicas, router=router,
                  policy=args.policy, mesh=mesh, telemetry=telemetry,
                  seed=args.seed, fleet_mode=args.fleet_mode,
                  replica_classes=classes, predictor=args.predictor,
                  obs=recorder)
    if args.async_fleet or args.autoscale:
        autoscaler = None
        if args.autoscale:
            r_max = args.r_max or n_replicas
            autoscaler = make_autoscaler(
                args.autoscale, r_min=args.r_min, r_max=r_max)
        fleet = AsyncFleetServer(cfg, params, engine_cfg,
                                 autoscaler=autoscaler, **common)
    else:
        fleet = FleetServer(cfg, params, engine_cfg, **common)
    if args.scenario:
        sc = make_scenario(
            args.scenario, n_requests=args.requests,
            n_replicas=n_replicas, n_workers=args.workers,
            slots_per_worker=args.slots,
            max_seq_len=engine_cfg.max_seq_len,
            vocab_size=cfg.vocab_size, seed=args.seed)
        fleet.submit_scenario(sc)
    else:
        rng = np.random.default_rng(args.seed)
        for i in range(args.requests):
            fleet.submit(ServeRequest(
                rid=i,
                tokens=rng.integers(1, cfg.vocab_size,
                                    size=int(rng.integers(4, 64))),
                max_new_tokens=args.max_new))
    stats = fleet.run()
    summary = telemetry.summary()
    print(f"[fleet] {cfg.name} R={stats['n_replicas']} "
          f"router={stats['router']} "
          f"scenario={args.scenario or 'synthetic'}: "
          f"{stats['tokens']} tokens in {stats['steps']} steps, "
          f"{stats['throughput_tok_s']:.1f} tok/s, "
          f"E={stats['energy_j']:.1f} J "
          f"({stats['idle_j']:.1f} J barrier idle), "
          f"{stats['energy_per_token']:.3f} J/tok, "
          f"cross-replica imbalance {stats['avg_cross_imbalance']:.1f}")
    def _s(x):     # percentiles are None when nothing completed
        return "n/a" if x is None else f"{x:.3f}s"

    print(f"[fleet] requests: {stats['completed']} done, "
          f"{stats['failed']} failed; "
          f"TTFT p95 {_s(summary['ttft']['p95'])}, "
          f"latency p95 {_s(summary['latency']['p95'])}, "
          f"SLO attainment {summary['slo_attainment']:.0%}")
    if stats.get("fleet_kind") == "async":
        print(f"[fleet] async: utilization {stats['utilization']:.0%}, "
              f"mean replicas on {stats['r_on_mean']:.2f}/"
              f"{stats['n_replicas']}, "
              f"{stats['scale_ups']} scale-ups / "
              f"{stats['scale_downs']} scale-downs, "
              f"{stats['drain_handoffs']} drain handoffs "
              f"({stats['drain_tokens_lost']} tokens recomputed)")
    if args.telemetry_out:
        telemetry.write_jsonl(args.telemetry_out)
        print(f"[fleet] telemetry -> {args.telemetry_out} "
              f"({len(telemetry.steps)} step + "
              f"{len(telemetry.requests)} request records)")
    if recorder is not None:
        write_trace(recorder, args.trace_out)
        seen = read_trace(args.trace_out)   # validate what we wrote
        print(f"[fleet] trace -> {args.trace_out} "
              f"({seen['n_points']} points, "
              f"{len(seen['requests'])} request spans)")
        ledger = fleet.straggler_ledger()
        top = max(ledger["by_cause"].items(),
                  key=lambda kv: kv[1], default=(None, 0.0))
        print(f"[fleet] straggler ledger: "
              f"{ledger['total_idle_j']:.1f} J idle attributed over "
              f"{ledger['charges']} charges; top cause {top[0]} "
              f"({top[1]:.1f} J)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default="bfio_h8")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-backend", default="slot",
                    choices=["slot", "paged"],
                    help="KV layout: contiguous per-slot rows or "
                         "vLLM-style paged blocks")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: max prompt tokens per request "
                         "per step (0 = synchronous)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="total prompt tokens per step across requests "
                         "(0 = same as --prefill-chunk)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="paged KV pool size in blocks (0 = capacity for "
                         "every slot at max_seq_len; smaller pools "
                         "oversubscribe and trigger preemption)")
    ap.add_argument("--preemption-mode", default="swap",
                    choices=["swap", "recompute"],
                    help="victim KV handling under memory pressure: swap "
                         "to host staging (bit-exact resume) or drop and "
                         "re-prefill on resume")
    ap.add_argument("--preemption-policy", default="lifo",
                    choices=["lifo", "fifo", "largest"],
                    help="victim selection under memory pressure")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical prompt-prefix KV blocks across "
                         "requests (paged backend, copy-on-write)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet mode: number of engine replicas behind "
                         "the fleet router (1 = bare engine)")
    ap.add_argument("--router", default="bfio",
                    help="fleet router: round_robin | least_loaded | "
                         "pod2 | bfio[_hH] | pod_bfio[_pP][_hH]")
    ap.add_argument("--fleet-mode", default="vec",
                    choices=["vec", "ref"],
                    help="fleet hot path: vectorized per-replica load "
                         "arrays (vec, default) or the reference O(R) "
                         "per-step re-gather loop (ref) — stats and "
                         "telemetry are bit-identical")
    ap.add_argument("--pods", type=int, default=1,
                    help="with P > 1, route hierarchically via "
                         "pod_bfio_pP: pick a pod by normalized load, "
                         "then one batched BF-IO solve across all pods "
                         "(overrides --router)")
    ap.add_argument("--replica-classes", default=None,
                    help="heterogeneous fleet spec, e.g. '2xg1b2,2xg2b4' "
                         "= 2 replicas of 1 worker x 2 slots + 2 of "
                         "2 x 4 (overrides --replicas/--workers/--slots "
                         "for the fleet shape)")
    ap.add_argument("--predictor", default=None,
                    choices=["oracle"],
                    help="predicted-output-length router term: 'oracle' "
                         "feeds each request's decode budget to the "
                         "BF-IO growth model")
    ap.add_argument("--async", dest="async_fleet", action="store_true",
                    help="event-driven fleet (per-replica clocks, "
                         "staleness-bounded routing) instead of the "
                         "barrier-stepped FleetServer")
    ap.add_argument("--autoscale", default=None,
                    choices=["util", "slo"],
                    help="autoscaling policy (implies --async): hold "
                         "windowed utilization near target (util) or "
                         "scale on windowed SLO attainment (slo)")
    ap.add_argument("--r-min", type=int, default=1,
                    help="autoscaler floor on active replicas")
    ap.add_argument("--r-max", type=int, default=0,
                    help="autoscaler ceiling on active replicas "
                         "(0 = --replicas)")
    ap.add_argument("--slo-ttft", type=float, default=1.0,
                    help="SLO bound on time-to-first-token (s)")
    ap.add_argument("--slo-tpot", type=float, default=0.1,
                    help="SLO bound on time-per-output-token (s)")
    ap.add_argument("--scenario", default=None,
                    choices=sorted(FLEET_SCENARIOS),
                    help="named scenario trace for fleet mode (timed "
                         "arrivals); omitted = synthetic stream at t=0")
    ap.add_argument("--telemetry-out", default=None,
                    help="write fleet telemetry (per-step, per-request, "
                         "summary) to this JSONL path")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request lifecycle spans as Chrome "
                         "trace-event JSON (open in Perfetto / "
                         "chrome://tracing); also prints the straggler "
                         "ledger's idle-energy attribution")
    args = ap.parse_args()

    if args.smoke or jax.default_backend() == "cpu":
        cfg = get_smoke_config(args.arch)
        mesh = make_cpu_mesh()
    else:  # pragma: no cover - real hardware path
        cfg = get_config(args.arch)
        mesh = make_production_mesh()

    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    engine_cfg = EngineConfig(
        n_workers=args.workers, slots_per_worker=args.slots,
        max_seq_len=256, cache_backend=args.cache_backend,
        prefill_chunk=args.prefill_chunk,
        prefill_budget=args.prefill_budget,
        paged_pool_blocks=args.pool_blocks,
        preemption_mode=args.preemption_mode,
        preemption_policy=args.preemption_policy,
        prefix_cache=args.prefix_cache)
    if (args.replicas > 1 or args.scenario or args.telemetry_out
            or args.replica_classes or args.pods > 1
            or args.async_fleet or args.autoscale or args.trace_out):
        serve_fleet(args, cfg, params, engine_cfg, mesh)
        return
    eng = ServingEngine(cfg, params, engine_cfg,
                        make_policy(args.policy), mesh=mesh)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(ServeRequest(
            rid=i,
            tokens=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(4, 64))),
            max_new_tokens=args.max_new))
    stats = eng.run()
    print(f"[serve] {cfg.name} policy={stats['policy']}: "
          f"{stats['tokens']} tokens in {stats['steps']} steps, "
          f"{stats['throughput_tok_s']:.1f} tok/s, "
          f"E={stats['energy_j']:.1f} J, "
          f"avg imbalance {stats['avg_imbalance']:.1f}")
    if args.cache_backend == "paged":
        # what the contiguous slot layout would pin (every slot at
        # max_seq_len) — NOT the pool size, which --pool-blocks may have
        # shrunk below it
        per_block = eng.backend.pool_bytes() // eng.backend.n_blocks
        dense = per_block * eng.backend.N * eng.backend.max_blocks
        print(f"[serve] paged KV: peak resident "
              f"{eng.kv_peak_bytes / 1e6:.2f} MB "
              f"({eng.kv_peak_bytes / max(dense, 1):.1%} of the "
              f"{dense / 1e6:.2f} MB the slot layout pins)")
        if stats["preemptions"]:
            print(f"[serve] memory pressure: {stats['preemptions']} "
                  f"preemptions ({args.preemption_mode}), "
                  f"{stats['tokens_swapped']} KV tokens swapped, "
                  f"{stats['tokens_recomputed']} recomputed")
        if args.prefix_cache:
            print(f"[serve] prefix cache: {stats['prefix_hits']}/"
                  f"{stats['prefix_queries']} block hits "
                  f"({stats['prefix_hit_rate']:.1%})")


if __name__ == "__main__":
    main()
