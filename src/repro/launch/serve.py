"""Serving launcher: ``python -m repro.launch.serve --arch <id> --policy
bfio_h20`` — drives the BF-IO-routed multi-worker engine end to end.

Memory-pressure knobs (``--cache-backend paged`` only):

* ``--pool-blocks N`` sizes the shared KV block pool below the
  every-slot-at-max-seq default, oversubscribing memory the way real
  engines do; on exhaustion the engine *preempts* a victim instead of
  crashing.
* ``--preemption-mode swap|recompute`` picks what happens to the
  victim's KV: staged host-side and restored bit-for-bit on resume
  (swap), or dropped and re-prefilled from prompt + generated tokens
  (recompute).  ``--preemption-policy lifo|fifo|largest`` picks the
  victim.
* ``--prefix-cache`` shares identical prompt-prefix KV blocks across
  requests (content-hash index, copy-on-write on the first divergent
  append) — resident KV then scales with *unique* prefix content.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import make_policy
from ..models import init_params, split_params
from ..serving import EngineConfig, ServeRequest, ServingEngine
from .mesh import make_cpu_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default="bfio_h8")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-backend", default="slot",
                    choices=["slot", "paged"],
                    help="KV layout: contiguous per-slot rows or "
                         "vLLM-style paged blocks")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: max prompt tokens per request "
                         "per step (0 = synchronous)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="total prompt tokens per step across requests "
                         "(0 = same as --prefill-chunk)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="paged KV pool size in blocks (0 = capacity for "
                         "every slot at max_seq_len; smaller pools "
                         "oversubscribe and trigger preemption)")
    ap.add_argument("--preemption-mode", default="swap",
                    choices=["swap", "recompute"],
                    help="victim KV handling under memory pressure: swap "
                         "to host staging (bit-exact resume) or drop and "
                         "re-prefill on resume")
    ap.add_argument("--preemption-policy", default="lifo",
                    choices=["lifo", "fifo", "largest"],
                    help="victim selection under memory pressure")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical prompt-prefix KV blocks across "
                         "requests (paged backend, copy-on-write)")
    args = ap.parse_args()

    if args.smoke or jax.default_backend() == "cpu":
        cfg = get_smoke_config(args.arch)
        mesh = make_cpu_mesh()
    else:  # pragma: no cover - real hardware path
        cfg = get_config(args.arch)
        mesh = make_production_mesh()

    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))
    eng = ServingEngine(
        cfg, params,
        EngineConfig(n_workers=args.workers, slots_per_worker=args.slots,
                     max_seq_len=256, cache_backend=args.cache_backend,
                     prefill_chunk=args.prefill_chunk,
                     prefill_budget=args.prefill_budget,
                     paged_pool_blocks=args.pool_blocks,
                     preemption_mode=args.preemption_mode,
                     preemption_policy=args.preemption_policy,
                     prefix_cache=args.prefix_cache),
        make_policy(args.policy), mesh=mesh)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(ServeRequest(
            rid=i,
            tokens=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(4, 64))),
            max_new_tokens=args.max_new))
    stats = eng.run()
    print(f"[serve] {cfg.name} policy={stats['policy']}: "
          f"{stats['tokens']} tokens in {stats['steps']} steps, "
          f"{stats['throughput_tok_s']:.1f} tok/s, "
          f"E={stats['energy_j']:.1f} J, "
          f"avg imbalance {stats['avg_imbalance']:.1f}")
    if args.cache_backend == "paged":
        # what the contiguous slot layout would pin (every slot at
        # max_seq_len) — NOT the pool size, which --pool-blocks may have
        # shrunk below it
        per_block = eng.backend.pool_bytes() // eng.backend.n_blocks
        dense = per_block * eng.backend.N * eng.backend.max_blocks
        print(f"[serve] paged KV: peak resident "
              f"{eng.kv_peak_bytes / 1e6:.2f} MB "
              f"({eng.kv_peak_bytes / max(dense, 1):.1%} of the "
              f"{dense / 1e6:.2f} MB the slot layout pins)")
        if stats["preemptions"]:
            print(f"[serve] memory pressure: {stats['preemptions']} "
                  f"preemptions ({args.preemption_mode}), "
                  f"{stats['tokens_swapped']} KV tokens swapped, "
                  f"{stats['tokens_recomputed']} recomputed")
        if args.prefix_cache:
            print(f"[serve] prefix cache: {stats['prefix_hits']}/"
                  f"{stats['prefix_queries']} block hits "
                  f"({stats['prefix_hit_rate']:.1%})")


if __name__ == "__main__":
    main()
