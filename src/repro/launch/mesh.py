"""Production meshes and sharding rules.

``make_production_mesh`` builds the 16x16 single-pod (256 chips) or
2x16x16 multi-pod (512 chips) mesh — as a FUNCTION so importing this module
never touches jax device state.

``ShardingRules`` maps the *logical* parameter axes emitted by the model
init (repro.models.layers.Param) to physical mesh axes, divisibility-aware
per architecture:

  * attention is sharded by (q+kv) heads when both divide the model axis,
    else by head_dim (always 128/64 -> divisible) — the head_dim variant is
    what keeps qwen2-72b's 8 KV heads sharded 16 ways at decode;
  * MoE experts shard over model when E % M == 0 (qwen3: 128/16), else the
    per-expert hidden dim (granite-moe: 40 experts, f=512/16=32);
  * train mode adds FSDP: the d_model ("embed") axis of every weight is
    sharded over "data", giving ZeRO-sharded optimizer state;
  * activations carry P(batch, None, "model") through the layer scan so
    the residual stash stays bounded (5 GB, not 80 GB, for qwen2-72b).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig

__all__ = [
    "make_production_mesh",
    "make_cpu_mesh",
    "batch_axes_for",
    "ShardingRules",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "activation_spec",
]


def _make_mesh(shape, axes):
    # jax 0.4.x has no jax.sharding.AxisType; Auto is the default there
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_cpu_mesh():
    """Trivial (1, 1) mesh for CPU tests — same axis names."""
    return _make_mesh((1, 1), ("data", "model"))


def batch_axes_for(mesh, global_batch: Optional[int] = None) -> tuple:
    """Mesh axes used for batch sharding: ("pod","data") when the pod axis
    exists; trimmed so the product divides the global batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if global_batch is None:
        return tuple(axes)
    # drop axes (outermost first) until divisible
    while axes:
        prod = int(np.prod([mesh.shape[a] for a in axes]))
        if global_batch % prod == 0:
            return tuple(axes)
        axes.pop(0)
    return ()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved logical-axis -> mesh-axes mapping for (config, mesh)."""

    table: dict

    @classmethod
    def build(cls, cfg: ModelConfig, mesh, *, mode: str = "serve",
              attn_pref: str = "auto") -> "ShardingRules":
        """attn_pref:
        * "auto": heads-first for train/prefill (score tiles stay sharded,
          no per-tile psum; replicated KV weights are small), hd-first for
          serve (the KV *cache* must shard — replicating qwen2-72b's cache
          is 43 GB/chip);
        * "heads_first" / "hd_first": force a variant (perf experiments).
        """
        M = int(mesh.shape.get("model", 1))
        D = int(mesh.shape.get("data", 1))

        def div(n, m=M):
            return m > 1 and n % m == 0

        if attn_pref == "auto":
            attn_pref = "hd_first" if mode == "serve" else "heads_first"

        # attention sharding variant
        if div(cfg.n_heads) and div(cfg.n_kv_heads):
            heads, kv_heads, hd = "model", "model", None
        elif attn_pref == "heads_first" and div(cfg.n_heads):
            heads, kv_heads, hd = "model", None, None
        elif div(cfg.hd):
            heads, kv_heads, hd = None, None, "model"
        elif div(cfg.n_heads):
            heads, kv_heads, hd = "model", None, None
        else:
            heads = kv_heads = hd = None

        # MoE sharding variant (EP vs TP-within-expert) — must agree with
        # repro.models.moe.moe_ffn's ep_mode switch
        if div(cfg.n_experts):
            experts, expert_mlp = "model", None
        elif cfg.is_moe and div(cfg.moe_d_ff):
            experts, expert_mlp = None, "model"
        else:
            experts = expert_mlp = None

        di = cfg.d_inner
        table = {
            "layers": None,
            "vocab": "model" if div(cfg.vocab_size) else None,
            "embed": "data" if (mode == "train" and div(cfg.d_model, D))
                     else None,
            "heads": heads,
            "kv_heads": kv_heads,
            "hd": hd,
            "hd2": None,
            "mlp": "model" if div(cfg.d_ff or 0) else None,
            "experts": experts,
            "expert_mlp": expert_mlp,
            "ssm_in": None,
            "ssm_inner": "model" if div(di) else None,
            "ssm_inner2": "model" if div(di) else None,
            "ssm_heads": None,
            "ssm_heads2": None,
            "gates": None,
            "conv_k": None,
            "enc_seq": None,
        }
        return cls(table=table)

    def spec_for(self, axes: tuple) -> P:
        phys = []
        used = set()
        for a in axes:
            m = self.table.get(a)
            if m is not None and m in used:
                m = None  # a mesh axis can appear only once per spec
            if m is not None:
                used.add(m)
            phys.append(m)
        # trim trailing Nones
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)


def param_shardings(axes_tree, cfg: ModelConfig, mesh, *,
                    mode: str = "serve", attn_pref: str = "auto"):
    """NamedSharding tree matching the params tree (from split_params)."""
    rules = ShardingRules.build(cfg, mesh, mode=mode, attn_pref=attn_pref)

    def one(axes):
        return NamedSharding(mesh, rules.spec_for(tuple(axes)))

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def activation_spec(cfg: ModelConfig, mesh, global_batch: int):
    """Sharding for the residual stream (B, S, d) through the scan."""
    baxes = batch_axes_for(mesh, global_batch)
    M = int(mesh.shape.get("model", 1))
    d_ok = M > 1 and cfg.d_model % M == 0
    spec = P(baxes if baxes else None, None, "model" if d_ok else None)
    return NamedSharding(mesh, spec)


def batch_shardings(batch_specs: dict, mesh, global_batch: int):
    """Shardings for a train/prefill batch dict: batch dim sharded."""
    baxes = batch_axes_for(mesh, global_batch)
    b = baxes if baxes else None

    def one(leaf):
        spec = [b] + [None] * (len(leaf.shape) - 1)
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, cfg: ModelConfig, mesh, global_batch: int,
                    kv_shard: str = "heads"):
    """Shardings for the decode cache pytree (leaves stacked on a leading
    layer axis; batch is dim 1).

    kv_shard="heads": KV head/hd dims per the rules (baseline);
    kv_shard="length": the KV length dim is sharded over the model axis
    (distributed flash-decode; see attention.decode_attention_lsharded)."""
    rules = ShardingRules.build(cfg, mesh, mode="serve")
    baxes = batch_axes_for(mesh, global_batch)
    b = baxes if baxes else None
    kv = rules.table["kv_heads"]
    hd = rules.table["hd"]
    M = int(mesh.shape.get("model", 1))

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        nd = len(leaf.shape)
        if nd == 1:            # lengths (B,)
            return NamedSharding(mesh, P(b))
        if "k" in names or "v" in names:       # (L, B, Lkv, Hkv, hd)
            if (kv_shard == "length" and nd >= 3
                    and leaf.shape[2] % max(M, 1) == 0 and M > 1):
                spec = [None, b, "model", None, None][:nd]
            else:
                spec = [None, b, None, kv, hd][:nd]
        elif "state" in names:                  # (L, B, H, dk, dv)
            spec = [None, b, None, None, None][:nd]
        elif "conv" in names:                   # (L, B, K-1, di)
            ssm_in = rules.table["ssm_inner"]
            spec = [None, b, None, ssm_in][:nd]
        elif "hcnm" in names:                   # (L, B, H, hd)
            spec = [None, b, None, None][:nd]
        else:
            spec = [None, b] + [None] * (nd - 2)
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_specs)
