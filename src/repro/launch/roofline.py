"""Roofline analysis from the compiled dry-run artifacts.

Three terms per (arch x shape), single-pod mesh, TPU v5e constants:

    compute    = FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips * 819e9 B/s)
    collective = collective bytes / (chips * 50e9 B/s per ICI link)

Sources and caveats:
  * XLA's ``cost_analysis()`` counts ``while`` (scan) bodies ONCE, so its
    FLOPs/bytes under-count scanned layers and grad-accumulation loops.
    The compute and memory terms therefore come from exact *analytic*
    accounting (documented below); the HLO numbers are reported alongside.
  * Collective bytes are parsed from the optimized HLO with **trip-count
    correction**: each collective inside a while body is multiplied by the
    product of enclosing loop trip counts (recovered from the loop
    condition's comparison constant).
  * MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the ratio
    MODEL_FLOPS / HLO_FLOPS(corrected-analytic) exposes remat/attention
    overhead.
"""
from __future__ import annotations

import dataclasses
import glob
import gzip
import json
import os
import re

from ..configs import config_for_shape, get_config, get_shape
from ..configs.base import ModelConfig
from ..configs.shapes import InputShape

__all__ = ["HW", "analytic_flops", "analytic_bytes", "corrected_collectives",
           "analyze_record", "main"]

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants."""

    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # B/s
    ici_bw: float = 50e9             # B/s per link
    hbm_bytes: float = 16e9


V5E = HW()


# --------------------------------------------------------------------------
# analytic FLOPs / bytes
# --------------------------------------------------------------------------

def _attn_layer_count(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // (cfg.attn_every + 1)
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def analytic_flops(cfg: ModelConfig, shape: InputShape) -> dict:
    """Exact-order FLOPs accounting for one step of the shape's kind."""
    B, S = shape.global_batch, shape.seq_len
    N_act = cfg.active_params()
    Hq, hd = cfg.n_heads, cfg.hd
    L_attn = _attn_layer_count(cfg)

    if shape.kind == "train":
        D = B * S
        matmul_fwd = 2 * N_act * D
        eff_window = min(S, cfg.sliding_window) if cfg.sliding_window else S
        attn_fwd = 2 * B * S * eff_window * Hq * hd * L_attn  # causal ~1/2 *2ops*2flops
        fwd = matmul_fwd + attn_fwd
        total = 3 * fwd          # fwd + bwd(2x)
        remat_total = 4 * fwd    # + recompute pass
        model = 6 * N_act * D
        return {"fwd": fwd, "total": total, "with_remat": remat_total,
                "model_flops": model, "attn_fraction": attn_fwd / fwd}
    if shape.kind == "prefill":
        D = B * S
        eff_window = min(S, cfg.sliding_window) if cfg.sliding_window else S
        fwd = 2 * N_act * D + 2 * B * S * eff_window * Hq * hd * L_attn
        return {"fwd": fwd, "total": fwd, "with_remat": fwd,
                "model_flops": 2 * N_act * D,
                "attn_fraction": 1 - 2 * N_act * D / fwd}
    # decode: one token per request
    kv_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    matmul = 2 * N_act * B
    attn = 4 * B * Hq * hd * kv_len * L_attn
    ssm = 0
    if cfg.family in ("ssm", "hybrid"):
        n_ssm = cfg.n_layers - L_attn
        di = cfg.d_inner
        dk = cfg.ssm_state or (di // cfg.n_ssm_heads)
        dv = di // cfg.n_ssm_heads
        ssm = 6 * B * cfg.n_ssm_heads * dk * dv * n_ssm
    fwd = matmul + attn + ssm
    return {"fwd": fwd, "total": fwd, "with_remat": fwd,
            "model_flops": 2 * N_act * B,
            "attn_fraction": (attn + ssm) / fwd}


def _kv_cache_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    B, S = shape.global_batch, shape.seq_len
    L_attn = _attn_layer_count(cfg)
    kv_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    kv = 2 * L_attn * B * kv_len * cfg.n_kv_heads * cfg.hd * 2  # bf16
    if cfg.family in ("ssm", "hybrid"):
        n_ssm = cfg.n_layers - L_attn
        di = cfg.d_inner
        dk = cfg.ssm_state or (di // cfg.n_ssm_heads)
        kv += n_ssm * B * cfg.n_ssm_heads * dk * (di // cfg.n_ssm_heads) * 4
    if cfg.family == "audio":
        kv += 2 * cfg.n_layers * B * cfg.encoder_seq * cfg.n_kv_heads \
            * cfg.hd * 2
    return float(kv)


def analytic_bytes(cfg: ModelConfig, shape: InputShape) -> dict:
    """HBM traffic estimate for one step (the memory roofline term)."""
    B, S = shape.global_batch, shape.seq_len
    n_params = cfg.n_params()
    if shape.kind == "decode":
        # every decode step streams the full resident weights + KV once
        w = 2 * n_params                       # bf16 weights read
        kv = _kv_cache_bytes(cfg, shape)       # cache read (write is +B tok)
        return {"weights": w, "kv": kv, "activations": 0.0,
                "total": w + kv}
    # train / prefill: weights read (bf16), plus activations r/w; train adds
    # grad + optimizer traffic (fp32 m, v read+write, fp32 master rw)
    acts = 0.0
    d = cfg.d_model
    per_tok = 2 * d * 2 * max(cfg.n_layers, 1) * 4  # resid rd/wr few times
    acts = B * S * per_tok
    w = 2 * n_params
    if shape.kind == "train":
        opt = n_params * (4 + 4 + 4 + 4) * 2   # m,v,master,grad rw fp32
        return {"weights": 3 * w, "kv": 0.0, "activations": 3 * acts,
                "optimizer": opt, "total": 3 * w + 3 * acts + opt}
    return {"weights": w, "kv": 0.0, "activations": acts,
            "total": w + acts}


# --------------------------------------------------------------------------
# trip-count-corrected collective parsing
# --------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|u32|s8|u8|pred|f64|s64)"
                       r"\[([\d,]*)\]")
_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8}


def _split_computations(text: str) -> dict[str, list[str]]:
    """Split optimized HLO text into computations.  A computation header is
    a column-0 line starting with '%name (' or 'ENTRY %name (' and ending
    with '{' (parameter lists may contain nested parens, so we only key on
    the leading token)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            head = line.split("(", 1)[0].strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.lstrip("%").strip()
            if name:
                cur = name
                comps[cur] = []
                continue
        if cur is not None:
            comps[cur].append(line)
            if line.strip() == "}":
                cur = None
    return comps


def _shape_bytes(s: str) -> int:
    tot = 0
    for t, dims in _SHAPE_RE.findall(s):
        n = 1
        for dstr in dims.split(","):
            if dstr:
                n *= int(dstr)
        tot += n * _BYTES[t]
    return tot


def corrected_collectives(text: str) -> dict:
    """Collective bytes with while-loop trip-count multiplication."""
    comps = _split_computations(text)

    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = [int(c) for ln in lines for c in _CONST_RE.findall(ln)]
        consts = [c for c in consts if c > 1]
        return max(consts) if consts else 1

    def walk(name: str, seen: tuple) -> dict:
        """bytes-by-op of computation ``name`` including nested calls."""
        if name in seen or name not in comps:
            return {}
        out: dict[str, float] = {}
        for ln in comps[name]:
            mw = _WHILE_RE.search(ln)
            if mw:
                tc = trip_count(mw.group(1))
                sub = walk(mw.group(2), seen + (name,))
                for k, v in sub.items():
                    out[k] = out.get(k, 0) + v * tc
                continue
            mcoll = _COLL_RE.search(ln)
            if mcoll:
                out[mcoll.group(2)] = out.get(mcoll.group(2), 0) \
                    + _shape_bytes(mcoll.group(1))
                continue
            for cal in _CALL_RE.findall(ln):
                sub = walk(cal, seen + (name,))
                for k, v in sub.items():
                    out[k] = out.get(k, 0) + v
        return out

    entry = None
    for name in comps:
        if "main" in name or entry is None:
            entry = name if "main" in name else entry
    if entry is None:
        # fall back: the computation that contains while/collectives most
        entry = max(comps, key=lambda n: len(comps[n]))
    by_op = walk(entry, ())
    return {"bytes_by_op": by_op, "total_bytes": sum(by_op.values())}


# --------------------------------------------------------------------------
# per-record analysis
# --------------------------------------------------------------------------

def analyze_record(rec: dict, hw: HW = V5E) -> dict:
    """Derive the three roofline terms (seconds) for one dry-run record."""
    arch, shape_name = rec["arch"], rec["shape"]
    shape = get_shape(shape_name)
    cfg = config_for_shape(get_config(arch), shape)
    chips = rec["chips"]

    fl = analytic_flops(cfg, shape)
    by = analytic_bytes(cfg, shape)
    coll = rec.get("collectives_corrected") or rec.get("collectives") or {}
    coll_bytes = coll.get("total_bytes", 0.0)

    t_compute = fl["with_remat"] / (chips * hw.peak_flops)
    t_memory = by["total"] / (chips * hw.hbm_bw)
    # collective bytes in the HLO are per-device program traffic
    t_coll = coll_bytes / hw.ici_bw

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_flops = rec.get("cost", {}).get("flops", 0.0)
    out = {
        "arch": arch, "shape": shape_name, "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": fl["model_flops"],
        "analytic_flops": fl["with_remat"],
        "useful_ratio": fl["model_flops"] / max(fl["with_remat"], 1.0),
        "hlo_flops_per_device": hlo_flops,
        "collective_bytes": coll_bytes,
        "attn_fraction": fl["attn_fraction"],
        "mem_breakdown": by,
        "ok": rec.get("ok", False),
    }
    # one sentence on what moves the dominant term down
    tips = {
        "compute": "reduce recompute (remat policy) or shard more of the "
                   "per-chip FLOPs (bigger model axis / better MoE EP)",
        "memory": "cut resident-weight restreams (wider batching amortizes "
                  "weight reads) or shrink the KV footprint (window/GQA)",
        "collective": "overlap or shrink collectives: reduce-scatter "
                      "instead of all-reduce, bf16 collectives, fewer "
                      "psum points per layer",
    }
    out["tip"] = tips[dominant]
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=RESULTS_DIR)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir,
                                              f"*__{args.mesh}.json"))):
        rec = json.load(open(path))
        gz = path.replace(".json", ".hlo.gz")
        if os.path.exists(gz) and "collectives_corrected" not in rec:
            text = gzip.open(gz, "rt").read()
            rec["collectives_corrected"] = corrected_collectives(text)
        rows.append(analyze_record(rec))

    hdr = (f"{'arch':24s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'dominant':>10s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']*1e3:9.3f} {r['t_memory_s']*1e3:9.3f} "
              f"{r['t_collective_s']*1e3:9.3f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
