import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  512 host devices let jax.make_mesh build the production meshes.

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape
x mesh) combination, proving the distribution config is coherent without
real hardware.

Per pair it lowers the right step function (train_step / prefill_step /
serve_step) with ShapeDtypeStruct inputs (no allocation), compiles for the
host backend, and records memory_analysis / cost_analysis / collective
byte counts (parsed from the optimized HLO) to a JSON artifact consumed by
the roofline analysis (repro.launch.roofline).

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --mesh both
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import (
    SHAPES,
    config_for_shape,
    get_config,
    get_shape,
    input_specs,
    list_archs,
)
from ..models import decode_fn, init_params, prefill_fn, split_params
from ..training.optimizer import AdamWConfig, init_opt_state
from ..training.train_loop import make_train_step
from .mesh import (
    activation_spec,
    batch_axes_for,
    batch_shardings,
    cache_shardings,
    make_production_mesh,
    param_shardings,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# HLO collective ops whose operand bytes constitute the collective roofline
# term (Section ROOFLINE of the spec).
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b", re.M)
_SHAPE_RE = re.compile(r"(f32|f16|bf16|s32|u32|s8|u8|pred|f64|s64|c64)"
                       r"\[([\d,]*)\]")
_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        nbytes = 0
        for t, dims in _SHAPE_RE.findall(shape_str):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _BYTES.get(t, 4)
        totals[op] = totals.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": totals, "counts_by_op": counts,
            "total_bytes": sum(totals.values()),
            "total_count": sum(counts.values())}


def _opt_sharding_tree(opt_shapes, pshard, mesh):
    rep = NamedSharding(mesh, P())
    return type(opt_shapes)(step=rep, m=pshard, v=pshard)


def build_lowered(arch: str, shape_name: str, mesh, decode_opt: bool = False):
    """Lower the step function for one (arch, shape) on the given mesh.

    ``decode_opt``: length-sharded KV cache + heads-first weights +
    distributed flash-decode (perf-optimized serve_step)."""
    shape = get_shape(shape_name)
    cfg = config_for_shape(get_config(arch), shape)
    baxes = batch_axes_for(mesh, shape.global_batch)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        tcfg = dataclasses.replace(cfg, dtype="float32")  # master weights
        specs = input_specs(tcfg, shape)
        ptree = jax.eval_shape(lambda: init_params(tcfg,
                                                   jax.random.PRNGKey(0)))
        pshapes, axes = split_params(ptree)
        pshard = param_shardings(axes, tcfg, mesh, mode="train")
        opt_shapes = jax.eval_shape(init_opt_state, pshapes)
        oshard = _opt_sharding_tree(opt_shapes, pshard, mesh)
        bshard = batch_shardings(specs, mesh, shape.global_batch)
        act = activation_spec(tcfg, mesh, shape.global_batch)
        # microbatching: keep peak activations bounded on 16 GB chips
        npar = cfg.n_params()
        # perf iteration (qwen2-72b train): FSDP regathers weights every
        # microbatch, so fewer/larger microbatches cut collective traffic
        # linearly while activation memory (bounded by remat + sharded
        # stash) still fits: accum 8->4 confirmed -2x all-gather bytes.
        grad_accum = 4 if npar > 4e9 else (2 if npar > 1e9 else 1)
        step = make_train_step(tcfg, AdamWConfig(), mesh=mesh,
                               batch_axes=baxes, act_spec=act,
                               grad_accum=grad_accum,
                               grad_shardings=pshard)
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(NamedSharding(mesh, P()), pshard,
                                    oshard),
                     donate_argnums=(0, 1))
        with jax.set_mesh(mesh):
            return fn.lower(pshapes, opt_shapes, specs), cfg

    # serving paths: bf16 params, serve-mode sharding
    ptree = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pshapes, axes = split_params(ptree)
    pshard = param_shardings(axes, cfg, mesh, mode="serve")

    if shape.kind == "prefill":
        # PD disaggregation (the paper's own serving architecture): prefill
        # workers are distinct from decode workers, so they may use the
        # heads-first sharding (no per-tile score psums); only decode
        # workers need the cache-shardable hd-first layout.
        pshard = param_shardings(axes, cfg, mesh, mode="prefill")
        bshard = batch_shardings(specs, mesh, shape.global_batch)
        # prefill is forward-only: no residual stash to bound, so keep the
        # residual replicated on the model axis — d-sharding it only buys
        # per-layer gather/scatter traffic (perf iteration 2)
        act = NamedSharding(mesh, P(baxes if baxes else None, None, None))

        def prefill_step(params, batch):
            return prefill_fn(cfg, params, batch,
                              max_len=shape.seq_len, mesh=mesh,
                              batch_axes=baxes, act_spec=act)

        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard))
        with jax.set_mesh(mesh):
            return fn.lower(pshapes, specs), cfg

    # decode
    M = int(mesh.shape.get("model", 1))
    use_len = (decode_opt and not cfg.sliding_window
               and shape.seq_len % max(M, 1) == 0 and M > 1
               and cfg.n_heads % M == 0)
    kv_shard = "length" if use_len else "heads"
    if use_len:
        pshard = param_shardings(axes, cfg, mesh, mode="serve",
                                 attn_pref="heads_first")
    cshard = cache_shardings(specs["cache"], cfg, mesh, shape.global_batch,
                             kv_shard=kv_shard)
    tok_shard = batch_shardings(
        {"tokens": specs["tokens"]}, mesh, shape.global_batch)["tokens"]

    def serve_step(params, cache, tokens):
        return decode_fn(cfg, params, cache, tokens, mesh=mesh,
                         batch_axes=baxes, kv_shard=kv_shard)

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, cshard, tok_shard),
                 out_shardings=(NamedSharding(mesh, P(baxes if baxes
                                                      else None)), cshard),
                 donate_argnums=(1,))
    with jax.set_mesh(mesh):
        return fn.lower(pshapes, specs["cache"], specs["tokens"]), cfg


def run_pair(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = RESULTS_DIR, verbose: bool = True,
             save_hlo: bool = False, tag: str = "",
             decode_opt: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "chips": int(n_chips), "ok": False}
    t0 = time.time()
    try:
        lowered, cfg = build_lowered(arch, shape_name, mesh,
                                     decode_opt=decode_opt)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("generated_code_size_in_bytes",
                      "argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec.setdefault("memory", {})[k] = int(v)
        cost = compiled.cost_analysis()
        if cost:
            c = cost if isinstance(cost, dict) else cost[0]
            rec["cost"] = {k: float(v) for k, v in c.items()
                           if isinstance(v, (int, float))
                           and (k in ("flops", "bytes accessed",
                                      "optimal_seconds")
                                or k.startswith("bytes accessed"))}
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        try:
            from .roofline import corrected_collectives
            rec["collectives_corrected"] = corrected_collectives(hlo)
        except Exception as e:  # noqa: BLE001 - parser is best-effort
            rec["collectives_corrected_error"] = str(e)
        rec["hlo_chars"] = len(hlo)
        if save_hlo:
            import gzip
            os.makedirs(out_dir, exist_ok=True)
            with gzip.open(os.path.join(
                    out_dir, f"{arch}__{shape_name}__{mesh_kind}.hlo.gz"),
                    "wt") as f:
                f.write(hlo)
        rec["n_params"] = int(cfg.n_params())
        rec["n_active_params"] = int(cfg.active_params())
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 - record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')})"
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: {status} "
              f"({rec['total_s']}s)", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--decode-opt", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_pair(arch, shape, mk, out_dir=args.out,
                               save_hlo=args.save_hlo, tag=args.tag,
                               decode_opt=args.decode_opt)
                n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
