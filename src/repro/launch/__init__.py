"""Launchers: production meshes, the multi-pod dry-run, roofline analysis,
and train/serve entry points.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time.
"""
from .mesh import (  # noqa: F401
    ShardingRules,
    activation_spec,
    batch_axes_for,
    batch_shardings,
    cache_shardings,
    make_cpu_mesh,
    make_production_mesh,
    param_shardings,
)
