"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

On real hardware this drives the production mesh; on CPU it runs the smoke
variant end-to-end (the same code path the dry-run lowers)."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..data import token_batches
from ..models import init_params, split_params
from ..training import AdamWConfig, train
from .mesh import make_cpu_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced variant (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.smoke or jax.default_backend() == "cpu":
        cfg = get_smoke_config(args.arch)
        mesh = make_cpu_mesh()
    else:  # pragma: no cover - real hardware path
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")
    params, _ = split_params(init_params(cfg, jax.random.PRNGKey(0)))

    def batches():
        for b in token_batches(vocab_size=cfg.vocab_size, batch=args.batch,
                               seq_len=args.seq, n_batches=args.steps):
            if cfg.family == "vlm":
                b["patches"] = np.zeros(
                    (args.batch, cfg.patch_tokens, cfg.d_model), np.float32)
            if cfg.family == "audio":
                b["frames"] = np.zeros(
                    (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)
            yield b

    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                      total_steps=args.steps)
    _, losses = train(cfg, params=params, batches=batches(), opt_cfg=opt,
                      mesh=mesh, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 2, 1)
                      if args.ckpt_dir else 0)
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
