"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Router (top-k over E experts) runs replicated in the pjit program; the
expert compute runs inside ``shard_map`` so the dispatch locality is
explicit:

* **EP mode** (E divisible by the model-axis size): each model shard owns
  E_loc = E/M experts; every shard gathers the tokens routed to *its*
  experts from its data shard into a fixed-capacity buffer
  (E_loc, C, d), runs the expert SwiGLU as a batched matmul (MXU-friendly),
  scatters weighted outputs back, and a single ``psum`` over the model axis
  combines contributions (disjoint across shards).  This all-reduce is
  exactly the paper's synchronized EP phase — the barrier the scheduler's
  imbalance reduction protects.

* **TP mode** (E not divisible, e.g. granite-moe's 40 experts on 16-way
  model): every shard holds all experts with the hidden dim f sharded; the
  same dispatch code runs with E_loc = E, and the psum combines the
  f-partial products.

Token overflow beyond capacity C is dropped (standard Switch behaviour);
capacity has a floor so decode batches don't drop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._compat import shard_map
from .layers import linear

__all__ = ["router_topk", "aux_load_balance_loss", "moe_ffn"]


def router_topk(x, w_router, k: int):
    """x: (B, S, d); w_router: (d, E).  Returns (probs, top_w, top_idx)."""
    logits = linear(x, w_router).astype(jnp.float32)   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)           # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return probs, top_w.astype(x.dtype), top_idx


def aux_load_balance_loss(probs, top_idx, n_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    E = n_experts
    # fraction of token-slots dispatched to e
    counts = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p = probs.reshape(-1, E).mean(axis=0)
    return E * jnp.sum(f * p)


def _local_moe(x, top_idx, top_w, w1, w3, w2, *, E: int, k: int,
               capacity: int, ep_mode: bool, model_axis: str):
    """Per-device block (inside shard_map).

    x: (B_loc, S, d); top_idx/top_w: (B_loc, S, k);
    EP: w1 (E_loc, d, f) local experts; TP: w1 (E, d, f_loc)."""
    Bl, S, d = x.shape
    T = Bl * S
    E_loc = w1.shape[0]
    e0 = (jax.lax.axis_index(model_axis) * E_loc) if ep_mode else 0

    x2 = x.reshape(T, d)
    flat_e = top_idx.reshape(-1)                        # (T*k,)
    flat_w = top_w.reshape(-1)
    local = (flat_e >= e0) & (flat_e < e0 + E_loc)
    le = jnp.where(local, flat_e - e0, E_loc)           # E_loc = trash bucket
    oh = jax.nn.one_hot(le, E_loc + 1, dtype=jnp.int32)  # (T*k, E_loc+1)
    pos = jnp.cumsum(oh, axis=0) - 1
    pos_e = jnp.take_along_axis(pos, le[:, None], axis=1)[:, 0]
    ok = local & (pos_e < capacity)
    slot = jnp.where(ok, le * capacity + pos_e, E_loc * capacity)
    n_slots = E_loc * capacity

    tok_id = jnp.arange(T * k, dtype=jnp.int32) // k
    tok_for_slot = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(
        jnp.where(ok, tok_id, 0))
    gate_for_slot = jnp.zeros((n_slots + 1,), x.dtype).at[slot].set(
        jnp.where(ok, flat_w, 0.0))
    filled = jnp.zeros((n_slots + 1,), jnp.bool_).at[slot].set(ok)

    # gather tokens -> (E_loc, C, d)
    buf = x2[tok_for_slot[:n_slots]].reshape(E_loc, capacity, d)
    # expert SwiGLU, batched over local experts
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w3)) \
        * jnp.einsum("ecd,edf->ecf", buf, w1)
    y = jnp.einsum("ecf,efd->ecd", h, w2)               # (E_loc, C, d)
    y = y.reshape(n_slots, d)
    w_slot = (gate_for_slot[:n_slots]
              * filled[:n_slots].astype(x.dtype))[:, None]
    out = jnp.zeros((T, d), y.dtype).at[tok_for_slot[:n_slots]].add(
        y * w_slot)
    out = jax.lax.psum(out, model_axis)
    return out.reshape(Bl, S, d).astype(x.dtype)


def moe_ffn(
    x, params, *,
    n_experts: int,
    k: int,
    mesh,
    batch_axes,
    capacity_factor: float = 1.25,
    min_capacity: int = 4,
    model_axis: str = "model",
):
    """Top-k MoE FFN.  x: (B, S, d).  params: router (d,E), w1/w3 (E,d,f),
    w2 (E,f,d).  Returns (out, aux_loss)."""
    B, S, d = x.shape
    probs, top_w, top_idx = router_topk(x, params["router"], k)
    aux = aux_load_balance_loss(probs, top_idx, n_experts)

    msize = mesh.shape[model_axis]
    ep_mode = (n_experts % msize == 0) and msize > 1
    E_loc = n_experts // msize if ep_mode else n_experts
    dsize = 1
    for a in batch_axes:
        dsize *= mesh.shape[a]
    T_loc = max(B // max(dsize, 1), 1) * S
    capacity = max(int(capacity_factor * T_loc * k / n_experts) + 1,
                   min_capacity)

    if ep_mode:
        w13_spec = P(model_axis, None, None)     # experts sharded
        w2_spec = P(model_axis, None, None)
    else:
        w13_spec = P(None, None, model_axis)     # hidden dim sharded (TP)
        w2_spec = P(None, model_axis, None)
    bspec = P(batch_axes, None, None)
    ispec = P(batch_axes, None, None)

    fn = functools.partial(_local_moe, E=n_experts, k=k, capacity=capacity,
                           ep_mode=ep_mode, model_axis=model_axis)
    out = shard_map(
        fn, mesh=mesh,
        in_specs=(bspec, ispec, ispec, w13_spec, w13_spec, w2_spec),
        out_specs=bspec,
        check_vma=False,
    )(x, top_idx, top_w, params["w1"], params["w3"], params["w2"])
    return out, aux
