"""JAX version compatibility shims for the model stack.

``shard_map`` moved twice across the JAX versions this repo targets:

* jax >= 0.5: ``jax.shard_map`` with the replication check spelled
  ``check_vma``;
* jax 0.4.x: ``jax.experimental.shard_map.shard_map`` with the same
  check spelled ``check_rep``.

Call sites use :func:`shard_map` below with the *new* keyword
(``check_vma``); the shim maps it onto whatever the installed JAX
provides, so the same model code runs on both.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map``.

    ``check_vma=None`` leaves the backend default; a bool is forwarded as
    ``check_vma`` (new JAX) or ``check_rep`` (0.4.x).
    """
    kw = {}
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
