"""Primitive layers (pure-functional JAX) with logical-axis metadata.

Every parameter is created as a ``Param(value, axes)`` where ``axes`` is a
tuple of *logical* axis names (one per array dim).  ``repro.launch.mesh``
maps logical axes to physical mesh axes per architecture (divisibility
aware), so the same model code serves CPU smoke tests, the 16x16 single-pod
mesh, and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Param",
    "split_params",
    "merge_params",
    "rms_norm",
    "layer_norm",
    "make_rope",
    "apply_rope",
    "dense_init",
    "embed_init",
    "norm_init",
    "linear",
    "swiglu",
    "gelu_mlp",
    "cross_entropy_loss",
]

PyTree = Any


@dataclasses.dataclass
class Param:
    """A parameter plus its logical sharding axes (one name per dim).

    Registered as a pytree node (value is the child, axes are static aux
    data) so ``jax.eval_shape`` can trace ``init_params`` without
    allocating — the dry-run pattern for 70B-scale configs."""

    value: jnp.ndarray
    axes: tuple

    def __post_init__(self) -> None:
        assert len(self.axes) == self.value.ndim, (
            f"axes {self.axes} vs shape {self.value.shape}")


def _param_unflatten(axes, children):
    p = Param.__new__(Param)
    p.value = children[0]
    p.axes = axes
    return p


jax.tree_util.register_pytree_node(
    Param, lambda p: ((p.value,), p.axes), _param_unflatten)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree: PyTree) -> tuple[PyTree, PyTree]:
    """Split a Param tree into (values, logical_axes) trees."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_param)
    return values, axes


def merge_params(values: PyTree, axes: PyTree) -> PyTree:
    return jax.tree.map(Param, values, axes,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray)
                        or isinstance(x, np.ndarray))


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, axes, scale: Optional[float] = None,
               dtype=jnp.float32) -> Param:
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                            dtype=jnp.float32)
    return Param(v.astype(dtype), axes)


def embed_init(key, vocab, d, dtype=jnp.float32) -> Param:
    v = jax.random.normal(key, (vocab, d), dtype=jnp.float32)
    return Param((v / np.sqrt(d)).astype(dtype), ("vocab", "embed"))


def norm_init(dim, axes=("embed",), dtype=jnp.float32) -> Param:
    return Param(jnp.ones((dim,), dtype=dtype), axes)


# --------------------------------------------------------------------------
# normalization / rotary
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    """RMSNorm in fp32 accumulation (TPU-friendly)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def make_rope(positions, head_dim: int, theta: float = 1e4):
    """Rotary embedding tables for integer positions: (..., hd/2) sin/cos."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (..., seq, heads, hd); sin/cos: (..., seq, hd/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast sin/cos over the heads axis
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# linear / MLP
# --------------------------------------------------------------------------

def linear(x, w: jnp.ndarray, b: Optional[jnp.ndarray] = None):
    """x @ w (+ b), contracting x's last dim with w's first dim.

    ``w`` may have extra trailing dims (e.g. (d, heads, hd)) which are
    preserved in the output.
    """
    out = jnp.einsum("...d,dk->...k", x, w.reshape(w.shape[0], -1))
    out = out.reshape(x.shape[:-1] + w.shape[1:])
    if b is not None:
        out = out + b
    return out


def swiglu(x, w_in, w_gate, w_out):
    """SwiGLU MLP: (silu(x@w_gate) * (x@w_in)) @ w_out."""
    h = jax.nn.silu(linear(x, w_gate)) * linear(x, w_in)
    return linear(h, w_out)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    """Classic GELU MLP (Whisper-style)."""
    return linear(jax.nn.gelu(linear(x, w_in, b_in)), w_out, b_out)


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def cross_entropy_loss(logits, targets, mask=None):
    """Mean next-token cross entropy in fp32; mask: (B, S) float weights."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
