"""Attention: blocked causal (flash-style scan over KV chunks), sliding
window, cross attention, and cached one-token decode with ragged lengths.

All paths are GQA-native: queries are shaped (B, S, Hkv, Gq, hd) inside the
einsums so the KV tensors are never materialized at Hq width (for qwen2-72b
decode that avoids an 8x KV blow-up).  The blocked implementation keeps the
materialized score tile at (B, Hkv, Gq, Sq, kv_chunk) instead of
(B, H, S, S), so 32k prefill lowers with bounded memory.  On TPU the
one-token decode path is served by the Pallas ``decode_attention`` kernel
(repro.kernels); the jnp path here is the oracle and the dry-run path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ._compat import shard_map

__all__ = ["causal_attention", "chunk_attention", "cross_attention",
           "decode_attention"]

_NEG = -1e30


def _group_q(q, n_kv: int):
    """(B, Sq, Hq, hd) -> (B, Sq, Hkv, G, hd)."""
    b, s, hq, hd = q.shape
    assert hq % n_kv == 0, (hq, n_kv)
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def _mask_tile(sqc, ckv, q_pos0, kv_start, *, skv_valid,
               sliding_window):
    """Static causal/window/padding mask for one (Sqc, C) tile (fp32 add)."""
    q_pos = q_pos0 + jnp.arange(sqc)[:, None]          # (Sqc, 1)
    kv_pos = kv_start + jnp.arange(ckv)[None, :]       # (1, C)
    mask = (kv_pos <= q_pos) & (kv_pos < skv_valid)
    if sliding_window:
        mask &= kv_pos > (q_pos - sliding_window)
    return mask                                         # (Sqc, C) bool


def _flash_fwd_scan(qf, k, v, bias, q_pos0, sliding_window, kv_chunk,
                    n_kv, skv_valid):
    """Forward online-softmax pass; returns (out fp32, L logsumexp)."""
    b, sqc, hkv, g, hd = qf.shape

    def body(carry, ci):
        m, l, acc = carry
        start = ci * kv_chunk
        kc = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
        bc = jax.lax.dynamic_slice_in_dim(bias, start, kv_chunk, axis=1)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qf.astype(kc.dtype), kc,
                       preferred_element_type=jnp.float32)
        kv_pos = start + jnp.arange(kv_chunk)
        q_pos = q_pos0 + jnp.arange(sqc)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (kv_pos[None, :]
                                                      < skv_valid)
        if sliding_window:
            mask &= kv_pos[None, :] > (q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, None, None], s, _NEG)
        s = s + bc[:, None, None, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sqc), _NEG, dtype=jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sqc), dtype=jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sqc, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_kv))
    out = acc / jnp.maximum(l[..., None], 1e-30)       # (B,Hkv,G,Sqc,hd)
    L = m + jnp.log(jnp.maximum(l, 1e-30))             # logsumexp per query
    return out, L


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash_attend(qf, k, v, bias, q_pos0, sliding_window, kv_chunk, n_kv,
                  skv_valid):
    """Flash attention for one query chunk (fp32 qf pre-scaled).

    Memory-bounded in both directions: the backward pass recomputes each
    (Sqc x C) tile instead of saving it — without this, differentiating
    through the online-softmax scan stores every tile and the "blocked"
    attention silently costs O(S^2) memory again.
    bias: (B, Skv_pad) additive fp32 (0 / -1e30) — carries ragged lengths.
    Returns (B, Sqc, Hkv, G, hd) fp32.
    """
    out, _ = _flash_fwd_scan(qf, k, v, bias, q_pos0, sliding_window,
                             kv_chunk, n_kv, skv_valid)
    return out.transpose(0, 3, 1, 2, 4)


def _flash_fwd(qf, k, v, bias, q_pos0, sliding_window, kv_chunk, n_kv,
               skv_valid):
    out, L = _flash_fwd_scan(qf, k, v, bias, q_pos0, sliding_window,
                             kv_chunk, n_kv, skv_valid)
    return out.transpose(0, 3, 1, 2, 4), (qf, k, v, bias, out, L)


def _flash_bwd(q_pos0, sliding_window, kv_chunk, n_kv, skv_valid, res, g_out):
    qf, k, v, bias, out, L = res            # out: (B,Hkv,G,Sqc,hd)
    b, sqc, hkv, gq, hd = g_out.shape
    dout = g_out.astype(jnp.float32).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Sqc,hd)
    D = jnp.sum(dout * out, axis=-1)                            # (B,Hkv,G,Sqc)
    q_pos = q_pos0 + jnp.arange(sqc)

    def body(dq, ci):
        start = ci * kv_chunk
        kc = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
        bc = jax.lax.dynamic_slice_in_dim(bias, start, kv_chunk, axis=1)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qf, kc.astype(jnp.float32))
        kv_pos = start + jnp.arange(kv_chunk)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (kv_pos[None, :]
                                                      < skv_valid)
        if sliding_window:
            mask &= kv_pos[None, :] > (q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, None, None], s, _NEG)
        s = s + bc[:, None, None, None, :]
        p = jnp.exp(s - L[..., None])                   # (B,Hkv,G,Sqc,C)
        dp = jnp.einsum("bhgqd,bchd->bhgqc", dout, vc.astype(jnp.float32))
        ds = p * (dp - D[..., None])
        dq = dq + jnp.einsum("bhgqc,bchd->bqhgd", ds, kc.astype(jnp.float32))
        dk_c = jnp.einsum("bhgqc,bqhgd->bchd", ds, qf)
        dv_c = jnp.einsum("bhgqc,bhgqd->bchd", p, dout)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros_like(qf)
    dq, (dk_chunks, dv_chunks) = jax.lax.scan(body, dq0, jnp.arange(n_kv))
    # (n_kv, B, C, Hkv, hd) -> (B, n_kv*C, Hkv, hd)
    dk = dk_chunks.transpose(1, 0, 2, 3, 4).reshape(k.shape)
    dv = dv_chunks.transpose(1, 0, 2, 3, 4).reshape(v.shape)
    return (dq, dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(bias))


_flash_attend.defvjp(_flash_fwd, _flash_bwd)


def _attend_q_chunk(qf, k, v, *, q_pos0: int, skv_valid: int,
                    sliding_window: int, kv_chunk: int,
                    lengths: Optional[jnp.ndarray], n_kv: int):
    """Online-softmax attention of one query chunk against k[:, :n_kv*C].

    qf: (B, Sq_c, Hkv, G, hd) pre-scaled fp32; k/v padded to kv_chunk
    multiples.  Returns fp32 (B, Sq_c, Hkv, G, hd)."""
    b = qf.shape[0]
    skv_pad = k.shape[1]
    if lengths is not None:
        bias = jnp.where(jnp.arange(skv_pad)[None, :] < lengths[:, None],
                         0.0, _NEG).astype(jnp.float32)
    else:
        bias = jnp.zeros((b, skv_pad), jnp.float32)
    return _flash_attend(qf, k, v, bias, q_pos0, sliding_window, kv_chunk,
                         n_kv, skv_valid)


def causal_attention(
    q, k, v,
    *,
    q_offset: int = 0,
    sliding_window: int = 0,
    kv_chunk: int = 512,
    q_chunk: int = 512,
    lengths: Optional[jnp.ndarray] = None,
):
    """Two-level blocked causal self-attention with online softmax.

    q: (B, Sq, Hq, hd);  k, v: (B, Skv, Hkv, hd), Hq % Hkv == 0.
    Query chunks are a *python* loop so each chunk's KV scan stops at the
    causal frontier (static trip count, no wasted FLOPs); KV chunks are a
    ``lax.scan``.  Peak score tile: (B, Hkv, G, q_chunk, kv_chunk) — this
    is what keeps 32k prefill and 4k train inside HBM even when the score
    tensor has no sharded dimension (head_dim-sharded configs).
    q_offset: absolute position of q[0]; lengths: (B,) valid kv lengths.
    Returns (B, Sq, Hq, hd).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    kv_chunk = min(kv_chunk, skv)
    # keep the unrolled q loop small for very long sequences
    n_q_target = max(1, sq // q_chunk)
    if n_q_target > 16:
        q_chunk = sq // 16
    q_chunk = min(q_chunk, sq)

    pad_kv = (-skv) % kv_chunk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = _group_q(q, hkv).astype(jnp.float32) * scale  # (B,Sq,Hkv,G,hd)

    outs = []
    for start in range(0, sq, q_chunk):
        stop = min(start + q_chunk, sq)
        qc = qf[:, start:stop]
        # causal frontier: this chunk never reads past q_offset+stop
        if sliding_window:
            lo = max(0, (q_offset + start - sliding_window + 1)
                     // kv_chunk * kv_chunk)
        else:
            lo = 0
        hi_tok = min(q_offset + stop, skv)
        n_kv = max(1, -(-(hi_tok - lo) // kv_chunk))
        k_sl = k[:, lo:lo + n_kv * kv_chunk]
        v_sl = v[:, lo:lo + n_kv * kv_chunk]
        o = _attend_q_chunk(
            qc, k_sl, v_sl, q_pos0=q_offset + start - lo,
            skv_valid=skv - lo, sliding_window=sliding_window,
            kv_chunk=kv_chunk,
            lengths=None if lengths is None else lengths - lo,
            n_kv=n_kv)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def chunk_attention(q, k_cache, v_cache, *, q_pos, kv_len):
    """Mid-prefill chunk attention with per-row query offsets.

    One prompt *chunk* (chunked prefill) attends against everything written
    to the row's KV cache so far — earlier chunks plus this chunk's own KV,
    which the caller has already scattered into the cache.

    q: (B, C, Hq, hd) — the chunk's queries, right-padded per row;
    k_cache, v_cache: (B, L, Hkv, hd) — the full per-row cache buffers;
    q_pos: (B, C) absolute position of each query token;
    kv_len: (B,) valid cache length *including* this chunk.

    Unlike :func:`causal_attention` the query offset is per-row (rows of a
    chunk batch sit at different prefill depths), so the causal frontier is
    ``kv_pos <= q_pos[b, i]``.  Padded query columns produce garbage rows
    that the caller drops.  Direct (non-flash) fp32 softmax: chunk sizes
    are bounded by the scheduler's per-step budget, so the score tile is
    (B, Hkv, G, C, L) with small C.
    """
    b, c, hq, hd = q.shape
    L, hkv = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = _group_q(q, hkv).astype(jnp.float32) * scale    # (B,C,Hkv,G,hd)
    s = jnp.einsum("bqhgd,blhd->bhgql", qf,
                   k_cache.astype(jnp.float32))          # (B,Hkv,G,C,L)
    kv_pos = jnp.arange(L)
    mask = ((kv_pos[None, None, :] <= q_pos[:, :, None])
            & (kv_pos[None, None, :] < kv_len[:, None, None]))  # (B,C,L)
    s = jnp.where(mask[:, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgql,blhd->bhgqd", p,
                     v_cache.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, c, hq, hd)
    return out.astype(q.dtype)


def cross_attention(q, k, v, *, lengths: Optional[jnp.ndarray] = None):
    """Non-causal attention over a (fixed) encoder sequence.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd)."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = _group_q(q, hkv).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if lengths is not None:
        mask = jnp.arange(skv)[None, :] < lengths[:, None]   # (B, Skv)
        s = jnp.where(mask[:, None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     sliding_window: int = 0, rolling: bool = False):
    """One-token attention against a KV cache with per-request lengths.

    q: (B, Hq, hd) — the new token's queries.
    k_cache, v_cache: (B, L, Hkv, hd); lengths: (B,) ints — the number of
    tokens generated so far *including* the new token (whose KV must
    already be written).

    ``rolling=True`` marks a ring-buffer cache (sliding-window archs): all
    L slots are valid once lengths >= L, and positional correctness comes
    from RoPE applied at write time.
    """
    b, hq, hd = q.shape
    L, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = (q.reshape(b, hkv, g, hd).astype(jnp.float32)
          * scale).astype(k_cache.dtype)
    s = jnp.einsum("bhgd,blhd->bhgl", qf, k_cache,
                   preferred_element_type=jnp.float32)  # (B,Hkv,G,L)
    pos = jnp.arange(L)[None, :]                       # (1, L)
    if rolling:
        mask = pos < jnp.minimum(lengths, L)[:, None]
    else:
        mask = pos < lengths[:, None]
        if sliding_window:
            mask &= pos >= (lengths[:, None] - sliding_window)
    s = jnp.where(mask[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, hd).astype(q.dtype)


def decode_attention_lsharded(q, k_cache, v_cache, lengths, *, mesh,
                              batch_axes=("data",), model_axis="model"):
    """Distributed flash-decode: KV cache sharded along the LENGTH axis.

    Each model shard attends q (replicated, tiny) against its local KV
    slice and the partial (m, l, acc) statistics are merged with an
    online-softmax combine — the only collectives are psums of
    (B, Hq)-sized stats and the (B, Hq, hd) accumulator, instead of the
    per-layer weight regathers / score psums that head_dim sharding
    forces (RoPE splits head_dim, so hd-sharded weights get re-gathered
    every layer).

    q: (B, Hq, hd); k_cache/v_cache: (B, L, Hkv, hd) with L sharded over
    ``model_axis``; lengths: (B,).  Returns (B, Hq, hd), replicated over
    the model axis.
    """
    from jax.sharding import PartitionSpec as P

    b_spec = batch_axes if batch_axes else None
    L = k_cache.shape[1]
    msize = mesh.shape[model_axis]
    assert L % msize == 0, (L, msize)
    l_loc = L // msize

    def local_fn(q, k, v, lengths):
        # q: (B, Hq, hd) replicated over model; k/v: (B, L_loc, Hkv, hd)
        b, hq, hd = q.shape
        hkv = k.shape[2]
        g = hq // hkv
        idx = jax.lax.axis_index(model_axis)
        offset = idx * l_loc
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        qf = (q.reshape(b, hkv, g, hd).astype(jnp.float32)
              * scale).astype(k.dtype)
        s = jnp.einsum("bhgd,blhd->bhgl", qf, k,
                       preferred_element_type=jnp.float32)  # (B,Hkv,G,Lloc)
        pos = offset + jnp.arange(l_loc)[None, :]      # (1, L_loc)
        mask = pos < lengths[:, None]
        s = jnp.where(mask[:, None, None, :], s, _NEG)
        m = s.max(axis=-1)                             # (B,Hkv,G)
        p = jnp.exp(s - m[..., None])
        l_sum = p.sum(axis=-1)
        acc = jnp.einsum("bhgl,blhd->bhgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        # online-softmax merge across shards (tiny collectives)
        m_all = jax.lax.pmax(m, model_axis)
        alpha = jnp.exp(jnp.clip(m - m_all, -60.0, 0.0))
        l_tot = jax.lax.psum(l_sum * alpha, model_axis)
        acc_tot = jax.lax.psum(acc * alpha[..., None], model_axis)
        out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
        return out.reshape(b, hq, hd).astype(q.dtype)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(b_spec, None, None),
                  P(b_spec, model_axis, None, None),
                  P(b_spec, model_axis, None, None),
                  P(b_spec)),
        out_specs=P(b_spec, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, lengths)
