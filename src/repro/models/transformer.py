"""Model assembly: parameter init, train forward, prefill, and decode for
all six architecture families (dense / moe / ssm / hybrid / vlm / audio).

Design points:
  * scan-over-layers with stacked parameters (small HLO, bounded compile
    time at 88 layers) + ``jax.checkpoint`` per layer (remat);
  * GQA attention with blocked causal kernel (attention.py);
  * heterogeneous stacks (xLSTM m/s interleave, Zamba2 mamba+shared-attn)
    are grouped: homogeneous runs are scanned, the interleaving is a small
    python loop over groups;
  * caches are plain dict pytrees, stacked along the scan axis, threaded
    through ``lax.scan`` as xs/ys;
  * every function is mesh-agnostic except MoE (shard_map inside) — pass a
    (1,1) mesh for CPU tests.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import attention as attn_lib
from . import ssm as ssm_lib
from .layers import (
    Param,
    apply_rope,
    cross_entropy_loss,
    dense_init,
    embed_init,
    linear,
    make_rope,
    norm_init,
    rms_norm,
    swiglu,
)
from .moe import moe_ffn

PyTree = Any


# ==========================================================================
# Parameter initialization
# ==========================================================================

def _attn_init(key, cfg: ModelConfig, *, cross: bool = False,
               dtype=jnp.float32) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), ("embed", "heads", "hd"),
                         dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), ("embed", "kv_heads", "hd"),
                         dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), ("embed", "kv_heads", "hd"),
                         dtype=dtype),
        "wo": dense_init(ks[3], (hq, hd, d), ("heads", "hd", "embed"),
                         scale=1.0 / np.sqrt(hq * hd), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = Param(jnp.zeros((hq, hd), dtype), ("heads", "hd"))
        p["bk"] = Param(jnp.zeros((hkv, hd), dtype), ("kv_heads", "hd"))
        p["bv"] = Param(jnp.zeros((hkv, hd), dtype), ("kv_heads", "hd"))
    return p


def _mlp_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d, f), ("embed", "mlp"), dtype=dtype),
        "w_out": dense_init(ks[2], (f, d), ("mlp", "embed"), dtype=dtype),
    }
    if cfg.mlp_variant == "swiglu":
        p["w_gate"] = dense_init(ks[1], (d, f), ("embed", "mlp"),
                                 dtype=dtype)
    return p


def _mlp_forward(cfg: ModelConfig, p, x):
    if cfg.mlp_variant == "swiglu":
        return swiglu(x, p["w_in"], p["w_gate"], p["w_out"])
    return linear(jax.nn.gelu(linear(x, p["w_in"])), p["w_out"])


def _moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), ("embed", "experts"),
                             dtype=jnp.float32),
        "w1": dense_init(ks[1], (E, d, f), ("experts", "embed", "expert_mlp"),
                         dtype=dtype),
        "w3": dense_init(ks[2], (E, d, f), ("experts", "embed", "expert_mlp"),
                         dtype=dtype),
        "w2": dense_init(ks[3], (E, f, d), ("experts", "expert_mlp", "embed"),
                         scale=1.0 / np.sqrt(f), dtype=dtype),
    }


def _mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.n_ssm_heads
    K = cfg.ssm_conv_width
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * N + H),
                           ("embed", "ssm_in"), dtype=dtype),
        "conv_w": Param(0.1 * jax.random.normal(ks[1], (K, di),
                                                dtype=jnp.float32)
                        .astype(dtype), ("conv_k", "ssm_inner")),
        "a_log": Param(jnp.log(jnp.linspace(1.0, float(max(H, 2)), H)),
                       ("ssm_heads",)),
        "dt_bias": Param(jnp.zeros((H,), jnp.float32), ("ssm_heads",)),
        "d_skip": Param(jnp.ones((H,), jnp.float32), ("ssm_heads",)),
        "norm": norm_init(di, ("ssm_inner",)),
        "w_out": dense_init(ks[2], (di, d), ("ssm_inner", "embed"),
                            dtype=dtype),
    }


def _mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.n_ssm_heads
    K = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di), ("embed", "ssm_in"),
                           dtype=dtype),
        "conv_w": Param(0.1 * jax.random.normal(ks[1], (K, di),
                                                jnp.float32).astype(dtype),
                        ("conv_k", "ssm_inner")),
        "wq": dense_init(ks[2], (di, di), ("ssm_inner", "ssm_inner2"),
                         dtype=dtype),
        "wk": dense_init(ks[3], (di, di), ("ssm_inner", "ssm_inner2"),
                         dtype=dtype),
        "wv": dense_init(ks[4], (di, di), ("ssm_inner", "ssm_inner2"),
                         dtype=dtype),
        "w_gates": dense_init(ks[5], (di, 2 * H), ("ssm_inner", "ssm_heads2"),
                              dtype=jnp.float32),
        "norm": norm_init(di, ("ssm_inner",)),
        "w_out": dense_init(ks[6], (di, d), ("ssm_inner", "embed"),
                            dtype=dtype),
    }


def _slstm_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    H = cfg.n_ssm_heads
    hd = d // H
    ks = jax.random.split(key, 4)
    return {
        "w_x": dense_init(ks[0], (d, H, 4, hd),
                          ("embed", "ssm_heads", "gates", "hd"), dtype=dtype),
        "b_x": Param(jnp.zeros((H, 4, hd), jnp.float32),
                     ("ssm_heads", "gates", "hd")),
        "r_h": Param(
            (0.5 / np.sqrt(hd)) * jax.random.normal(
                ks[1], (H, 4, hd, hd), jnp.float32).astype(dtype),
            ("ssm_heads", "gates", "hd", "hd2")),
        "w_ffn_in": dense_init(ks[2], (d, 2 * d), ("embed", "mlp"),
                               dtype=dtype),
        "w_ffn_out": dense_init(ks[3], (2 * d, d), ("mlp", "embed"),
                                dtype=dtype),
    }


def _block_init(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    """One decoder block of the given kind with its norms."""
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    if kind == "attn":
        p = {"norm1": norm_init(d), "attn": _attn_init(k1, cfg, dtype=dtype),
             "norm2": norm_init(d)}
        p["ffn"] = _moe_init(k2, cfg, dtype) if cfg.is_moe \
            else _mlp_init(k2, cfg, dtype)
        return p
    if kind == "mamba":
        return {"norm1": norm_init(d), "ssm": _mamba_init(k1, cfg, dtype)}
    if kind == "mlstm":
        return {"norm1": norm_init(d), "ssm": _mlstm_init(k1, cfg, dtype)}
    if kind == "slstm":
        return {"norm1": norm_init(d), "ssm": _slstm_init(k1, cfg, dtype),
                "norm2": norm_init(d)}
    raise ValueError(kind)


def _stack_init(key, cfg: ModelConfig, kind: str, n: int, dtype) -> dict:
    """n stacked blocks (leading scan axis on every leaf)."""
    keys = jax.random.split(key, n)
    blocks = [_block_init(k, cfg, kind, dtype) for k in keys]
    return jax.tree.map(
        lambda *xs: Param(jnp.stack([x.value for x in xs]),
                          ("layers",) + xs[0].axes),
        *blocks, is_leaf=lambda x: isinstance(x, Param))


def layer_pattern(cfg: ModelConfig) -> list[tuple[str, str, int]]:
    """Describe the decoder stack as homogeneous groups:
    list of (group_name, kind, n_blocks)."""
    if cfg.family in ("dense", "moe", "vlm"):
        return [("blocks", "attn", cfg.n_layers)]
    if cfg.family == "audio":
        return [("blocks", "attn", cfg.n_layers)]  # decoder; encoder separate
    if cfg.family == "ssm":
        # xLSTM [7:1]: every cfg.slstm_every-th block is sLSTM
        out = []
        run = 0
        gi = 0
        for i in range(cfg.n_layers):
            is_s = cfg.slstm_every and ((i + 1) % cfg.slstm_every == 0)
            if is_s:
                if run:
                    out.append((f"m{gi}", "mlstm", run))
                out.append((f"s{gi}", "slstm", 1))
                run = 0
                gi += 1
            else:
                run += 1
        if run:
            out.append((f"m{gi}", "mlstm", run))
        return out
    if cfg.family == "hybrid":
        # Zamba2: groups of attn_every mamba blocks + 1 *shared* attn block
        n_groups = cfg.n_layers // (cfg.attn_every + 1)
        rest = cfg.n_layers - n_groups * (cfg.attn_every + 1)
        out = []
        for gi in range(n_groups):
            out.append((f"m{gi}", "mamba", cfg.attn_every))
            out.append((f"shared{gi}", "shared_attn", 1))
        if rest:
            out.append(("m_tail", "mamba", rest))
        return out
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key) -> PyTree:
    """Full Param tree for the model."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 16)
    params: dict = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                            dtype=dtype),
        "final_norm": norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                       ("embed", "vocab"), dtype=dtype)
    groups = layer_pattern(cfg)
    shared_done = False
    for gi, (gname, kind, n) in enumerate(groups):
        k = jax.random.fold_in(keys[2], gi)
        if kind == "shared_attn":
            if not shared_done:
                params["shared_attn"] = _block_init(k, cfg, "attn", dtype)
                shared_done = True
            continue
        params[gname] = _stack_init(k, cfg, kind, n, dtype)
    if cfg.family == "audio":
        # encoder stack (non-causal attention + MLP) + learned positions
        params["enc_blocks"] = _stack_init(keys[3], cfg, "attn",
                                           cfg.encoder_layers, dtype)
        params["enc_pos"] = Param(
            0.01 * jax.random.normal(keys[4], (cfg.encoder_seq, cfg.d_model),
                                     jnp.float32).astype(dtype),
            ("enc_seq", "embed"))
        params["enc_norm"] = norm_init(cfg.d_model)
        # decoder cross-attention (one per decoder layer, stacked)
        cross = [
            {"norm": norm_init(cfg.d_model),
             "attn": _attn_init(jax.random.fold_in(keys[5], i), cfg,
                                cross=True, dtype=dtype)}
            for i in range(cfg.n_layers)
        ]
        params["cross_blocks"] = jax.tree.map(
            lambda *xs: Param(jnp.stack([x.value for x in xs]),
                              ("layers",) + xs[0].axes),
            *cross, is_leaf=lambda x: isinstance(x, Param))
    if cfg.family == "vlm":
        params["projector"] = _mlp_init(keys[6], cfg, dtype)
    return params


# ==========================================================================
# Block forward functions
# ==========================================================================

def _attn_forward(cfg: ModelConfig, p, x, *, sin, cos, mode: str,
                  cache=None, lengths=None, q_offset=0, mesh=None,
                  batch_axes=("data",), cross_kv=None, enc_lengths=None,
                  rolling=False, kv_shard="none"):
    """Self-attention block (+ FFN).  Returns (x, new_cache, aux)."""
    B = x.shape[0]
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    ap = p["attn"]
    q = linear(h, ap["wq"], ap.get("bq"))          # (B, S, Hq, hd)
    k = linear(h, ap["wk"], ap.get("bk"))          # (B, S, Hkv, hd)
    v = linear(h, ap["wv"], ap.get("bv"))
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    new_cache = None
    if mode == "decode":
        # write new kv at position lengths-1 (lengths already incremented)
        pos = lengths - 1
        L = cache["k"].shape[1]
        if rolling:
            pos = pos % L
        bidx = jnp.arange(B)
        k_cache = cache["k"].at[bidx, pos].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, pos].set(v[:, 0].astype(cache["v"].dtype))
        if kv_shard == "length" and not rolling:
            o = attn_lib.decode_attention_lsharded(
                q[:, 0], k_cache, v_cache, lengths, mesh=mesh,
                batch_axes=batch_axes)[:, None]
        else:
            o = attn_lib.decode_attention(q[:, 0], k_cache, v_cache,
                                          lengths,
                                          sliding_window=cfg.sliding_window,
                                          rolling=rolling)[:, None]
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = attn_lib.causal_attention(
            q, k, v, q_offset=q_offset,
            sliding_window=cfg.sliding_window,
            lengths=lengths if mode == "prefill" else None)
        if mode == "prefill":
            L = cache["k"].shape[1] if cache is not None else k.shape[1]
            S = k.shape[1]
            if L == S:
                new_cache = {"k": k, "v": v}
            else:
                kc = jnp.zeros((B, L) + k.shape[2:], k.dtype)
                new_cache = {"k": kc.at[:, :S].set(k),
                             "v": kc.at[:, :S].set(v)}
    x = x + linear(o.reshape(o.shape[:-2] + (-1,)),
                   ap["wo"].reshape(-1, cfg.d_model))

    aux = jnp.zeros((), jnp.float32)
    if cross_kv is not None:
        cp = p["cross"]
        hc = rms_norm(x, cp["norm"], cfg.norm_eps)
        qc = linear(hc, cp["attn"]["wq"], cp["attn"].get("bq"))
        oc = attn_lib.cross_attention(qc, cross_kv["k"], cross_kv["v"],
                                      lengths=enc_lengths)
        x = x + linear(oc.reshape(oc.shape[:-2] + (-1,)),
                       cp["attn"]["wo"].reshape(-1, cfg.d_model))

    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_ffn(h2, p["ffn"], n_experts=cfg.n_experts,
                         k=cfg.experts_per_token, mesh=mesh,
                         batch_axes=batch_axes,
                         capacity_factor=cfg.capacity_factor)
    else:
        y = _mlp_forward(cfg, p["ffn"], h2)
    return x + y, new_cache, aux


def _mamba_forward(cfg: ModelConfig, p, x, *, mode: str, cache=None,
                   lengths=None):
    """Mamba2 (SSD) block.  Returns (x, new_cache).

    ``lengths`` (prefill): padding steps get dt=0, which zeroes both the
    decay exponent and the input gate — the state is untouched beyond the
    true prompt length."""
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P_hd = di // H
    sp = p["ssm"]
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    proj = linear(h, sp["w_in"])          # (..., 2di+2N+H)
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + sp["dt_bias"].astype(jnp.float32))  # (...,H)
    a = -jnp.exp(sp["a_log"].astype(jnp.float32))              # (H,)
    if mode != "decode" and lengths is not None:
        valid = (jnp.arange(x.shape[1])[None, :]
                 < lengths[:, None]).astype(jnp.float32)
        dt = dt * valid[..., None]

    if mode == "decode":
        xc, conv_state = ssm_lib.causal_conv1d_step(
            xs[:, 0], sp["conv_w"], cache["conv"])
        xc = jax.nn.silu(xc)
        xh = xc.reshape(-1, H, P_hd)
        y, state = ssm_lib.linear_attention_step(
            jnp.broadcast_to(Cm[:, 0, None, :], Cm.shape[:1] + (H, N)),
            jnp.broadcast_to(Bm[:, 0, None, :], Bm.shape[:1] + (H, N)),
            xh, dt[:, 0] * a[None, :], dt[:, 0], cache["state"])
        y = y + sp["d_skip"].astype(y.dtype)[None, :, None] * xh
        y = y.reshape(y.shape[0], 1, di)
        new_cache = {"conv": conv_state, "state": state}
        zz = z
    else:
        xc, conv_state = ssm_lib.causal_conv1d(
            xs, sp["conv_w"],
            lengths=lengths if mode == "prefill" else None)
        xc = jax.nn.silu(xc)
        Bt, S = x.shape[0], x.shape[1]
        xh = xc.reshape(Bt, S, H, P_hd)
        y, state = ssm_lib.chunked_linear_attention(
            Cm[:, :, None, :], Bm[:, :, None, :], xh,
            dt * a[None, None, :], dt, chunk=128)
        y = y + sp["d_skip"].astype(y.dtype)[None, None, :, None] * xh
        y = y.reshape(Bt, S, di)
        new_cache = {"conv": conv_state, "state": state} \
            if mode == "prefill" else None
        zz = z
    y = rms_norm(y * jax.nn.silu(zz), sp["norm"], cfg.norm_eps)
    return x + linear(y, sp["w_out"]), new_cache


def _mlstm_forward(cfg: ModelConfig, p, x, *, mode: str, cache=None,
                   lengths=None):
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads
    hd = di // H
    sp = p["ssm"]
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    up = linear(h, sp["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)

    if mode == "decode":
        xc, conv_state = ssm_lib.causal_conv1d_step(
            xm[:, 0], sp["conv_w"], cache["conv"])
        xc = jax.nn.silu(xc)
        q = linear(xc, sp["wq"]).reshape(-1, H, hd)
        k = linear(xc, sp["wk"]).reshape(-1, H, hd) / np.sqrt(hd)
        v = linear(xc, sp["wv"]).reshape(-1, H, hd)
        gates = linear(xc, sp["w_gates"]).astype(jnp.float32)
        i_pre, f_pre = jnp.split(gates, 2, axis=-1)       # (B, H)
        y, state = ssm_lib.linear_attention_step(
            q, k, v, jax.nn.log_sigmoid(f_pre), jax.nn.sigmoid(i_pre),
            cache["state"], normalize=True)
        y = y.reshape(-1, 1, di)
        new_cache = {"conv": conv_state, "state": state}
        zz = z
    else:
        xc, conv_state = ssm_lib.causal_conv1d(
            xm, sp["conv_w"],
            lengths=lengths if mode == "prefill" else None)
        xc = jax.nn.silu(xc)
        Bt, S = x.shape[0], x.shape[1]
        q = linear(xc, sp["wq"]).reshape(Bt, S, H, hd)
        k = linear(xc, sp["wk"]).reshape(Bt, S, H, hd) / np.sqrt(hd)
        v = linear(xc, sp["wv"]).reshape(Bt, S, H, hd)
        gates = linear(xc, sp["w_gates"]).astype(jnp.float32)
        i_pre, f_pre = jnp.split(gates, 2, axis=-1)       # (B, S, H)
        log_f = jax.nn.log_sigmoid(f_pre)
        i_g = jax.nn.sigmoid(i_pre)
        if lengths is not None:
            valid = (jnp.arange(S)[None, :]
                     < lengths[:, None]).astype(jnp.float32)[..., None]
            log_f = log_f * valid   # decay 1 on padding
            i_g = i_g * valid       # no input on padding
        y, state = ssm_lib.chunked_linear_attention(
            q, k, v, log_f, i_g, chunk=128, normalize=True)
        y = y.reshape(Bt, S, di)
        new_cache = {"conv": conv_state, "state": state} \
            if mode == "prefill" else None
        zz = z
    y = rms_norm(y * jax.nn.silu(zz), sp["norm"], cfg.norm_eps)
    return x + linear(y, sp["w_out"]), new_cache


def _slstm_forward(cfg: ModelConfig, p, x, *, mode: str, cache=None,
                   lengths=None):
    d = cfg.d_model
    H = cfg.n_ssm_heads
    hd = d // H
    sp = p["ssm"]
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    xg = linear(h, sp["w_x"].reshape(d, -1)).reshape(
        h.shape[:-1] + (H, 4, hd)) + sp["b_x"].astype(h.dtype)
    if mode == "decode":
        y, state = ssm_lib.slstm_step(xg[:, 0], sp["r_h"], cache["hcnm"])
        y = y[:, None]
        new_cache = {"hcnm": state}
    else:
        valid = None
        if lengths is not None:
            valid = jnp.arange(xg.shape[1])[None, :] < lengths[:, None]
        y, state = ssm_lib.slstm_scan(xg, sp["r_h"], valid=valid)
        new_cache = {"hcnm": state} if mode == "prefill" else None
    y = y.reshape(y.shape[:2] + (d,))
    x = x + y
    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    ff = linear(jax.nn.gelu(linear(h2, sp["w_ffn_in"])), sp["w_ffn_out"])
    return x + ff, new_cache


# ==========================================================================
# Stack execution
# ==========================================================================

def _tree_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _block_forward(cfg, kind, p, x, *, mode, cache, common):
    """Dispatch one block.  Returns (x, new_cache, aux)."""
    if kind in ("attn", "shared_attn"):
        return _attn_forward(cfg, p, x, mode=mode, cache=cache, **common)
    lengths = common.get("lengths")
    if kind == "mamba":
        x, nc = _mamba_forward(cfg, p, x, mode=mode, cache=cache,
                               lengths=lengths)
    elif kind == "mlstm":
        x, nc = _mlstm_forward(cfg, p, x, mode=mode, cache=cache,
                               lengths=lengths)
    elif kind == "slstm":
        x, nc = _slstm_forward(cfg, p, x, mode=mode, cache=cache,
                               lengths=lengths)
    else:
        raise ValueError(kind)
    return x, nc, jnp.zeros((), jnp.float32)


def _empty_cache_block(cfg: ModelConfig, kind: str, batch: int,
                       max_len: int, dtype) -> Optional[dict]:
    hkv, hd = cfg.n_kv_heads, cfg.hd
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    K = cfg.ssm_conv_width
    if kind in ("attn", "shared_attn"):
        L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        z = jnp.zeros((batch, L, hkv, hd), dtype)
        return {"k": z, "v": z}
    if kind == "mamba":
        return {"conv": jnp.zeros((batch, K - 1, di), dtype),
                "state": jnp.zeros((batch, H, N, di // H), jnp.float32)}
    if kind == "mlstm":
        hd_i = di // H
        return {"conv": jnp.zeros((batch, K - 1, di), dtype),
                "state": jnp.zeros((batch, H, hd_i, hd_i + 1), jnp.float32)}
    if kind == "slstm":
        hd_s = cfg.d_model // H
        z = jnp.zeros((batch, H, hd_s), jnp.float32)
        return {"hcnm": (z, z, z, jnp.full((batch, H, hd_s), -1e30,
                                           jnp.float32))}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Decode cache pytree: per group, stacked along the scan axis."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: dict = {"lengths": jnp.zeros((batch,), jnp.int32)}
    for gname, kind, n in layer_pattern(cfg):
        blk = _empty_cache_block(cfg, kind, batch, max_len, dtype)
        cache[gname] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), blk)
    if cfg.family == "audio":
        # cross-attention KV computed at prefill: (layers, B, S_enc, Hkv, hd)
        z = jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads,
                       cfg.hd), dtype)
        cache["cross_kv"] = {"k": z, "v": z}
        cache["enc_lengths"] = jnp.full((batch,), cfg.encoder_seq, jnp.int32)
    return cache


def _run_stack(cfg: ModelConfig, params, x, *, mode: str, cache, common,
               remat: bool = True):
    """Run all groups; returns (x, new_cache, aux_total).

    ``cache`` entries (stacked per group) are threaded through lax.scan.
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    cross_all = common.pop("cross_all", None)
    act_spec = common.pop("act_spec", None)

    def _constrain(t):
        if act_spec is None:
            return t
        return jax.lax.with_sharding_constraint(t, act_spec)

    x = _constrain(x)

    for gname, kind, n in layer_pattern(cfg):
        gp = params["shared_attn"] if kind == "shared_attn" \
            else params[gname]
        gcache = cache.get(gname) if cache is not None else None

        if kind == "shared_attn":
            # single application, weights shared across groups
            c_in = _tree_slice(gcache, 0) if gcache is not None else None
            x, nc, aux = _block_forward(cfg, kind, gp, x, mode=mode,
                                        cache=c_in, common=dict(common))
            x = _constrain(x)
            aux_total = aux_total + aux
            if nc is not None:
                new_cache[gname] = jax.tree.map(lambda a: a[None], nc)
            continue

        if n == 1:
            p0 = _tree_slice(gp, 0)
            c0 = _tree_slice(gcache, 0) if gcache is not None else None
            if cross_all is not None:
                p0 = dict(p0)
                # cross handled only in audio path below (per-layer index)
            x, nc, aux = _block_forward(cfg, kind, p0, x, mode=mode,
                                        cache=c0, common=dict(common))
            x = _constrain(x)
            aux_total = aux_total + aux
            if nc is not None:
                new_cache[gname] = jax.tree.map(lambda a: a[None], nc)
            continue

        def layer(carry, xs):
            xx, aux_acc = carry
            p, c = xs
            xx, nc, aux = _block_forward(cfg, kind, p, xx, mode=mode,
                                         cache=c, common=dict(common))
            return (_constrain(xx), aux_acc + aux), nc

        fn = jax.checkpoint(layer) if remat else layer
        (x, aux_total), ncs = jax.lax.scan(
            fn, (x, aux_total), (gp, gcache))
        if mode != "train" and ncs is not None:
            new_cache[gname] = ncs
    return x, new_cache, aux_total


def _run_stack_audio(cfg: ModelConfig, params, x, *, mode: str, cache,
                     common, cross_kv, enc_lengths, remat: bool = True):
    """Decoder stack with per-layer cross attention (audio family)."""
    aux_total = jnp.zeros((), jnp.float32)
    act_spec = common.pop("act_spec", None)

    def _constrain(t):
        if act_spec is None:
            return t
        return jax.lax.with_sharding_constraint(t, act_spec)

    x = _constrain(x)
    gp = params["blocks"]
    cp = params["cross_blocks"]
    gcache = cache.get("blocks") if cache is not None else None

    def layer(carry, xs):
        xx, aux_acc = carry
        p, cb, c, ckv = xs
        p = dict(p)
        p["cross"] = cb
        cm = dict(common)
        cm["cross_kv"] = ckv
        cm["enc_lengths"] = enc_lengths
        xx, nc, aux = _attn_forward(cfg, p, xx, mode=mode, cache=c, **cm)
        return (_constrain(xx), aux_acc + aux), nc

    fn = jax.checkpoint(layer) if remat else layer
    (x, aux_total), ncs = jax.lax.scan(
        fn, (x, aux_total), (gp, cp, gcache, cross_kv))
    new_cache = {"blocks": ncs} if mode != "train" and ncs is not None else {}
    return x, new_cache, aux_total


def _encode_audio(cfg: ModelConfig, params, frames, remat: bool = True):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend): frames (B, S_enc, d)."""
    # follow the parameter compute dtype (mixed-precision train casts)
    x = frames.astype(params["enc_pos"].dtype) + params["enc_pos"][None]
    sin, cos = make_rope(jnp.arange(x.shape[1]), cfg.hd, cfg.rope_theta)
    sin, cos = sin[None], cos[None]

    def layer(xx, p):
        h = rms_norm(xx, p["norm1"], cfg.norm_eps)
        q = linear(h, p["attn"]["wq"], p["attn"].get("bq"))
        k = linear(h, p["attn"]["wk"], p["attn"].get("bk"))
        v = linear(h, p["attn"]["wv"], p["attn"].get("bv"))
        o = attn_lib.cross_attention(q, k, v)  # full bidirectional
        xx = xx + linear(o.reshape(o.shape[:-2] + (-1,)),
                         p["attn"]["wo"].reshape(-1, cfg.d_model))
        h2 = rms_norm(xx, p["norm2"], cfg.norm_eps)
        y = _mlp_forward(cfg, p["ffn"], h2)
        return xx + y, None

    fn = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(lambda c, p: fn(c, p), x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv_from_encoder(cfg: ModelConfig, params, enc_out):
    """Precompute per-decoder-layer cross K/V: (layers, B, S_enc, Hkv, hd)."""
    def one(cb):
        k = linear(enc_out, cb["attn"]["wk"], cb["attn"].get("bk"))
        v = linear(enc_out, cb["attn"]["wv"], cb["attn"].get("bv"))
        return {"k": k, "v": v}

    return jax.vmap(one, in_axes=0, out_axes=0)(params["cross_blocks"])


# ==========================================================================
# Model-level API
# ==========================================================================

def _embed_tokens(cfg, params, tokens):
    return params["embed"][tokens]


def _lm_logits(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"])
    return linear(x, params["lm_head"])


def _chunked_lm_loss(cfg, params, x, targets, mask, *, chunk: int = 256):
    """Fused lm_head + cross entropy, scanned over sequence chunks with
    remat, so the fp32 (B, S, V) logits tensor is never materialized (a
    256k-vocab model at B_loc=16, S=4096 would need ~67 GB otherwise)."""
    B, S, _ = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    if S % chunk != 0 or S <= chunk:
        logits = _lm_logits(cfg, params, x)
        return cross_entropy_loss(logits, targets, mask)
    n = S // chunk

    def body(carry, i):
        nll_sum, m_sum = carry
        xs = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ts = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        logits = _lm_logits(cfg, params, xs).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        msf = ms.astype(jnp.float32)
        return (nll_sum + jnp.sum((logz - gold) * msf),
                m_sum + jnp.sum(msf)), None

    (nll, m), _ = jax.lax.scan(jax.checkpoint(body),
                               (jnp.zeros((), jnp.float32),
                                jnp.zeros((), jnp.float32)),
                               jnp.arange(n))
    return nll / jnp.maximum(m, 1.0)


def _prepare_inputs(cfg: ModelConfig, params, batch):
    """Embed tokens; splice in frontend embeddings for vlm/audio."""
    x = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.family == "vlm" and "patches" in batch:
        proj = batch["patches"]
        pr = params["projector"]
        proj = _mlp_forward(cfg, pr, proj)
        # patches occupy the first patch_tokens positions of the sequence
        npt = proj.shape[1]
        x = jnp.concatenate([proj.astype(x.dtype), x[:, npt:]], axis=1)
    return x


def loss_fn(cfg: ModelConfig, params, batch, *, mesh=None,
            batch_axes=("data",), act_spec=None, remat: bool = True):
    """Next-token LM loss.  batch: tokens (B,S), targets (B,S), mask (B,S),
    plus 'patches' (vlm) or 'frames' (audio)."""
    x = _prepare_inputs(cfg, params, batch)
    B, S = batch["tokens"].shape
    sin, cos = make_rope(jnp.arange(S), cfg.hd, cfg.rope_theta)
    common = dict(sin=sin[None], cos=cos[None], mesh=mesh,
                  batch_axes=batch_axes, lengths=None, q_offset=0,
                  act_spec=act_spec)
    if cfg.family == "audio":
        enc = _encode_audio(cfg, params, batch["frames"], remat=remat)
        cross_kv = _cross_kv_from_encoder(cfg, params, enc)
        x, _, aux = _run_stack_audio(
            cfg, params, x, mode="train", cache=None, common=common,
            cross_kv=cross_kv,
            enc_lengths=batch.get("enc_lengths"), remat=remat)
    else:
        x, _, aux = _run_stack(cfg, params, x, mode="train", cache=None,
                               common=common, remat=remat)
    loss = _chunked_lm_loss(cfg, params, x, batch["targets"],
                            batch.get("mask"))
    return loss + cfg.router_aux_weight * aux


def prefill_fn(cfg: ModelConfig, params, batch, *, max_len: int,
               mesh=None, batch_axes=("data",), act_spec=None,
               remat: bool = True):
    """Prefill: run the prompt, build the decode cache.

    batch: tokens (B, S), lengths (B,) true prompt lengths; returns
    (last_logits (B, V), cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    lengths = batch.get("lengths",
                        jnp.full((B,), S, jnp.int32)).astype(jnp.int32)
    x = _prepare_inputs(cfg, params, batch)
    sin, cos = make_rope(jnp.arange(S), cfg.hd, cfg.rope_theta)
    common = dict(sin=sin[None], cos=cos[None], mesh=mesh,
                  batch_axes=batch_axes, lengths=lengths, q_offset=0,
                  act_spec=act_spec)
    cache = init_cache(cfg, B, max_len)
    if cfg.family == "audio":
        enc = _encode_audio(cfg, params, batch["frames"], remat=remat)
        cross_kv = _cross_kv_from_encoder(cfg, params, enc)
        cache["cross_kv"] = cross_kv
        x, nc, _ = _run_stack_audio(
            cfg, params, x, mode="prefill", cache=cache, common=common,
            cross_kv=cross_kv, enc_lengths=cache["enc_lengths"], remat=remat)
    else:
        x, nc, _ = _run_stack(cfg, params, x, mode="prefill", cache=cache,
                              common=common, remat=remat)
    for k, v in nc.items():
        cache[k] = v
    cache["lengths"] = lengths
    # logits at the last valid position of each row
    idx = jnp.clip(lengths - 1, 0, S - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return _lm_logits(cfg, params, x_last), cache


# ==========================================================================
# Chunked prefill + paged decode (attention-family stacks)
#
# These paths serve the pluggable cache backends of
# :mod:`repro.serving.cache_backend`:
#
#   * ``chunk_prefill_fn``       — process ONE prompt chunk per row against
#     contiguous per-slot cache rows (slot backend, chunked prefill);
#   * ``paged_decode_fn``        — one-token decode reading/writing KV
#     through a vLLM-style block table over a shared pool;
#   * ``paged_chunk_prefill_fn`` — prompt chunks over the paged pool.
#
# All three support only homogeneous attention stacks (dense / moe / vlm
# decoders: ``layer_pattern == [("blocks", "attn", n)]``); SSM/hybrid
# recurrences and audio cross-attention carry extra cache state that has
# no paged layout yet.
# ==========================================================================

def supports_paged_stack(cfg: ModelConfig) -> bool:
    """True iff the decoder is a single homogeneous attention stack whose
    KV cache is pure (k, v) pairs — the families the chunked/paged serving
    paths can drive."""
    return (cfg.family in ("dense", "moe", "vlm")
            and not cfg.sliding_window
            and layer_pattern(cfg) == [("blocks", "attn", cfg.n_layers)])


def _require_paged_stack(cfg: ModelConfig, what: str) -> None:
    if not supports_paged_stack(cfg):
        raise ValueError(
            f"{what} supports only attention-family models without a "
            f"sliding window (dense/moe/vlm), got family={cfg.family!r} "
            f"sliding_window={cfg.sliding_window}")


def _chunk_qkv(cfg: ModelConfig, p, xx, sin, cos):
    """Pre-attention half of an attn block: norm + q/k/v projection + rope."""
    h = rms_norm(xx, p["norm1"], cfg.norm_eps)
    ap = p["attn"]
    q = apply_rope(linear(h, ap["wq"], ap.get("bq")), sin, cos)
    k = apply_rope(linear(h, ap["wk"], ap.get("bk")), sin, cos)
    v = linear(h, ap["wv"], ap.get("bv"))
    return q, k, v


def _chunk_finish(cfg: ModelConfig, p, xx, o, *, mesh, batch_axes):
    """Post-attention half: output projection + (MoE-)FFN residual."""
    ap = p["attn"]
    xx = xx + linear(o.reshape(o.shape[:-2] + (-1,)),
                     ap["wo"].reshape(-1, cfg.d_model))
    h2 = rms_norm(xx, p["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe_ffn(h2, p["ffn"], n_experts=cfg.n_experts,
                       k=cfg.experts_per_token, mesh=mesh,
                       batch_axes=batch_axes,
                       capacity_factor=cfg.capacity_factor)
    else:
        y = _mlp_forward(cfg, p["ffn"], h2)
    return xx + y


def chunk_prefill_fn(cfg: ModelConfig, params, cache, tokens, offsets,
                     chunk_lens, *, mesh=None, batch_axes=("data",)):
    """Incremental prefill: run ONE chunk of each row's prompt against its
    (already partially filled) contiguous cache row.

    cache: gathered per-row cache slices {"lengths", "blocks": {"k", "v"}}
    with k/v of shape (layers, n, L, Hkv, hd); tokens: (n, C) right-padded
    chunk tokens; offsets: (n,) absolute position of each row's chunk
    start; chunk_lens: (n,) valid tokens in this chunk (0 marks a padding
    row — its cache writes are dropped and its logits are garbage).

    Returns (last_logits (n, V) at each row's final chunk position,
    updated cache slices with ``lengths = offsets + chunk_lens``).
    """
    _require_paged_stack(cfg, "chunk_prefill_fn")
    n, C = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    posmat = offsets[:, None] + jnp.arange(C)[None, :]          # (n, C)
    sin, cos = make_rope(posmat, cfg.hd, cfg.rope_theta)
    kv_len = offsets + chunk_lens
    L = cache["blocks"]["k"].shape[2]
    # padding columns scatter to position L -> dropped by JAX semantics
    wpos = jnp.where(jnp.arange(C)[None, :] < chunk_lens[:, None],
                     posmat, L)
    bidx = jnp.arange(n)

    def layer(xx, xs):
        p, c = xs
        q, k, v = _chunk_qkv(cfg, p, xx, sin, cos)
        kc = c["k"].at[bidx[:, None], wpos].set(k.astype(c["k"].dtype))
        vc = c["v"].at[bidx[:, None], wpos].set(v.astype(c["v"].dtype))
        o = attn_lib.chunk_attention(q, kc, vc, q_pos=posmat, kv_len=kv_len)
        xx = _chunk_finish(cfg, p, xx, o, mesh=mesh, batch_axes=batch_axes)
        return xx, {"k": kc, "v": vc}

    x, ncs = jax.lax.scan(layer, x, (params["blocks"], cache["blocks"]))
    idx = jnp.clip(chunk_lens - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    new_cache = dict(cache)
    new_cache["blocks"] = ncs
    new_cache["lengths"] = kv_len.astype(jnp.int32)
    return _lm_logits(cfg, params, x_last), new_cache


def _paged_contiguous(pool, bt, n, L):
    """Gather a request-major contiguous view (n, L, Hkv, hd) of a paged
    pool (n_blocks, block, Hkv, hd) through clipped block tables."""
    return pool[bt].reshape(n, L, *pool.shape[2:])


def paged_decode_fn(cfg: ModelConfig, params, k_pool, v_pool, tables,
                    lengths, blk, off, tokens, *, block_size: int,
                    attn_impl: str = "gather", mesh=None,
                    batch_axes=("data",)):
    """One decode step over a paged KV cache (vLLM block tables).

    k_pool/v_pool: (layers, n_blocks, block, Hkv, hd); tables: (n,
    max_blocks) int32 (-1 = unallocated); lengths: (n,) already counting
    the new token; blk/off: (n,) physical (block, offset) of the new
    token's KV (callers pass ``blk == n_blocks`` for padding rows, whose
    writes are then dropped).  tokens: (n,) int32.

    attn_impl:
      * ``"gather"`` (default) — materialize each row's blocks as a
        contiguous view and reuse :func:`attention.decode_attention`.
        Because masked positions contribute exactly zero, this is
        bit-identical to the contiguous slot cache whenever
        ``max_blocks * block_size`` equals the slot cache length — the
        parity oracle the engine tests rely on.
      * ``"ref"``    — :func:`repro.serving.paged_cache
        .paged_decode_attention_ref`, the standalone jnp oracle.
      * ``"pallas"`` — the TPU kernel
        (:mod:`repro.kernels.paged_attention`), which streams physical
        blocks via scalar-prefetched block tables and never materializes
        the contiguous view.

    Returns (next_tokens (n,) int32 greedy, k_pool, v_pool).
    """
    _require_paged_stack(cfg, "paged_decode_fn")
    if attn_impl not in ("gather", "ref", "pallas"):
        raise ValueError(f"unknown attn_impl {attn_impl!r}")
    n = tokens.shape[0]
    x = _embed_tokens(cfg, params, tokens[:, None])
    pos = lengths - 1
    sin, cos = make_rope(pos[:, None], cfg.hd, cfg.rope_theta)
    nb_pool = k_pool.shape[1]
    bt = jnp.clip(tables, 0, nb_pool - 1)
    L = tables.shape[1] * block_size

    def layer(xx, xs):
        p, kp, vp = xs
        q, k, v = _chunk_qkv(cfg, p, xx, sin, cos)
        kp = kp.at[blk, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[blk, off].set(v[:, 0].astype(vp.dtype))
        if attn_impl == "pallas":
            from ..kernels.paged_attention import \
                paged_decode_attention_pallas
            o = paged_decode_attention_pallas(
                q[:, 0], kp, vp, tables, lengths,
                block_size=block_size)[:, None]
        elif attn_impl == "ref":
            from ..serving.paged_cache import paged_decode_attention_ref
            o = paged_decode_attention_ref(
                q[:, 0], kp, vp, tables, lengths, block_size)[:, None]
        else:
            kc = _paged_contiguous(kp, bt, n, L)
            vc = _paged_contiguous(vp, bt, n, L)
            o = attn_lib.decode_attention(q[:, 0], kc, vc, lengths)[:, None]
        xx = _chunk_finish(cfg, p, xx, o, mesh=mesh, batch_axes=batch_axes)
        return xx, (kp, vp)

    x, (kps, vps) = jax.lax.scan(layer, x, (params["blocks"], k_pool,
                                            v_pool))
    nxt = jnp.argmax(_lm_logits(cfg, params, x[:, 0]), -1).astype(jnp.int32)
    return nxt, kps, vps


def paged_chunk_prefill_fn(cfg: ModelConfig, params, k_pool, v_pool, tables,
                           tokens, offsets, chunk_lens, wblk, woff, *,
                           block_size: int, mesh=None,
                           batch_axes=("data",)):
    """Chunked prefill over the paged pool: scatter each chunk's KV into
    the rows' blocks, then attend through a gathered contiguous view.

    wblk/woff: (n, C) physical (block, offset) of every chunk token
    (padding columns carry ``wblk == n_blocks`` -> dropped writes).
    Returns (last_logits (n, V), k_pool, v_pool).
    """
    _require_paged_stack(cfg, "paged_chunk_prefill_fn")
    n, C = tokens.shape
    x = _embed_tokens(cfg, params, tokens)
    posmat = offsets[:, None] + jnp.arange(C)[None, :]
    sin, cos = make_rope(posmat, cfg.hd, cfg.rope_theta)
    kv_len = offsets + chunk_lens
    nb_pool = k_pool.shape[1]
    bt = jnp.clip(tables, 0, nb_pool - 1)
    L = tables.shape[1] * block_size

    def layer(xx, xs):
        p, kp, vp = xs
        q, k, v = _chunk_qkv(cfg, p, xx, sin, cos)
        kp = kp.at[wblk, woff].set(k.astype(kp.dtype))
        vp = vp.at[wblk, woff].set(v.astype(vp.dtype))
        kc = _paged_contiguous(kp, bt, n, L)
        vc = _paged_contiguous(vp, bt, n, L)
        o = attn_lib.chunk_attention(q, kc, vc, q_pos=posmat, kv_len=kv_len)
        xx = _chunk_finish(cfg, p, xx, o, mesh=mesh, batch_axes=batch_axes)
        return xx, (kp, vp)

    x, (kps, vps) = jax.lax.scan(layer, x, (params["blocks"], k_pool,
                                            v_pool))
    idx = jnp.clip(chunk_lens - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    return _lm_logits(cfg, params, x_last), kps, vps


def decode_fn(cfg: ModelConfig, params, cache, tokens, *, mesh=None,
              batch_axes=("data",), kv_shard="none"):
    """One decode step.  tokens: (B,) int32 — the tokens sampled last step.
    Returns (logits (B, V), new cache)."""
    B = tokens.shape[0]
    lengths = cache["lengths"] + 1
    x = _embed_tokens(cfg, params, tokens[:, None])
    pos = lengths - 1
    sin, cos = make_rope(pos[:, None], cfg.hd, cfg.rope_theta)  # (B,1,hd/2)
    rolling = bool(cfg.sliding_window)
    common = dict(sin=sin, cos=cos, mesh=mesh, batch_axes=batch_axes,
                  lengths=lengths, q_offset=0, rolling=rolling,
                  kv_shard=kv_shard)
    new_cache = dict(cache)
    if cfg.family == "audio":
        x, nc, _ = _run_stack_audio(
            cfg, params, x, mode="decode", cache=cache, common=common,
            cross_kv=cache["cross_kv"], enc_lengths=cache["enc_lengths"],
            remat=False)
    else:
        x, nc, _ = _run_stack(cfg, params, x, mode="decode", cache=cache,
                              common=common, remat=False)
    for k, v in nc.items():
        new_cache[k] = v
    new_cache["lengths"] = lengths
    return _lm_logits(cfg, params, x[:, 0]), new_cache
