"""State-space / recurrent blocks: Mamba2 (SSD), mLSTM, sLSTM.

Mamba2 and mLSTM share one *chunked gated linear attention* core:

    S_t = exp(a_t) * S_{t-1} + i_t * k_t v_t^T          (state: dk x dv)
    y_t = q_t . S_t

computed chunk-parallel (intra-chunk quadratic form on the MXU, inter-chunk
state carry via ``lax.scan``) — this is the TPU-native adaptation of the
SSD algorithm; the per-chunk matmuls are 128-aligned.  The Pallas
``ssm_scan`` kernel implements the same contraction for the hot path;
this jnp version is the oracle / lowering path.

sLSTM has *nonlinear* recurrence (gates read h_{t-1}), so it is computed
with an honest sequential scan over time (the xLSTM paper's design point);
it appears only in a minority of layers (xLSTM[7:1]).

Decode = single-step state updates (the delta_k == 0 workload class of the
paper's Theorem 3: per-request serving cost is constant in response length).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = [
    "chunked_linear_attention",
    "linear_attention_step",
    "causal_conv1d",
    "causal_conv1d_step",
    "slstm_scan",
    "slstm_step",
]


def chunked_linear_attention(q, k, v, log_decay, gate_in, *,
                             chunk: int = 128, initial_state=None,
                             normalize: bool = False):
    """Chunk-parallel scan of the gated linear-attention recurrence.

    q, k: (B, S, H, dk);  v: (B, S, H, dv);
    log_decay: (B, S, H) (<= 0);  gate_in: (B, S, H) input gates i_t.
    k/q may have H=1 (shared across heads, Mamba2-style) — broadcast.

    Returns (y, final_state): y (B, S, H, dv), state (B, H, dk, dv).
    If ``normalize``, divides y by a normalizer running sum (mLSTM style:
    an extra all-ones value column).
    """
    B, S, H, dv = v.shape
    dk = k.shape[-1]
    Hk = k.shape[2]
    if normalize:
        v = jnp.concatenate(
            [v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
        dv_full = dv + 1
    else:
        dv_full = dv

    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    pad = n_chunks * chunk - S

    def padseq(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2)) \
            if pad else x

    # broadcast shared (Mamba2-style) k/q to all heads up front
    if Hk == 1 and H != 1:
        k = jnp.broadcast_to(k, (B, S, H, dk))
    if q.shape[2] == 1 and H != 1:
        q = jnp.broadcast_to(q, (B, S, H, dk))

    qf = padseq(q).astype(jnp.float32)
    kf = padseq(k).astype(jnp.float32)
    vf = padseq(v).astype(jnp.float32)
    af = padseq(log_decay).astype(jnp.float32)
    gf = padseq(gate_in).astype(jnp.float32)
    if pad:  # padded steps must not decay or contribute
        valid = jnp.arange(n_chunks * chunk) < S
        af = af * valid[None, :, None]
        gf = gf * valid[None, :, None]

    # reshape to (B, n_chunks, chunk, ...)
    def c(x):
        return x.reshape((B, n_chunks, chunk) + x.shape[2:])

    qc, kc, vc, ac, gc = c(qf), c(kf), c(vf), c(af), c(gf)
    A = jnp.cumsum(ac, axis=2)                       # (B, n, C, H) cum decay
    A_tot = A[:, :, -1]                              # (B, n, H)

    # intra-chunk: y[t] = sum_{tau<=t} exp(A_t - A_tau) g_tau (q_t.k_tau) v_tau
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    scores = jnp.einsum("bnchd,bnshd->bnhcs", qc, kc)
    At = A.transpose(0, 1, 3, 2)                     # (B,n,H,C)
    pair = jnp.clip(At[..., :, None] - At[..., None, :], -60.0, 60.0)
    decay_ct = jnp.exp(pair) * tri[None, None, None]  # (B,n,H,C,S)
    gates = gc.transpose(0, 1, 3, 2)                 # (B,n,H,S)
    w = scores * decay_ct * gates[..., None, :]      # (B,n,H,C,S)
    y_intra = jnp.einsum("bnhcs,bnshd->bnchd", w, vc)

    # inter-chunk: carry state across chunks
    # chunk input to state: U_n = sum_tau exp(A_tot - A_tau) g_tau k_tau v_tau^T
    wk = jnp.exp(jnp.clip(A_tot[:, :, None, :] - A, -60, 60)) * gc  # (B,n,C,H)
    U = jnp.einsum("bnchk,bnchv,bnch->bnhkv", kc, vc, wk)

    if initial_state is None:
        initial_state = jnp.zeros((B, H, dk, dv_full), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)
        if normalize and initial_state.shape[-1] == dv:
            initial_state = jnp.concatenate(
                [initial_state,
                 jnp.zeros(initial_state.shape[:-1] + (1,), jnp.float32)],
                axis=-1)

    scan_in = (A_tot.transpose(1, 0, 2),             # (n, B, H)
               U.transpose(1, 0, 2, 3, 4),           # (n, B, H, dk, dv)
               qc.transpose(1, 0, 2, 3, 4),          # (n, B, C, H, dk)
               A.transpose(1, 0, 2, 3))              # (n, B, C, H)

    def scan_body(state, xs):
        a_tot, u, q_n, a_cum = xs
        yi = jnp.einsum("bchk,bhkv,bch->bchv", q_n, state,
                        jnp.exp(jnp.clip(a_cum, -60, 60)))
        state = (jnp.exp(jnp.clip(a_tot, -60, 60))[..., None, None] * state
                 + u)
        return state, yi

    state, y_inter = jax.lax.scan(scan_body, initial_state, scan_in)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(
        B, n_chunks * chunk, H, dv_full)
    y = y_intra.reshape(B, n_chunks * chunk, H, dv_full) + y_inter
    y = y[:, :S]
    if normalize:
        norm = y[..., -1:]
        y = y[..., :-1] / jnp.maximum(jnp.abs(norm), 1e-6)
    return y.astype(v.dtype), state


def linear_attention_step(q, k, v, log_decay, gate_in, state, *,
                          normalize: bool = False):
    """Single decode step of the same recurrence.

    q, k: (B, H, dk); v: (B, H, dv); log_decay, gate_in: (B, H);
    state: (B, H, dk, dv(+1)).  Returns (y (B, H, dv), new_state).
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if normalize:
        vf = jnp.concatenate([vf, jnp.ones(vf.shape[:-1] + (1,),
                                           jnp.float32)], axis=-1)
    a = jnp.exp(jnp.clip(log_decay.astype(jnp.float32), -60, 60))
    u = jnp.einsum("bhk,bhv,bh->bhkv", kf, vf, gate_in.astype(jnp.float32))
    state = a[..., None, None] * state.astype(jnp.float32) + u
    y = jnp.einsum("bhk,bhkv->bhv", qf, state)
    if normalize:
        norm = y[..., -1:]
        y = y[..., :-1] / jnp.maximum(jnp.abs(norm), 1e-6)
    return y.astype(v.dtype), state


def causal_conv1d(x, w, *, initial_state=None, lengths=None):
    """Depthwise causal conv over time. x: (B, S, D); w: (K, D).

    Returns (y (B, S, D), final_state (B, K-1, D)).  With ``lengths`` the
    final state is gathered at the last *valid* K-1 positions per row
    (ragged prefill)."""
    B, S, D = x.shape
    K = w.shape[0]
    if initial_state is None:
        initial_state = jnp.zeros((B, K - 1, D), x.dtype)
    xp = jnp.concatenate([initial_state.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is small (4); unrolled taps
        y = y + xp[:, i:i + S].astype(jnp.float32) \
            * w[i].astype(jnp.float32)[None, None, :]
    if lengths is None:
        state = xp[:, S:]  # last K-1 inputs
    else:
        # xp index of the j-th state entry for row b: lengths[b] + j
        idx = lengths[:, None] + jnp.arange(K - 1)[None, :]   # (B, K-1)
        state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return y.astype(x.dtype), state


def causal_conv1d_step(x, w, state):
    """Single-token conv step. x: (B, D); state: (B, K-1, D)."""
    K = w.shape[0]
    xp = jnp.concatenate([state, x[:, None, :]], axis=1)  # (B, K, D)
    y = jnp.einsum("bkd,kd->bd", xp.astype(jnp.float32),
                   w.astype(jnp.float32))
    return y.astype(x.dtype), xp[:, 1:]


# --------------------------------------------------------------------------
# sLSTM (nonlinear recurrence -> sequential scan)
# --------------------------------------------------------------------------

def _slstm_cell(h, c, n, m, x_gates, r_weights):
    """One sLSTM step.  h, c, n: (B, H, hd); m: (B, H, hd) stabilizer.
    x_gates: (B, H, 4, hd) input contributions (W x + b) for i,f,z,o;
    r_weights: (H, 4, hd, hd) block-diagonal recurrent weights."""
    rec = jnp.einsum("bhd,hgde->bhge", h, r_weights)   # (B, H, 4, hd)
    g = (x_gates + rec).astype(jnp.float32)
    i_pre, f_pre, z_pre, o_pre = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    # exponential gating with stabilizer (xLSTM eqs.)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(jnp.clip(i_pre - m_new, -60, 0))
    f_g = jnp.exp(jnp.clip(log_f + m - m_new, -60, 0))
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_scan(x_gates, r_weights, *, initial=None, valid=None):
    """Sequential sLSTM over time.  x_gates: (B, S, H, 4, hd).
    ``valid``: optional (B, S) bool — padding steps leave the state frozen.
    Returns (h_seq (B, S, H, hd), final (h, c, n, m))."""
    B, S, H, _, hd = x_gates.shape
    if initial is None:
        z = jnp.zeros((B, H, hd), jnp.float32)
        initial = (z, z, z, jnp.full((B, H, hd), -1e30, jnp.float32))

    def body(carry, xs):
        if valid is not None:
            xg, vl = xs
        else:
            xg = xs
        h, c, n, m = carry
        h2, c2, n2, m2 = _slstm_cell(h, c, n, m, xg, r_weights)
        if valid is not None:
            keep = vl[:, None, None]
            h2 = jnp.where(keep, h2, h)
            c2 = jnp.where(keep, c2, c)
            n2 = jnp.where(keep, n2, n)
            m2 = jnp.where(keep, m2, m)
        return (h2, c2, n2, m2), h2

    xs = (x_gates.swapaxes(0, 1), valid.swapaxes(0, 1)) \
        if valid is not None else x_gates.swapaxes(0, 1)
    (h, c, n, m), hs = jax.lax.scan(body, initial, xs)
    return hs.swapaxes(0, 1).astype(x_gates.dtype), (h, c, n, m)


def slstm_step(x_gates, r_weights, state):
    """Single decode step.  x_gates: (B, H, 4, hd)."""
    h, c, n, m = state
    h, c, n, m = _slstm_cell(h, c, n, m, x_gates, r_weights)
    return h.astype(x_gates.dtype), (h, c, n, m)
