"""Model substrate: layers, attention, SSM blocks, MoE, and the per-family
model assembly (init / loss / prefill / decode)."""
from .layers import Param, merge_params, split_params  # noqa: F401
from .transformer import (  # noqa: F401
    chunk_prefill_fn,
    decode_fn,
    init_cache,
    init_params,
    layer_pattern,
    loss_fn,
    paged_chunk_prefill_fn,
    paged_decode_fn,
    prefill_fn,
    supports_paged_stack,
)
