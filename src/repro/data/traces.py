"""Arrival-trace generation (Section 6.1).

Requests arrive by a stationary Poisson process whose rate exceeds system
capacity (the overloaded regime of Definition 1), or in bursty episodes
(BurstGPT-style).  Also provides step-indexed adversarial-style instances
used by the theory-validation benchmarks.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.workload import ArrivalInstance, DriftModel, Request, unit_drift
from .synthetic import WorkloadSpec, decode_sampler, prefill_sampler

__all__ = [
    "poisson_trace",
    "bursty_trace",
    "diurnal_trace",
    "batched_rounds_instance",
    "overload_rate",
]


def overload_rate(spec: WorkloadSpec, G: int, B: int,
                  t_token: float = 1.005e-7, c_step: float = 9.775e-3,
                  factor: float = 1.5) -> float:
    """Arrival rate (req/s) that exceeds steady-state capacity by ``factor``.

    Steady state: ~G*B slots, mean occupancy time per request ~ E[o] steps of
    duration ~ (c + t_token * B * E[load per slot] * 1) ... we use the crude
    estimate dt ~= c_step + t_token * B * (mu_s + E[o]/2) and service rate
    G*B / (E[o] * dt).
    """
    e_o = 1.0 / spec.decode_p
    mu_s = spec.mu_s
    dt = c_step + t_token * B * (mu_s + 0.5 * e_o)
    service_rate = G * B / (e_o * dt)
    return factor * service_rate


def poisson_trace(
    spec: WorkloadSpec,
    *,
    n_requests: int,
    rate: float,
    drift: Optional[DriftModel] = None,
    seed: int = 0,
) -> ArrivalInstance:
    """Stationary Poisson arrivals at ``rate`` req/s (wall-clock arrival
    times; use SimConfig(time_based_arrivals=True))."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    times = np.cumsum(gaps)
    s = prefill_sampler(spec)(rng, n_requests)
    o = decode_sampler(spec)(rng, n_requests)
    reqs = [
        Request(rid=i, arrival_step=0, prefill=float(s[i]),
                decode_len=int(o[i]), arrival_time=float(times[i]))
        for i in range(n_requests)
    ]
    return ArrivalInstance(requests=reqs, drift=drift or unit_drift(),
                           name=f"{spec.name}-poisson")


def bursty_trace(
    spec: WorkloadSpec,
    *,
    n_requests: int,
    rate: float,
    burst_factor: float = 8.0,
    burst_frac: float = 0.25,
    period: float = 60.0,
    drift: Optional[DriftModel] = None,
    seed: int = 0,
) -> ArrivalInstance:
    """BurstGPT-style: alternating high/low-rate episodes with mean ``rate``."""
    rng = np.random.default_rng(seed)
    hi = rate * burst_factor
    lo = rate * (1.0 - burst_frac * burst_factor) / max(1.0 - burst_frac, 1e-9)
    lo = max(lo, rate * 0.05)
    times = []
    t = 0.0
    while len(times) < n_requests:
        in_burst = (t % period) < burst_frac * period
        r = hi if in_burst else lo
        t += rng.exponential(1.0 / r)
        times.append(t)
    times = np.asarray(times[:n_requests])
    s = prefill_sampler(spec)(rng, n_requests)
    o = decode_sampler(spec)(rng, n_requests)
    reqs = [
        Request(rid=i, arrival_step=0, prefill=float(s[i]),
                decode_len=int(o[i]), arrival_time=float(times[i]))
        for i in range(n_requests)
    ]
    return ArrivalInstance(requests=reqs, drift=drift or unit_drift(),
                           name=f"{spec.name}-bursty")


def diurnal_trace(
    spec: WorkloadSpec,
    *,
    n_requests: int,
    rate: float,
    amplitude: float = 0.8,
    period: float = 240.0,
    drift: Optional[DriftModel] = None,
    seed: int = 0,
) -> ArrivalInstance:
    """Diurnal ramp: nonhomogeneous Poisson with a sinusoidal rate

        lambda(t) = rate * (1 + amplitude * sin(2 pi t / period))

    sampled by thinning against ``lambda_max = rate * (1 + amplitude)``.
    The mean rate over a full period is ``rate``; peaks reach
    ``(1 + amplitude) * rate`` and troughs ``(1 - amplitude) * rate`` —
    the day/night load swing a fleet router must ride without
    re-provisioning."""
    if not (0.0 <= amplitude <= 1.0):
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    rng = np.random.default_rng(seed)
    lam_max = rate * (1.0 + amplitude)
    times = []
    t = 0.0
    while len(times) < n_requests:
        t += rng.exponential(1.0 / lam_max)
        lam = rate * (1.0 + amplitude * np.sin(2.0 * np.pi * t / period))
        if rng.uniform() * lam_max <= lam:
            times.append(t)
    times = np.asarray(times)
    s = prefill_sampler(spec)(rng, n_requests)
    o = decode_sampler(spec)(rng, n_requests)
    reqs = [
        Request(rid=i, arrival_step=0, prefill=float(s[i]),
                decode_len=int(o[i]), arrival_time=float(times[i]))
        for i in range(n_requests)
    ]
    return ArrivalInstance(requests=reqs, drift=drift or unit_drift(),
                           name=f"{spec.name}-diurnal")


def batched_rounds_instance(
    spec: WorkloadSpec,
    *,
    G: int,
    B: int,
    n_rounds: int,
    pool_factor: float = 3.0,
    homogeneous_decode: Optional[int] = None,
    drift: Optional[DriftModel] = None,
    seed: int = 0,
) -> ArrivalInstance:
    """Step-indexed overloaded instance: all requests available from step 0
    with a pool ``pool_factor`` times the total slot capacity times rounds —
    this guarantees Definition 1's overloaded condition along the run.

    ``homogeneous_decode`` forces o_i = o (Theorem 1's warm-up model).
    """
    rng = np.random.default_rng(seed)
    n = int(pool_factor * G * B * n_rounds)
    s = prefill_sampler(spec)(rng, n)
    if homogeneous_decode is not None:
        o = np.full(n, int(homogeneous_decode), dtype=np.int64)
    else:
        o = decode_sampler(spec)(rng, n)
    reqs = [
        Request(rid=i, arrival_step=0, prefill=float(s[i]), decode_len=int(o[i]))
        for i in range(n)
    ]
    return ArrivalInstance(requests=reqs, drift=drift or unit_drift(),
                           name=f"{spec.name}-rounds")
