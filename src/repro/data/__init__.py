"""Data pipeline: synthetic token batches, workload length distributions,
and arrival traces (Poisson / bursty / batched-rounds)."""
from .synthetic import (  # noqa: F401
    BURSTGPT_LIKE,
    LONGBENCH_HEAVY,
    LONGBENCH_LIKE,
    UNIFORM_PREFILL,
    WorkloadSpec,
    decode_sampler,
    prefill_sampler,
    token_batches,
)
from .traces import (  # noqa: F401
    batched_rounds_instance,
    bursty_trace,
    overload_rate,
    poisson_trace,
)
