"""Synthetic workload and token-batch generation.

Length distributions follow the shapes published in the paper:
  * decode lengths are geometric / discrete-exponential (Fig. 5, production
    traces: "most responses terminate quickly, a non-negligible tail runs
    for many tokens");
  * prefill lengths are broad and long-tailed (Fig. 6, LongBench: prompts
    are *much* longer than outputs) — we use a clipped lognormal;
  * BurstGPT-style light traces use shorter prompts and burstier arrivals.

Also provides token batches for the training substrate.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "WorkloadSpec",
    "LONGBENCH_LIKE",
    "BURSTGPT_LIKE",
    "UNIFORM_PREFILL",
    "prefill_sampler",
    "decode_sampler",
    "token_batches",
]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Parametric description of a request-length workload."""

    name: str
    # prefill (prompt-length) distribution — clipped lognormal
    prefill_log_mean: float
    prefill_log_sigma: float
    s_min: int
    s_max: int
    # decode-length distribution — geometric
    decode_p: float
    o_max: int = 4096

    @property
    def mu_s(self) -> float:
        """Empirical mean of the clipped prefill distribution (MC estimate)."""
        rng = np.random.default_rng(0)
        return float(prefill_sampler(self)(rng, 20_000).mean())

    @property
    def sigma_s(self) -> float:
        rng = np.random.default_rng(0)
        return float(prefill_sampler(self)(rng, 20_000).std())


# LongBench (Fig. 6): prompts cluster in the 2k-16k range with a heavy tail;
# outputs are short (hundreds of tokens), geometric-ish.
LONGBENCH_LIKE = WorkloadSpec(
    name="longbench",
    prefill_log_mean=np.log(6000.0),
    prefill_log_sigma=0.8,
    s_min=64,
    s_max=32_000,
    decode_p=1.0 / 256.0,
    o_max=4096,
)

# BurstGPT (lighter load): short conversational prompts, short outputs.
BURSTGPT_LIKE = WorkloadSpec(
    name="burstgpt",
    prefill_log_mean=np.log(512.0),
    prefill_log_sigma=1.0,
    s_min=8,
    s_max=8_000,
    decode_p=1.0 / 128.0,
    o_max=2048,
)

# Degenerate-ish uniform prefill (used in theory-validation benchmarks where
# sigma_s/s_max = kappa_0 must be controlled exactly).
UNIFORM_PREFILL = WorkloadSpec(
    name="uniform",
    prefill_log_mean=0.0,  # unused
    prefill_log_sigma=0.0,
    s_min=1,
    s_max=1000,
    decode_p=1.0 / 100.0,
)


def prefill_sampler(spec: WorkloadSpec) -> Callable[[np.random.Generator, int], np.ndarray]:
    """Sampler for prefill lengths s_i in [s_min, s_max]."""
    if spec.prefill_log_sigma <= 0:
        def sample_uniform(rng: np.random.Generator, n: int) -> np.ndarray:
            return rng.integers(spec.s_min, spec.s_max + 1, size=n).astype(
                np.float64)
        return sample_uniform

    def sample(rng: np.random.Generator, n: int) -> np.ndarray:
        s = rng.lognormal(spec.prefill_log_mean, spec.prefill_log_sigma, n)
        return np.clip(np.round(s), spec.s_min, spec.s_max).astype(np.float64)

    return sample


def decode_sampler(spec: WorkloadSpec) -> Callable[[np.random.Generator, int], np.ndarray]:
    """Sampler for decode lengths o_i ~ Geo(p), clipped to o_max."""

    def sample(rng: np.random.Generator, n: int) -> np.ndarray:
        o = rng.geometric(spec.decode_p, size=n)
        return np.clip(o, 1, spec.o_max).astype(np.int64)

    return sample


def token_batches(
    *,
    vocab_size: int,
    batch: int,
    seq_len: int,
    n_batches: int,
    seed: int = 0,
    pad_frac: float = 0.05,
    pad_id: int = 0,
):
    """Yield synthetic LM training batches: dict(tokens, targets, mask).

    Targets are next-token shifted; a tail fraction of each row is padding
    so the loss-mask path is exercised.
    """
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        toks = rng.integers(1, vocab_size, size=(batch, seq_len + 1),
                            dtype=np.int32)
        n_pad = int(seq_len * pad_frac)
        if n_pad > 0:
            lens = rng.integers(seq_len - n_pad, seq_len + 1, size=batch)
            for b, L in enumerate(lens):
                toks[b, L:] = pad_id
        yield {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": (toks[:, 1:] != pad_id).astype(np.float32),
        }


# Heavy-tail sensitivity variant: production traces mix short chat with
# 100k+-token agentic/document contexts; dispersion drives both the
# barrier idle (paper Fig. 1: >40 %) and the energy gap.  Used by the
# sensitivity rows of EXPERIMENTS.md §Paper-validation.
LONGBENCH_HEAVY = WorkloadSpec(
    name="longbench-heavy",
    prefill_log_mean=np.log(5000.0),
    prefill_log_sigma=1.4,
    s_min=64,
    s_max=131_072,
    decode_p=1.0 / 512.0,
    o_max=8192,
)
