"""Model / architecture configuration schema.

Every assigned architecture is expressed as a ``ModelConfig``; reduced smoke
variants are derived with ``smoke_variant``.  Families:

  dense   — decoder-only transformer (GQA, RoPE, SwiGLU)
  moe     — dense attention + top-k routed expert FFN (expert parallel)
  ssm     — xLSTM-style recurrent blocks (mLSTM / sLSTM), no KV cache
  hybrid  — Mamba2 blocks with a periodically applied *shared* attention
            block (Zamba2)
  vlm     — decoder-only LM consuming interleaved image-patch embeddings
            (vision tower is a stub per the assignment carve-out)
  audio   — encoder-decoder backbone consuming precomputed audio-frame
            embeddings (conv/mel frontend is a stub)
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "smoke_variant"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss
    # --- SSM / hybrid ---
    ssm_state: int = 0               # N: state size per head (mamba2)
    ssm_heads: int = 0               # number of SSM heads (0 -> n_heads)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv_width: int = 4          # causal depthwise conv kernel
    slstm_every: int = 0             # xLSTM: every k-th block is sLSTM
    attn_every: int = 0              # zamba2: shared attn after every k SSMs
    # --- attention ---
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full causal attention
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame-embedding length
    # --- vlm ---
    patch_tokens: int = 0            # image patch-embedding tokens per sample
    # --- misc ---
    mlp_variant: str = "swiglu"      # "swiglu" (3 mats) | "gelu" (2 mats)
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    source: str = ""                 # citation for the assigned config

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.n_heads

    @property
    def has_kv_cache(self) -> bool:
        return self.family != "ssm"

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab_size
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        di, N, H = self.d_inner, self.ssm_state, self.n_ssm_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        ffn_mats = 3 if self.mlp_variant == "swiglu" else 2
        per_ffn = ffn_mats * d * self.d_ff
        per_moe = 3 * d * self.moe_d_ff * self.n_experts + d * self.n_experts
        # mamba2 block: in_proj (z,x,B,C,dt) + out_proj
        per_mamba = d * (2 * di + 2 * N + H) + di * d
        # mlstm block: up (2di) + qkv (3 di^2) + gates + out
        per_mlstm = d * 2 * di + 3 * di * di + di * 2 * H + di * d
        # slstm block: gates (4 d^2) + recurrent + small ffn (2x 2d^2)
        per_slstm = 4 * d * d + 4 * d * (d // max(H, 1)) + 4 * d * d

        if self.family == "ssm":
            n_s = self.n_layers // self.slstm_every if self.slstm_every else 0
            n_m = self.n_layers - n_s
            return int(emb + n_m * per_mlstm + n_s * per_slstm)
        if self.family == "hybrid":
            n_attn_apps = self.n_layers // (self.attn_every + 1)
            n_ssm = self.n_layers - n_attn_apps
            # shared attn block: ONE weight set (tied across applications)
            return int(emb + n_ssm * per_mamba + per_attn + per_ffn)
        n_dec = self.n_layers
        block = per_attn + (per_moe if self.is_moe else per_ffn) + 2 * d
        total = emb + n_dec * block
        if self.encoder_layers:
            total += self.encoder_layers * (per_attn + per_ffn)
            total += n_dec * per_attn  # cross attention
        return int(total)

    def active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        dense_part = self.n_params() - 3 * d * self.moe_d_ff * self.n_experts \
            * self.n_layers
        active_ffn = 3 * d * self.moe_d_ff * self.experts_per_token \
            * self.n_layers
        return int(dense_part + active_ffn)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    nh = max(2, min(cfg.n_heads, 4))
    nkv = max(1, min(cfg.n_kv_heads, nh))
    hd = max(8, d // nh)
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=d,
        n_heads=nh,
        n_kv_heads=nkv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else cfg.d_ff,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
    )
    if cfg.is_moe:
        changes.update(n_experts=4, experts_per_token=2,
                       moe_d_ff=min(cfg.moe_d_ff, 128))
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=min(cfg.ssm_state or 16, 16),
                       ssm_heads=max(2, min(cfg.n_ssm_heads, 4)))
        if cfg.slstm_every:
            changes.update(slstm_every=2)
        if cfg.attn_every:
            changes.update(attn_every=1)
    if cfg.encoder_layers:
        changes.update(encoder_layers=1, encoder_seq=min(cfg.encoder_seq, 64))
    if cfg.patch_tokens:
        changes.update(patch_tokens=min(cfg.patch_tokens, 16))
    return dataclasses.replace(cfg, **changes)
