"""zamba2-1.2b [hybrid] — assigned architecture config."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_heads=32, ssm_expand=2,
    attn_every=5,  # 6 shared-attn applications + 2 tail mamba blocks
    source="arXiv:2411.15242 — Mamba2 blocks + shared attention block "
           "(weight-tied applications); fractional KV drift",
)
