"""whisper-tiny [audio] — assigned architecture config."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_seq=1500,  # 30 s of mel frames (stub conv)
    source="arXiv:2212.04356 — enc-dec; conv/mel frontend is a stub "
           "(precomputed frame embeddings)",
)
