"""llava-next-mistral-7b [vlm] — assigned architecture config."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, rope_theta=1e6,
    patch_tokens=2880,  # anyres tiling: ~5 tiles x 576 patches (stub ViT)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf — anyres tiling; vision "
           "tower is a stub (precomputed patch embeddings)",
)
