"""granite-moe-3b-a800m [moe] — assigned architecture config."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=0, moe_d_ff=512, n_experts=40, experts_per_token=8,
    vocab_size=49155,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base family — 40 experts "
           "top-8 (40 % 16 != 0 -> TP-within-expert sharding)",
)
