"""Architecture configs: the 10 assigned architectures, the 4 input shapes,
and the paper's serving-simulation configuration."""
from .base import ModelConfig, smoke_variant  # noqa: F401
from .registry import ARCHS, get_config, get_smoke_config, list_archs  # noqa: F401
from .shapes import (  # noqa: F401
    LONG_CONTEXT_WINDOW,
    SHAPES,
    InputShape,
    config_for_shape,
    get_shape,
    input_specs,
)
