"""Registry of the 10 assigned architectures (one module per arch, each
citing its source).  ``--arch <id>`` selects one in the launchers;
``smoke_variant`` derives the reduced CPU-testable variant."""
from __future__ import annotations

from . import (
    granite_34b,
    granite_8b,
    granite_moe_3b_a800m,
    llava_next_mistral_7b,
    minitron_4b,
    qwen2_72b,
    qwen3_moe_30b_a3b,
    whisper_tiny,
    xlstm_350m,
    zamba2_1p2b,
)
from .base import ModelConfig, smoke_variant

__all__ = ["ARCHS", "get_config", "get_smoke_config", "list_archs"]

_MODULES = [
    qwen3_moe_30b_a3b,
    whisper_tiny,
    granite_moe_3b_a800m,
    llava_next_mistral_7b,
    xlstm_350m,
    zamba2_1p2b,
    granite_34b,
    minitron_4b,
    qwen2_72b,
    granite_8b,
]

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke_config(name: str) -> ModelConfig:
    return smoke_variant(get_config(name))


def list_archs() -> list[str]:
    return sorted(ARCHS)
