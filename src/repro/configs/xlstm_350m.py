"""xlstm-350m [ssm] — assigned architecture config."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    ssm_heads=4, ssm_expand=2, slstm_every=8,  # xLSTM[7:1]
    source="arXiv:2405.04517 — sLSTM + mLSTM blocks, no KV cache "
           "(delta_k == 0 workload class)",
)
