"""granite-34b [dense] — assigned architecture config."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_variant="gelu",
    source="arXiv:2405.04324 — llama-arch code model, MQA (kv=1)",
)
