"""The 4 assigned input shapes and ``input_specs()`` — ShapeDtypeStruct
stand-ins for every model input (no device allocation; dry-run pattern).

Decode shapes lower ``serve_step`` (ONE new token against a KV cache of
``seq_len``), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention: native for ssm/hybrid; dense/moe/vlm/audio run it via the
sliding-window variant (rolling KV buffer) — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import ModelConfig

__all__ = ["InputShape", "SHAPES", "get_shape", "input_specs",
           "LONG_CONTEXT_WINDOW", "config_for_shape"]

LONG_CONTEXT_WINDOW = 8192  # sliding window used for long_500k on
                            # full-attention archs (beyond-paper variant)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-dependent config adjustments: long_500k switches full-attention
    archs to the sliding-window variant (rolling KV)."""
    if (shape.name == "long_500k" and cfg.family != "ssm"
            and cfg.sliding_window == 0):
        # hybrid zamba2's shared attention also needs a window at 500k?
        # No: its KV is small (few shared-attn applications) — keep full
        # attention for hybrid, window the pure full-attention families.
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            return dataclasses.replace(cfg,
                                       sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _frontend_specs(cfg: ModelConfig, batch: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.patch_tokens, cfg.d_model), dtype)
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), dtype)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's inputs.

    train  -> {tokens, targets, mask (+frontend)}
    prefill-> {tokens, lengths (+frontend)}
    decode -> {tokens (B,), cache (pytree of specs)}
    """
    cfg = config_for_shape(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
        specs.update(_frontend_specs(cfg, B))
        return specs
    if shape.kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "lengths": jax.ShapeDtypeStruct((B,), i32),
        }
        specs.update(_frontend_specs(cfg, B))
        return specs
    if shape.kind == "decode":
        from ..models.transformer import init_cache  # lazy: avoid cycle
        cache = jax.eval_shape(
            lambda: init_cache(cfg, B, S))
        return {
            "tokens": jax.ShapeDtypeStruct((B,), i32),
            "cache": cache,
        }
    raise ValueError(shape.kind)
