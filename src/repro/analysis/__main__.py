"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (against the baseline, if given), 1 findings,
2 usage error.  ``--write-baseline`` records the current findings and
exits 0 so the workflow is: run, triage, fix what's real, suppress
what's intentional, baseline the long tail.
"""
from __future__ import annotations

import argparse
import sys

from . import run_analysis
from .findings import Baseline

_KNOWN_CODES = {
    "RA101", "RA102", "RA103", "RA104",
    "RA201", "RA202", "RA203", "RA204", "RA205",
    "RA301", "RA302",
    "RA401", "RA402",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Contract-aware static analysis for the ref/vec "
                    "serving stack (see repro.analysis docstring for "
                    "the RA code families).")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to scan")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON; only findings "
                         "beyond it fail")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated RA codes to run "
                         "(default: all)")
    ap.add_argument("--rel-to", default=None,
                    help="anchor for relative finding paths "
                         "(default: each scanned directory)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the summary line")
    args = ap.parse_args(argv)

    select = None
    if args.select:
        select = {c.strip().upper() for c in args.select.split(",")
                  if c.strip()}
        bad = select - _KNOWN_CODES
        if bad:
            print(f"error: unknown code(s) {', '.join(sorted(bad))}; "
                  f"known: {', '.join(sorted(_KNOWN_CODES))}",
                  file=sys.stderr)
            return 2

    baseline = None
    if args.baseline and not args.write_baseline:
        try:
            baseline = Baseline.load(args.baseline)
        except FileNotFoundError:
            print(f"error: baseline {args.baseline} not found "
                  "(--write-baseline to create it)", file=sys.stderr)
            return 2
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    try:
        result = run_analysis(args.paths, rel_to=args.rel_to,
                              baseline=baseline, select=select)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        Baseline.from_findings(result.findings).save(
            args.write_baseline)
        if not args.quiet:
            print(f"wrote {len(result.findings)} finding(s) to "
                  f"{args.write_baseline}")
        return 0

    for f in result.new:
        print(f.format())
    for key in result.stale:
        code, path, symbol = key
        print(f"note: stale baseline entry {code} {path} [{symbol}] "
              "— finding fixed, prune it", file=sys.stderr)
    if not args.quiet:
        print(f"{len(result.new)} new finding(s), "
              f"{len(result.findings)} total, "
              f"{result.files} file(s) scanned", file=sys.stderr)
    return 1 if result.new else 0


if __name__ == "__main__":
    sys.exit(main())
