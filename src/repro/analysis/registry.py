"""Declared contracts of the ref/vec serving stack.

The passes are generic; everything repo-specific — which attributes are
step-scoped barrier state, which function pairs must keep a symmetric
ref/vec surface, which attribute names root a KV-pool object — lives
here as data.  Entries match files by *relative-path suffix*, so the
same registry drives the real tree, temp copies in mutation tests, and
the fixture corpus (tests pass their own :class:`Registry`).

Growing the system extends this file, not the passes: a new engine
stat accumulator is appended to the ``ServingEngine`` scope's
``attrs``; a new ref/vec seam adds a :class:`RefVecPair`; per-pair
``allow_ref`` / ``allow_vec`` declare the *intentional* surface
asymmetry (e.g. only the vec path touches the slot-table mirrors) so
that anything undeclared fails tier-1.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["StateScope", "VecSnapshotScope", "RefVecPair", "Registry",
           "DEFAULT_REGISTRY"]


@dataclasses.dataclass(frozen=True)
class StateScope:
    """Barrier-scope declaration (RA301): mutable per-class state that
    only ``roots``-rooted call graphs may write.  ``attrs`` are exact
    attribute names; ``attr_prefixes`` cover array families like the
    fleet's ``_snap_*`` caches."""

    file_suffix: str
    cls: str
    attrs: frozenset
    roots: frozenset                   # methods whose call graph may write
    attr_prefixes: tuple = ()

    def covers(self, attr: str) -> bool:
        return attr in self.attrs or any(
            attr.startswith(p) for p in self.attr_prefixes)


@dataclasses.dataclass(frozen=True)
class VecSnapshotScope:
    """Stale-snapshot contract (RA302): in ``cls``, methods reachable
    from ``vec_roots`` that mutate engine state (``mutators`` calls on
    anything derived from ``engines_attr``) must be followed by a
    ``refresh`` call — in the same method after the mutation, or in
    every vec-reachable caller after the call site."""

    file_suffix: str
    cls: str
    vec_roots: frozenset
    engines_attr: str = "engines"
    mutators: frozenset = frozenset({"step", "submit"})
    refresh: str = "_refresh"


@dataclasses.dataclass(frozen=True)
class RefVecPair:
    """A bit-identity-gated ref/vec seam (RA401/RA402): the two
    functions must touch the same config fields, stats/telemetry keys,
    self attributes, and shared-call keyword surface, minus the
    declared allowances.  ``cls=None`` declares a module-level pair."""

    file_suffix: str
    cls: Optional[str]
    ref: str
    vec: str
    allow_ref: frozenset = frozenset()
    allow_vec: frozenset = frozenset()


@dataclasses.dataclass(frozen=True)
class Registry:
    state_scopes: tuple = ()
    vec_scopes: tuple = ()
    pairs: tuple = ()
    # attribute names that root a shared-pool object wherever they
    # appear in a chain (RA204): self.kv.lengths[...] = x is a raw
    # pool mutation outside the owning module
    pool_roots: frozenset = frozenset({"kv", "allocator", "prefix"})
    # pool leaves that legitimately take functional re-assignment from
    # outside (jax arrays are updated by replacement) or wiring writes
    pool_functional_leaves: frozenset = frozenset(
        {"k_pool", "v_pool", "prefix"})
    # host accounting paths (RA104): step-rooted bookkeeping that must
    # stay numpy — an eager jnp op here dispatches to the device once
    # per barrier step
    host_hot: tuple = ()               # (file_suffix, qualname) pairs


_ENGINE = "serving/engine.py"
_FLEET = "fleet/server.py"
_ASYNC = "fleet/async_server.py"

DEFAULT_REGISTRY = Registry(
    state_scopes=(
        StateScope(
            file_suffix=_ENGINE, cls="ServingEngine",
            attrs=frozenset({
                "t_now", "steps", "energy_j", "imbalance_sum",
                "tokens_out", "kv_peak_bytes", "requests_failed",
                "preemptions", "tokens_swapped", "tokens_recomputed",
                "slot_tokens", "slot_load", "slot_age", "slot_max_new",
                "slot_eos", "slot_admit_seq", "_admit_seq", "slot_req",
            }),
            # the obs recorder wiring (`_obs_*`) is set once at
            # construction and only read on the hot paths
            attr_prefixes=("_obs_",),
            # submit is a documented pre-step entry point, drain the
            # fleet scale-down one; __init__ declares; everything else
            # must flow from step()/run()
            roots=frozenset({"__init__", "step", "run", "submit",
                             "drain"}),
        ),
        StateScope(
            file_suffix=_FLEET, cls="FleetServer",
            attrs=frozenset({
                "t_now", "steps", "idle_j", "imbalance_sum",
                "requests_failed", "_busy_mask", "_prev_preemptions",
                "_prev_prefix_hits", "_prev_prefix_revived",
                "_queue", "_live", "_seq",
            }),
            attr_prefixes=("_snap_", "_obs_"),
            roots=frozenset({"__init__", "step", "run", "submit",
                             "submit_scenario"}),
        ),
        StateScope(
            file_suffix=_ASYNC, cls="AsyncFleetServer",
            # inherited barrier state the async tick also writes, plus
            # the event-heap (`_ev_*`), replica-lifecycle (`_rs_*`),
            # autoscaler-window (`_as_*`), tick-accumulator (`_tick_*`)
            # and snapshot-timestamp (`_snap_*`) families
            attrs=frozenset({
                "t_now", "steps", "idle_j", "imbalance_sum",
                "_queue", "_live", "_prev_preemptions",
                "_prev_prefix_hits", "_prev_prefix_revived",
                "barrier_compat", "autoscaler",
                "max_snapshot_age", "record_routes", "route_log",
            }),
            attr_prefixes=("_ev_", "_rs_", "_as_", "_tick_", "_snap_",
                           "_obs_"),
            roots=frozenset({"__init__", "step", "run", "submit",
                             "submit_scenario"}),
        ),
    ),
    vec_scopes=(
        VecSnapshotScope(
            file_suffix=_FLEET, cls="FleetServer",
            vec_roots=frozenset({"_step_vec", "_route_vec"}),
        ),
    ),
    pairs=(
        RefVecPair(
            file_suffix=_ENGINE, cls="ServingEngine",
            ref="_decode_step_ref", vec="_decode_step_vec",
            # the seed path drives the flat cache + full-batch decode
            # directly; the vec path compacts through the backend seam
            # and the slot-table scalar mirrors
            allow_ref=frozenset({
                "attr:cache", "attr:params", "attr:_decode",
            }),
            allow_vec=frozenset({
                "attr:backend", "attr:_buckets", "attr:slot_age",
                "attr:slot_max_new", "attr:slot_eos",
            }),
        ),
        RefVecPair(
            file_suffix=_FLEET, cls="FleetServer",
            ref="_step_ref", vec="_step_vec",
            # each step drives its own route seam (checked as the
            # _route_ref/_route_vec pair below)
            allow_ref=frozenset({"attr:_route_ref"}),
            # the vec step reads the cached snapshot arrays instead of
            # re-gathering; both feed identical values to _account
            allow_vec=frozenset({"attr:_route_vec", "attr:_refresh",
                                 "attr:_busy_mask", "attr:_snap_*"}),
        ),
        RefVecPair(
            file_suffix=_FLEET, cls="FleetServer",
            ref="_route_ref", vec="_route_vec",
            # ref gathers engine state live; vec routes off snapshots
            allow_ref=frozenset({"attr:engines"}),
            allow_vec=frozenset({"attr:_refresh", "attr:_snap_*"}),
        ),
        RefVecPair(
            file_suffix=_ASYNC, cls="AsyncFleetServer",
            ref="_step_barrier", vec="_step_async",
            # the oracle side delegates wholesale to the inherited
            # barrier step; the async side's tick pipeline is its
            # declared (audited) surface — growing the tick beyond
            # these seams must be declared here
            allow_vec=frozenset({
                "attr:_next_time", "attr:_advance", "attr:_pop_events",
                "attr:_release_arrivals", "attr:_autoscale_due",
                "attr:_route_async", "attr:_start_pending",
                "attr:_record_tick",
            }),
        ),
        # the BF-IO swap-search backends (method="dense" vs the tiled
        # default) — module-level pair, gated bit-identical by
        # tests/test_bfio_swap.py
        RefVecPair(
            file_suffix="core/balancer_jax.py", cls=None,
            ref="_swap_once_dense", vec="_swap_once_tiled",
        ),
    ),
    host_hot=(
        (_ENGINE, "ServingEngine.step"),
        (_ENGINE, "ServingEngine._decode_step_ref"),
        (_ENGINE, "ServingEngine._decode_step_vec"),
        (_ENGINE, "ServingEngine.load_snapshot"),
        (_FLEET, "FleetServer._step_ref"),
        (_FLEET, "FleetServer._step_vec"),
        (_FLEET, "FleetServer._account"),
        (_FLEET, "FleetServer._dispatch"),
        (_ASYNC, "AsyncFleetServer._step_async"),
        (_ASYNC, "AsyncFleetServer._advance"),
        (_ASYNC, "AsyncFleetServer._route_async"),
        (_ASYNC, "AsyncFleetServer._record_tick"),
    ),
)
