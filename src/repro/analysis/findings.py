"""Findings, inline suppressions, and the committed baseline.

A finding is one contract violation at one source location, identified
by an ``RA`` code (see :mod:`repro.analysis` for the code families).
Two mechanisms keep pre-existing or intentional findings from failing
CI while every *new* finding does:

* **Inline suppression** — a ``# ra: ignore[RA204]`` comment on the
  flagged line (or ``# ra: ignore`` to suppress every code on it).
  Use this where the violation is intentional and the reason fits in
  the surrounding comment (e.g. the ref-path oracle's eager jnp ops).
* **Baseline** — a committed JSON file mapping ``(code, path, symbol)``
  to an allowed count.  ``python -m repro.analysis --write-baseline``
  regenerates it; CI fails only on findings beyond the baselined
  count, so new violations in an already-noisy symbol still fail.

Baseline matching is by (code, path, enclosing symbol), NOT by line
number, so unrelated edits shifting lines never invalidate it.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable, Optional

__all__ = ["Finding", "Suppressions", "Baseline", "apply_baseline"]

_SUPPRESS_RE = re.compile(
    r"#\s*ra:\s*ignore(?:\[([A-Za-z0-9_,\s]*)\])?")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One static-analysis finding.

    ``path`` is stored relative to the scan root (posix separators) so
    baselines match regardless of where the tree is checked out;
    ``symbol`` is the enclosing ``Class.method`` / function qualname
    (or ``<module>``) used for line-stable baseline matching.
    """

    path: str
    line: int
    code: str
    symbol: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.code} "
                f"[{self.symbol}] {self.message}")


class Suppressions:
    """Per-file map of line -> suppressed codes (None = all codes)."""

    def __init__(self, lines: Iterable[str]):
        self._by_line: dict[int, Optional[set[str]]] = {}
        for i, text in enumerate(lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            codes = m.group(1)
            if codes is None or not codes.strip():
                self._by_line[i] = None            # blanket ignore
            else:
                self._by_line[i] = {c.strip().upper()
                                    for c in codes.split(",") if c.strip()}

    def suppressed(self, line: int, code: str) -> bool:
        if line not in self._by_line:
            return False
        codes = self._by_line[line]
        return codes is None or code.upper() in codes


class Baseline:
    """Committed allowance of known findings: (code, path, symbol) ->
    count.  See the module docstring for the workflow."""

    VERSION = 1

    def __init__(self, entries: Optional[dict[tuple, int]] = None):
        self.entries: dict[tuple, int] = dict(entries or {})

    @classmethod
    def from_findings(cls, findings: Iterable[Finding]) -> "Baseline":
        entries: dict[tuple, int] = {}
        for f in findings:
            key = (f.code, f.path, f.symbol)
            entries[key] = entries.get(key, 0) + 1
        return cls(entries)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != cls.VERSION:
            raise ValueError(
                f"{path}: unsupported baseline version "
                f"{doc.get('version')!r} (this tool writes "
                f"{cls.VERSION}); regenerate with --write-baseline")
        entries = {}
        for e in doc["findings"]:
            entries[(e["code"], e["path"], e["symbol"])] = int(e["count"])
        return cls(entries)

    def save(self, path: str) -> None:
        rows = [{"code": c, "path": p, "symbol": s, "count": n}
                for (c, p, s), n in sorted(self.entries.items())]
        with open(path, "w") as f:
            json.dump({"version": self.VERSION, "findings": rows}, f,
                      indent=1, sort_keys=True)
            f.write("\n")


def apply_baseline(findings: list[Finding],
                   baseline: Baseline) -> tuple[list[Finding], list[tuple]]:
    """Split findings into (new, stale-baseline-keys).

    Each baseline entry absorbs up to ``count`` findings with the same
    (code, path, symbol); the rest are new.  Keys whose allowance is not
    fully used are returned as stale (informational — a fixed finding
    should eventually be dropped from the baseline, but staleness never
    fails the run)."""
    budget = dict(baseline.entries)
    new: list[Finding] = []
    for f in sorted(findings):
        key = (f.code, f.path, f.symbol)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
        else:
            new.append(f)
    stale = [k for k, n in sorted(budget.items()) if n > 0]
    return new, stale
