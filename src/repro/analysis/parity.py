"""RA4xx — ref/vec parity surface.

Every ref/vec seam in the repo (engine decode, fleet step, fleet
route, solver swap search) is gated bit-identical by tier-1, but the
gates only compare *outputs*.  A config knob or stats key consumed by
exactly one side passes those gates on today's traces and silently
forks behaviour on tomorrow's.  This pass compares the *input surface*
of each declared pair:

* ``cfg:<field>`` — config fields read (``self.cfg.x`` / ``cfg.x``),
* ``attr:<name>`` — ``self.<name>`` attributes touched,
* ``kw:<callee>:<name>`` — keyword names passed to callees,
* ``key:<literal>`` — constant string subscript keys,

and flags anything present on one side only, minus the pair's declared
``allow_ref`` / ``allow_vec`` (entries may end in ``*`` for prefix
matches, e.g. ``attr:_snap_*``).

Codes: **RA401** for one-sided config fields, **RA402** for any other
one-sided surface item.
"""
from __future__ import annotations

import ast
from typing import Optional

from .astutil import FunctionInfo, SourceFile, attr_parts
from .findings import Finding
from .registry import RefVecPair, Registry

__all__ = ["run", "surface_of"]

_CFG_NAMES = {"cfg", "config"}


def surface_of(fn_node: ast.AST) -> tuple[set[str], dict]:
    """(base surface items, callee -> keyword names).  Keyword items
    are kept separate so the caller can restrict the comparison to
    callees both sides share — a kwarg fed to a ref-only numpy helper
    is not a parity hazard, an extra kwarg on a shared ``_account``
    call is."""
    items: set[str] = set()
    kw_by_callee: dict[str, set[str]] = {}
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Attribute):
            parts = attr_parts(node)
            if not parts:
                continue
            for i, p in enumerate(parts[:-1]):
                if p in _CFG_NAMES:
                    items.add(f"cfg:{parts[i + 1]}")
                    break
            else:
                if parts[0] == "self" and len(parts) >= 2:
                    items.add(f"attr:{parts[1]}")
        elif isinstance(node, ast.Call):
            parts = attr_parts(node.func)
            callee = parts[-1] if parts else None
            if callee:
                kws = kw_by_callee.setdefault(callee, set())
                kws.update(kw.arg for kw in node.keywords
                           if kw.arg is not None)
        elif isinstance(node, ast.Subscript):
            sl = node.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value,
                                                           str):
                items.add(f"key:{sl.value}")
    # plain ``cfg.x`` chains start at a Name, drop the attr: duplicate
    items.discard("attr:cfg")
    items.discard("attr:config")
    return items, kw_by_callee


def _allowed(item: str, allow: frozenset) -> bool:
    return item in allow or any(
        a.endswith("*") and item.startswith(a[:-1]) for a in allow)


def _resolve_pair(sf: SourceFile, pair: RefVecPair
                  ) -> tuple[Optional[FunctionInfo],
                             Optional[FunctionInfo]]:
    if pair.cls is not None:
        methods = sf.methods_of(pair.cls)
        return methods.get(pair.ref), methods.get(pair.vec)
    top = {fi.name: fi for fi in sf.functions
           if fi.cls is None and "<locals>" not in fi.qualname}
    return top.get(pair.ref), top.get(pair.vec)


def _check_pair(sf: SourceFile, pair: RefVecPair,
                out: list[Finding]) -> None:
    ref_fi, vec_fi = _resolve_pair(sf, pair)
    if ref_fi is None or vec_fi is None:
        return                         # pair gone: parity moot here
    ref_s, ref_kw = surface_of(ref_fi.node)
    vec_s, vec_kw = surface_of(vec_fi.node)
    for callee in ref_kw.keys() & vec_kw.keys():
        ref_s.update(f"kw:{callee}:{k}" for k in ref_kw[callee])
        vec_s.update(f"kw:{callee}:{k}" for k in vec_kw[callee])

    def emit(item: str, fi: FunctionInfo, side: str, other: str):
        code = "RA401" if item.startswith("cfg:") else "RA402"
        out.append(Finding(
            sf.relpath, fi.node.lineno, code, fi.qualname,
            f"{item} is consumed only by the {side} side of the "
            f"{pair.ref}/{pair.vec} pair (absent from {other}) — "
            "declare it in the registry allowlist if the asymmetry "
            "is intentional"))

    for item in sorted(ref_s - vec_s):
        if not _allowed(item, pair.allow_ref):
            emit(item, ref_fi, "ref", pair.vec)
    for item in sorted(vec_s - ref_s):
        if not _allowed(item, pair.allow_vec):
            emit(item, vec_fi, "vec", pair.ref)


def run(sf: SourceFile, registry: Registry) -> list[Finding]:
    out: list[Finding] = []
    for pair in registry.pairs:
        if sf.relpath.endswith(pair.file_suffix):
            _check_pair(sf, pair, out)
    return out
