"""RA1xx — jit-hazard lint.

Traced values inside ``jax.jit`` / ``pallas_call``-reachable functions
must never leak to the host: a ``float()`` / ``np.asarray()`` /
``.item()`` on a tracer either crashes (ConcretizationTypeError) or —
worse — silently forces a device sync per call when the value is an
already-committed array.  Data-dependent Python branches burn a
recompile per branch outcome; unhashable static args fail at trace
time with an error far from the definition.

Codes:

* **RA101** — host sync on a traced value (``float``/``int``/``bool``
  builtins, ``np.asarray``/``np.array``, ``.item()``/``.tolist()``).
* **RA102** — Python ``if``/``while``/ternary branching on a traced
  value (shape/dtype/ndim reads and ``is None`` tests are static and
  exempt).
* **RA103** — a ``static_argnames``/``static_argnums`` parameter whose
  default is an unhashable literal (list/dict/set).
* **RA104** — eager ``jnp.*`` op inside a registry-declared host
  accounting path (one device dispatch per barrier step; use numpy or
  fold it into the jitted call).

Jit roots are found syntactically: ``@jax.jit`` /
``@functools.partial(jax.jit, ...)`` decorations, functions or lambdas
passed to ``jax.jit(...)`` / ``pl.pallas_call(...)``, plus everything
they reach through same-module calls.  Cross-module reachability is
out of scope (the fixture corpus pins the supported shapes).
"""
from __future__ import annotations

import ast

from .astutil import (
    FuncIndex,
    FunctionInfo,
    SourceFile,
    call_args,
    dotted,
)
from .findings import Finding
from .registry import Registry

__all__ = ["run"]

_JIT_SUFFIXES = ("jax.jit", "jit", "pjit", "pallas_call")
_HOST_BUILTINS = {"float", "int", "bool"}
_HOST_NP = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
            "onp.asarray", "onp.array"}
_HOST_METHODS = {"item", "tolist"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "nbytes",
                 "name", "sharding"}
_STATIC_FUNCS = {"len", "isinstance", "type", "getattr", "hasattr",
                 "range", "enumerate"}
_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)


def _is_jit_name(name) -> bool:
    return bool(name) and (name in _JIT_SUFFIXES
                           or any(name.endswith("." + s)
                                  for s in _JIT_SUFFIXES))


def _jit_call_wrapped(call: ast.Call):
    """Function-valued argument nodes of a jit/pallas_call call,
    unwrapping one level of functools.partial."""
    out = []
    for a in call_args(call):
        if isinstance(a, (ast.Lambda, ast.Name)):
            out.append(a)
        elif isinstance(a, ast.Call) and (dotted(a.func) or "").endswith(
                "partial"):
            out.extend(x for x in call_args(a)
                       if isinstance(x, (ast.Lambda, ast.Name)))
    return out


def _find_roots(sf: SourceFile) -> tuple[set, list]:
    """(jit-rooted function nodes, jit call sites) in a module."""
    roots: set[ast.AST] = set()
    jit_calls: list[ast.Call] = []
    by_name = {fi.name: fi.node for fi in sf.functions
               if not isinstance(fi.node, ast.Lambda)}
    for fi in sf.functions:
        for dec in getattr(fi.node, "decorator_list", []):
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _is_jit_name(dotted(target)):
                roots.add(fi.node)
                if isinstance(dec, ast.Call):
                    jit_calls.append(dec)
            elif (isinstance(dec, ast.Call)
                  and (dotted(dec.func) or "").endswith("partial")
                  and any(_is_jit_name(dotted(a)) for a in call_args(dec))):
                roots.add(fi.node)
                jit_calls.append(dec)
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call) and _is_jit_name(
                dotted(node.func))):
            continue
        jit_calls.append(node)
        for a in _jit_call_wrapped(node):
            if isinstance(a, ast.Lambda):
                roots.add(a)
            elif isinstance(a, ast.Name) and a.id in by_name:
                roots.add(by_name[a.id])
    return roots, jit_calls


def _close_over_callees(sf: SourceFile, roots: set) -> set:
    idx = FuncIndex(sf)
    by_node = {fi.node: fi for fi in sf.functions}
    stack = [by_node[n] for n in roots if n in by_node]
    seen: set[ast.AST] = set(roots)
    while stack:
        fi = stack.pop()
        for callee in idx.callees(fi):
            if callee.node not in seen:
                seen.add(callee.node)
                stack.append(callee)
    return seen


def _expr_tainted(node: ast.AST, tainted: set[str]) -> bool:
    """Does ``node`` (an expression) carry a traced value?  Shape/dtype
    reads and static builtins launder the taint (they are concrete at
    trace time)."""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _expr_tainted(node.value, tainted)
    if isinstance(node, ast.Subscript):
        # x.shape[0] is static; x[i] carries x's (and i's) taint
        if (isinstance(node.value, ast.Attribute)
                and node.value.attr in _STATIC_ATTRS):
            return False
        return (_expr_tainted(node.value, tainted)
                or _expr_tainted(node.slice, tainted))
    if isinstance(node, ast.Call):
        if (dotted(node.func) or "") in _STATIC_FUNCS:
            return False
        return any(_expr_tainted(a, tainted) for a in call_args(node)) \
            or _expr_tainted(node.func, tainted)
    if isinstance(node, (ast.Constant, ast.JoinedStr)):
        return False
    return any(_expr_tainted(c, tainted)
               for c in ast.iter_child_nodes(node))


def _tainted_params(fn: ast.AST, static: set[str]) -> set[str]:
    """Params that may carry tracers.  Keyword-only params are static
    configuration by repo convention (``*, tile_i=64, interpret=False``
    — arrays are always positional), and jit-declared static args are
    concrete at trace time."""
    args = fn.args
    names = [a.arg for a in (args.posonlyargs + args.args)]
    if args.vararg:
        names.append(args.vararg.arg)
    return {n for n in names
            if n not in ("self", "cls") and n not in static}


def _static_names(sf: SourceFile, jit_calls: list) -> dict:
    """fn node -> param names declared static at its jit boundary."""
    by_name = {fi.name: fi.node for fi in sf.functions
               if not isinstance(fi.node, ast.Lambda)}
    out: dict[ast.AST, set[str]] = {}
    for call in jit_calls:
        names: set[str] = set()
        nums: set[int] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                names |= {n.value for n in ast.walk(kw.value)
                          if isinstance(n, ast.Constant)
                          and isinstance(n.value, str)}
            elif kw.arg == "static_argnums":
                nums |= {n.value for n in ast.walk(kw.value)
                         if isinstance(n, ast.Constant)
                         and isinstance(n.value, int)}
        if not names and not nums:
            continue
        defs = [by_name[a.id] for a in _jit_call_wrapped(call)
                if isinstance(a, ast.Name) and a.id in by_name]
        for fi in sf.functions:
            if call in getattr(fi.node, "decorator_list", []):
                defs.append(fi.node)
        for fn in defs:
            pos = fn.args.posonlyargs + fn.args.args
            resolved = set(names)
            resolved |= {pos[i].arg for i in nums if i < len(pos)}
            out.setdefault(fn, set()).update(resolved)
    return out


def _propagate(fn: ast.AST, tainted: set[str]) -> set[str]:
    """One forward pass of assignment taint in source order (our
    functions are straight-line enough that a fixpoint is overkill)."""
    body = getattr(fn, "body", None)
    if body is None:                       # Lambda
        return tainted
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _expr_tainted(node.value,
                                                          tainted):
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        tainted.add(n.id)
        elif isinstance(node, ast.AugAssign) and isinstance(
                node.target, ast.Name):
            if _expr_tainted(node.value, tainted):
                tainted.add(node.target.id)
        elif isinstance(node, ast.For) and _expr_tainted(node.iter,
                                                         tainted):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    tainted.add(n.id)
    return tainted


def _branch_exempt(test: ast.AST) -> bool:
    """Static-under-trace tests: identity checks and isinstance."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _branch_exempt(test.operand)
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.Call) and (dotted(test.func) or "") in (
            "isinstance", "callable", "hasattr"):
        return True
    return False


def _check_fn(sf: SourceFile, fi: FunctionInfo, static: set[str],
              out: list[Finding]) -> None:
    tainted = _propagate(fi.node, _tainted_params(fi.node, static))
    if not tainted:
        return

    def emit(code: str, node: ast.AST, msg: str) -> None:
        out.append(Finding(sf.relpath, node.lineno, code,
                           sf.symbol_at(node.lineno), msg))

    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call):
            name = dotted(node.func) or ""
            args_tainted = any(_expr_tainted(a, tainted)
                               for a in call_args(node))
            if name in _HOST_BUILTINS and args_tainted:
                emit("RA101", node,
                     f"host sync: {name}() on a traced value inside a "
                     "jit-reachable function (concretizes the tracer / "
                     "forces a device sync)")
            elif name in _HOST_NP and args_tainted:
                emit("RA101", node,
                     f"host sync: {name}() pulls a traced value to "
                     "host inside a jit-reachable function")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in _HOST_METHODS
                  and _expr_tainted(node.func.value, tainted)):
                emit("RA101", node,
                     f"host sync: .{node.func.attr}() on a traced "
                     "value inside a jit-reachable function")
        elif isinstance(node, (ast.If, ast.While, ast.IfExp)):
            if (not _branch_exempt(node.test)
                    and _expr_tainted(node.test, tainted)):
                emit("RA102", node,
                     "data-dependent Python branch on a traced value "
                     "(recompiles per outcome; use lax.cond/jnp.where)")


def _check_static_args(sf: SourceFile, jit_calls: list,
                       out: list[Finding]) -> None:
    by_name = {fi.name: fi.node for fi in sf.functions
               if not isinstance(fi.node, ast.Lambda)}

    def wrapped_defs(call: ast.Call):
        defs = [by_name[a.id] for a in _jit_call_wrapped(call)
                if isinstance(a, ast.Name) and a.id in by_name]
        # decorator form: the call IS the decorator; find its function
        for fi in sf.functions:
            if call in getattr(fi.node, "decorator_list", []):
                defs.append(fi.node)
        return defs

    for call in jit_calls:
        static_names: set[str] = set()
        static_nums: set[int] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                static_names |= {n.value for n in ast.walk(kw.value)
                                 if isinstance(n, ast.Constant)
                                 and isinstance(n.value, str)}
            elif kw.arg == "static_argnums":
                static_nums |= {n.value for n in ast.walk(kw.value)
                                if isinstance(n, ast.Constant)
                                and isinstance(n.value, int)}
        if not static_names and not static_nums:
            continue
        for fn in wrapped_defs(call):
            args = fn.args.posonlyargs + fn.args.args
            defaults = [None] * (len(args) - len(fn.args.defaults)) \
                + list(fn.args.defaults)
            kw_defaults = dict(zip(
                (a.arg for a in fn.args.kwonlyargs), fn.args.kw_defaults))
            for i, a in enumerate(args):
                if (a.arg in static_names or i in static_nums) \
                        and isinstance(defaults[i], _MUTABLE_LITERALS):
                    out.append(Finding(
                        sf.relpath, defaults[i].lineno, "RA103",
                        sf.symbol_at(fn.lineno),
                        f"static arg {a.arg!r} defaults to an "
                        "unhashable literal — jit static args must "
                        "be hashable"))
            for a in fn.args.kwonlyargs:
                d = kw_defaults.get(a.arg)
                if a.arg in static_names and isinstance(
                        d, _MUTABLE_LITERALS):
                    out.append(Finding(
                        sf.relpath, d.lineno, "RA103",
                        sf.symbol_at(fn.lineno),
                        f"static arg {a.arg!r} defaults to an "
                        "unhashable literal — jit static args must "
                        "be hashable"))


def _check_host_hot(sf: SourceFile, registry: Registry,
                    out: list[Finding]) -> None:
    hot = {q for suffix, q in registry.host_hot
           if sf.relpath.endswith(suffix)}
    if not hot:
        return
    for fi in sf.functions:
        if fi.qualname not in hot:
            continue
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                name = dotted(node.func) or ""
                if name.startswith(("jnp.", "jax.numpy.")):
                    out.append(Finding(
                        sf.relpath, node.lineno, "RA104",
                        sf.symbol_at(node.lineno),
                        f"eager {name} in host accounting path "
                        f"{fi.qualname} — one device dispatch per "
                        "barrier step; use numpy or fold into the "
                        "jitted call"))


def run(sf: SourceFile, registry: Registry) -> list[Finding]:
    out: list[Finding] = []
    roots, jit_calls = _find_roots(sf)
    reachable = _close_over_callees(sf, roots)
    static_by_node = _static_names(sf, jit_calls)
    by_node = {fi.node: fi for fi in sf.functions}
    for node in reachable:
        fi = by_node.get(node)
        if fi is not None:
            _check_fn(sf, fi, static_by_node.get(node, set()), out)
    _check_static_args(sf, jit_calls, out)
    _check_host_hot(sf, registry, out)
    return out
