"""RA2xx — paged-KV allocator discipline.

The paged pool (PR 4) is refcounted and demand-driven: blocks obtained
from :class:`BlockAllocator` must be released on every exit path,
capacity growth must be pre-declared so admission control can price it,
and nobody outside the owning module may poke pool internals directly
— the ref/vec bit-identity gates assume the pool's bookkeeping arrays
only change through its API.

Codes:

* **RA201** — an ``alloc()`` / ``add_ref()`` call whose result is
  discarded (bare expression): the caller can never free what it
  obtained.  (``add_ref`` returns the block id for symmetry; dropping
  it is fine only in a loop over already-tracked blocks, which is the
  suppression case.)
* **RA202** — a release-path method (``release`` / ``free`` /
  ``_free`` / ``discard``) of a pool-holding class that performs no
  release call on any path: the canonical leak shape when a refactor
  drops the ``kv.release`` line.
* **RA203** — a pool-holding class calls growth APIs
  (``append_tokens`` / ``ensure_capacity``) but never declares demand
  (``append_demand`` / ``decode_block_demand`` / ``chunk_block_demand``)
  anywhere in the class — admission control can no longer see the
  growth coming.
* **RA204** — raw write *through* a pool object (``self.kv.lengths[s]
  = n``) outside the module that defines the pool classes.  Functional
  leaves (``k_pool`` / ``v_pool`` — jax arrays updated by replacement)
  are exempt.
* **RA205** — ``add_ref`` acquisitions followed by a fallible
  ``alloc`` with no cleanup handler: if the alloc raises Out-of-blocks
  the refs taken so far leak.  A ``try`` around the alloc whose
  handler releases makes it clean.
"""
from __future__ import annotations

import ast
from typing import Optional

from .astutil import FunctionInfo, SourceFile, attr_parts
from .findings import Finding
from .registry import Registry

__all__ = ["run"]

_POOL_CLASSES = {"BlockAllocator", "PagedKVCache"}
_RELEASE_METHOD_NAMES = {"release", "free", "_free", "discard"}
_RELEASE_VERBS = {"release", "free", "_free", "discard", "pop"}
_GROWTH_VERBS = {"append_tokens", "ensure_capacity"}
_DEMAND_VERBS = {"append_demand", "decode_block_demand",
                 "chunk_block_demand"}
_ACQUIRE_VERBS = {"alloc", "allocate", "add_ref"}


def _is_owner_module(sf: SourceFile) -> bool:
    return bool(_POOL_CLASSES & sf.classes.keys())


def _chain_verb(call: ast.Call) -> tuple[Optional[list[str]], str]:
    parts = attr_parts(call.func)
    if not parts or len(parts) < 2:
        return None, ""
    return parts, parts[-1]


def _touches_pool(parts: list[str], registry: Registry) -> bool:
    """Does the call chain pass through a pool-rooted attribute
    (``self.kv.release`` / ``self.backend.kv.alloc`` /
    ``self.allocator.free``)?"""
    return any(p in registry.pool_roots for p in parts[:-1])


def _class_pool_bound(sf: SourceFile, cls: str,
                      registry: Registry) -> bool:
    """Does any method of ``cls`` reach through a pool root?"""
    for fi in sf.methods_of(cls).values():
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Attribute):
                parts = attr_parts(node)
                if parts and any(p in registry.pool_roots
                                 for p in parts[1:]):
                    return True
    return False


def _check_discarded_acquire(sf: SourceFile, fi: FunctionInfo,
                             registry: Registry,
                             out: list[Finding]) -> None:
    for node in ast.walk(fi.node):
        if not (isinstance(node, ast.Expr)
                and isinstance(node.value, ast.Call)):
            continue
        parts, verb = _chain_verb(node.value)
        if parts and verb in {"alloc", "allocate"} \
                and _touches_pool(parts, registry):
            out.append(Finding(
                sf.relpath, node.lineno, "RA201",
                sf.symbol_at(node.lineno),
                f"result of {'.'.join(parts)}() discarded — the "
                "allocated blocks can never be freed"))


def _has_release(fi: FunctionInfo, registry: Registry) -> bool:
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call):
            parts, verb = _chain_verb(node)
            if parts and verb in _RELEASE_VERBS and (
                    _touches_pool(parts, registry)
                    or parts[0] == "self"):
                return True
    return False


def _check_release_contract(sf: SourceFile, cls: str,
                            registry: Registry,
                            out: list[Finding]) -> None:
    for name, fi in sf.methods_of(cls).items():
        if name not in _RELEASE_METHOD_NAMES:
            continue
        if sf.suppressions.suppressed(fi.node.lineno, "RA202"):
            continue
        if not _has_release(fi, registry):
            out.append(Finding(
                sf.relpath, fi.node.lineno, "RA202",
                fi.qualname,
                f"release-path method {cls}.{name} performs no "
                "release/free call on the pool — acquired blocks "
                "leak when this path runs"))


def _check_demand_contract(sf: SourceFile, cls: str,
                           registry: Registry,
                           out: list[Finding]) -> None:
    growth_sites: list[tuple[int, str, str]] = []
    declares = False
    for fi in sf.methods_of(cls).values():
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            parts, verb = _chain_verb(node)
            if not parts:
                continue
            if verb in _DEMAND_VERBS:
                declares = True
            elif verb in _GROWTH_VERBS and _touches_pool(parts,
                                                         registry):
                growth_sites.append(
                    (node.lineno, ".".join(parts), fi.qualname))
    if growth_sites and not declares:
        for line, chain, qual in growth_sites:
            if sf.suppressions.suppressed(line, "RA203"):
                continue
            out.append(Finding(
                sf.relpath, line, "RA203", sf.symbol_at(line),
                f"{chain}() grows the pool but {cls} never declares "
                "demand (append_demand/decode_block_demand/"
                "chunk_block_demand) — admission control cannot "
                "price the growth"))


def _check_raw_mutation(sf: SourceFile, registry: Registry,
                        out: list[Finding]) -> None:
    if _is_owner_module(sf):
        return
    for node in ast.walk(sf.tree):
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = node.targets
        for t in targets:
            parts = attr_parts(t)
            if not parts or len(parts) < 2:
                continue
            # writing *through* a pool root (root not the final leaf)
            if not any(p in registry.pool_roots for p in parts[:-1]):
                continue
            if parts[-1] in registry.pool_functional_leaves:
                continue
            out.append(Finding(
                sf.relpath, t.lineno, "RA204",
                sf.symbol_at(t.lineno),
                f"raw mutation of pool internals: {'.'.join(parts)} "
                "written outside the pool's owning module — use the "
                "pool API so refcounts/demand stay consistent"))


def _check_leaky_admit(sf: SourceFile, fi: FunctionInfo,
                       registry: Registry,
                       out: list[Finding]) -> None:
    add_ref_lines: list[int] = []
    guarded: set[int] = set()          # alloc lines with cleanup
    allocs: list[tuple[int, str]] = []
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Try):
            handler_frees = any(
                isinstance(n, ast.Call)
                and _chain_verb(n)[1] in _RELEASE_VERBS
                for h in node.handlers for n in ast.walk(h))
            if handler_frees:
                for n in ast.walk(node):
                    if isinstance(n, ast.Call) \
                            and _chain_verb(n)[1] in {"alloc",
                                                      "allocate"}:
                        guarded.add(n.lineno)
        if isinstance(node, ast.Call):
            parts, verb = _chain_verb(node)
            if not (parts and _touches_pool(parts, registry)):
                continue
            if verb == "add_ref":
                add_ref_lines.append(node.lineno)
            elif verb in {"alloc", "allocate"}:
                allocs.append((node.lineno, ".".join(parts)))
    for line, chain in allocs:
        prior = [r for r in add_ref_lines if r < line]
        if prior and line not in guarded:
            out.append(Finding(
                sf.relpath, line, "RA205", sf.symbol_at(line),
                f"{chain}() can raise after add_ref at line "
                f"{prior[-1]} — on failure the added refs leak; "
                "wrap the alloc and roll the refs back"))


def run(sf: SourceFile, registry: Registry) -> list[Finding]:
    out: list[Finding] = []
    _check_raw_mutation(sf, registry, out)
    pool_classes = [c for c in sf.classes
                    if _class_pool_bound(sf, c, registry)]
    for cls in pool_classes:
        _check_release_contract(sf, cls, registry, out)
        _check_demand_contract(sf, cls, registry, out)
    for fi in sf.functions:
        _check_discarded_acquire(sf, fi, registry, out)
        _check_leaky_admit(sf, fi, registry, out)
    return out
