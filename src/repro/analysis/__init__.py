"""Contract-aware static analysis for the ref/vec serving stack.

``python -m repro.analysis src/`` runs four AST/flow passes over the
tree — no imports of the analyzed code — and fails (exit 1) on any
finding not covered by an inline suppression or the committed
baseline.  Tier-1 runs it via ``tests/test_analysis.py``, so the
contracts below are enforced on every commit, not by reviewer memory.

Code families
=============

* **RA1xx — jit hazards** (:mod:`repro.analysis.jit_hazards`):
  RA101 host sync (``float()``/``np.asarray``/``.item()`` on a traced
  value), RA102 data-dependent Python branch on a traced value, RA103
  unhashable default for a static jit arg, RA104 eager ``jnp.*`` op in
  a registered host accounting path.
* **RA2xx — allocator discipline** (:mod:`repro.analysis.allocator`):
  RA201 discarded ``alloc()`` result, RA202 release-path method with
  no release call, RA203 pool growth with no demand declaration in the
  class, RA204 raw mutation of pool internals outside the owning
  module, RA205 ``add_ref`` followed by a fallible ``alloc`` with no
  cleanup.
* **RA3xx — barrier scope** (:mod:`repro.analysis.barrier`): RA301
  step-scoped state written outside the declared ``step()``-rooted
  call graph, RA302 vec-path engine mutation with no ``_refresh``
  afterwards (stale snapshot).
* **RA4xx — ref/vec parity surface** (:mod:`repro.analysis.parity`):
  RA401 config field consumed by one side of a declared ref/vec pair
  only, RA402 any other one-sided surface item (attribute, callee
  keyword, string key).

Suppressions and baseline
=========================

A finding on a line carrying ``# ra: ignore[RA204]`` (or a bare
``# ra: ignore``) is suppressed; use this where the violation is
intentional and locally explainable.  Everything else must be fixed or
admitted to ``tools/analysis_baseline.json`` — regenerate with
``python -m repro.analysis src/ --write-baseline
tools/analysis_baseline.json``.  Baseline entries match by
``(code, path, enclosing symbol)`` with a count, so line drift never
invalidates them, while a *new* finding in an already-baselined symbol
still fails.  Stale entries are reported informationally and should be
pruned when the underlying finding is fixed.

Repo-specific contracts (which attributes are step-scoped, which
function pairs are ref/vec seams, what counts as a pool root) live in
:mod:`repro.analysis.registry` as data; the passes are generic.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional

from . import allocator, barrier, jit_hazards, parity
from .astutil import SourceFile, iter_source_files
from .findings import Baseline, Finding, apply_baseline
from .registry import DEFAULT_REGISTRY, Registry

__all__ = ["run_analysis", "AnalysisResult", "Baseline", "Finding",
           "Registry", "DEFAULT_REGISTRY", "PASSES"]

PASSES = (
    ("jit_hazards", jit_hazards.run),
    ("allocator", allocator.run),
    ("barrier", barrier.run),
    ("parity", parity.run),
)


@dataclasses.dataclass
class AnalysisResult:
    findings: list        # post-suppression, pre-baseline
    new: list             # findings not absorbed by the baseline
    stale: list           # baseline keys with unused allowance
    files: int

    @property
    def ok(self) -> bool:
        return not self.new


def _scan_file(sf: SourceFile, registry: Registry,
               select: Optional[set]) -> list[Finding]:
    found: list[Finding] = []
    for _, pass_fn in PASSES:
        for f in pass_fn(sf, registry):
            if select and f.code not in select:
                continue
            if sf.suppressions.suppressed(f.line, f.code):
                continue
            found.append(f)
    return found


def run_analysis(paths, rel_to=None, registry: Registry = None,
                 baseline: Optional[Baseline] = None,
                 select: Optional[set] = None) -> AnalysisResult:
    """Run every pass over ``paths`` (files or directories).

    ``rel_to`` anchors the relative paths findings/baselines use
    (default: each path's parent for files, the path itself for
    directories — so scanning ``src/`` yields ``repro/...`` paths).
    """
    registry = registry or DEFAULT_REGISTRY
    findings: list[Finding] = []
    files = 0
    for p in paths:
        p = Path(p)
        anchor = Path(rel_to) if rel_to else (
            p if p.is_dir() else p.parent)
        for sf in iter_source_files(p, anchor):
            files += 1
            findings.extend(_scan_file(sf, registry, select))
    findings.sort()
    if baseline is None:
        return AnalysisResult(findings, list(findings), [], files)
    new, stale = apply_baseline(findings, baseline)
    return AnalysisResult(findings, new, stale, files)
