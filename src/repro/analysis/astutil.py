"""Shared AST machinery for the analysis passes.

Everything here is deliberately *syntactic*: the passes run on any
checkout (including broken ones) without importing the code under
analysis, so resolution is name-based — dotted chains, same-module /
same-class call graphs, and a light forward taint over function bodies.
The passes accept the imprecision and rely on the suppression/baseline
machinery (:mod:`repro.analysis.findings`) for the residue.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterator, Optional

from .findings import Suppressions

__all__ = ["SourceFile", "FunctionInfo", "iter_source_files", "dotted",
           "attr_parts", "call_args", "name_loads", "FuncIndex"]


@dataclasses.dataclass(eq=False)      # identity hash — nodes are unique
class FunctionInfo:
    """One function/method definition (lambdas included)."""

    qualname: str                 # "Cls.method", "func", "func.<locals>.g"
    node: ast.AST                 # FunctionDef | AsyncFunctionDef | Lambda
    cls: Optional[str]            # enclosing class name, if any

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")

    @property
    def line(self) -> int:
        return self.node.lineno


class SourceFile:
    """A parsed module plus its function/class index and suppressions."""

    def __init__(self, path: Path, relpath: str, text: str):
        self.path = path
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        self.suppressions = Suppressions(self.lines)
        self.functions: list[FunctionInfo] = []
        self.classes: dict[str, ast.ClassDef] = {}
        self._index()
        self._symbol_spans: list[tuple[int, int, str]] = sorted(
            (fi.node.lineno, getattr(fi.node, "end_lineno", fi.node.lineno),
             fi.qualname)
            for fi in self.functions)

    def _index(self) -> None:
        def visit(node, prefix: str, cls: Optional[str]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    self.functions.append(FunctionInfo(q, child, cls))
                    visit(child, f"{q}.<locals>.", cls)
                elif isinstance(child, ast.ClassDef):
                    self.classes[child.name] = child
                    visit(child, f"{prefix}{child.name}.", child.name)
                elif isinstance(child, ast.Lambda):
                    self.functions.append(
                        FunctionInfo(f"{prefix}<lambda>", child, cls))
                else:
                    visit(child, prefix, cls)
        visit(self.tree, "", None)

    def symbol_at(self, line: int) -> str:
        """Innermost function qualname containing ``line``."""
        best = "<module>"
        best_span = None
        for lo, hi, q in self._symbol_spans:
            if lo <= line <= hi:
                if best_span is None or hi - lo <= best_span:
                    best, best_span = q, hi - lo
        return best

    def methods_of(self, cls_name: str) -> dict[str, FunctionInfo]:
        return {fi.name: fi for fi in self.functions
                if fi.cls == cls_name and "<locals>" not in fi.qualname}

    def class_call_graph(self, cls_name: str) -> dict[str, set[str]]:
        """method name -> same-class methods it calls via ``self.m(...)``
        (or references as ``self.m`` — bound-method passing counts)."""
        methods = self.methods_of(cls_name)
        graph: dict[str, set[str]] = {m: set() for m in methods}
        for name, fi in methods.items():
            for node in ast.walk(fi.node):
                if (isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and node.attr in methods):
                    graph[name].add(node.attr)
        return graph

    @staticmethod
    def reachable(graph: dict[str, set[str]], roots) -> set[str]:
        seen = set()
        stack = [r for r in roots if r in graph]
        while stack:
            m = stack.pop()
            if m in seen:
                continue
            seen.add(m)
            stack.extend(graph.get(m, ()))
        return seen


def iter_source_files(root: Path, rel_to: Path) -> Iterator[SourceFile]:
    """Yield parsed ``SourceFile``s under ``root`` (or ``root`` itself
    for a single file), paths relative to ``rel_to``.  Unparseable files
    are skipped — a syntax error fails the test suite on its own."""
    paths = [root] if root.is_file() else sorted(root.rglob("*.py"))
    for p in paths:
        try:
            text = p.read_text()
            yield SourceFile(p, p.relative_to(rel_to).as_posix(), text)
        except (SyntaxError, UnicodeDecodeError):
            continue


def dotted(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain ("jax.jit", "self.kv.free");
    None for anything rooted in a non-name (e.g. a call result)."""
    parts = attr_parts(node)
    return ".".join(parts) if parts else None


def attr_parts(node: ast.AST) -> Optional[list[str]]:
    """["self", "backend", "kv", "lengths"] for nested attributes;
    subscripts are transparent (``a.b[i].c`` -> [a, b, c])."""
    parts: list[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            return parts[::-1]
        else:
            return None


def call_args(call: ast.Call) -> list[ast.AST]:
    """Positional + keyword argument value nodes."""
    return list(call.args) + [kw.value for kw in call.keywords]


def name_loads(node: ast.AST) -> set[str]:
    """All Name identifiers read anywhere inside ``node``."""
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


class FuncIndex:
    """Module-level function lookup + module-local call graph, used by
    the jit-hazard pass to close over reachable callees."""

    def __init__(self, sf: SourceFile):
        self.sf = sf
        # simple name -> FunctionInfo (module level and class methods;
        # ambiguity resolved last-wins, acceptable for our modules)
        self.by_name: dict[str, FunctionInfo] = {}
        for fi in sf.functions:
            if not isinstance(fi.node, ast.Lambda):
                self.by_name.setdefault(fi.name, fi)

    def callees(self, fi: FunctionInfo) -> set["FunctionInfo"]:
        """Module-local functions called from ``fi`` by simple name or
        ``self.method`` (resolved within the same class)."""
        out = set()
        methods = self.sf.methods_of(fi.cls) if fi.cls else {}
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id in self.by_name:
                out.add(self.by_name[f.id].qualname)
            elif (isinstance(f, ast.Attribute)
                  and isinstance(f.value, ast.Name)
                  and f.value.id == "self" and f.attr in methods):
                out.add(methods[f.attr].qualname)
        by_qual = {x.qualname: x for x in self.sf.functions}
        return {by_qual[q] for q in out if q in by_qual}
