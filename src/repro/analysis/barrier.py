"""RA3xx — barrier / state-scope discipline.

The universal-balancing analysis prices imbalance at *step*
granularity: engine and fleet accumulators (telemetry counters, slot
table mirrors, snapshot caches) are meaningful only if they mutate
inside the barrier — i.e. from call graphs rooted at ``step()`` (or
the other declared roots).  A helper that bumps ``self.t_now`` from an
ad-hoc entry point silently breaks the pricing and every downstream
bit-identity gate.

Codes:

* **RA301** — a registry-declared step-scoped attribute is written
  from a method *not* reachable from the scope's roots.
* **RA302** — on the vec path, engine state is mutated (``eng.step``
  / ``eng.submit`` on an object drawn from ``self.engines``) with no
  ``_refresh`` afterwards — neither later in the same method nor after
  the call site in every vec-reachable caller — so the cached
  ``_snap_*`` arrays go stale and the vec route diverges from ref.
"""
from __future__ import annotations

import ast

from .astutil import SourceFile, attr_parts
from .findings import Finding
from .registry import Registry, StateScope, VecSnapshotScope

__all__ = ["run"]


def _self_attr_writes(fn_node: ast.AST):
    """Yield (line, attr) for writes to ``self.<attr>`` (plain,
    augmented, subscripted, or tuple-unpacked)."""
    for node in ast.walk(fn_node):
        targets: list[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            stack = [t]
            while stack:
                x = stack.pop()
                if isinstance(x, (ast.Tuple, ast.List)):
                    stack.extend(x.elts)
                    continue
                parts = attr_parts(x)
                if parts and len(parts) >= 2 and parts[0] == "self":
                    yield x.lineno, parts[1]


def _check_scope(sf: SourceFile, scope: StateScope,
                 out: list[Finding]) -> None:
    if scope.cls not in sf.classes:
        return
    graph = sf.class_call_graph(scope.cls)
    allowed = sf.reachable(graph, scope.roots)
    for name, fi in sf.methods_of(scope.cls).items():
        if name in allowed:
            continue
        for line, attr in _self_attr_writes(fi.node):
            if scope.covers(attr):
                out.append(Finding(
                    sf.relpath, line, "RA301", sf.symbol_at(line),
                    f"step-scoped state self.{attr} written in "
                    f"{scope.cls}.{name}, which is not reachable "
                    f"from the declared barrier roots "
                    f"({', '.join(sorted(scope.roots))}) — state "
                    "must mutate inside the step boundary"))


def _mutation_sites(sf: SourceFile, scope: VecSnapshotScope,
                    fn_node: ast.AST) -> list[int]:
    """Lines in ``fn_node`` that mutate engine state: calls to a
    mutator verb on ``self.<engines_attr>[...]`` directly or on a
    local bound to it."""
    engine_locals: set[str] = set()
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            parts = attr_parts(node.value)
            if parts and scope.engines_attr in parts:
                engine_locals.add(node.targets[0].id)
        elif isinstance(node, ast.For) and isinstance(node.target,
                                                     ast.Name):
            parts = attr_parts(node.iter)
            if parts and scope.engines_attr in parts:
                engine_locals.add(node.target.id)
    sites = []
    for node in ast.walk(fn_node):
        if not isinstance(node, ast.Call):
            continue
        parts = attr_parts(node.func)
        if not parts or parts[-1] not in scope.mutators:
            continue
        if scope.engines_attr in parts[:-1] \
                or parts[0] in engine_locals:
            sites.append(node.lineno)
    return sites


def _refresh_lines(sf: SourceFile, scope: VecSnapshotScope,
                   fn_node: ast.AST) -> list[int]:
    return [node.lineno for node in ast.walk(fn_node)
            if isinstance(node, ast.Call)
            and attr_parts(node.func) == ["self", scope.refresh]]


def _call_sites_of(sf: SourceFile, caller_node: ast.AST,
                   method: str) -> list[int]:
    return [node.lineno for node in ast.walk(caller_node)
            if isinstance(node, ast.Call)
            and attr_parts(node.func) == ["self", method]]


def _check_vec_scope(sf: SourceFile, scope: VecSnapshotScope,
                     out: list[Finding]) -> None:
    if scope.cls not in sf.classes:
        return
    methods = sf.methods_of(scope.cls)
    graph = sf.class_call_graph(scope.cls)
    vec_reachable = sf.reachable(graph, scope.vec_roots)
    for name in sorted(vec_reachable):
        fi = methods.get(name)
        if fi is None:
            continue
        sites = _mutation_sites(sf, scope, fi.node)
        if not sites:
            continue
        refreshes = _refresh_lines(sf, scope, fi.node)
        for site in sites:
            if any(r > site for r in refreshes):
                continue                      # refreshed in-method
            # else every vec-reachable caller must refresh after the
            # call into this method
            callers = [c for c in vec_reachable
                       if name in graph.get(c, set()) and c != name]
            covered = bool(callers)
            for c in callers:
                c_node = methods[c].node
                c_sites = _call_sites_of(sf, c_node, name)
                c_refresh = _refresh_lines(sf, scope, c_node)
                if not all(any(r > s for r in c_refresh)
                           for s in c_sites):
                    covered = False
            if not covered:
                out.append(Finding(
                    sf.relpath, site, "RA302", sf.symbol_at(site),
                    f"vec-path engine mutation in {scope.cls}.{name} "
                    f"with no {scope.refresh}() afterwards (in-method "
                    "or at every vec-reachable call site) — the "
                    "cached snapshot arrays go stale and the vec "
                    "route diverges from ref"))


def run(sf: SourceFile, registry: Registry) -> list[Finding]:
    out: list[Finding] = []
    for scope in registry.state_scopes:
        if sf.relpath.endswith(scope.file_suffix):
            _check_scope(sf, scope, out)
    for vscope in registry.vec_scopes:
        if sf.relpath.endswith(vscope.file_suffix):
            _check_vec_scope(sf, vscope, out)
    return out
