"""Pallas TPU kernel: fused RMS norm (normalize + scale) over rows.

Row-blocked: grid over row tiles; each block loads a (BLK_R, d) tile into
VMEM, reduces in fp32 on the VPU, multiplies by the (broadcast) scale, and
writes back — one HBM round trip instead of norm+mul materializing
intermediates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rms_norm_pallas"]


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (BLK_R, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_r", "interpret"))
def rms_norm_pallas(x, scale, *, eps: float = 1e-5, blk_r: int = 256,
                    interpret: bool = True):
    """x: (..., d); scale: (d,).  Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    R = x2.shape[0]
    blk_r = min(blk_r, R)
    pad = (-R) % blk_r
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // blk_r,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_r, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((blk_r, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
