"""Jit'd public wrappers for the Pallas kernels.

``use_pallas`` selects the kernel path; on CPU the kernels execute in
interpret mode (Python emulation of the kernel body — correctness
validation), on TPU they compile natively.  The jnp oracles in ``ref.py``
are the default path for dry-run lowering (the roofline is derived from the
XLA program; the Pallas kernels are the deployment hot path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_pallas
from .rms_norm import rms_norm_pallas
from .ssm_scan import ssm_chunk_scan_pallas

__all__ = ["decode_attention", "ssm_chunk_scan", "rms_norm", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, lengths, *,
                     use_pallas: bool = False, blk_l: int = 512):
    """One-token GQA decode attention (see decode_attention.py)."""
    if use_pallas:
        return decode_attention_pallas(q, k_cache, v_cache, lengths,
                                       blk_l=blk_l, interpret=not on_tpu())
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths)


def ssm_chunk_scan(q, k, v, log_decay, gate, *, use_pallas: bool = False,
                   chunk: int = 128):
    """Gated linear-attention scan (see ssm_scan.py)."""
    if use_pallas:
        B, S, H, dk = q.shape
        pad = (-S) % chunk
        if pad:
            def padseq(x):
                widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
                return jnp.pad(x, widths)
            q, k, v = padseq(q), padseq(k), padseq(v)
            # padding steps: no decay, no input
            log_decay = padseq(log_decay)
            gate = padseq(gate)
        y, state = ssm_chunk_scan_pallas(q, k, v, log_decay, gate,
                                         chunk=chunk,
                                         interpret=not on_tpu())
        return y[:, :S], state
    return ref.ssm_chunk_scan_ref(q, k, v, log_decay, gate)


def rms_norm(x, scale, *, eps: float = 1e-5, use_pallas: bool = False):
    """Fused RMS norm (see rms_norm.py)."""
    if use_pallas:
        return rms_norm_pallas(x, scale, eps=eps, interpret=not on_tpu())
    return ref.rms_norm_ref(x, scale, eps=eps)
