"""Pallas TPU kernels for the perf-critical compute layers, with jnp
oracles (ref.py) and jit'd wrappers (ops.py)."""
from .bfio_swap import swap_best, swap_best_pallas, swap_best_xla  # noqa: F401
from .ops import decode_attention, on_tpu, rms_norm, ssm_chunk_scan  # noqa: F401
from .paged_attention import paged_decode_attention_pallas  # noqa: F401
