"""Tiled pairwise swap-score search for BF-IO refinement.

One refinement step of the exchange argument needs, for every admitted
candidate pair (i, j) assigned to different workers, the windowed max-load
objective *after* exchanging them:

    val[i, j] = sum_h max( max_{g != g_i, g_j} loads[g, h],
                           loads[g_i, h] + c_j[h] - c_i[h],
                           loads[g_j, h] + c_i[h] - c_j[h] )

and the (i, j) minimizing it.  The dense formulation materializes an
(N, N, W) tensor per refinement iteration; this module computes the same
reduction in (TILE_I, TILE_J) blocks with a running per-row argmin so peak
memory is O(TILE_I * TILE_J * W) and the output is just two (N,) vectors:

    best_val[i] = min_j val[i, j]        best_j[i] = argmin_j val[i, j]

(first minimizer per row — the global argmin over ``best_val`` then
reproduces the dense row-major tie-breaking exactly).

Three interchangeable backends with identical semantics:

* ``swap_best_pallas`` — Pallas kernel, grid (N/TILE_I, N/TILE_J), the
  running argmin carried in the revisited output block across the inner
  j-grid dimension.  Interpret mode on CPU (correctness), native on TPU.
  For TPU the W axis can be zero-padded to the 128-lane boundary
  (``pad_lanes``): padded lanes contribute max(-inf, 0, 0) = 0 to the
  windowed sum, so results are unchanged for the non-negative loads of
  this problem.
* ``swap_best_xla`` — pure-XLA fallback tiled over i only (``lax.map``
  over row blocks, full j extent per block); the production CPU path.
* ``swap_best_dense`` lives in ``ref.py`` as the O(N^2 W) oracle.

The max-excluding-two-rows term uses the top-3 per window position
(computed once per call, O(G W)): the max over workers excluding rows
{g_i, g_j} is v1 unless t1 is excluded, then v2 unless t2 is excluded,
then v3 — at most two rows are ever excluded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["swap_prep", "swap_best_pallas", "swap_best_xla", "swap_best"]


def swap_prep(loads, cands, assign, valid):
    """Shared O(G W + N W) prepass for all backends.

    Returns (lo, ga, adm, vtop, ttop):
      lo   : (N, W) f32  load row of each candidate's worker (0 if unadmitted)
      ga   : (N,)   i32  assigned worker (clipped to 0 for unadmitted)
      adm  : (N,)  bool  admitted mask
      vtop : (3, W) f32  top-3 load values per window position
      ttop : (2, W) i32  rows achieving top-1 / top-2
    """
    loads = jnp.asarray(loads, jnp.float32)
    cands = jnp.asarray(cands, jnp.float32)
    G = loads.shape[0]
    adm = (assign >= 0) & valid
    ga = jnp.clip(assign, 0).astype(jnp.int32)
    lo = jnp.where(adm[:, None], loads[ga], 0.0)
    idx = jnp.argsort(-loads, axis=0)                       # (G, W)
    t1, t2 = idx[0], idx[jnp.minimum(1, G - 1)]
    t3 = idx[jnp.minimum(2, G - 1)]
    v1 = jnp.take_along_axis(loads, t1[None, :], axis=0)[0]
    v2 = jnp.take_along_axis(loads, t2[None, :], axis=0)[0]
    v3 = jnp.take_along_axis(loads, t3[None, :], axis=0)[0]
    vtop = jnp.stack([v1, v2, v3])
    ttop = jnp.stack([t1, t2]).astype(jnp.int32)
    return lo, ga, adm, vtop, ttop


def _pair_vals(ci, li, gai, admi, cj, lj, gaj, admj, vtop, ttop):
    """Swap objective for an (I, J) block; shared by both tiled backends."""
    diff = cj[None, :, :] - ci[:, None, :]                  # (I, J, W)
    la = li[:, None, :] + diff                              # g_i row after swap
    lb = lj[None, :, :] - diff                              # g_j row after swap
    ga3 = gai[:, None, None]
    gb3 = gaj[None, :, None]
    t1 = ttop[0][None, None, :]
    t2 = ttop[1][None, None, :]
    e1 = (t1 != ga3) & (t1 != gb3)
    e2 = (t2 != ga3) & (t2 != gb3)
    ex = jnp.where(e1, vtop[0][None, None, :],
                   jnp.where(e2, vtop[1][None, None, :],
                             vtop[2][None, None, :]))
    val = jnp.sum(jnp.maximum(ex, jnp.maximum(la, lb)), axis=-1)
    feas = admi[:, None] & admj[None, :] & (gai[:, None] != gaj[None, :])
    return jnp.where(feas, val, jnp.inf)                    # (I, J)


def _swap_kernel(ci_ref, li_ref, gai_ref, admi_ref,
                 cj_ref, lj_ref, gaj_ref, admj_ref,
                 vtop_ref, ttop_ref, val_ref, arg_ref, *, tile_j: int):
    jblk = pl.program_id(1)

    @pl.when(jblk == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref[...], jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref[...])

    val = _pair_vals(
        ci_ref[...], li_ref[...], gai_ref[...][:, 0], admi_ref[...][:, 0] > 0,
        cj_ref[...], lj_ref[...], gaj_ref[...][:, 0], admj_ref[...][:, 0] > 0,
        vtop_ref[...], ttop_ref[...])
    row_min = val.min(axis=1)
    row_arg = val.argmin(axis=1).astype(jnp.int32) + jblk * tile_j
    prev_v, prev_a = val_ref[...], arg_ref[...]
    better = row_min < prev_v                    # strict: keep first minimizer
    val_ref[...] = jnp.where(better, row_min, prev_v)
    arg_ref[...] = jnp.where(better, row_arg, prev_a)


def _pad_rows(x, n, fill=0):
    pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


@functools.partial(jax.jit,
                   static_argnames=("tile_i", "tile_j", "interpret",
                                    "pad_lanes"))
def swap_best_pallas(loads, cands, assign, valid, *, tile_i: int = 64,
                     tile_j: int = 64, interpret: bool = True,
                     pad_lanes: bool = False):
    """Pallas tiled swap search.  Returns (best_val (N,), best_j (N,))."""
    lo, ga, adm, vtop, ttop = swap_prep(loads, cands, assign, valid)
    cands = jnp.asarray(cands, jnp.float32)
    N, W = cands.shape
    tile_i, tile_j = min(tile_i, N), min(tile_j, N)
    np_i = pl.cdiv(N, tile_i) * tile_i
    np_j = pl.cdiv(N, tile_j) * tile_j
    npad = max(np_i, np_j)
    if pad_lanes and W % 128:                    # TPU lane alignment
        wpad = (-W) % 128
        cands = jnp.pad(cands, ((0, 0), (0, wpad)))
        lo = jnp.pad(lo, ((0, 0), (0, wpad)))
        vtop = jnp.pad(vtop, ((0, 0), (0, wpad)), constant_values=-jnp.inf)
        ttop = jnp.pad(ttop, ((0, 0), (0, wpad)), constant_values=-1)
        W += wpad
    cands = _pad_rows(cands, npad)
    lo = _pad_rows(lo, npad)
    ga2 = _pad_rows(ga, npad)[:, None]
    adm2 = _pad_rows(adm.astype(jnp.int32), npad)[:, None]

    grid = (npad // tile_i, npad // tile_j)
    ispec = lambda bs: pl.BlockSpec(bs, lambda i, j: (i, 0))  # noqa: E731
    jspec = lambda bs: pl.BlockSpec(bs, lambda i, j: (j, 0))  # noqa: E731
    vals, args = pl.pallas_call(
        functools.partial(_swap_kernel, tile_j=tile_j),
        grid=grid,
        in_specs=[
            ispec((tile_i, W)), ispec((tile_i, W)),
            ispec((tile_i, 1)), ispec((tile_i, 1)),
            jspec((tile_j, W)), jspec((tile_j, W)),
            jspec((tile_j, 1)), jspec((tile_j, 1)),
            pl.BlockSpec((3, W), lambda i, j: (0, 0)),
            pl.BlockSpec((2, W), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_i,), lambda i, j: (i,)),
            pl.BlockSpec((tile_i,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.int32),
        ],
        interpret=interpret,
    )(cands, lo, ga2, adm2, cands, lo, ga2, adm2, vtop, ttop)
    return vals[:N], args[:N]


@functools.partial(jax.jit, static_argnames=("tile_i",))
def swap_best_xla(loads, cands, assign, valid, *, tile_i: int = 128):
    """XLA fallback: same reduction tiled over i only (lax.map over row
    blocks, full j extent per block) — the production CPU path."""
    lo, ga, adm, vtop, ttop = swap_prep(loads, cands, assign, valid)
    cands = jnp.asarray(cands, jnp.float32)
    N, W = cands.shape
    if N <= tile_i:      # single block: skip the map machinery entirely
        val = _pair_vals(cands, lo, ga, adm, cands, lo, ga, adm, vtop, ttop)
        return val.min(axis=1), val.argmin(axis=1).astype(jnp.int32)
    tile_i = min(tile_i, N)
    npad = pl.cdiv(N, tile_i) * tile_i
    ci = _pad_rows(cands, npad).reshape(-1, tile_i, W)
    li = _pad_rows(lo, npad).reshape(-1, tile_i, W)
    gai = _pad_rows(ga, npad).reshape(-1, tile_i)
    admi = _pad_rows(adm, npad).reshape(-1, tile_i)

    def block(blk):
        bci, bli, bga, badm = blk
        val = _pair_vals(bci, bli, bga, badm, cands, lo, ga, adm, vtop, ttop)
        return val.min(axis=1), val.argmin(axis=1).astype(jnp.int32)

    vals, args = jax.lax.map(block, (ci, li, gai, admi))
    return vals.reshape(-1)[:N], args.reshape(-1)[:N]


def swap_best(loads, cands, assign, valid, *, backend: str = "xla", **kw):
    """Dispatch: ``backend`` in {"pallas", "xla"} (dense oracle in ref.py)."""
    if backend == "pallas":
        return swap_best_pallas(loads, cands, assign, valid, **kw)
    if backend == "xla":
        return swap_best_xla(loads, cands, assign, valid, **kw)
    raise ValueError(f"unknown swap backend {backend!r}")
