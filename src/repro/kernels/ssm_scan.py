"""Pallas TPU kernel: chunked gated linear-attention scan (Mamba2 SSD /
mLSTM inner loop).

The recurrence S_t = exp(a_t) S_{t-1} + g_t k_t v_t^T, y_t = q_t . S_t is
computed chunk-parallel: grid = (batch, head, chunks) with the chunk axis
innermost-sequential, carrying the (dk, dv) state in VMEM scratch.  Per
chunk the kernel does three MXU matmuls on (C, dk)x(dk, dv)-shaped tiles:

    y_intra = (tril(exp(A_t - A_s)) * g_s * (q k^T)) v      (C x C) form
    y_inter = exp(A_t) * q . S_prev
    S_new   = exp(A_C) S_prev + sum_s exp(A_C - A_s) g_s k_s v_s^T

This is the TPU-native adaptation of SSD: the GPU version leans on warp
shuffles for the inner scan; here everything is re-blocked so the chunk
matmuls are 128-aligned and the cross-chunk carry is the only sequential
dependency (VMEM-resident, no HBM round trip).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_chunk_scan_pallas"]


def _kernel(q_ref, k_ref, v_ref, a_ref, g_ref, y_ref, s_out_ref,
            state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)     # (C, dk)
    k = k_ref[0, :, 0].astype(jnp.float32)     # (C, dk)
    v = v_ref[0, :, 0].astype(jnp.float32)     # (C, dv)
    a = a_ref[0, :, 0].astype(jnp.float32)     # (C,)
    g = g_ref[0, :, 0].astype(jnp.float32)     # (C,)

    A = jnp.cumsum(a)                          # (C,) cumulative log decay
    # intra-chunk quadratic form
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (C, C)
    pair = jnp.clip(A[:, None] - A[None, :], -60.0, 60.0)
    row = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    w = scores * jnp.exp(pair) * g[None, :] * (col <= row)
    y = jnp.dot(w, v, preferred_element_type=jnp.float32)          # (C, dv)

    # inter-chunk contribution from carried state
    state = state_ref[...]                     # (dk, dv)
    y += jnp.exp(jnp.clip(A, -60, 60))[:, None] * jnp.dot(
        q, state, preferred_element_type=jnp.float32)

    # state update
    A_tot = A[-1]
    wk = jnp.exp(jnp.clip(A_tot - A, -60, 60)) * g                 # (C,)
    state = jnp.exp(jnp.clip(A_tot, -60, 60)) * state + jnp.dot(
        (k * wk[:, None]).T, v, preferred_element_type=jnp.float32)
    state_ref[...] = state

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        s_out_ref[0, 0] = state.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_chunk_scan_pallas(q, k, v, log_decay, gate, *, chunk: int = 128,
                          interpret: bool = True):
    """q, k: (B, S, H, dk); v: (B, S, H, dv); log_decay/gate: (B, S, H).

    Returns (y (B, S, H, dv), final_state (B, H, dk, dv)).
    S must be padded to a multiple of ``chunk`` by the caller (ops.py does).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    grid = (B, H, n_chunks)

    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dk), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, dk), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, dv), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dv), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, dv), v.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_decay, gate)
    return y, s_out
