"""Pallas TPU kernel: paged one-token decode attention.

Same online-softmax structure as decode_attention.py, but the KV blocks
are fetched *indirectly* through a per-request block table (vLLM paging):
the block table arrives via scalar prefetch (SMEM) and drives the
BlockSpec index_map, so each grid step DMAs exactly one physical KV block
HBM->VMEM — no contiguous-cache materialization, no gather of the pool.

This is the TPU adaptation of paged attention: the GPU version does
per-warp pointer chasing; on TPU the indirection moves into the prefetch
-> index_map path and the MXU still sees dense (block, hd) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_decode_attention_pallas"]

_NEG = -1e30


def _kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_size: int, n_blocks: int):
    b = pl.program_id(0)
    blk = pl.program_id(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (Gq, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (block, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    hd = q.shape[-1]
    scale = jax.lax.rsqrt(jnp.asarray(hd, jnp.float32))

    s = jnp.dot(q * scale, k.T,
                preferred_element_type=jnp.float32)  # (Gq, block)
    length = lengths_ref[b]
    pos = blk * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid_block = tables_ref[b, blk] >= 0
    s = jnp.where((pos < length) & valid_block, s, _NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(blk == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def paged_decode_attention_pallas(q, k_pool, v_pool, block_tables, lengths,
                                  *, block_size: int,
                                  interpret: bool = True):
    """q: (B, Hq, hd); k_pool/v_pool: (n_pool_blocks, block, Hkv, hd);
    block_tables: (B, max_blocks) int32 (-1 = unallocated);
    lengths: (B,).  Returns (B, Hq, hd).

    Grid = (B, Hkv, max_blocks); the block-table scalar prefetch drives
    the k/v index_map, fetching physical block ``tables[b, blk]``.
    """
    B, Hq, hd = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    max_blocks = block_tables.shape[1]
    qg = q.reshape(B, Hkv, G, hd)
    # clamp -1 entries for the DMA (they are masked in-kernel)
    tables = jnp.maximum(block_tables.astype(jnp.int32), 0)

    grid = (B, Hkv, max_blocks)
    out = pl.pallas_call(
        functools.partial(_kernel, block_size=block_size,
                          n_blocks=max_blocks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, l, T_, L_: (b, h, 0, 0)),
                pl.BlockSpec((1, block_size, 1, hd),
                             lambda b, h, l, T_, L_: (T_[b, l], 0, h, 0)),
                pl.BlockSpec((1, block_size, 1, hd),
                             lambda b, h, l, T_, L_: (T_[b, l], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, l, T_, L_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(tables, lengths.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(B, Hq, hd)
