"""Pure-jnp oracles for the Pallas kernels (the correctness references).

Each function mirrors a kernel in this package with the same signature and
semantics; tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref", "ssm_chunk_scan_ref", "rms_norm_ref",
           "bfio_swap_best_ref"]


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """One-token GQA decode attention.

    q: (B, Hq, hd); k_cache/v_cache: (B, L, Hkv, hd); lengths: (B,).
    Returns (B, Hq, hd).  fp32 softmax accumulation.
    """
    b, hq, hd = q.shape
    L, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    qf = qf / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhgd,blhd->bhgl", qf, k_cache.astype(jnp.float32))
    mask = jnp.arange(L)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, hd).astype(q.dtype)


def ssm_chunk_scan_ref(q, k, v, log_decay, gate):
    """Gated linear-attention recurrence (sequential reference).

    q, k: (B, S, H, dk); v: (B, S, H, dv); log_decay/gate: (B, S, H).
    Returns (y (B, S, H, dv), final_state (B, H, dk, dv)).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    af = log_decay.astype(jnp.float32)
    gf = gate.astype(jnp.float32)

    def step(state, t):
        a = jnp.exp(af[:, t])[..., None, None]
        u = jnp.einsum("bhk,bhv,bh->bhkv", kf[:, t], vf[:, t], gf[:, t])
        state = a * state + u
        y = jnp.einsum("bhk,bhkv->bhv", qf[:, t], state)
        return state, y

    state0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    state, ys = jax.lax.scan(step, state0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), state


def bfio_swap_best_ref(loads, cands, assign, valid):
    """Dense oracle for the BF-IO pairwise swap search (bfio_swap.py).

    Materializes the full (N, N, W) post-swap tensor and reduces it to the
    per-row (best_val (N,), best_j (N,)) the tiled kernels produce.
    """
    from .bfio_swap import _pair_vals, swap_prep

    lo, ga, adm, vtop, ttop = swap_prep(loads, cands, assign, valid)
    cands = jnp.asarray(cands, jnp.float32)
    val = _pair_vals(cands, lo, ga, adm, cands, lo, ga, adm, vtop, ttop)
    return val.min(axis=1), val.argmin(axis=1).astype(jnp.int32)


def rms_norm_ref(x, scale, eps: float = 1e-5):
    """RMS norm over the last dim, fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
